//! Quickstart: the typed Planner API in ~40 lines.
//!
//! Open a planning session for a network on a cluster, search for the
//! optimal layer-wise parallelization strategy, and compare it against
//! the standard baselines — all through the fallible, typed front door.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use optcnn::planner::{Network, Planner, StrategyKind};
use optcnn::util::{fmt_bytes, fmt_secs};

fn main() -> optcnn::Result<()> {
    // 1. The workload: AlexNet at the paper's per-GPU batch of 32, on a
    //    single-node 4x P100 cluster (swap in .cluster(ClusterSpec::...)
    //    for arbitrary topologies).
    let mut planner = Planner::builder(Network::AlexNet).devices(4).build()?;
    let graph = planner.graph();
    println!(
        "network: {} ({} layers, {:.1}M params)",
        graph.name,
        graph.num_layers(),
        graph.total_params() as f64 / 1e6
    );

    // 2. The search (Algorithm 1 through the session's backend).
    let opt = planner.optimize()?;
    println!(
        "layer-wise optimum found: {} (K={} after {} node + {} edge eliminations)",
        fmt_secs(opt.cost),
        opt.stats.final_nodes,
        opt.stats.node_eliminations,
        opt.stats.edge_eliminations
    );

    // 3. Compare against the baselines on the simulated cluster. The
    //    session reuses its cost tables and plans across these queries.
    println!("\n{:<12} {:>14} {:>16} {:>14}", "strategy", "step time", "throughput", "comm/step");
    for kind in StrategyKind::ALL {
        let eval = planner.evaluate(kind)?;
        println!(
            "{:<12} {:>14} {:>12.0} im/s {:>14}",
            kind.name(),
            fmt_secs(eval.sim.step_time),
            eval.sim_throughput,
            fmt_bytes(eval.comm.total())
        );
    }
    let stats = planner.session_stats();
    println!(
        "(session: {} table build, {} search, {} plan misses for 4+1 queries)",
        stats.table_builds, stats.searches, stats.plan_misses
    );

    // 4. Show a few interesting per-layer choices of the optimum.
    println!("\nselected layer configurations (layer-wise optimum):");
    for l in &planner.graph().layers {
        let cfg = opt.strategy.config(l.id);
        if cfg.total() < 4 || cfg.deg[1] > 1 || cfg.deg[2] > 1 {
            println!("  {:<8} {}", l.name, cfg.label());
        }
    }
    Ok(())
}
