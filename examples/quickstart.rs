//! Quickstart: the library in ~40 lines.
//!
//! Build a network graph and a device graph, search for the optimal
//! layer-wise parallelization strategy, and compare it against the
//! standard baselines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use optcnn::cost::{CostModel, CostTables};
use optcnn::device::DeviceGraph;
use optcnn::graph::nets;
use optcnn::metrics::comm_volume;
use optcnn::optimizer::{self, strategies};
use optcnn::sim::simulate;
use optcnn::util::{fmt_bytes, fmt_secs};

fn main() {
    // 1. The workload: AlexNet at the paper's per-GPU batch of 32, and a
    //    single-node 4x P100 cluster.
    let ndev = 4;
    let graph = nets::alexnet(32 * ndev);
    let devices = DeviceGraph::p100_cluster(ndev);
    println!(
        "network: {} ({} layers, {:.1}M params)",
        graph.name,
        graph.num_layers(),
        graph.total_params() as f64 / 1e6
    );

    // 2. The cost model and the search (Algorithm 1).
    let cm = CostModel::new(&graph, &devices);
    let tables = CostTables::build(&cm, ndev);
    let opt = optimizer::optimize(&tables);
    println!(
        "layer-wise optimum found: {} (K={} after {} node + {} edge eliminations)",
        fmt_secs(opt.cost),
        opt.stats.final_nodes,
        opt.stats.node_eliminations,
        opt.stats.edge_eliminations
    );

    // 3. Compare against the baselines on the simulated cluster.
    println!("\n{:<12} {:>14} {:>16} {:>14}", "strategy", "step time", "throughput", "comm/step");
    for (name, strat) in [
        ("data", strategies::data_parallel(&graph, ndev)),
        ("model", strategies::model_parallel(&graph, ndev)),
        ("owt", strategies::owt(&graph, ndev)),
        ("layerwise", opt.strategy.clone()),
    ] {
        let rep = simulate(&graph, &devices, &strat, &cm);
        let comm = comm_volume(&cm, &strat);
        println!(
            "{:<12} {:>14} {:>12.0} im/s {:>14}",
            name,
            fmt_secs(rep.step_time),
            rep.throughput(32 * ndev),
            fmt_bytes(comm.total())
        );
    }

    // 4. Show a few interesting per-layer choices of the optimum.
    println!("\nselected layer configurations (layer-wise optimum):");
    for l in &graph.layers {
        let cfg = opt.strategy.config(l.id);
        if cfg.total() < ndev || cfg.deg[1] > 1 || cfg.deg[2] > 1 {
            println!("  {:<8} {}", l.name, cfg.label());
        }
    }
}
