//! Reproduce Table 5: the optimal VGG-16 strategy on a 4-GPU node, with
//! the full per-layer breakdown and cost attribution — then show how the
//! optimum changes when the cluster's interconnect changes (an ablation
//! the paper's cost model enables but does not print).
//!
//! ```sh
//! cargo run --release --example optimize_vgg
//! ```

use optcnn::cost::{CostModel, CostTables};
use optcnn::device::{ComputeModel, DeviceGraph};
use optcnn::graph::nets;
use optcnn::optimizer;
use optcnn::util::fmt_secs;
use optcnn::util::table::Table;

fn optimize_on(devices: &DeviceGraph, title: &str) {
    let ndev = devices.num_devices();
    let graph = nets::vgg16(32 * ndev);
    let cm = CostModel::new(&graph, devices);
    let tables = CostTables::build(&cm, ndev);
    let opt = optimizer::optimize(&tables);

    let mut table = Table::new(title, &["layer", "config", "t_C", "t_S"]);
    for l in &graph.layers {
        let cfg = opt.strategy.config(l.id);
        table.row(vec![
            l.name.clone(),
            cfg.label(),
            fmt_secs(cm.t_c(l, cfg)),
            fmt_secs(cm.t_s(l, cfg)),
        ]);
    }
    table.print();
    println!("estimated step time: {}\n", fmt_secs(opt.cost));
}

fn main() {
    // The paper's single node: NVLink-connected 4x P100.
    optimize_on(
        &DeviceGraph::p100_cluster(4),
        "VGG-16 on 4x P100, NVLink (the paper's Table 5 setting)",
    );

    // Ablation: a PCIe-only box (4x less intra-node bandwidth). The
    // optimum shifts toward configurations that move fewer tensor bytes.
    optimize_on(
        &DeviceGraph::cluster("pcie_box", 1, 4, 4e9, 4e9, 4e9, ComputeModel::p100()),
        "ablation: same box with a 4 GB/s PCIe-only interconnect",
    );
}
