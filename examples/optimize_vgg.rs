//! Reproduce Table 5: the optimal VGG-16 strategy on a 4-GPU node, with
//! the full per-layer breakdown and cost attribution — then show how the
//! optimum changes when the cluster's interconnect changes (an ablation
//! the paper's cost model enables but does not print), expressed as two
//! `ClusterSpec`s fed to the same Planner builder.
//!
//! ```sh
//! cargo run --release --example optimize_vgg
//! ```

use optcnn::cost::CostModel;
use optcnn::planner::{ClusterSpec, Network, Planner};
use optcnn::util::fmt_secs;
use optcnn::util::table::Table;

fn optimize_on(cluster: ClusterSpec, title: &str) -> optcnn::Result<()> {
    let mut planner = Planner::builder(Network::Vgg16).cluster(cluster).build()?;
    let opt = planner.optimize()?;
    let graph = planner.graph();
    let cm = CostModel::new(graph, planner.device_graph());

    let mut table = Table::new(title, &["layer", "config", "t_C", "t_S"]);
    for l in &graph.layers {
        let cfg = opt.strategy.config(l.id);
        table.row(vec![
            l.name.clone(),
            cfg.label(),
            fmt_secs(cm.t_c(l, cfg)),
            fmt_secs(cm.t_s(l, cfg)),
        ]);
    }
    table.print();
    println!("estimated step time: {}\n", fmt_secs(opt.cost));
    Ok(())
}

fn main() -> optcnn::Result<()> {
    // The paper's single node: NVLink-connected 4x P100.
    optimize_on(
        ClusterSpec::p100(4)?,
        "VGG-16 on 4x P100, NVLink (the paper's Table 5 setting)",
    )?;

    // Ablation: a PCIe-only box (4x less intra-node bandwidth). The
    // optimum shifts toward configurations that move fewer tensor bytes.
    optimize_on(
        ClusterSpec::new(1, 4).name("pcie_box").intra_bw(4e9).inter_bw(4e9).host_bw(4e9),
        "ablation: same box with a 4 GB/s PCIe-only interconnect",
    )
}
