//! Custom networks end to end: build an arbitrary graph with the
//! fallible `GraphBuilder`, export it as a `GraphSpec` JSON document,
//! load it back, and plan it — demonstrating that the planner is not
//! limited to the builtin benchmark nets, and that a spec-loaded graph
//! plans byte-identically to the in-memory one (content addressing).
//!
//! ```sh
//! cargo run --release --example custom_net
//! ```

use optcnn::graph::{CompGraph, GraphBuilder, PoolKind};
use optcnn::planner::{NetworkSpec, Planner, StrategyKind};
use optcnn::util::json::Json;
use optcnn::util::{fmt_bytes, fmt_secs};

/// A little residual CNN that exists in no builder: two conv stages with
/// a skip connection, global-ish pooling, and a classifier head.
fn build_skipnet(batch: usize) -> optcnn::Result<CompGraph> {
    let mut b = GraphBuilder::new("skipnet");
    let x = b.input(batch, 3, 64, 64)?;
    let c1 = b.conv2d("stem", x, 32, (3, 3), (1, 1), (1, 1))?;
    let c2 = b.conv2d("body_a", c1, 32, (3, 3), (1, 1), (1, 1))?;
    let c3 = b.conv2d("body_b", c2, 32, (3, 3), (1, 1), (1, 1))?;
    let res = b.add("skip", c1, c3)?;
    let p = b.pool2d("pool", res, PoolKind::Max, (4, 4), (4, 4), (0, 0))?;
    let f1 = b.fully_connected("fc1", p, 256)?;
    let f2 = b.fully_connected("fc2", f1, 10)?;
    b.softmax("softmax", f2)?;
    b.finish()
}

fn main() -> optcnn::Result<()> {
    // 1. Build the custom graph (every step is fallible — no panics on
    //    bad wiring) and show its structural content address.
    let net = build_skipnet(64)?;
    println!(
        "{}: {} layers, {:.2}M params, digest {}",
        net.name,
        net.num_layers(),
        net.total_params() as f64 / 1e6,
        net.digest()
    );

    // 2. Round-trip through the wire form. This exact JSON also works
    //    inline in `optcnn serve` requests ({"graph": ...}) and on disk
    //    for `--network-file`.
    let spec_text = net.to_spec().to_string();
    println!("spec: {} bytes of JSON", spec_text.len());
    let reloaded = CompGraph::from_spec(&Json::parse(&spec_text).expect("spec parses"))?;
    assert_eq!(net.digest(), reloaded.digest(), "round-trip preserves identity");

    // 3. Plan both copies on 2 devices. The graphs are structurally
    //    identical, so the plans are byte-identical.
    let mut a = Planner::builder(NetworkSpec::custom(net)?).devices(2).build()?;
    let mut b = Planner::builder(NetworkSpec::custom(reloaded)?).devices(2).build()?;
    let plan_a = a.plan(StrategyKind::Layerwise)?;
    let plan_b = b.plan(StrategyKind::Layerwise)?;
    assert_eq!(
        plan_a.to_json().to_string(),
        plan_b.to_json().to_string(),
        "spec-loaded and builder-built graphs must plan identically"
    );

    // 4. The numbers.
    let eval = a.evaluate(StrategyKind::Layerwise)?;
    let data = a.evaluate(StrategyKind::Data)?;
    println!(
        "layerwise: step {} ({:.0} img/s), comm {}/step",
        fmt_secs(eval.estimate),
        eval.throughput,
        fmt_bytes(eval.comm.total())
    );
    println!(
        "data-parallel baseline: step {} ({:.0} img/s)",
        fmt_secs(data.estimate),
        data.throughput
    );
    println!("custom net planned end to end — no enum required.");
    Ok(())
}
