//! Scalability study (the Figure 7 headline numbers): speedup of each
//! strategy from 1 to 16 GPUs for the three benchmark CNNs, against the
//! linear-scaling ideal.
//!
//! ```sh
//! cargo run --release --example scaling_sweep
//! ```

use optcnn::planner::{Network, Planner, StrategyKind};
use optcnn::util::table::Table;

fn main() -> optcnn::Result<()> {
    let devices = [1usize, 2, 4, 8, 16];
    for net in [Network::AlexNet, Network::Vgg16, Network::InceptionV3] {
        let base = Planner::builder(net)
            .devices(1)
            .build()?
            .evaluate(StrategyKind::Data)?
            .throughput;
        let mut table = Table::new(
            &format!("{net}: speedup over 1 GPU (per-GPU batch 32)"),
            &["GPUs", "data", "model", "owt", "layerwise", "ideal"],
        );
        let mut final_speedups = Vec::new();
        for &ndev in &devices {
            let mut planner = Planner::builder(net).devices(ndev).build()?;
            let mut row = vec![ndev.to_string()];
            for kind in StrategyKind::ALL {
                let sp = planner.evaluate(kind)?.throughput / base;
                if ndev == 16 {
                    final_speedups.push(sp);
                }
                row.push(format!("{sp:.1}x"));
            }
            row.push(format!("{ndev}.0x"));
            table.row(row);
        }
        table.print();
        let best_baseline = final_speedups[..3].iter().cloned().fold(0.0, f64::max);
        println!(
            "at 16 GPUs: layer-wise {:.1}x vs best baseline {:.1}x \
             (paper: 12.2-15.5x vs 6.1-11.2x)\n",
            final_speedups[3], best_baseline
        );
    }
    Ok(())
}
