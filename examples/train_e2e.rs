//! End-to-end validation driver (the repo's "all layers compose" proof).
//!
//! Trains MiniCNN on synthetic 32x32 data for 300 steps across 4
//! simulated devices, through the full stack:
//!
//!   L1 Pallas kernels -> L2 JAX layer functions -> AOT HLO artifacts ->
//!   PJRT CPU engines inside worker threads -> L3 coordinator
//!   (repartitioning + parameter server)
//!
//! under THREE strategies — data parallelism, OWT, and the cost-model
//! optimum — and checks they produce identical loss curves (the paper's
//! accuracy-preservation claim), while the single-device oracle artifact
//! provides the ground truth. Requires `make artifacts`.
//!
//! ```sh
//! cargo run --release --example train_e2e [-- --steps 300]
//! ```

use optcnn::data::SyntheticDataset;
use optcnn::exec::{OracleTrainer, Trainer};
use optcnn::graph::nets;
use optcnn::planner::{Network, Planner, StrategyKind};
use optcnn::runtime::ArtifactStore;
use optcnn::util::cli::Args;
use optcnn::util::fmt_bytes;

const NDEV: usize = 4;
const LR: f32 = 0.01;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[]);
    let steps = args.usize_or("steps", 300)?;
    let dir = args.get_or("artifacts", "artifacts");
    let store = ArtifactStore::load(dir)?;
    let batch = store.batch;
    let ds = SyntheticDataset::new(10, 3, 32, 32, 0.3, 7);

    // the cost-model-optimal layer-wise strategy for MiniCNN on 4 devices,
    // resolved through the typed Planner session API
    let g = nets::minicnn(batch)?;
    let mut planner = Planner::builder(Network::MiniCnn)
        .devices(NDEV)
        .per_gpu_batch(batch / NDEV)
        .build()?;
    let layerwise = planner.strategy(StrategyKind::Layerwise)?;
    println!("layer-wise optimum for minicnn on {NDEV} devices:");
    for l in &g.layers {
        println!("  {:<8} {}", l.name, layerwise.config(l.id).label());
    }

    let mut runs = vec![
        ("data".to_string(), planner.strategy(StrategyKind::Data)?),
        ("owt".to_string(), planner.strategy(StrategyKind::Owt)?),
        ("layerwise".to_string(), layerwise),
    ];

    // oracle first: single-device ground truth
    let seed = 42;
    let probe =
        Trainer::new(&store, nets::minicnn(batch)?, runs[0].1.clone(), NDEV, LR, seed)?;
    let mut oracle = OracleTrainer::new(&store, "minicnn", batch, probe.master_params(), LR)?;
    drop(probe);

    let mut curves: Vec<(String, Vec<f32>, f64, u64)> = Vec::new();
    for (name, strat) in runs.drain(..) {
        let mut trainer =
            Trainer::new(&store, nets::minicnn(batch)?, strat, NDEV, LR, seed)?;
        let t0 = std::time::Instant::now();
        let mut curve = Vec::with_capacity(steps);
        for step in 0..steps {
            let (x, y) = ds.batch(step % 32, batch);
            let loss = trainer.step(&x, &y)?;
            curve.push(loss);
            if step % 50 == 0 {
                println!("[{name:<9}] step {step:>4}  loss {loss:.4}");
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        curves.push((name, curve, dt, trainer.comm.total()));
    }

    // oracle curve
    let mut oracle_curve = Vec::with_capacity(steps);
    for step in 0..steps {
        let (x, y) = ds.batch(step % 32, batch);
        oracle_curve.push(oracle.step(&x, &y)?);
    }

    println!("\n== results ({} steps, batch {}) ==", steps, batch);
    println!(
        "{:<10} {:>10} {:>10} {:>12} {:>14} {:>16}",
        "strategy", "loss[0]", "loss[end]", "wall (s)", "img/s (CPU)", "comm (msg bytes)"
    );
    for (name, curve, dt, comm) in &curves {
        println!(
            "{:<10} {:>10.4} {:>10.4} {:>12.1} {:>14.1} {:>16}",
            name,
            curve[0],
            curve[curve.len() - 1],
            dt,
            (steps * batch) as f64 / dt,
            fmt_bytes(*comm as f64)
        );
    }
    println!(
        "{:<10} {:>10.4} {:>10.4}   (single-device JAX train-step artifact)",
        "oracle",
        oracle_curve[0],
        oracle_curve[oracle_curve.len() - 1]
    );

    // the paper's invariant: every strategy trains the SAME network
    let mut max_dev = 0.0f32;
    for (name, curve, _, _) in &curves {
        for (a, b) in curve.iter().zip(oracle_curve.iter()) {
            let rel = (a - b).abs() / b.abs().max(1.0);
            max_dev = max_dev.max(rel);
            assert!(
                rel < 5e-3,
                "{name} diverged from the oracle: {a} vs {b} (rel {rel})"
            );
        }
    }
    println!(
        "\nall strategies match the single-device oracle \
         (max relative loss deviation {:.2e}) — accuracy preserved by design",
        max_dev
    );
    assert!(
        oracle_curve.last().unwrap() < &(oracle_curve[0] * 0.2),
        "training did not converge"
    );
    println!("loss decreased {:.1}x over {steps} steps — training converges",
        oracle_curve[0] / oracle_curve.last().unwrap());
    Ok(())
}
