"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel must match its oracle
to float32 tolerance across the pytest/hypothesis shape sweep before
`compile.aot` will emit artifacts.
"""

import jax.numpy as jnp
from jax import lax


def matmul_ref(x, w):
    """Oracle for kernels.matmul.matmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def conv2d_valid_ref(x, w, sh: int = 1, sw: int = 1):
    """Oracle for kernels.conv2d.conv2d_valid (NCHW x OIHW, VALID)."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(sh, sw),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_valid_grads_ref(x, w, dy, sh: int = 1, sw: int = 1):
    """Oracle gradients via jax autodiff on the lax convolution."""
    import jax

    def f(x_, w_):
        return conv2d_valid_ref(x_, w_, sh, sw)

    _, vjp = jax.vjp(f, x, w)
    return vjp(dy)


def maxpool_ref(x, kh: int, kw: int, sh: int, sw: int):
    """VALID max-pooling oracle (NCHW)."""
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 1, kh, kw), (1, 1, sh, sw), "VALID"
    )
