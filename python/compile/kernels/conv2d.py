"""Conv2d as im2col + the Pallas matmul kernel (L1).

The paper's conv layers are cuDNN calls; on the TPU-shaped stack the same
computation is an im2col patch extraction (pure data movement, expressed
in jnp and fused by XLA) feeding the MXU matmul kernel. Inputs arrive
pre-padded from the Rust executor's halo-exchange (VALID convolution on a
slab), so the kernel itself never pads.

Gradients are provided explicitly (``col2im`` transpose) because
``pallas_call`` has no autodiff rule; `compile.layers` wires these into a
``jax.custom_vjp``.
"""

import jax
import jax.numpy as jnp

from . import matmul


def im2col(x, kh: int, kw: int, sh: int, sw: int):
    """Extract conv patches: ``[n, c, h, w] -> [n*oh*ow, c*kh*kw]``.

    Row-major over (n, oh, ow); column-major over (c, dy, dx) to match the
    ``[cout, cin*kh*kw]`` weight flattening.
    """
    n, c, h, w = x.shape
    oh = (h - kh) // sh + 1
    ow = (w - kw) // sw + 1
    # gather shifted views: [kh, kw, n, c, oh, ow]
    cols = jnp.stack(
        [
            jnp.stack(
                [
                    jax.lax.slice(
                        x,
                        (0, 0, dy, dx),
                        (n, c, dy + (oh - 1) * sh + 1, dx + (ow - 1) * sw + 1),
                        (1, 1, sh, sw),
                    )
                    for dx in range(kw)
                ]
            )
            for dy in range(kh)
        ]
    )
    # -> [n, oh, ow, c, kh, kw] -> [n*oh*ow, c*kh*kw]
    cols = cols.transpose(2, 4, 5, 3, 0, 1)
    return cols.reshape(n * oh * ow, c * kh * kw), (oh, ow)


def col2im(cols, x_shape, kh: int, kw: int, sh: int, sw: int):
    """Transpose of :func:`im2col`: scatter-add patches back to the image.

    ``cols``: ``[n*oh*ow, c*kh*kw]`` -> ``[n, c, h, w]`` with overlapping
    contributions summed (exactly the conv data-gradient semantics).
    """
    n, c, h, w = x_shape
    oh = (h - kh) // sh + 1
    ow = (w - kw) // sw + 1
    cols = cols.reshape(n, oh, ow, c, kh, kw).transpose(4, 5, 0, 3, 1, 2)
    # cols is now [kh, kw, n, c, oh, ow]
    out = jnp.zeros(x_shape, dtype=cols.dtype)
    for dy in range(kh):
        for dx in range(kw):
            patch = cols[dy, dx]  # [n, c, oh, ow]
            # scatter-add with stride: build index grids once per offset
            hs = dy + sh * jnp.arange(oh)
            ws = dx + sw * jnp.arange(ow)
            out = out.at[:, :, hs[:, None], ws[None, :]].add(patch)
    return out


def conv2d_valid(x, w, sh: int = 1, sw: int = 1):
    """VALID 2-D convolution via im2col + Pallas matmul.

    ``x``: [n, cin, h, w] (already halo-padded by the caller),
    ``w``: [cout, cin, kh, kw]. Returns [n, cout, oh, ow].
    """
    n = x.shape[0]
    cout, cin, kh, kw = w.shape
    assert x.shape[1] == cin, f"cin mismatch: {x.shape} vs {w.shape}"
    cols, (oh, ow) = im2col(x, kh, kw, sh, sw)
    wf = w.reshape(cout, cin * kh * kw).T  # [cin*kh*kw, cout]
    y = matmul.matmul(cols, wf)  # [n*oh*ow, cout]
    return y.reshape(n, oh, ow, cout).transpose(0, 3, 1, 2)


def conv2d_valid_grads(x, w, dy, sh: int = 1, sw: int = 1):
    """Explicit gradients of :func:`conv2d_valid`.

    Returns ``(dx, dw)``; both matmuls run on the Pallas kernel.
    """
    n = x.shape[0]
    cout, cin, kh, kw = w.shape
    oh, ow = dy.shape[2], dy.shape[3]
    dyf = dy.transpose(0, 2, 3, 1).reshape(n * oh * ow, cout)
    cols, _ = im2col(x, kh, kw, sh, sw)
    # dw = dy^T @ cols  -> [cout, cin*kh*kw]
    dw = matmul.matmul(dyf.T, cols).reshape(cout, cin, kh, kw)
    # dx = col2im(dy @ w_flat)
    wf = w.reshape(cout, cin * kh * kw)
    dcols = matmul.matmul(dyf, wf)  # [n*oh*ow, cin*kh*kw]
    dx = col2im(dcols, x.shape, kh, kw, sh, sw)
    return dx, dw
