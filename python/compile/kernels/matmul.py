"""Tiled matmul Pallas kernel (L1).

The compute hot-spot of both fully-connected layers and the im2col
formulation of convolution. The kernel follows TPU idiom even though it
executes here under ``interpret=True`` on the CPU PJRT plugin (DESIGN.md
§Hardware-Adaptation):

* the grid walks (M/bm, N/bn) output tiles — each grid step owns one
  VMEM-resident output block, the BlockSpec index maps express the
  HBM->VMEM staging that a CUDA kernel would do with threadblocks;
* the K dimension is looped *inside* the kernel in ``bk`` chunks with a
  float32 VMEM accumulator, the MXU-friendly schedule (128-aligned tiles
  feed the 128x128 systolic array on real hardware);
* block shapes are clamped to the problem size so small shard shapes from
  the partitioned executor still compile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile sizes (clamped per call).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, w_ref, o_ref, *, bk: int, k_total: int):
    """One (bm, bn) output tile: loop K in bk chunks, accumulate in f32."""
    bm = x_ref.shape[0]
    bn = w_ref.shape[1]
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    num_k = k_total // bk

    def body(i, acc):
        xs = jax.lax.dynamic_slice(x_ref[...], (0, i * bk), (bm, bk))
        ws = jax.lax.dynamic_slice(w_ref[...], (i * bk, 0), (bk, bn))
        return acc + jnp.dot(xs, ws, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, num_k, body, acc)
    rem = k_total - num_k * bk
    if rem:
        xs = jax.lax.dynamic_slice(x_ref[...], (0, num_k * bk), (bm, rem))
        ws = jax.lax.dynamic_slice(w_ref[...], (num_k * bk, 0), (rem, bn))
        acc = acc + jnp.dot(xs, ws, preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _clamp_pow2(x: int, cap: int) -> int:
    """Largest divisor of x that is <= cap (keeps the grid exact)."""
    for d in range(min(x, cap), 0, -1):
        if x % d == 0:
            return d
    return 1


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, w, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK):
    """``x @ w`` for 2-D operands via the Pallas kernel.

    Tile sizes are clamped to divisors of the problem so every shard shape
    the Rust executor produces is accepted.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims disagree: {x.shape} @ {w.shape}"
    bm = _clamp_pow2(m, bm)
    bn = _clamp_pow2(n, bn)
    bk = min(bk, k)

    kernel = functools.partial(_matmul_kernel, bk=bk, k_total=k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, w)


def vmem_bytes(m: int, n: int, k: int, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN) -> int:
    """Estimated VMEM residency of one grid step (for the DESIGN.md §Perf
    roofline estimate): x block + w block + f32 accumulator."""
    bm = _clamp_pow2(m, bm)
    bn = _clamp_pow2(n, bn)
    return 4 * (bm * k + k * bn + bm * bn)
