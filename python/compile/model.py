"""Layer-2 model: MiniCNN forward/backward/train-step in JAX.

This is the end-to-end demo network (mirrors ``graph::nets::minicnn`` on
the Rust side): conv8-pool-conv16-pool-fc64-fc10-softmax over 32x32x3
inputs. The full train step is lowered as a single artifact and serves as
the single-device numerical oracle that every partitioned execution must
match (the paper's accuracy-preservation argument, checked end-to-end).
"""

import jax
import jax.numpy as jnp

from . import layers

#: (name, kind, attrs) — keep in sync with graph::nets::minicnn.
ARCH = [
    ("conv1", "conv", dict(cout=8, cin=3, k=3, pad=1, relu=True)),
    ("pool1", "pool", dict(k=2, s=2)),
    ("conv2", "conv", dict(cout=16, cin=8, k=3, pad=1, relu=True)),
    ("pool2", "pool", dict(k=2, s=2)),
    ("fc1", "fc", dict(cin=16 * 8 * 8, cout=64, relu=True)),
    ("fc2", "fc", dict(cin=64, cout=10, relu=False)),
]


def init_params(seed: int = 0):
    """He-init parameters as a flat dict name -> (w, b)."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, kind, a in ARCH:
        if kind == "conv":
            key, k1 = jax.random.split(key)
            fan_in = a["cin"] * a["k"] * a["k"]
            w = jax.random.normal(k1, (a["cout"], a["cin"], a["k"], a["k"]), jnp.float32)
            params[name] = (w * jnp.sqrt(2.0 / fan_in), jnp.zeros((a["cout"],), jnp.float32))
        elif kind == "fc":
            key, k1 = jax.random.split(key)
            w = jax.random.normal(k1, (a["cin"], a["cout"]), jnp.float32)
            params[name] = (
                w * jnp.sqrt(2.0 / a["cin"]),
                jnp.zeros((a["cout"],), jnp.float32),
            )
    return params


def param_order():
    """Flat parameter ordering used by the AOT train-step artifact."""
    return [name for name, kind, _ in ARCH if kind in ("conv", "fc")]


def forward(params, x):
    """Full forward pass to logits. Pads conv inputs explicitly (the
    partitioned executor does the same via halo slabs)."""
    h = x
    for name, kind, a in ARCH:
        if kind == "conv":
            p = a["pad"]
            hp = jnp.pad(h, ((0, 0), (0, 0), (p, p), (p, p)))
            w, b = params[name]
            h = layers.conv2d(hp, w, b, (1, 1), a["relu"])
        elif kind == "pool":
            h = layers.maxpool(h, (a["k"], a["k"]), (a["s"], a["s"]))
        elif kind == "fc":
            w, b = params[name]
            h = layers.fc_from_4d(h, w, b, a["relu"]) if h.ndim == 4 else layers.fc(
                h, w, b, a["relu"]
            )
    return h


def loss_fn(params, x, y):
    """Mean cross-entropy over the batch."""
    logits = forward(params, x)
    loss, _ = layers.softmax_xent(logits, y)
    return loss / x.shape[0]


def train_step(params, x, y, lr):
    """One SGD step; returns (loss, new_params)."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return loss, new_params


def train_step_flat(x, y, lr, *flat_params):
    """Positional-argument wrapper for AOT lowering: parameters are
    passed/returned as a flat (w1, b1, w2, b2, ...) tuple in
    :func:`param_order` order."""
    names = param_order()
    params = {
        n: (flat_params[2 * i], flat_params[2 * i + 1]) for i, n in enumerate(names)
    }
    loss, new_params = train_step(params, x, y, lr)
    out = [loss]
    for n in names:
        out.extend(new_params[n])
    return tuple(out)
