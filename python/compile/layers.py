"""Layer-2 JAX layer functions (forward + backward).

Each layer the partitioned executor schedules has a forward function and
an explicit backward function here. Forwards route their hot loop through
the Layer-1 Pallas kernels; because ``pallas_call`` carries no autodiff
rule, convolution and fully-connected layers are wrapped in
``jax.custom_vjp`` with backward passes that *also* run on the Pallas
matmul kernel.

Conventions (matching the Rust executor's repartitioning):
* conv/pool inputs arrive **pre-padded** (halo slabs) — everything is a
  VALID window op here;
* activations are folded into the layer (``relu`` flag);
* backward functions take the layer inputs and the upstream gradient and
  return gradients for inputs and parameters.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import conv2d as kconv
from .kernels import matmul as kmm
from .kernels import ref


# --------------------------------------------------------------------------
# Convolution (+ optional fused relu)
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def conv2d(x, w, b, stride=(1, 1), relu=True):
    """VALID conv + bias + optional relu. x: [n,cin,h,w], w: [cout,cin,kh,kw]."""
    y = kconv.conv2d_valid(x, w, stride[0], stride[1]) + b[None, :, None, None]
    return jnp.maximum(y, 0.0) if relu else y


def _conv2d_fwd(x, w, b, stride, relu):
    y = conv2d(x, w, b, stride, relu)
    return y, (x, w, y)


def _conv2d_bwd(stride, relu, res, dy):
    x, w, y = res
    if relu:
        dy = jnp.where(y > 0.0, dy, 0.0)
    dx, dw = kconv.conv2d_valid_grads(x, w, dy, stride[0], stride[1])
    db = dy.sum(axis=(0, 2, 3))
    return dx, dw, db


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


def conv2d_bwd(x, w, b, dy, stride=(1, 1), relu=True):
    """Standalone backward entry point for AOT lowering: returns
    (dx, dw, db). Recomputes the forward activation for the relu mask
    (rematerialization keeps the artifact self-contained)."""
    _, vjp = jax.vjp(lambda x_, w_, b_: conv2d(x_, w_, b_, stride, relu), x, w, b)
    return vjp(dy)


def conv2d_bwd_norelu(x, w, dy, stride=(1, 1)):
    """Backward for a linear conv. The bias does not participate in any
    gradient (db = dy.sum), so it is *not* an input — XLA would dead-code
    it out of the lowered module and the PJRT argument count would no
    longer match the manifest."""
    zero_b = jnp.zeros((w.shape[0],), x.dtype)
    _, vjp = jax.vjp(lambda x_, w_, b_: conv2d(x_, w_, b_, stride, False), x, w, zero_b)
    return vjp(dy)


# --------------------------------------------------------------------------
# Fully-connected (+ optional fused relu)
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fc(x, w, b, relu=True):
    """x: [n, cin] @ w: [cin, cout] + b, optional relu."""
    y = kmm.matmul(x, w) + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y


def _fc_fwd(x, w, b, relu):
    y = fc(x, w, b, relu)
    return y, (x, w, y)


def _fc_bwd(relu, res, dy):
    x, w, y = res
    if relu:
        dy = jnp.where(y > 0.0, dy, 0.0)
    dx = kmm.matmul(dy, w.T)
    dw = kmm.matmul(x.T, dy)
    db = dy.sum(axis=0)
    return dx, dw, db


fc.defvjp(_fc_fwd, _fc_bwd)


def fc_bwd(x, w, b, dy, relu=True):
    """Standalone backward for AOT: returns (dx, dw, db)."""
    _, vjp = jax.vjp(lambda x_, w_, b_: fc(x_, w_, b_, relu), x, w, b)
    return vjp(dy)


def fc_bwd_norelu(x, w, dy):
    """Backward for a linear FC layer (no bias input; see
    :func:`conv2d_bwd_norelu`)."""
    zero_b = jnp.zeros((w.shape[1],), x.dtype)
    _, vjp = jax.vjp(lambda x_, w_, b_: fc(x_, w_, b_, False), x, w, zero_b)
    return vjp(dy)


def fc_from_4d(x, w, b, relu=True):
    """FC over a flattened 4-D activation (the implicit Flatten)."""
    return fc(x.reshape(x.shape[0], -1), w, b, relu)


# --------------------------------------------------------------------------
# Pooling (pure jnp: memory-bound, autodiff-native)
# --------------------------------------------------------------------------


def maxpool(x, kernel=(2, 2), stride=(2, 2)):
    """VALID max pool, NCHW."""
    return ref.maxpool_ref(x, kernel[0], kernel[1], stride[0], stride[1])


def maxpool_bwd(x, dy, kernel=(2, 2), stride=(2, 2)):
    """Backward of maxpool: routes gradient to the argmax positions."""
    _, vjp = jax.vjp(lambda x_: maxpool(x_, kernel, stride), x)
    return vjp(dy)[0]


# --------------------------------------------------------------------------
# Softmax + cross-entropy head
# --------------------------------------------------------------------------


def softmax_xent(logits, labels):
    """Returns (summed loss over the tile's samples, dlogits).

    ``labels`` are one-hot rows. dlogits is the gradient of the *sum* —
    the executor divides by the global batch when scaling the update.
    """
    z = logits - jax.scipy.special.logsumexp(logits, axis=1, keepdims=True)
    loss = -(labels * z).sum()
    dlogits = jnp.exp(z) - labels
    return loss, dlogits


# --------------------------------------------------------------------------
# SGD (reference; the Rust parameter server applies updates natively)
# --------------------------------------------------------------------------


def sgd(param, grad, lr):
    return param - lr * grad
