"""Build-time compile path: JAX model (L2) + Pallas kernels (L1) + AOT lowering.

Nothing in this package runs at request time; `compile.aot` lowers
everything to HLO text once and the Rust coordinator executes the
artifacts through PJRT.
"""
