"""AOT lowering driver: JAX/Pallas -> HLO text artifacts + manifest.

Usage (what ``make artifacts`` runs)::

    cd python && python -m compile.aot --out ../artifacts [--devices 4]

Emits one ``<key>.hlo.txt`` per (operation, shard-shape) reachable by the
MiniCNN end-to-end demo on up to ``--devices`` simulated devices, plus the
single-device full-model train-step oracle, plus ``manifest.json`` mapping
keys to files and I/O shapes.

Interchange format is HLO **text**: jax >= 0.5 serializes HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published ``xla`` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

The artifact *keys* are a shared contract with the Rust executor
(``rust/src/exec/artifacts keys``); an integration test on the Rust side
asserts every key it can request exists in the manifest.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import layers, model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# Config-space enumeration (mirrors rust parallel::enumerate_configs for
# the MiniCNN layer types; the Rust integration test pins the parity)
# --------------------------------------------------------------------------


def divisors(n):
    return [d for d in range(1, n + 1) if n % d == 0]


def conv_pool_configs(shape, ndev):
    """All (dn, dc, dh, dw) with each degree dividing the extent and
    product <= ndev (4-D layers: sample/channel/height/width)."""
    n, c, h, w = shape
    out = []
    for dn in divisors(n):
        if dn > ndev:
            continue
        for dc in divisors(c):
            if dn * dc > ndev:
                continue
            for dh in divisors(h):
                if dn * dc * dh > ndev:
                    continue
                for dw in divisors(w):
                    if dn * dc * dh * dw <= ndev:
                        out.append((dn, dc, dh, dw))
    return out


def fc_configs(shape, ndev):
    n, c = shape
    return [
        (dn, dc)
        for dn in divisors(n)
        if dn <= ndev
        for dc in divisors(c)
        if dn * dc <= ndev
    ]


# --------------------------------------------------------------------------
# Artifact builders: (key, jax function, example args)
# --------------------------------------------------------------------------


def spec_entries(batch: int, ndev: int):
    """Yield (key, fn, arg_shapes) for every artifact the demo may need."""
    s = jax.ShapeDtypeStruct
    seen = set()

    def emit(key, fn, shapes):
        if key not in seen:
            seen.add(key)
            yield key, fn, [s(sh, F32) for sh in shapes]

    for name, kind, a in model.ARCH:
        if kind == "conv":
            out = conv_out_shape(name, batch)
            cin, k, relu = a["cin"], a["k"], a["relu"]
            for dn, dc, dh, dw in conv_pool_configs(out, ndev):
                nt, ct = out[0] // dn, out[1] // dc
                ht, wt = out[2] // dh, out[3] // dw
                hs, ws = ht + k - 1, wt + k - 1  # stride-1 VALID slab
                sig = f"n{nt}_ci{cin}_h{hs}_w{ws}_co{ct}_k{k}x{k}_s1x1_r{int(relu)}"
                x_sh, w_sh, b_sh = (nt, cin, hs, ws), (ct, cin, k, k), (ct,)
                dy_sh = (nt, ct, ht, wt)
                yield from emit(
                    f"conv2d_fwd_{sig}",
                    lambda x, w, b, relu=relu: (layers.conv2d(x, w, b, (1, 1), relu),),
                    [x_sh, w_sh, b_sh],
                )
                if relu:
                    yield from emit(
                        f"conv2d_bwd_{sig}",
                        lambda x, w, b, dy: layers.conv2d_bwd(x, w, b, dy, (1, 1), True),
                        [x_sh, w_sh, b_sh, dy_sh],
                    )
                else:
                    # linear conv: bias is not an input (XLA would DCE it)
                    yield from emit(
                        f"conv2d_bwd_{sig}",
                        lambda x, w, dy: layers.conv2d_bwd_norelu(x, w, dy, (1, 1)),
                        [x_sh, w_sh, dy_sh],
                    )
        elif kind == "pool":
            out = pool_out_shape(name, batch)
            k = a["k"]
            for dn, dc, dh, dw in conv_pool_configs(out, ndev):
                nt, ct = out[0] // dn, out[1] // dc
                ht, wt = out[2] // dh, out[3] // dw
                hs, ws = ht * k, wt * k  # k=s, no halo
                sig = f"n{nt}_c{ct}_h{hs}_w{ws}_k{k}_s{k}"
                x_sh, dy_sh = (nt, ct, hs, ws), (nt, ct, ht, wt)
                yield from emit(
                    f"maxpool_fwd_{sig}",
                    lambda x, k=k: (layers.maxpool(x, (k, k), (k, k)),),
                    [x_sh],
                )
                yield from emit(
                    f"maxpool_bwd_{sig}",
                    lambda x, dy, k=k: (layers.maxpool_bwd(x, dy, (k, k), (k, k)),),
                    [x_sh, dy_sh],
                )
        elif kind == "fc":
            cin, cout, relu = a["cin"], a["cout"], a["relu"]
            for dn, dc in fc_configs((batch, cout), ndev):
                nt, ct = batch // dn, cout // dc
                sig = f"n{nt}_ci{cin}_co{ct}_r{int(relu)}"
                x_sh, w_sh, b_sh, dy_sh = (nt, cin), (cin, ct), (ct,), (nt, ct)
                yield from emit(
                    f"fc_fwd_{sig}",
                    lambda x, w, b, relu=relu: (layers.fc(x, w, b, relu),),
                    [x_sh, w_sh, b_sh],
                )
                if relu:
                    yield from emit(
                        f"fc_bwd_{sig}",
                        lambda x, w, b, dy: layers.fc_bwd(x, w, b, dy, True),
                        [x_sh, w_sh, b_sh, dy_sh],
                    )
                else:
                    yield from emit(
                        f"fc_bwd_{sig}",
                        lambda x, w, dy: layers.fc_bwd_norelu(x, w, dy),
                        [x_sh, w_sh, dy_sh],
                    )

    # softmax head: sample partitioning only
    for dn in divisors(batch):
        if dn > ndev:
            continue
        nt = batch // dn
        yield from emit(
            f"softmax_xent_n{nt}_c10",
            lambda logits, labels: layers.softmax_xent(logits, labels),
            [(nt, 10), (nt, 10)],
        )

    # the single-device train-step oracle
    yield from emit(
        f"minicnn_train_step_n{batch}",
        model.train_step_flat,
        [(batch, 3, 32, 32), (batch, 10), ()]
        + [sh for n in model.param_order() for sh in param_shapes(n)],
    )


def conv_out_shape(name, batch):
    return {"conv1": (batch, 8, 32, 32), "conv2": (batch, 16, 16, 16)}[name]


def pool_out_shape(name, batch):
    return {"pool1": (batch, 8, 16, 16), "pool2": (batch, 16, 8, 8)}[name]


def param_shapes(name):
    attrs = dict((n, a) for n, k, a in model.ARCH if k in ("conv", "fc"))
    a = attrs[name]
    if "k" in a:
        return [(a["cout"], a["cin"], a["k"], a["k"]), (a["cout"],)]
    return [(a["cin"], a["cout"]), (a["cout"],)]


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def build(out_dir: str, batch: int, ndev: int, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "batch": batch,
        "devices": ndev,
        "network": "minicnn",
        "artifacts": {},
    }
    t0 = time.time()
    count = 0
    for key, fn, args in spec_entries(batch, ndev):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{key}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        try:
            out_shapes = [
                list(o.shape) for o in jax.tree_util.tree_leaves(lowered.out_info)
            ]
        except AttributeError:
            out_shapes = []
        manifest["artifacts"][key] = {
            "file": fname,
            "inputs": [list(a.shape) for a in args],
            "outputs": out_shapes,
        }
        count += 1
        if verbose and count % 20 == 0:
            print(f"  lowered {count} artifacts ({time.time() - t0:.1f}s)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        print(f"wrote {count} artifacts + manifest.json to {out_dir} "
              f"in {time.time() - t0:.1f}s")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=32, help="global batch")
    ap.add_argument("--devices", type=int, default=4)
    args = ap.parse_args()
    build(args.out, args.batch, args.devices)


if __name__ == "__main__":
    main()
