"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including the awkward non-128-aligned shard
shapes the partitioned executor produces) and asserts allclose against
`kernels.ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, matmul, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- matmul


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
)
def test_matmul_matches_ref_shapes(m, k, n):
    x, w = rand(0, (m, k)), rand(1, (k, n))
    np.testing.assert_allclose(
        matmul.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 96, 64), (1, 1, 1), (7, 13, 3)])
def test_matmul_key_shapes(m, k, n):
    x, w = rand(2, (m, k)), rand(3, (k, n))
    np.testing.assert_allclose(
        matmul.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (32, 16, 64), (128, 128, 128)])
def test_matmul_tile_size_invariance(bm, bn, bk):
    x, w = rand(4, (48, 56), ), rand(5, (56, 24))
    got = matmul.matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_inner_dim():
    with pytest.raises(AssertionError):
        matmul.matmul(rand(0, (4, 5)), rand(1, (6, 7)))


def test_vmem_estimate_positive_and_monotone():
    small = matmul.vmem_bytes(32, 32, 32)
    big = matmul.vmem_bytes(128, 128, 512)
    assert 0 < small < big


# ---------------------------------------------------------------- im2col


@given(
    n=st.integers(1, 3),
    c=st.integers(1, 4),
    h=st.integers(3, 12),
    kh=st.integers(1, 3),
    s=st.integers(1, 2),
)
def test_im2col_col2im_adjoint(n, c, h, kh, s):
    """col2im is the transpose of im2col: <im2col(x), y> == <x, col2im(y)>."""
    w = h  # square inputs
    if h < kh:
        return
    x = rand(6, (n, c, h, w))
    cols, (oh, ow) = conv2d.im2col(x, kh, kh, s, s)
    y = rand(7, cols.shape)
    lhs = jnp.vdot(cols, y)
    rhs = jnp.vdot(x, conv2d.col2im(y, x.shape, kh, kh, s, s))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


def test_im2col_known_case():
    # 1x1x3x3 iota, 2x2 kernel stride 1 -> 4 patches of 4
    x = jnp.arange(9.0, dtype=jnp.float32).reshape(1, 1, 3, 3)
    cols, (oh, ow) = conv2d.im2col(x, 2, 2, 1, 1)
    assert (oh, ow) == (2, 2)
    np.testing.assert_allclose(
        cols,
        jnp.array(
            [[0, 1, 3, 4], [1, 2, 4, 5], [3, 4, 6, 7], [4, 5, 7, 8]], jnp.float32
        ),
    )


# ---------------------------------------------------------------- conv2d


@given(
    n=st.integers(1, 3),
    cin=st.integers(1, 4),
    cout=st.integers(1, 4),
    hw=st.integers(4, 10),
    k=st.integers(1, 3),
    s=st.integers(1, 2),
)
def test_conv2d_valid_matches_lax(n, cin, cout, hw, k, s):
    x = rand(8, (n, cin, hw, hw))
    w = rand(9, (cout, cin, k, k))
    np.testing.assert_allclose(
        conv2d.conv2d_valid(x, w, s, s),
        ref.conv2d_valid_ref(x, w, s, s),
        rtol=1e-4,
        atol=1e-4,
    )


@given(
    n=st.integers(1, 2),
    cin=st.integers(1, 3),
    cout=st.integers(1, 3),
    hw=st.integers(4, 8),
    k=st.integers(1, 3),
)
def test_conv2d_grads_match_autodiff(n, cin, cout, hw, k):
    x = rand(10, (n, cin, hw, hw))
    w = rand(11, (cout, cin, k, k))
    oh = hw - k + 1
    dy = rand(12, (n, cout, oh, oh))
    dx, dw = conv2d.conv2d_valid_grads(x, w, dy)
    dx_r, dw_r = ref.conv2d_valid_grads_ref(x, w, dy)
    np.testing.assert_allclose(dx, dx_r, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(dw, dw_r, rtol=1e-4, atol=1e-3)


def test_conv2d_stride2_shard_shape():
    # the exact slab shape family the executor produces (h_t + k - 1)
    x = rand(13, (8, 3, 18, 34))
    w = rand(14, (8, 3, 3, 3))
    got = conv2d.conv2d_valid(x, w)
    assert got.shape == (8, 8, 16, 32)
    np.testing.assert_allclose(got, ref.conv2d_valid_ref(x, w), rtol=1e-4, atol=1e-4)
