"""L2 layer correctness: custom_vjp layers vs pure-jnp forward + autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers
from compile.kernels import ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def conv_ref(x, w, b, relu):
    y = ref.conv2d_valid_ref(x, w) + b[None, :, None, None]
    return jnp.maximum(y, 0.0) if relu else y


def fc_ref(x, w, b, relu):
    y = x @ w + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y


@pytest.mark.parametrize("relu", [True, False])
def test_conv2d_forward_matches_ref(relu):
    x, w, b = rand(0, (2, 3, 10, 10)), rand(1, (4, 3, 3, 3)), rand(2, (4,))
    np.testing.assert_allclose(
        layers.conv2d(x, w, b, (1, 1), relu), conv_ref(x, w, b, relu),
        rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("relu", [True, False])
def test_conv2d_custom_vjp_matches_autodiff(relu):
    x, w, b = rand(3, (2, 3, 8, 8)), rand(4, (4, 3, 3, 3)), rand(5, (4,))

    def loss_pallas(x, w, b):
        return (layers.conv2d(x, w, b, (1, 1), relu) ** 2).sum()

    def loss_ref(x, w, b):
        return (conv_ref(x, w, b, relu) ** 2).sum()

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g1, g2):
        np.testing.assert_allclose(a, r, rtol=1e-3, atol=1e-3)


def test_conv2d_bwd_entry_point():
    x, w, b = rand(6, (2, 3, 8, 8)), rand(7, (4, 3, 3, 3)), rand(8, (4,))
    dy = rand(9, (2, 4, 6, 6))
    dx, dw, db = layers.conv2d_bwd(x, w, b, dy, (1, 1), True)
    assert dx.shape == x.shape and dw.shape == w.shape and db.shape == b.shape


@given(n=st.integers(1, 8), cin=st.integers(1, 16), cout=st.integers(1, 12))
def test_fc_forward_matches_ref(n, cin, cout):
    x, w, b = rand(10, (n, cin)), rand(11, (cin, cout)), rand(12, (cout,))
    np.testing.assert_allclose(
        layers.fc(x, w, b, True), fc_ref(x, w, b, True), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("relu", [True, False])
def test_fc_custom_vjp_matches_autodiff(relu):
    x, w, b = rand(13, (6, 20)), rand(14, (20, 8)), rand(15, (8,))

    def loss_pallas(x, w, b):
        return (layers.fc(x, w, b, relu) ** 2).sum()

    def loss_ref(x, w, b):
        return (fc_ref(x, w, b, relu) ** 2).sum()

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g1, g2):
        np.testing.assert_allclose(a, r, rtol=1e-3, atol=1e-3)


def test_maxpool_and_bwd():
    x = rand(16, (2, 3, 8, 8))
    y = layers.maxpool(x)
    assert y.shape == (2, 3, 4, 4)
    np.testing.assert_allclose(y, ref.maxpool_ref(x, 2, 2, 2, 2))
    dy = rand(17, y.shape)
    dx = layers.maxpool_bwd(x, dy)
    # gradient mass is conserved when maxima are unique
    np.testing.assert_allclose(dx.sum(), dy.sum(), rtol=1e-5)


def test_softmax_xent_loss_and_grad():
    logits = rand(18, (5, 10))
    labels = jax.nn.one_hot(jnp.arange(5), 10)
    loss, dlogits = layers.softmax_xent(logits, labels)

    def ref_loss(z):
        z = z - jax.scipy.special.logsumexp(z, axis=1, keepdims=True)
        return -(labels * z).sum()

    np.testing.assert_allclose(loss, ref_loss(logits), rtol=1e-5)
    np.testing.assert_allclose(
        dlogits, jax.grad(ref_loss)(logits), rtol=1e-4, atol=1e-5
    )


def test_softmax_xent_partitions_sum_to_whole():
    # sample-partitioned softmax: partial losses/grads concatenate exactly
    logits = rand(19, (8, 10))
    labels = jax.nn.one_hot(jnp.arange(8) % 10, 10)
    full_loss, full_d = layers.softmax_xent(logits, labels)
    l1, d1 = layers.softmax_xent(logits[:4], labels[:4])
    l2, d2 = layers.softmax_xent(logits[4:], labels[4:])
    np.testing.assert_allclose(full_loss, l1 + l2, rtol=1e-5)
    np.testing.assert_allclose(full_d, jnp.concatenate([d1, d2]), rtol=1e-5)


def test_sgd():
    p, g = jnp.ones((3,)), jnp.full((3,), 2.0)
    np.testing.assert_allclose(layers.sgd(p, g, 0.1), jnp.full((3,), 0.8))
