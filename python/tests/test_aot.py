"""AOT pipeline: spec enumeration, HLO text emission, manifest integrity."""

import json
import os

import pytest

from compile import aot, model


def test_divisors():
    assert aot.divisors(12) == [1, 2, 3, 4, 6, 12]
    assert aot.divisors(1) == [1]


def test_conv_pool_configs_budget():
    cfgs = aot.conv_pool_configs((32, 8, 32, 32), 4)
    assert (1, 1, 1, 1) in cfgs
    assert (4, 1, 1, 1) in cfgs
    assert (1, 1, 2, 2) in cfgs
    assert all(d1 * d2 * d3 * d4 <= 4 for d1, d2, d3, d4 in cfgs)


def test_fc_configs():
    cfgs = aot.fc_configs((32, 64), 4)
    assert (4, 1) in cfgs and (1, 4) in cfgs and (2, 2) in cfgs


def test_spec_keys_are_unique_and_cover_core_ops():
    entries = list(aot.spec_entries(batch=32, ndev=2))
    keys = [k for k, _, _ in entries]
    assert len(keys) == len(set(keys)), "duplicate artifact keys"
    kinds = {k.rsplit("_n", 1)[0].rsplit("_", 1)[0] for k in keys}
    for prefix in ("conv2d_fwd", "conv2d_bwd", "maxpool_fwd", "maxpool_bwd",
                   "fc_fwd", "fc_bwd"):
        assert any(k.startswith(prefix) for k in keys), prefix
    assert any(k.startswith("softmax_xent") for k in keys)
    assert any(k.startswith("minicnn_train_step") for k in keys)


def test_hlo_text_emission(tmp_path):
    """Lower a single small artifact and sanity-check the HLO text."""
    import jax
    import jax.numpy as jnp
    from compile import layers

    f = lambda x, w, b: (layers.fc(x, w, b, True),)
    s = jax.ShapeDtypeStruct
    lowered = jax.jit(f).lower(
        s((4, 8), jnp.float32), s((8, 3), jnp.float32), s((3,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,3]" in text  # output shape appears


@pytest.mark.slow
def test_full_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, batch=8, ndev=2, verbose=False)
    m2 = json.load(open(os.path.join(out, "manifest.json")))
    assert m2["artifacts"].keys() == manifest["artifacts"].keys()
    for key, meta in m2["artifacts"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), key
        assert open(path).read(200).startswith("HloModule")


def test_param_shapes_match_model():
    params = model.init_params(0)
    for name in model.param_order():
        expect = [list(t.shape) for t in params[name]]
        got = [list(s) for s in aot.param_shapes(name)]
        assert got == expect, name
