"""MiniCNN model: shapes, training dynamics, and the partitioning
equivalences the Rust executor relies on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import layers, model


def data(n=8, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n, 3, 32, 32), jnp.float32)
    y = jax.nn.one_hot(jax.random.randint(k2, (n,), 0, 10), 10)
    return x, y


def test_forward_shapes():
    params = model.init_params(0)
    x, _ = data(4)
    logits = model.forward(params, x)
    assert logits.shape == (4, 10)


def test_param_order_and_shapes():
    params = model.init_params(0)
    names = model.param_order()
    assert names == ["conv1", "conv2", "fc1", "fc2"]
    assert params["conv1"][0].shape == (8, 3, 3, 3)
    assert params["fc1"][0].shape == (1024, 64)


def test_loss_decreases_over_training():
    params = model.init_params(0)
    x, y = data(8)
    losses = []
    for _ in range(15):
        loss, params = model.train_step(params, x, y, 0.01)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], losses


def test_train_step_flat_matches_dict_api():
    params = model.init_params(1)
    x, y = data(4, seed=1)
    loss_d, new_d = model.train_step(params, x, y, 0.02)
    flat = [t for n in model.param_order() for t in params[n]]
    out = model.train_step_flat(x, y, jnp.float32(0.02), *flat)
    np.testing.assert_allclose(out[0], loss_d, rtol=1e-6)
    i = 1
    for n in model.param_order():
        for t in new_d[n]:
            np.testing.assert_allclose(out[i], t, rtol=1e-5, atol=1e-6)
            i += 1


def test_sample_partitioned_conv_equals_full():
    """Data-parallel equivalence: conv over a batch == concat of conv over
    sample shards (the executor's n-split path)."""
    params = model.init_params(0)
    w, b = params["conv1"]
    x, _ = data(8)
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    full = layers.conv2d(xp, w, b, (1, 1), True)
    parts = [layers.conv2d(xp[i : i + 4], w, b, (1, 1), True) for i in (0, 4)]
    np.testing.assert_allclose(full, jnp.concatenate(parts), rtol=1e-4, atol=1e-5)


def test_channel_partitioned_conv_equals_full():
    """Model-parallel equivalence: conv with a cout shard == channel slice
    of the full conv (the executor's c-split path)."""
    params = model.init_params(0)
    w, b = params["conv1"]
    x, _ = data(4)
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    full = layers.conv2d(xp, w, b, (1, 1), True)
    lo = layers.conv2d(xp, w[:4], b[:4], (1, 1), True)
    hi = layers.conv2d(xp, w[4:], b[4:], (1, 1), True)
    np.testing.assert_allclose(full, jnp.concatenate([lo, hi], axis=1), rtol=1e-4, atol=1e-5)


def test_spatially_partitioned_conv_equals_full():
    """Height-split with a halo slab == rows of the full conv (the
    executor's h-split path with zero-padded borders)."""
    params = model.init_params(0)
    w, b = params["conv1"]
    x, _ = data(2)
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))  # 34x34 slab
    full = layers.conv2d(xp, w, b, (1, 1), True)  # [2,8,32,32]
    # top half: padded rows 0..18 (out rows 0..16); bottom: rows 16..34
    top = layers.conv2d(xp[:, :, 0:18, :], w, b, (1, 1), True)
    bot = layers.conv2d(xp[:, :, 16:34, :], w, b, (1, 1), True)
    np.testing.assert_allclose(full[:, :, :16], top, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(full[:, :, 16:], bot, rtol=1e-4, atol=1e-5)


def test_channel_partitioned_fc_equals_full():
    params = model.init_params(0)
    w, b = params["fc2"]
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64), jnp.float32)
    full = layers.fc(x, w, b, False)
    lo = layers.fc(x, w[:, :5], b[:5], False)
    hi = layers.fc(x, w[:, 5:], b[5:], False)
    np.testing.assert_allclose(full, jnp.concatenate([lo, hi], axis=1), rtol=1e-4, atol=1e-5)
