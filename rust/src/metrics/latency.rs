//! Lock-free serving metrics: a log-bucketed latency histogram and a
//! balanced gauge, both plain atomics so the request hot path never
//! takes a lock to record an observation (DESIGN.md §13).
//!
//! The histogram trades precision for a fixed footprint: one `AtomicU64`
//! per power-of-two microsecond bucket. A reported quantile is the upper
//! bound of the bucket holding the target rank, so it is exact to within
//! a factor of two — the right resolution for spotting a p99 that moved
//! from microseconds to milliseconds, which is what the `{"want":
//! "metrics"}` probe exists for. Recording is a single `fetch_add`;
//! reading sweeps the 64 buckets without stopping writers, so a quantile
//! taken under load is a consistent-enough snapshot, never a torn one.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of power-of-two buckets: bucket `i` holds observations in
/// `[2^i, 2^(i+1))` microseconds (bucket 0 also absorbs sub-microsecond
/// observations). 64 buckets cover any `u64` microsecond value.
const BUCKETS: usize = 64;

/// A fixed-size, lock-free histogram of durations in microseconds.
///
/// Writers call [`record`](LatencyHistogram::record) concurrently from
/// any number of threads; readers call
/// [`quantile`](LatencyHistogram::quantile) / [`count`](LatencyHistogram::count)
/// at any time. All operations are wait-free single atomics per bucket.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

/// The bucket index of a microsecond observation: its bit length, so
/// values in `[2^i, 2^(i+1))` share bucket `i`.
fn bucket_of(us: u64) -> usize {
    (63 - us.max(1).leading_zeros()) as usize
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Record one observation.
    pub fn record(&self, elapsed: Duration) {
        let us = u64::try_from(elapsed.as_micros()).unwrap_or(u64::MAX);
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations, in microseconds (mean = `sum_us / count`).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// The largest observation recorded, in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// The `q`-quantile (`0.0 ..= 1.0`) in microseconds: the upper bound
    /// of the bucket containing the target rank, so exact to within 2x.
    /// `None` on an empty histogram (there is no honest number to give).
    pub fn quantile(&self, q: f64) -> Option<u64> {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return None;
        }
        // rank 1..=total: p50 of 10 observations is the 5th smallest
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                // upper bound of bucket i, saturating for the top bucket
                return Some((2u64 << i).wrapping_sub(1).max(1));
            }
        }
        Some(u64::MAX)
    }
}

/// A balanced up/down counter for in-flight work. Increments and
/// decrements must pair (use a guard); the value is a point-in-time
/// snapshot, exact only in quiescence.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    /// A gauge at zero.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Increment; returns the new value.
    pub fn inc(&self) -> u64 {
        self.value.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Decrement. Saturates at zero instead of wrapping, so an unpaired
    /// decrement cannot turn the gauge into 2^64.
    pub fn dec(&self) {
        let mut cur = self.value.load(Ordering::Relaxed);
        while cur > 0 {
            match self.value.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// The current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), 63);
    }

    #[test]
    fn quantiles_bound_the_observations_within_2x() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), None, "empty histogram has no quantile");
        for us in [10u64, 20, 30, 40, 1000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum_us(), 1100);
        assert_eq!(h.max_us(), 1000);
        // p50 rank is the 3rd smallest (30us, bucket [16,32) -> 31)
        assert_eq!(h.quantile(0.5), Some(31));
        // p99 rank is the 5th (1000us, bucket [512,1024) -> 1023)
        assert_eq!(h.quantile(0.99), Some(1023));
        // every quantile upper-bounds the true value and is within 2x
        for (q, truth) in [(0.2, 10u64), (0.4, 20), (0.6, 30), (0.8, 40), (1.0, 1000)] {
            let est = h.quantile(q).unwrap();
            assert!(est >= truth && est < truth * 2, "q{q}: {est} vs {truth}");
        }
    }

    #[test]
    fn histogram_is_safe_under_concurrent_recording() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for us in 1..=1000u64 {
                        h.record(Duration::from_micros(us));
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert_eq!(h.max_us(), 1000);
        assert!(h.quantile(0.5).unwrap() >= 500);
    }

    #[test]
    fn gauge_saturates_at_zero() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0);
        assert_eq!(g.inc(), 1);
        assert_eq!(g.inc(), 2);
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec(); // unpaired: must not wrap
        assert_eq!(g.get(), 0);
    }
}
