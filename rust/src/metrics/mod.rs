//! Throughput and communication-cost accounting (Figures 7 & 8), plus
//! the lock-free serving metrics ([`latency`]) behind `optcnn serve`'s
//! `{"want": "metrics"}` probe (DESIGN.md §13).

pub mod latency;

pub use latency::{Gauge, LatencyHistogram};

use crate::cost::CostModel;
use crate::parallel::Strategy;

/// Per-step communication volume, split by cause.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommBreakdown {
    /// Bytes moved to re-partition tensors between layers (`t_X` traffic).
    pub xfer_bytes: f64,
    /// Bytes moved to synchronize parameters (`t_S` traffic).
    pub sync_bytes: f64,
}

impl CommBreakdown {
    pub fn total(&self) -> f64 {
        self.xfer_bytes + self.sync_bytes
    }
}

/// Total data transferred in each step under `strategy` (the Figure 8
/// metric). Pure accounting — independent of timing assumptions.
pub fn comm_volume(cm: &CostModel, strategy: &Strategy) -> CommBreakdown {
    let g = cm.graph;
    let mut out = CommBreakdown::default();
    for l in &g.layers {
        out.sync_bytes += cm.s_bytes(l, strategy.config(l.id));
    }
    for &(s, d) in &g.edges {
        out.xfer_bytes += cm.x_bytes(
            g.layer(s),
            g.layer(d),
            cm.edge_in_idx(s, d),
            strategy.config(s),
            strategy.config(d),
        );
    }
    out
}

/// Images/second at a given per-step time.
pub fn throughput(global_batch: usize, step_time: f64) -> f64 {
    global_batch as f64 / step_time
}

/// Speedup table entry: strategy throughput normalized to a 1-device run.
pub fn speedup(throughput_n: f64, throughput_1: f64) -> f64 {
    throughput_n / throughput_1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::graph::nets;
    use crate::optimizer::strategies;

    #[test]
    fn data_parallel_volume_dominated_by_sync() {
        // AlexNet's 61M params under data parallelism: sync volume dwarfs
        // tensor movement (there is none for pure data parallelism).
        let g = nets::alexnet(32 * 4).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let v = comm_volume(&cm, &strategies::data_parallel(&g, 4));
        assert_eq!(v.xfer_bytes, 0.0);
        assert!(v.sync_bytes > 1e9);
    }

    #[test]
    fn owt_reduces_alexnet_communication_dramatically() {
        // The paper's Figure 8: OWT cuts AlexNet comm by >10x vs data
        // parallelism (fc layers hold ~95% of AlexNet's parameters).
        let g = nets::alexnet(32 * 4).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let dp = comm_volume(&cm, &strategies::data_parallel(&g, 4));
        let ow = comm_volume(&cm, &strategies::owt(&g, 4));
        assert!(
            dp.total() > 5.0 * ow.total(),
            "dp {} vs owt {}",
            dp.total(),
            ow.total()
        );
    }

    #[test]
    fn throughput_formula() {
        assert_eq!(throughput(128, 0.5), 256.0);
        assert_eq!(speedup(300.0, 100.0), 3.0);
    }
}
