//! Pre-planning static analysis (DESIGN.md §11).
//!
//! Everything the planner can know about a (graph, cluster, budget)
//! request *before* any cost table is built, computed from graph
//! structure alone in four prongs:
//!
//! * **Reducibility** — Algorithm 1's node/edge eliminations replayed
//!   symbolically (no cost matrices; see [`optimizer`]): a graph either
//!   collapses to the paper's 2-node kernel ([`Reducibility::FullyReducible`])
//!   or leaves a [`Residual`](Reducibility::Residual) kernel whose
//!   strategies the elimination backend must brute-force. The surviving
//!   subgraph is returned as a [`ResidualKernel`] — the structural seed
//!   for a future exact-DP backend over irreducible graphs (ROADMAP #1).
//! * **Search-cost certificate** — the exact per-layer configuration
//!   counts ([`parallel::count_configs`], the counting twin of
//!   `enumerate_configs`) composed into the exact final-enumeration
//!   size as a checked `u128` plus an always-finite `log2`, so callers
//!   know what a search will cost before paying for it. `optcnn serve`
//!   rejects custom graphs whose residual enumeration exceeds
//!   [`MAX_RESIDUAL_SPACE_LOG2`](crate::planner::MAX_RESIDUAL_SPACE_LOG2)
//!   instead of pinning a worker thread, and `--backend auto` picks
//!   between elimination and budgeted DFS from the same number.
//! * **Memory-feasibility precheck** — [`memory::min_layer_peak_bytes`]
//!   (the peak is monotone in every partition degree, so the minimum
//!   sits at maximal degrees) compared against the budget per layer:
//!   an unsatisfiable layer fast-fails [`OptError::Infeasible`] with
//!   *exactly* the verdict `CostTables::build_budgeted` would reach
//!   after building half the tables, and feasible layers report what
//!   fraction of their configuration space survives the budget.
//! * **Graph lints** — structured [`Diagnostic`]s for structural smells
//!   a valid graph can still carry: sinks whose output is never
//!   consumed, partitionable dimensions of extent 1, stride windows
//!   that skip input, padding that mats whole windows.
//!
//! The pass never constructs a [`CostTables`](crate::cost::CostTables):
//! `tests/analyze.rs` pins the planner/service table-build counters at
//! zero across analysis.
//!
//! [`optimizer`]: crate::optimizer

#![warn(missing_docs)]

use std::fmt;

use crate::device::DeviceGraph;
use crate::error::OptError;
use crate::graph::{CompGraph, LayerId, OpKind};
use crate::memory::{self, MemBudget};
use crate::parallel::{allowed_dims, count_configs, enumerate_configs};
use crate::util::json::Json;

/// How far Algorithm 1's eliminations shrink a graph, decided from
/// structure alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reducibility {
    /// Node/edge eliminations collapse the graph to at most two nodes —
    /// the paper's normal case (`K = 2` for every benchmark network),
    /// where the final enumeration is a cheap `C²` scan.
    FullyReducible,
    /// An irreducible kernel survives: the elimination backend must
    /// brute-force the product space of these nodes.
    Residual {
        /// Nodes remaining at the elimination fixpoint (the paper's `K`).
        nodes: usize,
        /// Distinct merged edges among them.
        edges: usize,
    },
}

impl fmt::Display for Reducibility {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Reducibility::FullyReducible => write!(f, "fully-reducible"),
            Reducibility::Residual { nodes, edges } => {
                write!(f, "residual ({nodes} nodes, {edges} edges)")
            }
        }
    }
}

/// The subgraph surviving the elimination fixpoint, named by original
/// layer ids. For a fully reducible graph this is the trivial 2-node
/// kernel; for an irreducible one it is the exact structure a future
/// DP-over-kernels backend would operate on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResidualKernel {
    /// Surviving layer ids, ascending.
    pub nodes: Vec<LayerId>,
    /// Surviving merged edges `(src, dst)`, deduplicated (the fixpoint
    /// guarantees no parallel edges remain), sorted.
    pub edges: Vec<(LayerId, LayerId)>,
    /// Node eliminations the replay applied to reach the fixpoint.
    pub node_eliminations: usize,
    /// Edge eliminations the replay applied to reach the fixpoint.
    pub edge_eliminations: usize,
}

/// The exact cost of searching this graph, known before any table is
/// built: per-layer configuration counts and their compositions over
/// the residual kernel (what the elimination backend's final
/// enumeration visits) and over every layer (what the exhaustive DFS
/// baseline's leaf space holds).
#[derive(Debug, Clone, PartialEq)]
pub struct SearchCertificate {
    /// `|enumerate_configs(layer, ndev)|` per layer, indexed by layer id.
    pub layer_configs: Vec<u64>,
    /// Exact product of `layer_configs` over the residual kernel's
    /// nodes; `None` when it overflows `u128`.
    pub residual_space: Option<u128>,
    /// `log2` of the residual product (finite even when the exact
    /// product overflows).
    pub residual_space_log2: f64,
    /// Exact product of `layer_configs` over *all* layers — the
    /// exhaustive baseline's leaf count; `None` on overflow.
    pub full_space: Option<u128>,
    /// `log2` of the full product.
    pub full_space_log2: f64,
}

/// How serious a [`Diagnostic`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Informational: a structural fact worth knowing, not a problem.
    Info,
    /// Suspicious: almost certainly a spec mistake, but planning works.
    Warning,
    /// Broken: the graph cannot mean what its author intended.
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Info => write!(f, "info"),
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// One structured lint finding. `code` is a stable kebab-case name
/// (like [`PlanCheck`](crate::error::PlanCheck)'s) so tools and tests
/// can match findings without parsing prose.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// How serious the finding is.
    pub severity: Severity,
    /// Stable kebab-case lint name (`unreachable-layer`, `dead-output`,
    /// `degenerate-dim`, `stride-gap`, `pad-window`, `over-parallel`).
    pub code: &'static str,
    /// The layer the finding is about; `None` for graph-level findings.
    pub layer: Option<LayerId>,
    /// One-line human-readable description.
    pub message: String,
}

/// Memory feasibility of one layer under the requested budget.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerFeasibility {
    /// Legal configurations at the requested device count.
    pub configs: u64,
    /// Configurations whose per-device peak fits the budget.
    pub feasible: u64,
    /// Bytes of the smallest-footprint configuration
    /// ([`memory::min_layer_peak_bytes`]).
    pub min_bytes: f64,
}

/// The memory prong of the report, present when a budget was supplied.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryPrecheck {
    /// Per-layer feasibility, indexed by layer id.
    pub per_layer: Vec<LayerFeasibility>,
    /// Lowest-id layer with no feasible configuration, with the bytes
    /// by which its smallest configuration still overshoots — the exact
    /// payload `CostTables::build_budgeted` puts in
    /// [`OptError::Infeasible`].
    pub infeasible: Option<(String, u64)>,
}

impl MemoryPrecheck {
    /// The typed error a planning request with this budget would fail
    /// with, if any — byte-for-byte what `build_budgeted` reports.
    pub fn to_error(&self) -> Option<OptError> {
        self.infeasible
            .as_ref()
            .map(|(layer, overshoot)| OptError::Infeasible {
                layer: layer.clone(),
                overshoot: *overshoot,
            })
    }
}

/// Everything [`analyze`] learns about a request without building a
/// cost table.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalysisReport {
    /// The device count the certificate and precheck were computed for.
    pub ndev: usize,
    /// Reducibility class of the elimination fixpoint.
    pub reducibility: Reducibility,
    /// The surviving subgraph (trivial for fully reducible graphs).
    pub kernel: ResidualKernel,
    /// The exact search-cost certificate.
    pub certificate: SearchCertificate,
    /// Memory feasibility, when a budget was supplied.
    pub memory: Option<MemoryPrecheck>,
    /// Lint findings, in layer order.
    pub diagnostics: Vec<Diagnostic>,
}

impl AnalysisReport {
    /// Number of error-severity diagnostics.
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    /// Number of warning-severity diagnostics.
    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// Machine-readable form, shared by `optcnn analyze --json` and the
    /// `{"want":"analyze"}` serve probe. Exact `u128` space sizes do not
    /// fit a JSON number (f64), so they are emitted as decimal *strings*
    /// (null on overflow) alongside always-numeric `log2` fields.
    pub fn to_json(&self) -> Json {
        let space = |s: Option<u128>| match s {
            Some(v) => Json::Str(v.to_string()),
            None => Json::Null,
        };
        let kernel = Json::obj(vec![
            ("nodes", Json::Arr(self.kernel.nodes.iter().map(|&n| Json::Num(n as f64)).collect())),
            (
                "edges",
                Json::Arr(
                    self.kernel
                        .edges
                        .iter()
                        .map(|&(s, d)| Json::Arr(vec![Json::Num(s as f64), Json::Num(d as f64)]))
                        .collect(),
                ),
            ),
            ("node_eliminations", Json::Num(self.kernel.node_eliminations as f64)),
            ("edge_eliminations", Json::Num(self.kernel.edge_eliminations as f64)),
        ]);
        let certificate = Json::obj(vec![
            (
                "layer_configs",
                Json::Arr(
                    self.certificate.layer_configs.iter().map(|&c| Json::Num(c as f64)).collect(),
                ),
            ),
            ("residual_space", space(self.certificate.residual_space)),
            ("residual_space_log2", Json::Num(self.certificate.residual_space_log2)),
            ("full_space", space(self.certificate.full_space)),
            ("full_space_log2", Json::Num(self.certificate.full_space_log2)),
        ]);
        let memory = match &self.memory {
            None => Json::Null,
            Some(m) => Json::obj(vec![
                (
                    "per_layer",
                    Json::Arr(
                        m.per_layer
                            .iter()
                            .map(|f| {
                                Json::obj(vec![
                                    ("configs", Json::Num(f.configs as f64)),
                                    ("feasible", Json::Num(f.feasible as f64)),
                                    ("min_bytes", Json::Num(f.min_bytes)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "infeasible",
                    match &m.infeasible {
                        None => Json::Null,
                        Some((layer, overshoot)) => Json::obj(vec![
                            ("layer", Json::Str(layer.clone())),
                            ("overshoot", Json::Num(*overshoot as f64)),
                        ]),
                    },
                ),
            ]),
        };
        let diagnostics = Json::Arr(
            self.diagnostics
                .iter()
                .map(|d| {
                    Json::obj(vec![
                        ("severity", Json::Str(d.severity.to_string())),
                        ("code", Json::Str(d.code.to_string())),
                        (
                            "layer",
                            match d.layer {
                                Some(l) => Json::Num(l as f64),
                                None => Json::Null,
                            },
                        ),
                        ("message", Json::Str(d.message.clone())),
                    ])
                })
                .collect(),
        );
        // a stable class token (the Display form carries counts, which
        // the kernel object already reports)
        let class = match self.reducibility {
            Reducibility::FullyReducible => "fully-reducible",
            Reducibility::Residual { .. } => "residual",
        };
        Json::obj(vec![
            ("ndev", Json::Num(self.ndev as f64)),
            ("reducibility", Json::Str(class.to_string())),
            ("kernel", kernel),
            ("certificate", certificate),
            ("memory", memory),
            ("diagnostics", diagnostics),
        ])
    }
}

/// Run the full static pass: reducibility, certificate, memory
/// precheck (when `budget` is supplied), and lints. Purely structural —
/// no [`CostTables`](crate::cost::CostTables) is ever constructed.
pub fn analyze(
    graph: &CompGraph,
    devices: &DeviceGraph,
    ndev: usize,
    budget: Option<MemBudget>,
) -> AnalysisReport {
    let kernel = replay_eliminations(graph);
    let reducibility = if kernel.nodes.len() <= 2 {
        Reducibility::FullyReducible
    } else {
        Reducibility::Residual { nodes: kernel.nodes.len(), edges: kernel.edges.len() }
    };
    let certificate = certify(graph, &kernel, ndev);
    let memory = budget.map(|b| precheck_memory(graph, ndev, b));
    let diagnostics = lint(graph, devices, ndev);
    AnalysisReport { ndev, reducibility, kernel, certificate, memory, diagnostics }
}

/// The service-side fast gate: the certificate cap plus the memory
/// fast-fail, skipping the lints and per-config feasibility fractions
/// the full [`analyze`] report carries. Called by `PlanService` inside
/// its single-flight build closure, before any cost table exists.
///
/// * A residual enumeration above `cap_log2` answers
///   [`OptError::SearchSpaceExceeded`] (sizes rounded up to whole
///   bits).
/// * A budget no configuration of some layer can satisfy answers
///   [`OptError::Infeasible`] for the lowest-id such layer, with the
///   byte-identical overshoot `CostTables::build_budgeted` would report
///   after building half the tables
///   ([`memory::min_layer_peak_bytes`]'s guarantee).
pub fn precheck(
    graph: &CompGraph,
    ndev: usize,
    budget: Option<MemBudget>,
    cap_log2: f64,
) -> Result<(), OptError> {
    let kernel = replay_eliminations(graph);
    let mut log2 = 0.0f64;
    for &id in &kernel.nodes {
        log2 += (count_configs(&graph.layers[id], ndev) as f64).log2();
    }
    if log2 > cap_log2 {
        return Err(OptError::SearchSpaceExceeded {
            space_log2: log2.ceil() as u32,
            cap_log2: cap_log2.ceil() as u32,
        });
    }
    if let Some(b) = budget {
        for l in &graph.layers {
            let min = memory::min_layer_peak_bytes(l, ndev);
            if !b.admits(min) {
                // the same arithmetic build_budgeted uses: overshoot is
                // the min over configs of (peak - budget), ceiled, >= 1
                return Err(OptError::Infeasible {
                    layer: l.name.clone(),
                    overshoot: (min - b.bytes_per_dev).ceil().max(1.0) as u64,
                });
            }
        }
    }
    Ok(())
}

/// Replay Algorithm 1's elimination fixpoint on graph structure alone:
/// the exact scan order of [`optimizer::optimize`](crate::optimizer::optimize)
/// — node eliminations to exhaustion, then edge eliminations, repeated
/// until neither applies — with `(src, dst)` pairs standing in for the
/// cost matrices. Because the rules only read degrees and endpoints,
/// the surviving node set here *is* the `final_nodes` the real search
/// will enumerate, which is what makes the certificate exact.
fn replay_eliminations(graph: &CompGraph) -> ResidualKernel {
    let n = graph.num_layers();
    let mut alive = vec![true; n];
    // lazy deletion mirrors the optimizer: taken edges become None, and
    // the adjacency lists may point at them (skipped via `live`)
    let mut edges: Vec<Option<(usize, usize)>> =
        graph.edges.iter().map(|&(s, d)| Some((s, d))).collect();
    let mut in_ids: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut out_ids: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (idx, &(s, d)) in graph.edges.iter().enumerate() {
        out_ids[s].push(idx);
        in_ids[d].push(idx);
    }
    let mut in_deg: Vec<usize> = in_ids.iter().map(|v| v.len()).collect();
    let mut out_deg: Vec<usize> = out_ids.iter().map(|v| v.len()).collect();
    let live = |edges: &[Option<(usize, usize)>], idx: usize| edges[idx].is_some();

    let mut node_eliminations = 0;
    let mut edge_eliminations = 0;
    loop {
        let mut changed = false;

        // node eliminations: in-degree 1, out-degree 1
        loop {
            let mut applied = false;
            for j in 0..n {
                if !alive[j] || in_deg[j] != 1 || out_deg[j] != 1 {
                    continue;
                }
                let e1 = in_ids[j].iter().copied().find(|&idx| live(&edges, idx));
                let e2 = out_ids[j].iter().copied().find(|&idx| live(&edges, idx));
                let (Some(e1), Some(e2)) = (e1, e2) else { continue };
                let (i, _) = edges[e1].take().unwrap_or((0, 0));
                let (_, k) = edges[e2].take().unwrap_or((0, 0));
                alive[j] = false;
                in_deg[j] = 0;
                out_deg[j] = 0;
                let new_idx = edges.len();
                edges.push(Some((i, k)));
                out_ids[i].push(new_idx);
                in_ids[k].push(new_idx);
                node_eliminations += 1;
                applied = true;
                changed = true;
                break;
            }
            if !applied {
                break;
            }
        }

        // edge eliminations: parallel edges with identical endpoints
        loop {
            let mut applied = false;
            'outer: for src in 0..n {
                if !alive[src] {
                    continue;
                }
                let live_out: Vec<usize> =
                    out_ids[src].iter().copied().filter(|&idx| live(&edges, idx)).collect();
                for (p, &a) in live_out.iter().enumerate() {
                    for &b in &live_out[p + 1..] {
                        if edges[a].map(|e| e.1) == edges[b].map(|e| e.1) {
                            let dst = edges[a].take().map(|e| e.1).unwrap_or(0);
                            edges[b] = None;
                            let new_idx = edges.len();
                            edges.push(Some((src, dst)));
                            out_ids[src].push(new_idx);
                            in_ids[dst].push(new_idx);
                            in_deg[dst] -= 1;
                            out_deg[src] -= 1;
                            edge_eliminations += 1;
                            applied = true;
                            changed = true;
                            break 'outer;
                        }
                    }
                }
            }
            if !applied {
                break;
            }
        }

        if !changed {
            break;
        }
    }

    let nodes: Vec<LayerId> = (0..n).filter(|&i| alive[i]).collect();
    let mut kernel_edges: Vec<(LayerId, LayerId)> = edges.iter().flatten().copied().collect();
    kernel_edges.sort_unstable();
    kernel_edges.dedup();
    ResidualKernel { nodes, edges: kernel_edges, node_eliminations, edge_eliminations }
}

/// Compose per-layer configuration counts into the exact enumeration
/// sizes of the residual kernel and of the whole graph.
fn certify(graph: &CompGraph, kernel: &ResidualKernel, ndev: usize) -> SearchCertificate {
    let layer_configs: Vec<u64> =
        graph.layers.iter().map(|l| count_configs(l, ndev)).collect();
    let compose = |ids: &mut dyn Iterator<Item = usize>| -> (Option<u128>, f64) {
        let mut space: Option<u128> = Some(1);
        let mut log2 = 0.0f64;
        for id in ids {
            let c = layer_configs[id];
            log2 += (c as f64).log2();
            space = space.and_then(|s| s.checked_mul(c as u128));
        }
        (space, log2)
    };
    let (residual_space, residual_space_log2) =
        compose(&mut kernel.nodes.iter().copied());
    let (full_space, full_space_log2) = compose(&mut (0..graph.num_layers()));
    SearchCertificate {
        layer_configs,
        residual_space,
        residual_space_log2,
        full_space,
        full_space_log2,
    }
}

/// The memory prong: per-layer feasible fractions plus the exact
/// fast-fail verdict (see [`MemoryPrecheck`]).
fn precheck_memory(graph: &CompGraph, ndev: usize, budget: MemBudget) -> MemoryPrecheck {
    let mut per_layer = Vec::with_capacity(graph.num_layers());
    let mut infeasible: Option<(String, u64)> = None;
    for l in &graph.layers {
        let configs = enumerate_configs(l, ndev);
        let feasible = configs
            .iter()
            .filter(|c| budget.admits(memory::layer_peak_bytes(l, c)))
            .count() as u64;
        let min_bytes = memory::min_layer_peak_bytes(l, ndev);
        if feasible == 0 && infeasible.is_none() {
            // the same arithmetic build_budgeted uses, so the verdicts
            // agree bit for bit: min over (peak - budget), ceiled, >= 1
            let overshoot = (min_bytes - budget.bytes_per_dev).ceil().max(1.0) as u64;
            infeasible = Some((l.name.clone(), overshoot));
        }
        per_layer.push(LayerFeasibility { configs: configs.len() as u64, feasible, min_bytes });
    }
    MemoryPrecheck { per_layer, infeasible }
}

/// The lint prong: structural smells a *valid* graph can still carry.
fn lint(graph: &CompGraph, devices: &DeviceGraph, ndev: usize) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if ndev > devices.num_devices() {
        out.push(Diagnostic {
            severity: Severity::Warning,
            code: "over-parallel",
            layer: None,
            message: format!(
                "analysis requested {ndev} devices but the cluster has {}",
                devices.num_devices()
            ),
        });
    }

    // reachability from the input (layer 0). Validated graphs cannot
    // actually strand a layer (every non-input layer has a predecessor
    // and edges only point forward), but the lint is cheap insurance
    // against a future relaxation of those invariants.
    let n = graph.num_layers();
    let mut reachable = vec![false; n];
    if n > 0 {
        reachable[0] = true;
        for &(s, d) in &graph.edges {
            if reachable[s] {
                reachable[d] = true;
            }
        }
    }
    let mut consumed = vec![false; n];
    for &(s, _) in &graph.edges {
        consumed[s] = true;
    }

    let dim_names = ["n", "c", "h", "w"];
    for l in &graph.layers {
        if !reachable[l.id] {
            out.push(Diagnostic {
                severity: Severity::Error,
                code: "unreachable-layer",
                layer: Some(l.id),
                message: format!("layer `{}` is not reachable from the input", l.name),
            });
        }
        // a sink that is not the final layer computes output nobody reads
        if !consumed[l.id] && l.id + 1 != n {
            out.push(Diagnostic {
                severity: Severity::Warning,
                code: "dead-output",
                layer: Some(l.id),
                message: format!(
                    "output of layer `{}` is never consumed (sink before the final layer)",
                    l.name
                ),
            });
        }
        // partitionable dimensions of extent 1 silently shrink the
        // config space — worth knowing, not a mistake
        let allowed = allowed_dims(&l.op);
        for d in 0..l.out_shape.len().min(4) {
            if allowed[d] && l.out_shape[d] == 1 {
                out.push(Diagnostic {
                    severity: Severity::Info,
                    code: "degenerate-dim",
                    layer: Some(l.id),
                    message: format!(
                        "dimension {} of `{}` has extent 1 and cannot be partitioned",
                        dim_names[d], l.name
                    ),
                });
            }
        }
        // window-shape smells on sliding operators
        if let OpKind::Conv2d { kernel, stride, padding, .. }
        | OpKind::Pool2d { kernel, stride, padding, .. } = &l.op
        {
            for (axis, (k, s, p)) in [
                ("rows", (kernel.0, stride.0, padding.0)),
                ("cols", (kernel.1, stride.1, padding.1)),
            ] {
                if s > k {
                    out.push(Diagnostic {
                        severity: Severity::Warning,
                        code: "stride-gap",
                        layer: Some(l.id),
                        message: format!(
                            "`{}` {axis}: stride {s} exceeds kernel {k}, so input is skipped",
                            l.name
                        ),
                    });
                }
                if p >= k {
                    out.push(Diagnostic {
                        severity: Severity::Warning,
                        code: "pad-window",
                        layer: Some(l.id),
                        message: format!(
                            "`{}` {axis}: padding {p} >= kernel {k}, so some windows read \
                             only padding",
                            l.name
                        ),
                    });
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{nets, GraphBuilder};
    use crate::planner::ClusterSpec;

    fn p100(n: usize) -> DeviceGraph {
        #[allow(clippy::unwrap_used)]
        ClusterSpec::p100(n).unwrap().device_graph().unwrap()
    }

    #[test]
    fn chains_and_benchmarks_are_fully_reducible() {
        for name in ["lenet5", "alexnet", "vgg16", "inception_v3", "resnet18"] {
            let g = nets::by_name(name, 64).unwrap();
            let d = p100(2);
            let r = analyze(&g, &d, 2, None);
            assert_eq!(r.reducibility, Reducibility::FullyReducible, "{name}");
            assert!(r.kernel.nodes.len() <= 2, "{name}");
            assert_eq!(r.errors(), 0, "{name}");
        }
    }

    #[test]
    fn replay_matches_the_real_optimizer_fixpoint() {
        use crate::cost::{CostModel, CostTables};
        for name in ["lenet5", "inception_v3", "resnet18"] {
            let g = nets::by_name(name, 64).unwrap();
            let d = p100(2);
            let kernel = replay_eliminations(&g);
            let t = CostTables::build(&CostModel::new(&g, &d), 2).unwrap();
            let opt = crate::optimizer::optimize(&t);
            assert_eq!(kernel.nodes.len(), opt.stats.final_nodes, "{name}");
            assert_eq!(kernel.node_eliminations, opt.stats.node_eliminations, "{name}");
            assert_eq!(kernel.edge_eliminations, opt.stats.edge_eliminations, "{name}");
        }
    }

    /// A diamond whose branches each split again — node elimination
    /// never applies to the inner fan nodes (in 1 / out 2 or in 2 /
    /// out 1 at best after merges), leaving a >2-node kernel.
    fn irreducible() -> CompGraph {
        let mut b = GraphBuilder::new("irreducible");
        let x = b.input(4, 4, 8, 8).unwrap();
        let a = b.conv2d("a", x, 4, (1, 1), (1, 1), (0, 0)).unwrap();
        let c = b.conv2d("c", x, 4, (1, 1), (1, 1), (0, 0)).unwrap();
        // cross links: a and c each feed BOTH joins, so neither join's
        // in-edges can collapse pairwise and no node has degree (1,1)
        let j1 = b.add("j1", a, c).unwrap();
        let j2 = b.concat("j2", &[a, c]).unwrap();
        let m1 = b.conv2d("m1", j1, 4, (1, 1), (1, 1), (0, 0)).unwrap();
        let m2 = b.conv2d("m2", j2, 4, (1, 1), (1, 1), (0, 0)).unwrap();
        let t1 = b.add("t1", m1, m2).unwrap();
        let t2 = b.concat("t2", &[m1, m2]).unwrap();
        let z = b.concat("z", &[t1, t2]).unwrap();
        let f = b.fully_connected("fc", z, 10).unwrap();
        b.softmax("sm", f).unwrap();
        b.finish().unwrap()
    }

    #[test]
    fn cross_linked_branches_classify_residual() {
        let g = irreducible();
        let d = p100(2);
        let r = analyze(&g, &d, 2, None);
        match r.reducibility {
            Reducibility::Residual { nodes, edges } => {
                assert!(nodes > 2, "kernel has {nodes} nodes");
                assert!(edges > 0);
                assert_eq!(nodes, r.kernel.nodes.len());
                assert_eq!(edges, r.kernel.edges.len());
            }
            other => panic!("expected Residual, got {other:?}"),
        }
        // the kernel's edges connect kernel nodes only
        for &(s, d) in &r.kernel.edges {
            assert!(r.kernel.nodes.contains(&s) && r.kernel.nodes.contains(&d));
        }
    }

    #[test]
    fn certificate_composes_counting_twin_exactly() {
        let g = nets::minicnn(32).unwrap();
        let d = p100(4);
        let r = analyze(&g, &d, 4, None);
        for (l, &count) in g.layers.iter().zip(&r.certificate.layer_configs) {
            assert_eq!(count, enumerate_configs(l, 4).len() as u64, "{}", l.name);
        }
        let full: u128 =
            r.certificate.layer_configs.iter().map(|&c| c as u128).product();
        assert_eq!(r.certificate.full_space, Some(full));
        let residual: u128 = r
            .kernel
            .nodes
            .iter()
            .map(|&i| r.certificate.layer_configs[i] as u128)
            .product();
        assert_eq!(r.certificate.residual_space, Some(residual));
        assert!(r.certificate.residual_space_log2 <= r.certificate.full_space_log2);
        assert!((r.certificate.residual_space_log2 - (residual as f64).log2()).abs() < 1e-9);
    }

    #[test]
    fn memory_precheck_reports_fractions_and_feasibility() {
        let g = nets::lenet5(32).unwrap();
        let d = p100(2);
        // a roomy budget admits everything
        let roomy = analyze(&g, &d, 2, Some(MemBudget::new(u64::MAX)));
        let m = roomy.memory.as_ref().unwrap();
        assert!(m.infeasible.is_none());
        assert!(m.per_layer.iter().all(|f| f.feasible == f.configs && f.configs > 0));
        // one byte admits nothing: the verdict names the lowest-id layer
        let broke = analyze(&g, &d, 2, Some(MemBudget::new(1)));
        let m = broke.memory.as_ref().unwrap();
        let (layer, overshoot) = m.infeasible.as_ref().unwrap();
        assert_eq!(layer, &g.layers[0].name, "lowest-id infeasible layer wins");
        assert!(*overshoot >= 1);
        assert!(matches!(m.to_error(), Some(OptError::Infeasible { .. })));
    }

    #[test]
    fn lints_fire_on_designed_smells_and_not_on_builtins() {
        for name in ["lenet5", "alexnet", "vgg16", "inception_v3", "resnet18", "minicnn"] {
            let g = nets::by_name(name, 64).unwrap();
            let d = p100(2);
            let r = analyze(&g, &d, 2, None);
            assert_eq!(r.errors(), 0, "{name}: {:?}", r.diagnostics);
            assert_eq!(r.warnings(), 0, "{name}: {:?}", r.diagnostics);
        }

        // dead output: a branch nobody consumes, before the final layer
        let mut b = GraphBuilder::new("dead");
        let x = b.input(4, 3, 8, 8).unwrap();
        let _orphan = b.conv2d("orphan", x, 4, (3, 3), (1, 1), (1, 1)).unwrap();
        let keep = b.conv2d("keep", x, 4, (3, 3), (1, 1), (1, 1)).unwrap();
        let f = b.fully_connected("fc", keep, 10).unwrap();
        b.softmax("sm", f).unwrap();
        let g = b.finish().unwrap();
        let r = analyze(&g, &p100(2), 2, None);
        assert!(
            r.diagnostics.iter().any(|d| d.code == "dead-output" && d.layer == Some(1)),
            "{:?}",
            r.diagnostics
        );

        // stride-gap and pad-window on a hand-built conv
        let mut b = GraphBuilder::new("smelly");
        let x = b.input(2, 3, 16, 16).unwrap();
        let c = b.conv2d("skippy", x, 4, (2, 2), (3, 3), (0, 0)).unwrap();
        let c2 = b.conv2d("matted", c, 4, (3, 3), (1, 1), (3, 3)).unwrap();
        let f = b.fully_connected("fc", c2, 10).unwrap();
        b.softmax("sm", f).unwrap();
        let g = b.finish().unwrap();
        let r = analyze(&g, &p100(2), 2, None);
        assert!(r.diagnostics.iter().any(|d| d.code == "stride-gap"), "{:?}", r.diagnostics);
        assert!(r.diagnostics.iter().any(|d| d.code == "pad-window"), "{:?}", r.diagnostics);

        // degenerate-dim is informational
        let mut b = GraphBuilder::new("thin");
        let x = b.input(1, 3, 8, 8).unwrap();
        let c = b.conv2d("c", x, 4, (3, 3), (1, 1), (1, 1)).unwrap();
        let f = b.fully_connected("fc", c, 10).unwrap();
        b.softmax("sm", f).unwrap();
        let g = b.finish().unwrap();
        let r = analyze(&g, &p100(2), 2, None);
        let deg: Vec<_> =
            r.diagnostics.iter().filter(|d| d.code == "degenerate-dim").collect();
        assert!(!deg.is_empty(), "batch 1 must flag the n dimension");
        assert!(deg.iter().all(|d| d.severity == Severity::Info));
    }

    #[test]
    fn over_parallel_requests_warn() {
        let g = nets::lenet5(32).unwrap();
        let r = analyze(&g, &p100(2), 8, None);
        assert!(r.diagnostics.iter().any(|d| d.code == "over-parallel"));
    }
}
