//! Baseline parallelization strategies (paper §6, "Baselines").
//!
//! * **Data parallelism** — every layer partitions the sample dimension
//!   across all devices.
//! * **Model parallelism** — every layer partitions its output-channel
//!   dimension (Krizhevsky 2014's variant: parameters spread equally).
//! * **OWT** ("one weird trick") — data parallelism for conv/pool layers,
//!   model parallelism for densely-connected layers.
//!
//! Degrees are clipped to the largest legal divisor of the relevant
//! extent, so every produced strategy is valid for the given graph.

use crate::graph::{CompGraph, Layer, OpKind};
use crate::parallel::{PConfig, Strategy};

/// Largest divisor of `extent` that is `<= cap`.
fn largest_divisor_leq(extent: usize, cap: usize) -> usize {
    (1..=cap.min(extent)).rev().find(|d| extent % d == 0).unwrap_or(1)
}

fn sample_cfg(layer: &Layer, ndev: usize) -> PConfig {
    PConfig::new(largest_divisor_leq(layer.out_shape[0], ndev), 1, 1, 1)
}

fn channel_cfg(layer: &Layer, ndev: usize) -> PConfig {
    PConfig::new(1, largest_divisor_leq(layer.out_shape[1], ndev), 1, 1)
}

/// Pure data parallelism on `ndev` devices.
pub fn data_parallel(g: &CompGraph, ndev: usize) -> Strategy {
    Strategy { configs: g.layers.iter().map(|l| sample_cfg(l, ndev)).collect() }
}

/// Pure model (channel) parallelism on `ndev` devices. Layers that cannot
/// partition channels (input, softmax) fall back to sample partitioning.
pub fn model_parallel(g: &CompGraph, ndev: usize) -> Strategy {
    Strategy {
        configs: g
            .layers
            .iter()
            .map(|l| match l.op {
                OpKind::Input | OpKind::Softmax => sample_cfg(l, ndev),
                _ => channel_cfg(l, ndev),
            })
            .collect(),
    }
}

/// "One weird trick" (Krizhevsky 2014): data parallelism for
/// convolutional/pooling layers, model parallelism for fully-connected
/// layers.
pub fn owt(g: &CompGraph, ndev: usize) -> Strategy {
    Strategy {
        configs: g
            .layers
            .iter()
            .map(|l| match l.op {
                OpKind::FullyConnected { .. } => channel_cfg(l, ndev),
                _ => sample_cfg(l, ndev),
            })
            .collect(),
    }
}

/// Look up a named baseline (CLI entry point). `layerwise` is handled by
/// the optimizer, not here.
pub fn by_name(name: &str, g: &CompGraph, ndev: usize) -> Option<Strategy> {
    match name {
        "data" => Some(data_parallel(g, ndev)),
        "model" => Some(model_parallel(g, ndev)),
        "owt" => Some(owt(g, ndev)),
        _ => None,
    }
}

/// The strategies compared throughout the paper's evaluation.
pub const BASELINE_NAMES: [&str; 3] = ["data", "model", "owt"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, CostTables};
    use crate::device::DeviceGraph;
    use crate::graph::nets;

    #[test]
    fn data_parallel_uses_all_devices_on_every_layer() {
        let g = nets::alexnet(32 * 4).unwrap();
        let s = data_parallel(&g, 4);
        assert!(s.configs.iter().all(|c| c.deg[0] == 4 && c.total() == 4));
    }

    #[test]
    fn owt_switches_for_fc_layers() {
        let g = nets::vgg16(32 * 4).unwrap();
        let s = owt(&g, 4);
        for l in &g.layers {
            let c = s.config(l.id);
            match l.op {
                OpKind::FullyConnected { .. } => {
                    assert_eq!(c.deg[1], 4, "{} should be channel-split", l.name)
                }
                _ => assert_eq!(c.deg[1], 1, "{} should be sample-split", l.name),
            }
        }
    }

    #[test]
    fn model_parallel_shards_every_param_layer() {
        let g = nets::alexnet(32 * 8).unwrap();
        let s = model_parallel(&g, 8);
        for l in &g.layers {
            if l.has_params() {
                assert!(s.config(l.id).deg[1] > 1, "{} unsharded", l.name);
            }
        }
    }

    #[test]
    fn degrees_respect_divisibility() {
        // batch 96 on 16 devices: 16 divides 96? no (96/16=6, yes it does).
        // Try odd extents: lenet conv1 has 6 channels; channel degree on 4
        // devices must clip to 3.
        let g = nets::lenet5(32).unwrap();
        let s = model_parallel(&g, 4);
        let conv1 = g.layers.iter().find(|l| l.name == "conv1").unwrap();
        assert_eq!(s.config(conv1.id).deg[1], 3);
    }

    #[test]
    fn baselines_are_legal_configs() {
        for ndev in [2usize, 4] {
            let g = nets::inception_v3(32 * ndev).unwrap();
            let d = DeviceGraph::p100_cluster(ndev).unwrap();
            let t = CostTables::build(&CostModel::new(&g, &d), ndev).unwrap();
            for name in BASELINE_NAMES {
                let s = by_name(name, &g, ndev).unwrap();
                for (l, c) in s.configs.iter().enumerate() {
                    assert!(
                        t.index_of(l, c).is_some(),
                        "{name}: illegal config {} for layer {}",
                        c.label(),
                        g.layer(l).name
                    );
                }
            }
        }
    }
}
