//! Exhaustive depth-first baseline (paper Table 3's comparison point).
//!
//! Enumerates every strategy (`O(E·C^N)`) with branch-and-bound pruning
//! and an optional wall-clock deadline, exactly the baseline the paper
//! reports taking `> 24 hours` on VGG-16 / Inception-v3.

use std::time::{Duration, Instant};

use crate::cost::CostTables;
use crate::parallel::Strategy;

/// Outcome of a (possibly truncated) exhaustive search.
#[derive(Debug, Clone)]
pub struct DfsResult {
    /// Best complete strategy found (None only if the deadline fired
    /// before any leaf was reached).
    pub strategy: Option<Strategy>,
    pub cost: f64,
    /// Whether the search space was fully explored.
    pub complete: bool,
    /// Search-tree nodes visited.
    pub visited: u64,
}

struct Dfs<'a> {
    tables: &'a CostTables,
    /// For layer `l`: edge-table indices whose dst == l (src < l always).
    in_edges: Vec<Vec<usize>>,
    deadline: Option<Instant>,
    best: f64,
    best_idx: Vec<usize>,
    sel: Vec<usize>,
    visited: u64,
    timed_out: bool,
}

/// Exhaustively search for the optimal strategy. `budget = None` means run
/// to completion (only sensible for small graphs).
pub fn dfs_optimal(tables: &CostTables, budget: Option<Duration>) -> DfsResult {
    let n = tables.configs.len();
    let mut in_edges = vec![Vec::new(); n];
    for (ei, e) in tables.edges.iter().enumerate() {
        debug_assert!(e.src < e.dst, "edges must be topological");
        in_edges[e.dst].push(ei);
    }
    let mut s = Dfs {
        tables,
        in_edges,
        deadline: budget.map(|b| Instant::now() + b),
        best: f64::INFINITY,
        best_idx: vec![0; n],
        sel: vec![0; n],
        visited: 0,
        timed_out: false,
    };
    s.recurse(0, 0.0);
    DfsResult {
        strategy: if s.best.is_finite() {
            Some(tables.strategy_from_indices(&s.best_idx))
        } else {
            None
        },
        cost: s.best,
        complete: !s.timed_out,
        visited: s.visited,
    }
}

impl<'a> Dfs<'a> {
    fn recurse(&mut self, layer: usize, acc: f64) {
        if self.timed_out || acc >= self.best {
            return;
        }
        self.visited += 1;
        // Deadline checks are amortized: every 4096 visits.
        if self.visited & 0xFFF == 0 {
            if let Some(d) = self.deadline {
                if Instant::now() >= d {
                    self.timed_out = true;
                    return;
                }
            }
        }
        if layer == self.tables.configs.len() {
            self.best = acc;
            self.best_idx.copy_from_slice(&self.sel);
            return;
        }
        for c in 0..self.tables.num_configs(layer) {
            self.sel[layer] = c;
            let mut add = self.tables.node_cost[layer][c];
            for &ei in &self.in_edges[layer] {
                let e = &self.tables.edges[ei];
                add += e.at(self.sel[e.src], c, self.tables.num_configs(layer));
            }
            self.recurse(layer + 1, acc + add);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, CostTables};
    use crate::device::DeviceGraph;
    use crate::graph::nets;

    #[test]
    fn dfs_completes_on_lenet() {
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let t = CostTables::build(&CostModel::new(&g, &d), 2).unwrap();
        let r = dfs_optimal(&t, None);
        assert!(r.complete);
        let s = r.strategy.unwrap();
        assert_eq!(s.configs.len(), g.num_layers());
    }

    #[test]
    fn deadline_truncates_large_search() {
        let g = nets::vgg16(128).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let t = CostTables::build(&CostModel::new(&g, &d), 4).unwrap();
        let r = dfs_optimal(&t, Some(Duration::from_millis(50)));
        assert!(!r.complete, "VGG-16 at 4 devices must not finish in 50ms");
        assert!(r.visited > 0);
    }

    #[test]
    fn dfs_cost_consistent_with_tables() {
        let g = nets::lenet5(32).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let t = CostTables::build(&CostModel::new(&g, &d), 2).unwrap();
        let r = dfs_optimal(&t, None);
        let idx: Vec<usize> = r
            .strategy
            .as_ref()
            .unwrap()
            .configs
            .iter()
            .enumerate()
            .map(|(l, c)| t.index_of(l, c).unwrap())
            .collect();
        assert!((t.strategy_cost(&idx) - r.cost).abs() < 1e-9 * r.cost.max(1.0));
    }
}
