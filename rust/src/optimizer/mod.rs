//! Strategy search (paper §5.2, Algorithm 1).
//!
//! The optimizer operates on precomputed [`CostTables`]: it iteratively
//! applies **node elimination** (Eq. 2) and **edge elimination** (Eq. 3)
//! until a fixpoint, enumerates all strategies for the reduced graph
//! (`K` nodes, typically 2), then undoes the eliminations in reverse
//! order, materializing the optimal configuration for every eliminated
//! node from the recorded `argmin` tables (Theorems 1 & 2).
//!
//! Complexity: `O(E·C³ + K·C^K)` (Table 2), versus `O(E·C^N)` for the
//! exhaustive DFS baseline in [`dfs`].

pub mod dfs;
pub mod strategies;

use crate::cost::{CostTables, EdgeTable};
use crate::parallel::Strategy;

/// Search statistics for the Table 2/3 analysis.
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    pub node_eliminations: usize,
    pub edge_eliminations: usize,
    /// Nodes remaining in the final graph (the paper's `K`).
    pub final_nodes: usize,
    /// Complete strategies for the final graph whose total cost was
    /// evaluated. Branch-and-bound prunes *partial* assignments that
    /// provably cannot improve, so this can be below the full `C^K`
    /// product, but every assignment that reaches a leaf is counted —
    /// improving or not. (For the DFS backend this is the search-tree
    /// node count instead; see `backend::ExhaustiveDfs`.)
    pub enumerated: u64,
    /// The exact size of the space the search ranged over — the
    /// [`analyze`](crate::analyze) certificate's number: the product of
    /// per-node config counts over the *final* (post-elimination) graph
    /// here, or over every layer for the DFS backend. `None` when the
    /// product overflows `u128`. Always `enumerated <= space_size` for
    /// this backend (branch-and-bound only prunes), with equality when
    /// no partial assignment is pruned.
    pub space_size: Option<u128>,
}

/// An optimal strategy under the cost model, with provenance.
#[derive(Debug, Clone)]
pub struct Optimized {
    pub strategy: Strategy,
    /// `t_O` of the strategy (seconds/step under Equation 1).
    pub cost: f64,
    pub stats: SearchStats,
}

/// A working edge: endpoints plus a dense `C_src x C_dst` cost matrix.
#[derive(Debug, Clone)]
struct WEdge {
    src: usize,
    dst: usize,
    cost: Vec<f64>,
}

/// Undo records for the reconstruction phase (Algorithm 1, lines 15-23).
enum Undo {
    Node {
        /// The eliminated node and its neighbors.
        j: usize,
        i: usize,
        k: usize,
        /// `argmin_cj` table indexed `[ci * C_k + ck]`.
        argmin: Vec<u32>,
    },
    Edge,
}

/// Result of running the elimination fixpoint (Algorithm 1, lines 4-13)
/// without the final enumeration: the surviving nodes, the live merged
/// edges, and the undo log that reconstructs eliminated nodes.
struct Eliminated {
    alive: Vec<bool>,
    edges: Vec<Option<WEdge>>,
    undo: Vec<Undo>,
    node_eliminations: usize,
    edge_eliminations: usize,
}

/// The residual kernel after node/edge elimination, renumbered as a
/// standalone table set. This is the (PR 8) "residual kernel" the
/// differential backend cross-check (`audit::cross_check`) searches
/// exhaustively: it is small (typically 2 nodes for the builtins) where
/// the full graph is not, yet by Theorems 1 & 2 its optimum extends to
/// the full graph's.
pub struct ReducedProblem {
    /// Original layer ids of the kernel nodes, ascending; position `p`
    /// in this list is node `p` of the reduced tables.
    pub nodes: Vec<usize>,
    /// Cost tables over just the kernel, node ids renumbered to
    /// `0..nodes.len()`, merged edge matrices carried verbatim.
    pub tables: CostTables,
}

/// Run the elimination fixpoint and package the residual kernel as
/// standalone tables (see [`ReducedProblem`]). The kernel's optimal cost
/// equals the full problem's minus the eliminated nodes' folded
/// contributions — already baked into the merged edge matrices — so both
/// backends can be run over it cheaply and compared.
pub fn reduce(tables: &CostTables) -> ReducedProblem {
    let n = tables.configs.len();
    let elim = eliminate(tables);
    let nodes: Vec<usize> = (0..n).filter(|&i| elim.alive[i]).collect();
    let mut pos = vec![usize::MAX; n];
    for (p, &node) in nodes.iter().enumerate() {
        pos[node] = p;
    }
    let configs = nodes.iter().map(|&i| tables.configs[i].clone()).collect();
    let node_cost = nodes.iter().map(|&i| tables.node_cost[i].clone()).collect();
    let edges = elim
        .edges
        .iter()
        .flatten()
        .map(|e| EdgeTable { src: pos[e.src], dst: pos[e.dst], cost: e.cost.clone() })
        .collect();
    ReducedProblem {
        nodes,
        tables: CostTables {
            configs,
            node_cost,
            edges,
            ndev: tables.ndev,
            budget: tables.budget,
        },
    }
}

/// The elimination fixpoint shared by [`optimize`] and [`reduce`].
fn eliminate(tables: &CostTables) -> Eliminated {
    let n = tables.configs.len();
    let ncfg: Vec<usize> = (0..n).map(|l| tables.num_configs(l)).collect();
    let node_cost: Vec<&[f64]> = tables.node_cost.iter().map(|v| v.as_slice()).collect();

    let mut alive = vec![true; n];
    let mut edges: Vec<Option<WEdge>> = tables
        .edges
        .iter()
        .map(|e| Some(WEdge { src: e.src, dst: e.dst, cost: e.cost.clone() }))
        .collect();
    let mut undo: Vec<Undo> = Vec::new();
    let mut node_eliminations = 0usize;
    let mut edge_eliminations = 0usize;

    // Adjacency indices over alive edges (edge ids per endpoint): keeps
    // both elimination scans O(degree) instead of O(E) (§Perf log #4).
    let mut in_ids: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut out_ids: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (idx, e) in edges.iter().enumerate() {
        let e = e.as_ref().unwrap();
        out_ids[e.src].push(idx);
        in_ids[e.dst].push(idx);
    }
    let mut in_deg: Vec<usize> = in_ids.iter().map(|v| v.len()).collect();
    let mut out_deg: Vec<usize> = out_ids.iter().map(|v| v.len()).collect();
    // lazy deletion: entries in in_ids/out_ids may point at taken edges;
    // skip those when scanning.
    let live = |edges: &[Option<WEdge>], idx: usize| edges[idx].is_some();

    // --- Elimination fixpoint (lines 4-13) ---
    loop {
        let mut changed = false;

        // Node eliminations: nodes with exactly one in-edge and one
        // out-edge. Scan until none applies.
        loop {
            let mut applied = false;
            for j in 0..n {
                if !alive[j] || in_deg[j] != 1 || out_deg[j] != 1 {
                    continue;
                }
                let e1_idx = *in_ids[j].iter().find(|&&idx| live(&edges, idx)).unwrap();
                let e2_idx = *out_ids[j].iter().find(|&&idx| live(&edges, idx)).unwrap();
                let i = edges[e1_idx].as_ref().unwrap().src;
                let k = edges[e2_idx].as_ref().unwrap().dst;
                debug_assert_ne!(i, k, "DAG cannot route i->j->i");

                let (ci_n, cj_n, ck_n) = (ncfg[i], ncfg[j], ncfg[k]);
                let e1 = edges[e1_idx].take().unwrap();
                let e2 = edges[e2_idx].take().unwrap();
                let nj = node_cost[j];

                // Eq. 2: e'(ci, ck) = min_cj nj(cj) + e1(ci,cj) + e2(cj,ck)
                let mut cost = vec![f64::INFINITY; ci_n * ck_n];
                let mut argmin = vec![0u32; ci_n * ck_n];
                for ci in 0..ci_n {
                    let e1_row = &e1.cost[ci * cj_n..(ci + 1) * cj_n];
                    for cj in 0..cj_n {
                        let base = nj[cj] + e1_row[cj];
                        let e2_row = &e2.cost[cj * ck_n..(cj + 1) * ck_n];
                        let out = &mut cost[ci * ck_n..(ci + 1) * ck_n];
                        let arg = &mut argmin[ci * ck_n..(ci + 1) * ck_n];
                        for ck in 0..ck_n {
                            let v = base + e2_row[ck];
                            if v < out[ck] {
                                out[ck] = v;
                                arg[ck] = cj as u32;
                            }
                        }
                    }
                }

                alive[j] = false;
                in_deg[j] = 0;
                out_deg[j] = 0;
                let new_idx = edges.len();
                edges.push(Some(WEdge { src: i, dst: k, cost }));
                // degrees: i loses out-edge to j but gains one to k (net
                // zero); same for k's in-degree. Index the new edge.
                out_ids[i].push(new_idx);
                in_ids[k].push(new_idx);
                undo.push(Undo::Node { j, i, k, argmin });
                node_eliminations += 1;
                applied = true;
                changed = true;
                break;
            }
            if !applied {
                break;
            }
        }

        // Edge eliminations: parallel edges with identical endpoints.
        // Scan each node's live out-edges grouped by destination.
        loop {
            let mut applied = false;
            'outer: for src in 0..n {
                if !alive[src] {
                    continue;
                }
                let live_out: Vec<usize> =
                    out_ids[src].iter().copied().filter(|&idx| live(&edges, idx)).collect();
                for (p, &a) in live_out.iter().enumerate() {
                    for &b in &live_out[p + 1..] {
                        if edges[a].as_ref().unwrap().dst == edges[b].as_ref().unwrap().dst {
                            let ea = edges[a].take().unwrap();
                            let eb = edges[b].take().unwrap();
                            let dst = ea.dst;
                            // Eq. 3: sum the matrices.
                            let cost: Vec<f64> =
                                ea.cost.iter().zip(eb.cost.iter()).map(|(x, y)| x + y).collect();
                            let new_idx = edges.len();
                            edges.push(Some(WEdge { src, dst, cost }));
                            out_ids[src].push(new_idx);
                            in_ids[dst].push(new_idx);
                            in_deg[dst] -= 1;
                            out_deg[src] -= 1;
                            undo.push(Undo::Edge);
                            edge_eliminations += 1;
                            applied = true;
                            changed = true;
                            break 'outer;
                        }
                    }
                }
            }
            if !applied {
                break;
            }
        }

        if !changed {
            break;
        }
    }

    Eliminated { alive, edges, undo, node_eliminations, edge_eliminations }
}

/// Run Algorithm 1 on prebuilt cost tables.
pub fn optimize(tables: &CostTables) -> Optimized {
    let n = tables.configs.len();
    let ncfg: Vec<usize> = (0..n).map(|l| tables.num_configs(l)).collect();
    let node_cost: Vec<&[f64]> = tables.node_cost.iter().map(|v| v.as_slice()).collect();

    let Eliminated { alive, edges, undo, node_eliminations, edge_eliminations } =
        eliminate(tables);
    let mut stats =
        SearchStats { node_eliminations, edge_eliminations, ..SearchStats::default() };

    // --- Enumerate the final graph (line 14) ---
    let final_nodes: Vec<usize> = (0..n).filter(|&i| alive[i]).collect();
    stats.final_nodes = final_nodes.len();
    stats.space_size = final_nodes
        .iter()
        .try_fold(1u128, |acc, &node| acc.checked_mul(ncfg[node] as u128));
    let final_edges: Vec<&WEdge> = edges.iter().flatten().collect();

    let mut chosen = vec![0usize; n];
    let mut best = f64::INFINITY;
    let mut best_sel = vec![0usize; final_nodes.len()];
    let mut sel = vec![0usize; final_nodes.len()];
    // position of each node in final_nodes (for edge lookups)
    let mut pos = vec![usize::MAX; n];
    for (p, &node) in final_nodes.iter().enumerate() {
        pos[node] = p;
    }
    enumerate_final(
        &final_nodes,
        &final_edges,
        &node_cost,
        &ncfg,
        &pos,
        0,
        0.0,
        &mut sel,
        &mut best,
        &mut best_sel,
        &mut stats.enumerated,
    );
    for (p, &node) in final_nodes.iter().enumerate() {
        chosen[node] = best_sel[p];
    }

    // --- Undo phase (lines 15-23) ---
    for u in undo.iter().rev() {
        if let Undo::Node { j, i, k, argmin } = u {
            let ck_n = ncfg[*k];
            chosen[*j] = argmin[chosen[*i] * ck_n + chosen[*k]] as usize;
        }
    }

    let cost = tables.strategy_cost(&chosen);
    debug_assert!(
        (cost - best).abs() <= 1e-9 * best.max(1.0),
        "reconstructed strategy cost {cost} != DP cost {best}"
    );
    Optimized { strategy: tables.strategy_from_indices(&chosen), cost, stats }
}

/// Depth-first product enumeration over the final graph's nodes with
/// branch-and-bound pruning (costs are nonnegative).
#[allow(clippy::too_many_arguments)]
fn enumerate_final(
    nodes: &[usize],
    edges: &[&WEdge],
    node_cost: &[&[f64]],
    ncfg: &[usize],
    pos: &[usize],
    depth: usize,
    acc: f64,
    sel: &mut Vec<usize>,
    best: &mut f64,
    best_sel: &mut Vec<usize>,
    enumerated: &mut u64,
) {
    if depth == nodes.len() {
        // Count every complete assignment whose cost was computed, not
        // just the improving ones — `SearchStats.enumerated` reports
        // enumeration work (Table 3), which must not depend on how
        // often the incumbent happened to improve.
        *enumerated += 1;
        if acc < *best {
            *best = acc;
            best_sel.copy_from_slice(sel);
        }
        return;
    }
    if acc >= *best {
        return; // prune
    }
    let node = nodes[depth];
    for c in 0..ncfg[node] {
        sel[depth] = c;
        let mut add = node_cost[node][c];
        // edges whose both endpoints are now assigned
        for e in edges {
            let (ps, pd) = (pos[e.src], pos[e.dst]);
            if ps.max(pd) == depth {
                let (cs, cd) = (sel[ps], sel[pd]);
                add += e.cost[cs * ncfg[e.dst] + cd];
            }
        }
        enumerate_final(
            nodes, edges, node_cost, ncfg, pos, depth + 1, acc + add, sel, best, best_sel,
            enumerated,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostModel;
    use crate::device::DeviceGraph;
    use crate::graph::nets;

    fn tables_for(net: &str, ndev: usize) -> CostTables {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        CostTables::build(&cm, ndev).unwrap()
    }

    #[test]
    fn chain_network_reduces_to_two_nodes() {
        let t = tables_for("lenet5", 2);
        let r = optimize(&t);
        assert_eq!(r.stats.final_nodes, 2, "chains must collapse to K=2");
        assert!(r.cost.is_finite());
    }

    #[test]
    fn inception_reduces_to_two_nodes() {
        let t = tables_for("inception_v3", 2);
        let r = optimize(&t);
        assert_eq!(r.stats.final_nodes, 2, "paper: K=2 for Inception-v3");
        assert!(r.stats.edge_eliminations > 0, "branches require edge elims");
    }

    #[test]
    fn resnet_reduces_to_two_nodes() {
        let t = tables_for("resnet18", 2);
        let r = optimize(&t);
        assert_eq!(r.stats.final_nodes, 2, "paper: K=2 for ResNet too");
    }

    #[test]
    fn dp_matches_exhaustive_on_lenet() {
        // Theorem 1+2 end-to-end: the DP optimum equals brute force.
        let t = tables_for("lenet5", 2);
        let dp = optimize(&t);
        let brute = dfs::dfs_optimal(&t, None);
        assert!(brute.complete);
        assert!(
            (dp.cost - brute.cost).abs() <= 1e-9 * brute.cost,
            "dp {} vs dfs {}",
            dp.cost,
            brute.cost
        );
    }

    #[test]
    fn optimum_beats_or_ties_baselines() {
        for ndev in [2usize, 4] {
            let g = nets::alexnet(32 * ndev).unwrap();
            let d = DeviceGraph::p100_cluster(ndev).unwrap();
            let cm = CostModel::new(&g, &d);
            let t = CostTables::build(&cm, ndev).unwrap();
            let opt = optimize(&t);
            for s in [
                strategies::data_parallel(&g, ndev),
                strategies::model_parallel(&g, ndev),
                strategies::owt(&g, ndev),
            ] {
                let c = cm.t_o(&s);
                assert!(
                    opt.cost <= c * (1.0 + 1e-9),
                    "optimal {} must not exceed baseline {}",
                    opt.cost,
                    c
                );
            }
        }
    }

    #[test]
    fn enumerated_counts_visited_assignments_not_improvements() {
        // Hand-built 2-node tables with a zero edge matrix pin the
        // semantics exactly. Enumeration order (configs ascending):
        //   (c0=0, c1=0) cost 0+1 = 1  -> leaf, improving
        //   (c0=0, c1=1) cost 0+5 = 5  -> leaf, NOT improving
        //   (c0=1, ...)  partial 10    -> pruned before any leaf
        // `enumerated` must count the two visited complete assignments;
        // the old increment-on-improvement reported 1.
        use crate::cost::EdgeTable;
        use crate::parallel::PConfig;
        let two = || vec![PConfig::serial(), PConfig::data(2)];
        let tables = CostTables {
            configs: vec![two(), two()],
            node_cost: vec![vec![0.0, 10.0], vec![1.0, 5.0]],
            edges: vec![EdgeTable { src: 0, dst: 1, cost: vec![0.0; 4] }],
            ndev: 2,
            budget: None,
        };
        let r = optimize(&tables);
        assert_eq!(r.stats.final_nodes, 2);
        assert_eq!(r.stats.enumerated, 2, "visited assignments, not improvements");
        // the certificate reports the whole 2x2 space even though one
        // branch was pruned
        assert_eq!(r.stats.space_size, Some(4));
        assert!((r.cost - 1.0).abs() < 1e-12);
        assert_eq!(r.strategy.configs, vec![PConfig::serial(), PConfig::serial()]);
    }

    #[test]
    fn space_size_certifies_the_final_enumeration_exactly_when_nothing_prunes() {
        // Zero node-0 costs keep every partial assignment strictly below
        // the incumbent, so branch-and-bound never fires and the visited
        // leaf count must equal the certified product.
        use crate::cost::EdgeTable;
        use crate::parallel::PConfig;
        let three = || vec![PConfig::serial(), PConfig::data(2), PConfig::data(4)];
        let tables = CostTables {
            configs: vec![three(), three()],
            node_cost: vec![vec![0.0; 3], vec![1.0, 5.0, 9.0]],
            edges: vec![EdgeTable { src: 0, dst: 1, cost: vec![0.0; 9] }],
            ndev: 4,
            budget: None,
        };
        let r = optimize(&tables);
        assert_eq!(r.stats.space_size, Some(9));
        assert_eq!(r.stats.enumerated, 9, "no prune: every leaf is visited");
    }

    #[test]
    fn reduced_kernel_optimum_matches_full_search() {
        // `reduce` must preserve the optimum: the folded edge matrices
        // carry the eliminated nodes' contributions, so an exhaustive
        // search over the kernel alone lands on the full problem's
        // optimal cost *and* (both searches are lexicographic-first)
        // the same kernel-node assignments.
        for net in ["lenet5", "alexnet", "resnet18"] {
            let t = tables_for(net, 2);
            let full = optimize(&t);
            let red = reduce(&t);
            assert_eq!(red.nodes.len(), full.stats.final_nodes);
            assert_eq!(red.tables.configs.len(), red.nodes.len());
            let brute = dfs::dfs_optimal(&red.tables, None);
            assert!(brute.complete);
            assert!(
                (full.cost - brute.cost).abs() <= 1e-9 * full.cost,
                "{net}: full {} vs kernel {}",
                full.cost,
                brute.cost
            );
            let kernel = brute.strategy.unwrap();
            for (p, &node) in red.nodes.iter().enumerate() {
                assert_eq!(
                    kernel.configs[p], full.strategy.configs[node],
                    "{net}: kernel node {node} assignment diverged"
                );
            }
        }
    }

    #[test]
    fn strategy_cost_is_consistent() {
        let t = tables_for("alexnet", 4);
        let r = optimize(&t);
        let idx: Vec<usize> = r
            .strategy
            .configs
            .iter()
            .enumerate()
            .map(|(l, c)| t.index_of(l, c).unwrap())
            .collect();
        assert!((t.strategy_cost(&idx) - r.cost).abs() < 1e-9 * r.cost);
    }
}
