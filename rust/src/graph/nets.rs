//! Network builders for the paper's benchmark CNNs.
//!
//! Shapes follow the original publications; activation layers are folded
//! into the producing conv/fc (see module docs). All builders take the
//! **global** batch size (the paper uses per-GPU batch 32, so global batch
//! = 32 x #devices) and return `Result` through the fallible
//! [`GraphBuilder`] API — a builtin with a positive batch cannot actually
//! fail, but the builders compose with untrusted graph sources behind one
//! error type instead of panicking.

use super::{CompGraph, GraphBuilder, LayerId, PoolKind};
use crate::error::{OptError, Result};

/// LeNet-5 (LeCun et al.): 32x32x1 input, two conv/pool stages, three FCs.
pub fn lenet5(batch: usize) -> Result<CompGraph> {
    let mut b = GraphBuilder::new("lenet5");
    let x = b.input(batch, 1, 32, 32)?;
    let c1 = b.conv2d("conv1", x, 6, (5, 5), (1, 1), (0, 0))?;
    let p1 = b.pool2d("pool1", c1, PoolKind::Avg, (2, 2), (2, 2), (0, 0))?;
    let c2 = b.conv2d("conv2", p1, 16, (5, 5), (1, 1), (0, 0))?;
    let p2 = b.pool2d("pool2", c2, PoolKind::Avg, (2, 2), (2, 2), (0, 0))?;
    let f1 = b.fully_connected("fc3", p2, 120)?;
    let f2 = b.fully_connected("fc4", f1, 84)?;
    let f3 = b.fully_connected("fc5", f2, 10)?;
    b.softmax("softmax", f3)?;
    b.finish()
}

/// AlexNet (Krizhevsky et al. 2012), single-tower variant.
pub fn alexnet(batch: usize) -> Result<CompGraph> {
    let mut b = GraphBuilder::new("alexnet");
    let x = b.input(batch, 3, 224, 224)?;
    let c1 = b.conv2d("conv1", x, 96, (11, 11), (4, 4), (2, 2))?;
    let p1 = b.pool2d("pool1", c1, PoolKind::Max, (3, 3), (2, 2), (0, 0))?;
    let c2 = b.conv2d("conv2", p1, 256, (5, 5), (1, 1), (2, 2))?;
    let p2 = b.pool2d("pool2", c2, PoolKind::Max, (3, 3), (2, 2), (0, 0))?;
    let c3 = b.conv2d("conv3", p2, 384, (3, 3), (1, 1), (1, 1))?;
    let c4 = b.conv2d("conv4", c3, 384, (3, 3), (1, 1), (1, 1))?;
    let c5 = b.conv2d("conv5", c4, 256, (3, 3), (1, 1), (1, 1))?;
    let p5 = b.pool2d("pool5", c5, PoolKind::Max, (3, 3), (2, 2), (0, 0))?;
    let f6 = b.fully_connected("fc6", p5, 4096)?;
    let f7 = b.fully_connected("fc7", f6, 4096)?;
    let f8 = b.fully_connected("fc8", f7, 1000)?;
    b.softmax("softmax", f8)?;
    b.finish()
}

/// VGG-16 configuration D (Simonyan & Zisserman 2014).
pub fn vgg16(batch: usize) -> Result<CompGraph> {
    let mut b = GraphBuilder::new("vgg16");
    let x = b.input(batch, 3, 224, 224)?;
    let mut cur = x;
    let mut idx = 0usize;
    let stages: [(usize, usize); 5] = [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)];
    for (si, &(reps, ch)) in stages.iter().enumerate() {
        for _ in 0..reps {
            idx += 1;
            cur = b.conv2d(&format!("conv{}", idx), cur, ch, (3, 3), (1, 1), (1, 1))?;
        }
        cur = b.pool2d(&format!("pool{}", si + 1), cur, PoolKind::Max, (2, 2), (2, 2), (0, 0))?;
    }
    let f1 = b.fully_connected("fc6", cur, 4096)?;
    let f2 = b.fully_connected("fc7", f1, 4096)?;
    let f3 = b.fully_connected("fc8", f2, 1000)?;
    b.softmax("softmax", f3)?;
    b.finish()
}

/// Inception-v3 (Szegedy et al. 2016), BN folded into convs.
pub fn inception_v3(batch: usize) -> Result<CompGraph> {
    let mut b = GraphBuilder::new("inception_v3");
    let x = b.input(batch, 3, 299, 299)?;
    // Stem
    let c = b.conv2d("stem_conv1", x, 32, (3, 3), (2, 2), (0, 0))?;
    let c = b.conv2d("stem_conv2", c, 32, (3, 3), (1, 1), (0, 0))?;
    let c = b.conv2d("stem_conv3", c, 64, (3, 3), (1, 1), (1, 1))?;
    let c = b.pool2d("stem_pool1", c, PoolKind::Max, (3, 3), (2, 2), (0, 0))?;
    let c = b.conv2d("stem_conv4", c, 80, (1, 1), (1, 1), (0, 0))?;
    let c = b.conv2d("stem_conv5", c, 192, (3, 3), (1, 1), (0, 0))?;
    let mut cur = b.pool2d("stem_pool2", c, PoolKind::Max, (3, 3), (2, 2), (0, 0))?;

    // Inception-A x3 (35x35)
    for (m, pool_ch) in [(0usize, 32usize), (1, 64), (2, 64)] {
        let n = |s: &str| format!("mixedA{}_{}", m, s);
        let b1 = b.conv2d(&n("1x1"), cur, 64, (1, 1), (1, 1), (0, 0))?;
        let b5 = b.conv2d(&n("5x5_r"), cur, 48, (1, 1), (1, 1), (0, 0))?;
        let b5 = b.conv2d(&n("5x5"), b5, 64, (5, 5), (1, 1), (2, 2))?;
        let b3 = b.conv2d(&n("3x3_r"), cur, 64, (1, 1), (1, 1), (0, 0))?;
        let b3 = b.conv2d(&n("3x3a"), b3, 96, (3, 3), (1, 1), (1, 1))?;
        let b3 = b.conv2d(&n("3x3b"), b3, 96, (3, 3), (1, 1), (1, 1))?;
        let bp = b.pool2d(&n("pool"), cur, PoolKind::Avg, (3, 3), (1, 1), (1, 1))?;
        let bp = b.conv2d(&n("pool_proj"), bp, pool_ch, (1, 1), (1, 1), (0, 0))?;
        cur = b.concat(&n("concat"), &[b1, b5, b3, bp])?;
    }

    // Reduction-A (to 17x17)
    {
        let b3 = b.conv2d("redA_3x3", cur, 384, (3, 3), (2, 2), (0, 0))?;
        let bd = b.conv2d("redA_dbl_r", cur, 64, (1, 1), (1, 1), (0, 0))?;
        let bd = b.conv2d("redA_dbl_a", bd, 96, (3, 3), (1, 1), (1, 1))?;
        let bd = b.conv2d("redA_dbl_b", bd, 96, (3, 3), (2, 2), (0, 0))?;
        let bp = b.pool2d("redA_pool", cur, PoolKind::Max, (3, 3), (2, 2), (0, 0))?;
        cur = b.concat("redA_concat", &[b3, bd, bp])?;
    }

    // Inception-B x4 (17x17), factorized 7x7 convolutions
    for (m, c7) in [(0usize, 128usize), (1, 160), (2, 160), (3, 192)] {
        let n = |s: &str| format!("mixedB{}_{}", m, s);
        let b1 = b.conv2d(&n("1x1"), cur, 192, (1, 1), (1, 1), (0, 0))?;
        let b7 = b.conv2d(&n("7x7_r"), cur, c7, (1, 1), (1, 1), (0, 0))?;
        let b7 = b.conv2d(&n("7x7_a"), b7, c7, (1, 7), (1, 1), (0, 3))?;
        let b7 = b.conv2d(&n("7x7_b"), b7, 192, (7, 1), (1, 1), (3, 0))?;
        let bd = b.conv2d(&n("dbl_r"), cur, c7, (1, 1), (1, 1), (0, 0))?;
        let bd = b.conv2d(&n("dbl_a"), bd, c7, (7, 1), (1, 1), (3, 0))?;
        let bd = b.conv2d(&n("dbl_b"), bd, c7, (1, 7), (1, 1), (0, 3))?;
        let bd = b.conv2d(&n("dbl_c"), bd, c7, (7, 1), (1, 1), (3, 0))?;
        let bd = b.conv2d(&n("dbl_d"), bd, 192, (1, 7), (1, 1), (0, 3))?;
        let bp = b.pool2d(&n("pool"), cur, PoolKind::Avg, (3, 3), (1, 1), (1, 1))?;
        let bp = b.conv2d(&n("pool_proj"), bp, 192, (1, 1), (1, 1), (0, 0))?;
        cur = b.concat(&n("concat"), &[b1, b7, bd, bp])?;
    }

    // Reduction-B (to 8x8)
    {
        let b3 = b.conv2d("redB_3x3_r", cur, 192, (1, 1), (1, 1), (0, 0))?;
        let b3 = b.conv2d("redB_3x3", b3, 320, (3, 3), (2, 2), (0, 0))?;
        let b7 = b.conv2d("redB_7x7_r", cur, 192, (1, 1), (1, 1), (0, 0))?;
        let b7 = b.conv2d("redB_7x7_a", b7, 192, (1, 7), (1, 1), (0, 3))?;
        let b7 = b.conv2d("redB_7x7_b", b7, 192, (7, 1), (1, 1), (3, 0))?;
        let b7 = b.conv2d("redB_7x7_c", b7, 192, (3, 3), (2, 2), (0, 0))?;
        let bp = b.pool2d("redB_pool", cur, PoolKind::Max, (3, 3), (2, 2), (0, 0))?;
        cur = b.concat("redB_concat", &[b3, b7, bp])?;
    }

    // Inception-C x2 (8x8)
    for m in 0..2usize {
        let n = |s: &str| format!("mixedC{}_{}", m, s);
        let b1 = b.conv2d(&n("1x1"), cur, 320, (1, 1), (1, 1), (0, 0))?;
        let b3 = b.conv2d(&n("3x3_r"), cur, 384, (1, 1), (1, 1), (0, 0))?;
        let b3a = b.conv2d(&n("3x3_wa"), b3, 384, (1, 3), (1, 1), (0, 1))?;
        let b3b = b.conv2d(&n("3x3_wb"), b3, 384, (3, 1), (1, 1), (1, 0))?;
        let bd = b.conv2d(&n("dbl_r"), cur, 448, (1, 1), (1, 1), (0, 0))?;
        let bd = b.conv2d(&n("dbl_3"), bd, 384, (3, 3), (1, 1), (1, 1))?;
        let bda = b.conv2d(&n("dbl_wa"), bd, 384, (1, 3), (1, 1), (0, 1))?;
        let bdb = b.conv2d(&n("dbl_wb"), bd, 384, (3, 1), (1, 1), (1, 0))?;
        let bp = b.pool2d(&n("pool"), cur, PoolKind::Avg, (3, 3), (1, 1), (1, 1))?;
        let bp = b.conv2d(&n("pool_proj"), bp, 192, (1, 1), (1, 1), (0, 0))?;
        cur = b.concat(&n("concat"), &[b1, b3a, b3b, bda, bdb, bp])?;
    }

    let gp = b.pool2d("global_pool", cur, PoolKind::Avg, (8, 8), (1, 1), (0, 0))?;
    let fc = b.fully_connected("fc", gp, 1000)?;
    b.softmax("softmax", fc)?;
    b.finish()
}

/// ResNet-18 (He et al. 2016) — extension network; the paper notes its
/// graph also reduces to K=2 under node/edge elimination.
pub fn resnet18(batch: usize) -> Result<CompGraph> {
    let mut b = GraphBuilder::new("resnet18");
    let x = b.input(batch, 3, 224, 224)?;
    let c1 = b.conv2d("conv1", x, 64, (7, 7), (2, 2), (3, 3))?;
    let mut cur = b.pool2d("pool1", c1, PoolKind::Max, (3, 3), (2, 2), (1, 1))?;

    let block = |b: &mut GraphBuilder,
                 cur: LayerId,
                 name: &str,
                 ch: usize,
                 stride: usize|
     -> Result<LayerId> {
        let c1 = b.conv2d(
            &format!("{name}_conv1"),
            cur,
            ch,
            (3, 3),
            (stride, stride),
            (1, 1),
        )?;
        let c2 = b.conv2d(&format!("{name}_conv2"), c1, ch, (3, 3), (1, 1), (1, 1))?;
        let short = if stride != 1 {
            b.conv2d(&format!("{name}_down"), cur, ch, (1, 1), (stride, stride), (0, 0))?
        } else {
            cur
        };
        b.add(&format!("{name}_add"), short, c2)
    };

    for (si, &(ch, first_stride)) in [(64usize, 1usize), (128, 2), (256, 2), (512, 2)]
        .iter()
        .enumerate()
    {
        cur = block(&mut b, cur, &format!("s{}b1", si + 1), ch, first_stride)?;
        cur = block(&mut b, cur, &format!("s{}b2", si + 1), ch, 1)?;
    }

    let gp = b.pool2d("global_pool", cur, PoolKind::Avg, (7, 7), (1, 1), (0, 0))?;
    let fc = b.fully_connected("fc", gp, 1000)?;
    b.softmax("softmax", fc)?;
    b.finish()
}

/// ResNet-50 (He et al. 2016), bottleneck blocks — stresses the
/// eliminator with deeper residual structure than ResNet-18.
pub fn resnet50(batch: usize) -> Result<CompGraph> {
    let mut b = GraphBuilder::new("resnet50");
    let x = b.input(batch, 3, 224, 224)?;
    let c1 = b.conv2d("conv1", x, 64, (7, 7), (2, 2), (3, 3))?;
    let mut cur = b.pool2d("pool1", c1, PoolKind::Max, (3, 3), (2, 2), (1, 1))?;

    let bottleneck = |b: &mut GraphBuilder,
                      cur: LayerId,
                      name: &str,
                      mid: usize,
                      stride: usize,
                      project: bool|
     -> Result<LayerId> {
        let out_ch = mid * 4;
        let c1 = b.conv2d(&format!("{name}_c1"), cur, mid, (1, 1), (stride, stride), (0, 0))?;
        let c2 = b.conv2d(&format!("{name}_c2"), c1, mid, (3, 3), (1, 1), (1, 1))?;
        let c3 = b.conv2d(&format!("{name}_c3"), c2, out_ch, (1, 1), (1, 1), (0, 0))?;
        let short = if project {
            b.conv2d(&format!("{name}_proj"), cur, out_ch, (1, 1), (stride, stride), (0, 0))?
        } else {
            cur
        };
        b.add(&format!("{name}_add"), short, c3)
    };

    for (si, &(mid, reps, first_stride)) in
        [(64usize, 3usize, 1usize), (128, 4, 2), (256, 6, 2), (512, 3, 2)].iter().enumerate()
    {
        for r in 0..reps {
            let stride = if r == 0 { first_stride } else { 1 };
            let project = r == 0;
            cur =
                bottleneck(&mut b, cur, &format!("s{}b{}", si + 1, r + 1), mid, stride, project)?;
        }
    }

    let gp = b.pool2d("global_pool", cur, PoolKind::Avg, (7, 7), (1, 1), (0, 0))?;
    let fc = b.fully_connected("fc", gp, 1000)?;
    b.softmax("softmax", fc)?;
    b.finish()
}

/// MiniCNN: the end-to-end training demo network (32x32x3 input). Small
/// enough that every shard shape reachable on <=4 devices can be AOT
/// compiled and executed through the interpret-mode Pallas kernels.
pub fn minicnn(batch: usize) -> Result<CompGraph> {
    let mut b = GraphBuilder::new("minicnn");
    let x = b.input(batch, 3, 32, 32)?;
    let c1 = b.conv2d("conv1", x, 8, (3, 3), (1, 1), (1, 1))?;
    let p1 = b.pool2d("pool1", c1, PoolKind::Max, (2, 2), (2, 2), (0, 0))?;
    let c2 = b.conv2d("conv2", p1, 16, (3, 3), (1, 1), (1, 1))?;
    let p2 = b.pool2d("pool2", c2, PoolKind::Max, (2, 2), (2, 2), (0, 0))?;
    let f1 = b.fully_connected("fc1", p2, 64)?;
    let f2 = b.fully_connected("fc2", f1, 10)?;
    b.softmax("softmax", f2)?;
    b.finish()
}

/// Look a builder up by name (CLI/config entry point). Unknown names are
/// [`OptError::UnknownNetwork`].
pub fn by_name(name: &str, batch: usize) -> Result<CompGraph> {
    match name {
        "lenet5" | "lenet" => lenet5(batch),
        "alexnet" => alexnet(batch),
        "vgg16" | "vgg" => vgg16(batch),
        "inception_v3" | "inception" | "inceptionv3" => inception_v3(batch),
        "resnet18" | "resnet" => resnet18(batch),
        "resnet50" => resnet50(batch),
        "minicnn" => minicnn(batch),
        _ => Err(OptError::UnknownNetwork(name.to_string())),
    }
}

/// All benchmark network names (for sweeps).
pub const PAPER_NETS: [&str; 3] = ["alexnet", "vgg16", "inception_v3"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn alexnet_shapes_match_publication() {
        let g = alexnet(128).unwrap();
        let conv1 = g.layers.iter().find(|l| l.name == "conv1").unwrap();
        assert_eq!(conv1.out_shape, vec![128, 96, 55, 55]);
        let fc6 = g.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert_eq!(fc6.in_shapes[0], vec![128, 256, 6, 6]);
        assert_eq!(fc6.param_count(), 4096 * 9216 + 4096);
        // ~61M params total
        let p = g.total_params();
        assert!((55_000_000..70_000_000).contains(&p), "alexnet params {p}");
    }

    #[test]
    fn vgg16_has_13_convs_and_138m_params() {
        let g = vgg16(32).unwrap();
        let convs = g
            .layers
            .iter()
            .filter(|l| matches!(l.op, OpKind::Conv2d { .. }))
            .count();
        assert_eq!(convs, 13);
        let p = g.total_params();
        assert!((130_000_000..145_000_000).contains(&p), "vgg params {p}");
        // conv8 = 3rd conv of stage 4 in config D is 512 channels at 28x28
        let conv8 = g.layers.iter().find(|l| l.name == "conv8").unwrap();
        assert_eq!(conv8.out_shape, vec![32, 512, 28, 28]);
    }

    #[test]
    fn inception_reaches_expected_stage_shapes() {
        let g = inception_v3(32).unwrap();
        let reda = g.layers.iter().find(|l| l.name == "redA_concat").unwrap();
        assert_eq!(&reda.out_shape[1..], &[768, 17, 17]);
        let redb = g.layers.iter().find(|l| l.name == "redB_concat").unwrap();
        assert_eq!(&redb.out_shape[1..], &[1280, 8, 8]);
        let last_concat = g.layers.iter().find(|l| l.name == "mixedC1_concat").unwrap();
        assert_eq!(&last_concat.out_shape[1..], &[2048, 8, 8]);
        // ~100+ layers, ~24M params
        assert!(g.num_layers() > 90, "layers {}", g.num_layers());
        let p = g.total_params();
        assert!((20_000_000..30_000_000).contains(&p), "inception params {p}");
    }

    #[test]
    fn resnet18_shapes() {
        let g = resnet18(32).unwrap();
        let fc = g.layers.iter().find(|l| l.name == "fc").unwrap();
        assert_eq!(fc.in_shapes[0], vec![32, 512, 1, 1]);
        let p = g.total_params();
        assert!((10_000_000..13_000_000).contains(&p), "resnet18 params {p}");
    }

    #[test]
    fn all_builders_pass_validate() {
        for name in
            ["lenet5", "alexnet", "vgg16", "inception_v3", "resnet18", "resnet50", "minicnn"]
        {
            let g = by_name(name, 64).unwrap();
            g.validate().unwrap();
            assert!(g.total_train_flops() > 0.0);
            assert_eq!(g.batch(), 64);
        }
    }

    #[test]
    fn resnet50_shapes_and_params() {
        let g = resnet50(32).unwrap();
        let fc = g.layers.iter().find(|l| l.name == "fc").unwrap();
        assert_eq!(fc.in_shapes[0], vec![32, 2048, 1, 1]);
        let p = g.total_params();
        // ~25.5M params (BN folded)
        assert!((23_000_000..28_000_000).contains(&p), "resnet50 params {p}");
        // 16 bottleneck blocks => 16 Add nodes
        let adds = g.layers.iter().filter(|l| matches!(l.op, OpKind::Add)).count();
        assert_eq!(adds, 16);
    }

    #[test]
    fn unknown_name_is_a_typed_error() {
        assert!(matches!(by_name("nope", 1), Err(OptError::UnknownNetwork(_))));
    }

    #[test]
    fn zero_batch_is_an_error_not_a_degenerate_graph() {
        assert!(matches!(lenet5(0), Err(OptError::InvalidGraph(_))));
    }
}
