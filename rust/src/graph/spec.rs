//! `GraphSpec`: the wire form of a computation graph (DESIGN.md §5).
//!
//! A spec is an exact JSON (de)serialization of a [`CompGraph`] — the
//! same role `plan/json.rs` plays for execution plans. It is how
//! arbitrary user networks enter the planner: inline over TCP (`optcnn
//! serve`'s `graph` field), from disk (`--network-file`), or exported
//! from a builtin (`optcnn graph --out`). The round-trip is exact: a
//! spec-loaded graph plans byte-identically to the builder-built one
//! (pinned by `tests/graph_spec.rs`).
//!
//! ```json
//! {"version": 1, "name": "minicnn", "layers": [
//!   {"name": "input", "op": "input", "inputs": [], "shape": [64, 3, 32, 32]},
//!   {"name": "conv1", "op": "conv", "inputs": [0], "shape": [64, 8, 32, 32],
//!    "cout": 8, "kernel": [3, 3], "stride": [1, 1], "padding": [1, 1]},
//!   {"name": "fc1", "op": "fc", "inputs": [1], "shape": [64, 10], "cout": 10},
//!   {"name": "softmax", "op": "softmax", "inputs": [2], "shape": [64, 10]}]}
//! ```
//!
//! Layer ids are array positions; `inputs` lists producer ids in edge
//! order; `shape` is the declared output shape, checked on load against
//! what the operator actually produces. Every malformed spec — unknown
//! ops, dangling or backward (cyclic) `inputs`, shape mismatches,
//! degenerate windows — is a typed
//! [`OptError::InvalidGraph`](crate::error::OptError::InvalidGraph),
//! never a panic: this parser faces untrusted bytes.
//!
//! Loading re-runs the shared shape inference and [`CompGraph::validate`],
//! so a spec that parses is exactly as trustworthy as a builder-built
//! graph.
//!
//! # Content addressing
//!
//! [`CompGraph::digest`] is the graph's structural identity: the
//! canonical spec form with every cosmetic name stripped, compared by
//! value (never by a lossy hash, following the cluster-memo precedent in
//! `planner::service`). Two textually different specs of the same
//! network — reordered keys, renamed layers — share one digest, so they
//! share plan-cache and single-flight memo entries; two structurally
//! different graphs can never alias.

use std::sync::Arc;

use crate::error::Result;
use crate::util::json::Json;

use super::{invalid, CompGraph, Layer, OpKind, PoolKind};

/// Spec format version (the `version` field).
pub const SPEC_VERSION: f64 = 1.0;

/// Magnitude caps on spec-declared numbers. Structural validation alone
/// does not bound *sizes*, and downstream code enumerates divisors of
/// every extent and multiplies parameter dimensions — an untrusted spec
/// declaring a `10^12`-sample batch would pin a serving thread for
/// hours, and huge `cout`/`padding` values overflow `usize` arithmetic.
/// The caps are far past any real CNN (65536 = 2048 GPUs at the paper's
/// 32/GPU batch) and each violation is a typed error naming the cap.
pub const MAX_SPEC_EXTENT: usize = 65_536;
/// Cap on one layer's declared element count (`shape` product);
/// 2^32 f32 elements is a 16 GiB activation.
pub const MAX_SPEC_VOLUME: usize = 1 << 32;
/// Cap on each kernel/stride/padding component.
pub const MAX_SPEC_WINDOW: usize = 65_536;

/// Structural identity of a computation graph: the canonical,
/// name-free spec serialization, compared by value. Cheap to clone
/// (`Arc<str>`), hashable, and stable across processes — the content
/// address the plan caches and the service's single-flight memo key on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GraphDigest {
    canon: Arc<str>,
}

impl GraphDigest {
    /// The canonical name-free serialization this digest compares by.
    pub fn canonical(&self) -> &str {
        &self.canon
    }

    /// A short hex fingerprint for logs and table output. Display only —
    /// identity comparisons use the full canonical form.
    pub fn hex(&self) -> String {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.canon.hash(&mut h);
        format!("{:016x}", h.finish())
    }
}

impl std::fmt::Display for GraphDigest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.hex())
    }
}

fn uint_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn pair_arr(p: (usize, usize)) -> Json {
    Json::Arr(vec![Json::Num(p.0 as f64), Json::Num(p.1 as f64)])
}

/// The spec object for one layer. `with_name` distinguishes the wire
/// form (named) from the canonical digest form (names stripped).
fn layer_json(g: &CompGraph, l: &Layer, with_name: bool) -> Json {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    if with_name {
        fields.push(("name", Json::Str(l.name.clone())));
    }
    fields.push(("op", Json::Str(l.op.mnemonic().to_string())));
    fields.push(("inputs", uint_arr(&g.predecessors(l.id))));
    fields.push(("shape", uint_arr(&l.out_shape)));
    match &l.op {
        OpKind::Input | OpKind::Softmax | OpKind::Concat | OpKind::Add => {}
        OpKind::Conv2d { cout, kernel, stride, padding } => {
            fields.push(("cout", Json::Num(*cout as f64)));
            fields.push(("kernel", pair_arr(*kernel)));
            fields.push(("stride", pair_arr(*stride)));
            fields.push(("padding", pair_arr(*padding)));
        }
        OpKind::Pool2d { kind, kernel, stride, padding } => {
            fields.push((
                "kind",
                Json::Str(match kind {
                    PoolKind::Max => "max".to_string(),
                    PoolKind::Avg => "avg".to_string(),
                }),
            ));
            fields.push(("kernel", pair_arr(*kernel)));
            fields.push(("stride", pair_arr(*stride)));
            fields.push(("padding", pair_arr(*padding)));
        }
        OpKind::FullyConnected { cout } => {
            fields.push(("cout", Json::Num(*cout as f64)));
        }
    }
    Json::obj(fields)
}

/// Position-free canonical form of a single layer: the operator, its
/// parameters, and the declared shapes (output and per-slot inputs) —
/// no layer name, no graph-positional input ids. Two layers share this
/// form exactly when every per-layer quantity the cost and memory
/// models derive from them (config enumeration, `t_C`/`t_S`, tiling
/// geometry, peak bytes) is identical, regardless of where each layer
/// sits in its graph. This is the per-layer analogue of the whole-graph
/// [`GraphDigest`], and the layer component of the cost-table memo key
/// (`cost::memo::TableMemo`, DESIGN.md §7).
pub(crate) fn layer_canon(l: &Layer) -> String {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    fields.push(("op", Json::Str(l.op.mnemonic().to_string())));
    fields.push(("shape", uint_arr(&l.out_shape)));
    fields.push((
        "in_shapes",
        Json::Arr(l.in_shapes.iter().map(|s| uint_arr(s)).collect()),
    ));
    match &l.op {
        OpKind::Input | OpKind::Softmax | OpKind::Concat | OpKind::Add => {}
        OpKind::Conv2d { cout, kernel, stride, padding } => {
            fields.push(("cout", Json::Num(*cout as f64)));
            fields.push(("kernel", pair_arr(*kernel)));
            fields.push(("stride", pair_arr(*stride)));
            fields.push(("padding", pair_arr(*padding)));
        }
        OpKind::Pool2d { kind, kernel, stride, padding } => {
            fields.push((
                "kind",
                Json::Str(match kind {
                    PoolKind::Max => "max".to_string(),
                    PoolKind::Avg => "avg".to_string(),
                }),
            ));
            fields.push(("kernel", pair_arr(*kernel)));
            fields.push(("stride", pair_arr(*stride)));
            fields.push(("padding", pair_arr(*padding)));
        }
        OpKind::FullyConnected { cout } => {
            fields.push(("cout", Json::Num(*cout as f64)));
        }
    }
    Json::obj(fields).to_string()
}

// ---- parsing helpers (strict: no silent truncation off the wire) ----

fn uints(v: &Json, what: &str) -> Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| invalid(format!("{what} must be an array of whole numbers")))?
        .iter()
        .map(|x| {
            x.as_exact_usize()
                .ok_or_else(|| invalid(format!("{what} must hold whole numbers >= 0")))
        })
        .collect()
}

fn pair(v: &Json, what: &str) -> Result<(usize, usize)> {
    let xs = uints(v, what)?;
    if xs.len() != 2 {
        return Err(invalid(format!("{what} must be a [h, w] pair, got {} entries", xs.len())));
    }
    Ok((xs[0], xs[1]))
}

/// Fields a spec layer may carry, by operator. Unknown keys are errors —
/// a misspelled field must not be silently ignored.
fn allowed_keys(op: &str) -> &'static [&'static str] {
    const COMMON: [&str; 4] = ["name", "op", "inputs", "shape"];
    match op {
        "conv" => &["name", "op", "inputs", "shape", "cout", "kernel", "stride", "padding"],
        "pool" => &["name", "op", "inputs", "shape", "kind", "kernel", "stride", "padding"],
        "fc" => &["name", "op", "inputs", "shape", "cout"],
        _ => &COMMON,
    }
}

/// One parsed spec layer, before cross-layer wiring.
struct SpecLayer {
    name: String,
    op: OpKind,
    inputs: Vec<usize>,
    shape: Vec<usize>,
}

fn layer_from(id: usize, v: &Json) -> Result<SpecLayer> {
    let obj = v
        .as_obj()
        .ok_or_else(|| invalid(format!("layer {id}: expected an object")))?;
    let op_tag = obj
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| invalid(format!("layer {id}: needs an `op` string")))?;
    for key in obj.keys() {
        if !allowed_keys(op_tag).contains(&key.as_str()) {
            return Err(invalid(format!(
                "layer {id}: unknown field `{key}` for op `{op_tag}`"
            )));
        }
    }
    let name = match obj.get("name") {
        None => format!("l{id}"),
        Some(n) => n
            .as_str()
            .ok_or_else(|| invalid(format!("layer {id}: `name` must be a string")))?
            .to_string(),
    };
    let ctx = |field: &str| format!("layer {id} (`{name}`): `{field}`");
    let cout = |obj: &std::collections::BTreeMap<String, Json>| -> Result<usize> {
        obj.get("cout")
            .and_then(Json::as_exact_usize)
            .ok_or_else(|| invalid(format!("{} must be a whole number", ctx("cout"))))
    };
    let window = |field: &str| -> Result<(usize, usize)> {
        pair(
            obj.get(field)
                .ok_or_else(|| invalid(format!("{} is required", ctx(field))))?,
            &ctx(field),
        )
    };
    let op = match op_tag {
        "input" => OpKind::Input,
        "conv" => OpKind::Conv2d {
            cout: cout(obj)?,
            kernel: window("kernel")?,
            stride: window("stride")?,
            padding: window("padding")?,
        },
        "pool" => OpKind::Pool2d {
            kind: match obj.get("kind").and_then(Json::as_str) {
                Some("max") => PoolKind::Max,
                Some("avg") => PoolKind::Avg,
                other => {
                    return Err(invalid(format!(
                        "{} must be \"max\" or \"avg\", got {other:?}",
                        ctx("kind")
                    )))
                }
            },
            kernel: window("kernel")?,
            stride: window("stride")?,
            padding: window("padding")?,
        },
        "fc" => OpKind::FullyConnected { cout: cout(obj)? },
        "softmax" => OpKind::Softmax,
        "concat" => OpKind::Concat,
        "add" => OpKind::Add,
        other => {
            return Err(invalid(format!(
                "layer {id}: unknown op `{other}` (known: input, conv, pool, fc, \
                 softmax, concat, add)"
            )))
        }
    };
    let inputs = uints(
        obj.get("inputs")
            .ok_or_else(|| invalid(format!("{} is required", ctx("inputs"))))?,
        &ctx("inputs"),
    )?;
    let shape = uints(
        obj.get("shape")
            .ok_or_else(|| invalid(format!("{} is required", ctx("shape"))))?,
        &ctx("shape"),
    )?;
    // magnitude caps: bound what the planner will enumerate/multiply
    if let Some(&d) = shape.iter().find(|&&d| d > MAX_SPEC_EXTENT) {
        return Err(invalid(format!(
            "{} extent {d} exceeds the {MAX_SPEC_EXTENT} cap",
            ctx("shape")
        )));
    }
    let volume = shape.iter().try_fold(1usize, |v, &d| v.checked_mul(d));
    if !matches!(volume, Some(v) if v <= MAX_SPEC_VOLUME) {
        return Err(invalid(format!(
            "{} has more than {MAX_SPEC_VOLUME} elements",
            ctx("shape")
        )));
    }
    if let OpKind::Conv2d { kernel, stride, padding, .. }
    | OpKind::Pool2d { kernel, stride, padding, .. } = &op
    {
        for (field, &(a, b)) in [("kernel", kernel), ("stride", stride), ("padding", padding)] {
            if a > MAX_SPEC_WINDOW || b > MAX_SPEC_WINDOW {
                return Err(invalid(format!(
                    "{} component exceeds the {MAX_SPEC_WINDOW} cap",
                    ctx(field)
                )));
            }
        }
    }
    Ok(SpecLayer { name, op, inputs, shape })
}

impl CompGraph {
    /// Serialize this graph as a `GraphSpec` document — the exact wire
    /// form `optcnn serve` accepts inline and `--network-file` loads.
    pub fn to_spec(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(SPEC_VERSION)),
            ("name", Json::Str(self.name.clone())),
            (
                "layers",
                Json::Arr(self.layers.iter().map(|l| layer_json(self, l, true)).collect()),
            ),
        ])
    }

    /// Parse and fully validate a `GraphSpec` document. Input shapes are
    /// re-derived from the `inputs` wiring and every declared `shape` is
    /// checked against the shared shape inference, so a loaded graph
    /// satisfies exactly the invariants a builder-built one does.
    pub fn from_spec(v: &Json) -> Result<CompGraph> {
        let obj = v.as_obj().ok_or_else(|| invalid("spec must be a JSON object".into()))?;
        for key in obj.keys() {
            if !["version", "name", "layers"].contains(&key.as_str()) {
                return Err(invalid(format!("unknown spec field `{key}`")));
            }
        }
        match obj.get("version").and_then(Json::as_f64) {
            Some(v) if v == SPEC_VERSION => {}
            other => {
                return Err(invalid(format!(
                    "spec version must be {SPEC_VERSION}, got {other:?}"
                )))
            }
        }
        let name = obj
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| invalid("spec needs a `name` string".into()))?;
        if name.is_empty() {
            return Err(invalid("spec `name` must be non-empty".into()));
        }
        let raw = obj
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| invalid("spec needs a `layers` array".into()))?;
        let parsed: Vec<SpecLayer> =
            raw.iter().enumerate().map(|(i, l)| layer_from(i, l)).collect::<Result<_>>()?;
        let mut layers = Vec::with_capacity(parsed.len());
        let mut edges = Vec::new();
        for (id, sl) in parsed.iter().enumerate() {
            let mut in_shapes = Vec::with_capacity(sl.inputs.len());
            for &src in &sl.inputs {
                let producer = parsed.get(src).ok_or_else(|| {
                    invalid(format!(
                        "layer {id} (`{}`): dangling input {src} (graph has {} layers)",
                        sl.name,
                        parsed.len()
                    ))
                })?;
                in_shapes.push(producer.shape.clone());
                edges.push((src, id));
            }
            layers.push(Layer {
                id,
                name: sl.name.clone(),
                op: sl.op.clone(),
                out_shape: sl.shape.clone(),
                in_shapes,
            });
        }
        CompGraph::new(name.to_string(), layers, edges)
    }

    /// The graph's structural content address (see [`GraphDigest`]):
    /// the canonical spec form with the graph and layer names stripped.
    /// Computed once and cached for the graph's lifetime — mutate
    /// `layers`/`edges` before the first call, not after (planner-owned
    /// graphs are never mutated post-construction).
    pub fn digest(&self) -> &GraphDigest {
        self.digest.get_or_init(|| {
            let canon = Json::Arr(
                self.layers.iter().map(|l| layer_json(self, l, false)).collect(),
            );
            GraphDigest { canon: canon.to_string().into() }
        })
    }

    /// Graphviz DOT rendering (`optcnn graph --dot`): one node per layer
    /// labeled with its name, operator, and output shape. Layer names
    /// come from user specs, so label text is escaped — a `"` or `\` in
    /// a name must not break out of the quoted DOT string.
    pub fn to_dot(&self) -> String {
        use std::fmt::Write as _;
        // order matters: escaping `"` first would double-escape the
        // backslashes that escape introduces
        let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
        let mut out = String::new();
        let _ = writeln!(out, "digraph \"{}\" {{", esc(&self.name));
        let _ = writeln!(out, "  rankdir=TB; node [shape=box, fontname=\"monospace\"];");
        for l in &self.layers {
            let _ = writeln!(
                out,
                "  l{} [label=\"{}\\n{} {:?}\"];",
                l.id,
                esc(&l.name),
                l.op.mnemonic(),
                l.out_shape
            );
        }
        for &(s, d) in &self.edges {
            let _ = writeln!(out, "  l{s} -> l{d};");
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::{nets, GraphBuilder};
    use super::*;
    use crate::error::OptError;

    #[test]
    fn builtin_round_trips_exactly() {
        for name in ["lenet5", "alexnet", "inception_v3", "resnet18"] {
            let g = nets::by_name(name, 64).unwrap();
            let text = g.to_spec().to_string();
            let back = CompGraph::from_spec(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.name, g.name);
            assert_eq!(back.edges, g.edges);
            assert_eq!(back.num_layers(), g.num_layers());
            for (a, b) in g.layers.iter().zip(back.layers.iter()) {
                assert_eq!(a.op, b.op, "{name}: op of layer {}", a.id);
                assert_eq!(a.out_shape, b.out_shape, "{name}: shape of layer {}", a.id);
                assert_eq!(a.in_shapes, b.in_shapes, "{name}: inputs of layer {}", a.id);
                assert_eq!(a.name, b.name);
            }
            // the spec of the round-tripped graph is byte-identical
            assert_eq!(back.to_spec().to_string(), text, "{name}");
        }
    }

    #[test]
    fn digest_ignores_cosmetic_names_only() {
        let build = |gname: &str, lname: &str, cout: usize| {
            let mut b = GraphBuilder::new(gname);
            let x = b.input(4, 3, 8, 8).unwrap();
            let c = b.conv2d(lname, x, cout, (3, 3), (1, 1), (1, 1)).unwrap();
            let f = b.fully_connected("fc", c, 10).unwrap();
            b.softmax("sm", f).unwrap();
            b.finish().unwrap()
        };
        let a = build("net-a", "conv", 8);
        let renamed = build("net-b", "conv_alias", 8);
        let wider = build("net-a", "conv", 16);
        assert_eq!(a.digest(), renamed.digest(), "names are cosmetic");
        assert_ne!(a.digest(), wider.digest(), "structure is identity");
        assert_eq!(a.digest().hex().len(), 16);
    }

    #[test]
    fn layer_canon_is_position_and_name_free() {
        // the same conv in two different graph positions (and under two
        // different names) canonicalizes identically ...
        let mut b = GraphBuilder::new("a");
        let x = b.input(4, 3, 8, 8).unwrap();
        let c1 = b.conv2d("first", x, 3, (3, 3), (1, 1), (1, 1)).unwrap();
        let c2 = b.conv2d("second", c1, 3, (3, 3), (1, 1), (1, 1)).unwrap();
        let f = b.fully_connected("fc", c2, 10).unwrap();
        b.softmax("sm", f).unwrap();
        let g = b.finish().unwrap();
        let conv_a = layer_canon(&g.layers[1]);
        let conv_b = layer_canon(&g.layers[2]);
        assert_eq!(conv_a, conv_b, "same op+shapes at different positions must alias");
        assert!(!conv_a.contains("first"), "names must be stripped: {conv_a}");
        // ... while a parameter change separates them
        let mut b = GraphBuilder::new("b");
        let x = b.input(4, 3, 8, 8).unwrap();
        let c = b.conv2d("first", x, 3, (3, 3), (2, 2), (1, 1)).unwrap();
        let f = b.fully_connected("fc", c, 10).unwrap();
        b.softmax("sm", f).unwrap();
        let h = b.finish().unwrap();
        assert_ne!(conv_a, layer_canon(&h.layers[1]), "stride is structural");
    }

    #[test]
    fn malformed_specs_are_typed_errors() {
        let parse = |text: &str| CompGraph::from_spec(&Json::parse(text).unwrap());
        for (what, text) in [
            ("not an object", "[1, 2]"),
            ("bad version", r#"{"version": 9, "name": "x", "layers": []}"#),
            ("no layers", r#"{"version": 1, "name": "x", "layers": []}"#),
            (
                "unknown op",
                r#"{"version": 1, "name": "x", "layers": [
                    {"op": "teleport", "inputs": [], "shape": [1, 1, 1, 1]}]}"#,
            ),
            (
                "dangling input",
                r#"{"version": 1, "name": "x", "layers": [
                    {"op": "input", "inputs": [], "shape": [1, 3, 4, 4]},
                    {"op": "softmax", "inputs": [9], "shape": [1, 3]}]}"#,
            ),
            (
                "unknown field",
                r#"{"version": 1, "name": "x", "layers": [
                    {"op": "input", "inputs": [], "shape": [1, 3, 4, 4], "sprocket": 1}]}"#,
            ),
            (
                "shape mismatch",
                r#"{"version": 1, "name": "x", "layers": [
                    {"op": "input", "inputs": [], "shape": [1, 3, 4, 4]},
                    {"op": "fc", "cout": 10, "inputs": [0], "shape": [1, 11]},
                    {"op": "softmax", "inputs": [1], "shape": [1, 11]}]}"#,
            ),
        ] {
            let err = parse(text).unwrap_err();
            assert!(matches!(err, OptError::InvalidGraph(_)), "{what}: {err:?}");
            assert!(!err.to_string().is_empty(), "{what}");
        }
    }

    #[test]
    fn dot_lists_every_layer_and_edge() {
        let g = nets::lenet5(8).unwrap();
        let dot = g.to_dot();
        assert!(dot.starts_with("digraph"));
        assert_eq!(dot.matches(" -> ").count(), g.num_edges());
        assert!(dot.contains("conv1"));
    }

    #[test]
    fn dot_escapes_hostile_layer_names() {
        // a name built to break out of the quoted label and inject an
        // attribute: `"]; malicious [label="` — spec names are
        // user-supplied, so to_dot must neutralize it
        let mut b = GraphBuilder::new(r#"quoted " graph \"#);
        let x = b.input(8, 1, 8, 8).unwrap();
        let c = b
            .conv2d(r#"evil"]; mal [label="x"\"#, x, 4, (3, 3), (1, 1), (1, 1))
            .unwrap();
        let f = b.fully_connected("fc", c, 10).unwrap();
        b.softmax("sm", f).unwrap();
        let g = b.finish().unwrap();
        let dot = g.to_dot();
        // every quote and backslash of the hostile name rides escaped
        assert!(dot.contains(r#"evil\"]; mal [label=\"x\"\\"#), "{dot}");
        assert!(dot.contains(r#"digraph "quoted \" graph \\""#), "{dot}");
        // structurally: balanced unescaped quotes on every line, and no
        // line gained a second attribute list from the injection
        for line in dot.lines() {
            let unescaped = line.replace("\\\\", "").replace("\\\"", "");
            assert_eq!(
                unescaped.matches('"').count() % 2,
                0,
                "unbalanced quotes in {line:?}"
            );
            assert!(
                unescaped.matches('[').count() <= 1,
                "injected attribute list in {line:?}"
            );
        }
    }
}
