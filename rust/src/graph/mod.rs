//! Computation graphs (paper §4).
//!
//! A node is a layer `l_i`; an edge `(l_i, l_j)` is a tensor produced by
//! `l_i` and consumed by `l_j`. Shapes are row-major with semantic
//! dimensions `[N, C, H, W]` for 4-D activations and `[N, C]` for
//! fully-connected activations (N = sample, C = channel).
//!
//! Activation functions are folded into the producing layer (as cuDNN does
//! and as the paper's layer counts imply: AlexNet = 11 layers,
//! VGG-16 = 21, Inception-v3 = 102).

pub mod nets;

pub type LayerId = usize;

/// Pooling flavor. Cost-wise identical; kept for fidelity of the builders.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Max,
    Avg,
}

/// The operator a layer applies. Spatial parameters follow cuDNN
/// convention: kernel (kh, kw), stride (sh, sw), padding (ph, pw).
/// `Eq + Hash` so operators can key structural dedup maps (the cost
/// tables fold edges with identical operator/shape signatures).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Graph input (the data loader). Carries no compute.
    Input,
    /// 2-D convolution (+ folded activation). `cout` output channels.
    Conv2d {
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
    },
    /// 2-D pooling.
    Pool2d { kind: PoolKind, kernel: (usize, usize), stride: (usize, usize), padding: (usize, usize) },
    /// Fully-connected (+ folded activation). Flattens 4-D inputs.
    FullyConnected { cout: usize },
    /// Softmax + cross-entropy head.
    Softmax,
    /// Channel-dimension concatenation (Inception modules).
    Concat,
    /// Element-wise residual addition (ResNet blocks).
    Add,
}

impl OpKind {
    /// Short operator mnemonic for table output.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Input => "input",
            OpKind::Conv2d { .. } => "conv",
            OpKind::Pool2d { .. } => "pool",
            OpKind::FullyConnected { .. } => "fc",
            OpKind::Softmax => "softmax",
            OpKind::Concat => "concat",
            OpKind::Add => "add",
        }
    }
}

/// A layer (graph node): operator plus inferred output shape.
#[derive(Debug, Clone)]
pub struct Layer {
    pub id: LayerId,
    pub name: String,
    pub op: OpKind,
    /// Output activation shape, `[N, C, H, W]` or `[N, C]`.
    pub out_shape: Vec<usize>,
    /// Input activation shapes (one per in-edge, in edge order).
    pub in_shapes: Vec<Vec<usize>>,
}

impl Layer {
    /// Number of trainable parameters.
    pub fn param_count(&self) -> usize {
        match &self.op {
            OpKind::Conv2d { cout, kernel, .. } => {
                let cin = self.in_shapes[0][1];
                cout * cin * kernel.0 * kernel.1 + cout
            }
            OpKind::FullyConnected { cout } => {
                let cin: usize = self.in_shapes[0][1..].iter().product();
                cout * cin + cout
            }
            _ => 0,
        }
    }

    /// Parameter bytes (f32).
    pub fn param_bytes(&self) -> f64 {
        self.param_count() as f64 * 4.0
    }

    /// Forward FLOPs for the **whole** layer at the stored batch size.
    pub fn fwd_flops(&self) -> f64 {
        let out: f64 = self.out_shape.iter().product::<usize>() as f64;
        match &self.op {
            OpKind::Input => 0.0,
            OpKind::Conv2d { kernel, .. } => {
                let cin = self.in_shapes[0][1] as f64;
                2.0 * out * cin * (kernel.0 * kernel.1) as f64
            }
            OpKind::Pool2d { kernel, .. } => out * (kernel.0 * kernel.1) as f64,
            OpKind::FullyConnected { .. } => {
                let cin: f64 = self.in_shapes[0][1..].iter().product::<usize>() as f64;
                2.0 * out * cin
            }
            OpKind::Softmax => 5.0 * out,
            OpKind::Concat => 0.0,
            OpKind::Add => out,
        }
    }

    /// Total (forward + backward) FLOPs. Backward re-runs roughly two
    /// convolution-shaped passes (data grad + weight grad), the standard
    /// 3x-forward approximation for training compute.
    pub fn train_flops(&self) -> f64 {
        match &self.op {
            OpKind::Input => 0.0,
            _ => 3.0 * self.fwd_flops(),
        }
    }

    /// Bytes of activation output (f32).
    pub fn out_bytes(&self) -> f64 {
        self.out_shape.iter().product::<usize>() as f64 * 4.0
    }

    /// Bytes touched per training step (inputs + output + params, fwd+bwd).
    /// Used for the memory-bound roofline of cheap layers.
    pub fn mem_bytes(&self) -> f64 {
        let ins: f64 =
            self.in_shapes.iter().map(|s| s.iter().product::<usize>() as f64 * 4.0).sum();
        // fwd reads ins writes out; bwd reads grads writes grads: ~3x.
        3.0 * (ins + self.out_bytes()) + 2.0 * self.param_bytes()
    }

    /// Does this layer carry trainable parameters?
    pub fn has_params(&self) -> bool {
        matches!(self.op, OpKind::Conv2d { .. } | OpKind::FullyConnected { .. })
    }
}

/// A computation graph: layers plus directed tensor edges.
#[derive(Debug, Clone)]
pub struct CompGraph {
    pub name: String,
    pub layers: Vec<Layer>,
    pub edges: Vec<(LayerId, LayerId)>,
}

impl CompGraph {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id]
    }

    /// Ids of layers feeding `id`, in edge order.
    pub fn predecessors(&self, id: LayerId) -> Vec<LayerId> {
        self.edges.iter().filter(|(_, d)| *d == id).map(|(s, _)| *s).collect()
    }

    /// Ids of layers consuming `id`'s output.
    pub fn successors(&self, id: LayerId) -> Vec<LayerId> {
        self.edges.iter().filter(|(s, _)| *s == id).map(|(_, d)| *d).collect()
    }

    /// Total trainable parameters.
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Total per-step training FLOPs.
    pub fn total_train_flops(&self) -> f64 {
        self.layers.iter().map(|l| l.train_flops()).sum()
    }

    /// Validate structural invariants (shapes on edges agree, DAG order,
    /// single input, no dangling edges). Panics with a diagnostic on
    /// violation; used by builder tests.
    pub fn check(&self) {
        assert!(!self.layers.is_empty());
        assert!(matches!(self.layers[0].op, OpKind::Input), "layer 0 must be Input");
        for (i, l) in self.layers.iter().enumerate() {
            assert_eq!(l.id, i, "layer ids must be dense");
        }
        for &(s, d) in &self.edges {
            assert!(s < self.layers.len() && d < self.layers.len(), "dangling edge ({s},{d})");
            assert!(s < d, "edges must go forward in topological id order: ({s},{d})");
        }
        for l in &self.layers {
            let preds = self.predecessors(l.id);
            assert_eq!(
                preds.len(),
                l.in_shapes.len(),
                "layer {} ({}) in-degree mismatch",
                l.name,
                l.id
            );
            for (k, p) in preds.iter().enumerate() {
                assert_eq!(
                    self.layers[*p].out_shape, l.in_shapes[k],
                    "shape mismatch on edge {}->{}",
                    self.layers[*p].name, l.name
                );
            }
        }
    }
}

/// Incremental graph builder with shape inference.
pub struct GraphBuilder {
    name: String,
    layers: Vec<Layer>,
    edges: Vec<(LayerId, LayerId)>,
}

fn conv_out(hw: usize, k: usize, s: usize, p: usize) -> usize {
    assert!(hw + 2 * p >= k, "kernel {k} larger than padded extent {}", hw + 2 * p);
    (hw + 2 * p - k) / s + 1
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder { name: name.to_string(), layers: Vec::new(), edges: Vec::new() }
    }

    fn push(
        &mut self,
        name: String,
        op: OpKind,
        inputs: &[LayerId],
        out_shape: Vec<usize>,
    ) -> LayerId {
        let id = self.layers.len();
        let in_shapes = inputs.iter().map(|&i| self.layers[i].out_shape.clone()).collect();
        for &i in inputs {
            self.edges.push((i, id));
        }
        self.layers.push(Layer { id, name, op, out_shape, in_shapes });
        id
    }

    /// The graph input: `[n, c, h, w]` images.
    pub fn input(&mut self, n: usize, c: usize, h: usize, w: usize) -> LayerId {
        assert!(self.layers.is_empty(), "input must be the first layer");
        self.push("input".into(), OpKind::Input, &[], vec![n, c, h, w])
    }

    pub fn conv2d(
        &mut self,
        name: &str,
        input: LayerId,
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
    ) -> LayerId {
        let s = self.layers[input].out_shape.clone();
        assert_eq!(s.len(), 4, "conv2d needs a 4-D input, got {:?}", s);
        let out = vec![
            s[0],
            cout,
            conv_out(s[2], kernel.0, stride.0, padding.0),
            conv_out(s[3], kernel.1, stride.1, padding.1),
        ];
        self.push(name.into(), OpKind::Conv2d { cout, kernel, stride, padding }, &[input], out)
    }

    pub fn pool2d(
        &mut self,
        name: &str,
        input: LayerId,
        kind: PoolKind,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
    ) -> LayerId {
        let s = self.layers[input].out_shape.clone();
        assert_eq!(s.len(), 4, "pool2d needs a 4-D input, got {:?}", s);
        let out = vec![
            s[0],
            s[1],
            conv_out(s[2], kernel.0, stride.0, padding.0),
            conv_out(s[3], kernel.1, stride.1, padding.1),
        ];
        self.push(name.into(), OpKind::Pool2d { kind, kernel, stride, padding }, &[input], out)
    }

    pub fn fully_connected(&mut self, name: &str, input: LayerId, cout: usize) -> LayerId {
        let s = self.layers[input].out_shape.clone();
        let out = vec![s[0], cout];
        self.push(name.into(), OpKind::FullyConnected { cout }, &[input], out)
    }

    pub fn softmax(&mut self, name: &str, input: LayerId) -> LayerId {
        let s = self.layers[input].out_shape.clone();
        assert_eq!(s.len(), 2, "softmax expects a 2-D input, got {:?}", s);
        self.push(name.into(), OpKind::Softmax, &[input], s)
    }

    /// Channel concatenation of 4-D activations with equal N/H/W.
    pub fn concat(&mut self, name: &str, inputs: &[LayerId]) -> LayerId {
        assert!(inputs.len() >= 2);
        let first = self.layers[inputs[0]].out_shape.clone();
        let mut c = 0;
        for &i in inputs {
            let s = &self.layers[i].out_shape;
            assert_eq!(s.len(), 4);
            assert_eq!((s[0], s[2], s[3]), (first[0], first[2], first[3]), "concat NHW mismatch");
            c += s[1];
        }
        let out = vec![first[0], c, first[2], first[3]];
        self.push(name.into(), OpKind::Concat, inputs, out)
    }

    /// Element-wise residual addition; shapes must match exactly.
    pub fn add(&mut self, name: &str, a: LayerId, b: LayerId) -> LayerId {
        let sa = self.layers[a].out_shape.clone();
        assert_eq!(sa, self.layers[b].out_shape, "add shape mismatch");
        self.push(name.into(), OpKind::Add, &[a, b], sa)
    }

    pub fn finish(self) -> CompGraph {
        let g = CompGraph { name: self.name, layers: self.layers, edges: self.edges };
        g.check();
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(n: usize) -> CompGraph {
        let mut b = GraphBuilder::new("tiny");
        let x = b.input(n, 3, 8, 8);
        let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), (1, 1));
        let p1 = b.pool2d("p1", c1, PoolKind::Max, (2, 2), (2, 2), (0, 0));
        let f1 = b.fully_connected("f1", p1, 10);
        b.softmax("sm", f1);
        b.finish()
    }

    #[test]
    fn shape_inference_chain() {
        let g = tiny(2);
        assert_eq!(g.layer(1).out_shape, vec![2, 4, 8, 8]); // same-pad conv
        assert_eq!(g.layer(2).out_shape, vec![2, 4, 4, 4]); // 2x2/2 pool
        assert_eq!(g.layer(3).out_shape, vec![2, 10]);
        assert_eq!(g.layer(4).out_shape, vec![2, 10]);
    }

    #[test]
    fn param_counts() {
        let g = tiny(2);
        assert_eq!(g.layer(1).param_count(), 4 * 3 * 3 * 3 + 4);
        assert_eq!(g.layer(3).param_count(), 10 * (4 * 4 * 4) + 10);
        assert_eq!(g.layer(2).param_count(), 0);
    }

    #[test]
    fn flops_scale_with_batch() {
        let f2 = tiny(2).total_train_flops();
        let f4 = tiny(4).total_train_flops();
        assert!((f4 / f2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn conv_flops_formula() {
        let g = tiny(1);
        // conv: 2 * (1*4*8*8) * 3 * 9 fwd
        assert_eq!(g.layer(1).fwd_flops(), 2.0 * (4.0 * 64.0) * 3.0 * 9.0);
        assert_eq!(g.layer(1).train_flops(), 3.0 * g.layer(1).fwd_flops());
    }

    #[test]
    fn concat_and_add_shapes() {
        let mut b = GraphBuilder::new("branchy");
        let x = b.input(1, 8, 4, 4);
        let a = b.conv2d("a", x, 8, (1, 1), (1, 1), (0, 0));
        let c = b.conv2d("c", x, 16, (1, 1), (1, 1), (0, 0));
        let cat = b.concat("cat", &[a, c]);
        let d = b.conv2d("d", cat, 8, (1, 1), (1, 1), (0, 0));
        let res = b.add("res", a, d);
        let g = {
            let f = b.fully_connected("f", res, 10);
            b.softmax("sm", f);
            b.finish()
        };
        assert_eq!(g.layer(cat).out_shape, vec![1, 24, 4, 4]);
        assert_eq!(g.layer(res).out_shape, vec![1, 8, 4, 4]);
        assert_eq!(g.predecessors(res), vec![a, d]);
        assert_eq!(g.successors(x), vec![a, c]);
    }

    #[test]
    #[should_panic]
    fn mismatched_add_panics() {
        let mut b = GraphBuilder::new("bad");
        let x = b.input(1, 3, 4, 4);
        let a = b.conv2d("a", x, 4, (1, 1), (1, 1), (0, 0));
        b.add("bad", x, a);
    }

    #[test]
    fn graph_check_passes_on_builders() {
        tiny(32).check();
    }
}
