//! Computation graphs (paper §4).
//!
//! A node is a layer `l_i`; an edge `(l_i, l_j)` is a tensor produced by
//! `l_i` and consumed by `l_j`. Shapes are row-major with semantic
//! dimensions `[N, C, H, W]` for 4-D activations and `[N, C]` for
//! fully-connected activations (N = sample, C = channel).
//!
//! Activation functions are folded into the producing layer (as cuDNN does
//! and as the paper's layer counts imply: AlexNet = 11 layers,
//! VGG-16 = 21, Inception-v3 = 102).
//!
//! Construction is **fallible end to end**: [`GraphBuilder`] methods and
//! [`CompGraph::validate`] return [`OptError::InvalidGraph`] instead of
//! panicking, because graphs arrive not only from the trusted builders in
//! [`nets`] but also as untrusted [`spec`] JSON over TCP (`optcnn serve`)
//! and from `--network-file` — a panicking builder would be a crash
//! vector there (DESIGN.md §5).

pub mod nets;
pub mod spec;

use crate::error::{OptError, Result};

pub use spec::GraphDigest;

pub type LayerId = usize;

/// Shorthand for the module's error variant.
fn invalid(msg: String) -> OptError {
    OptError::InvalidGraph(msg)
}

/// Pooling flavor. Cost-wise identical; kept for fidelity of the builders.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Max,
    Avg,
}

/// The operator a layer applies. Spatial parameters follow cuDNN
/// convention: kernel (kh, kw), stride (sh, sw), padding (ph, pw).
/// `Eq + Hash` so operators can key structural dedup maps (the cost
/// tables fold edges with identical operator/shape signatures).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Graph input (the data loader). Carries no compute.
    Input,
    /// 2-D convolution (+ folded activation). `cout` output channels.
    Conv2d {
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
    },
    /// 2-D pooling.
    Pool2d { kind: PoolKind, kernel: (usize, usize), stride: (usize, usize), padding: (usize, usize) },
    /// Fully-connected (+ folded activation). Flattens 4-D inputs.
    FullyConnected { cout: usize },
    /// Softmax + cross-entropy head.
    Softmax,
    /// Channel-dimension concatenation (Inception modules).
    Concat,
    /// Element-wise residual addition (ResNet blocks).
    Add,
}

impl OpKind {
    /// Short operator mnemonic for table output.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Input => "input",
            OpKind::Conv2d { .. } => "conv",
            OpKind::Pool2d { .. } => "pool",
            OpKind::FullyConnected { .. } => "fc",
            OpKind::Softmax => "softmax",
            OpKind::Concat => "concat",
            OpKind::Add => "add",
        }
    }

    /// Legal in-degree range `(min, max)`; `max` is `None` for variadic
    /// operators (concat).
    fn arity(&self) -> (usize, Option<usize>) {
        match self {
            OpKind::Input => (0, Some(0)),
            OpKind::Conv2d { .. }
            | OpKind::Pool2d { .. }
            | OpKind::FullyConnected { .. }
            | OpKind::Softmax => (1, Some(1)),
            OpKind::Add => (2, Some(2)),
            OpKind::Concat => (2, None),
        }
    }
}

/// A layer (graph node): operator plus inferred output shape.
#[derive(Debug, Clone)]
pub struct Layer {
    pub id: LayerId,
    pub name: String,
    pub op: OpKind,
    /// Output activation shape, `[N, C, H, W]` or `[N, C]`.
    pub out_shape: Vec<usize>,
    /// Input activation shapes (one per in-edge, in edge order).
    pub in_shapes: Vec<Vec<usize>>,
}

impl Layer {
    /// Number of trainable parameters.
    pub fn param_count(&self) -> usize {
        match &self.op {
            OpKind::Conv2d { cout, kernel, .. } => {
                let cin = self.in_shapes[0][1];
                cout * cin * kernel.0 * kernel.1 + cout
            }
            OpKind::FullyConnected { cout } => {
                let cin: usize = self.in_shapes[0][1..].iter().product();
                cout * cin + cout
            }
            _ => 0,
        }
    }

    /// Parameter bytes (f32).
    pub fn param_bytes(&self) -> f64 {
        self.param_count() as f64 * 4.0
    }

    /// Forward FLOPs for the **whole** layer at the stored batch size.
    pub fn fwd_flops(&self) -> f64 {
        let out: f64 = self.out_shape.iter().product::<usize>() as f64;
        match &self.op {
            OpKind::Input => 0.0,
            OpKind::Conv2d { kernel, .. } => {
                let cin = self.in_shapes[0][1] as f64;
                2.0 * out * cin * (kernel.0 * kernel.1) as f64
            }
            OpKind::Pool2d { kernel, .. } => out * (kernel.0 * kernel.1) as f64,
            OpKind::FullyConnected { .. } => {
                let cin: f64 = self.in_shapes[0][1..].iter().product::<usize>() as f64;
                2.0 * out * cin
            }
            OpKind::Softmax => 5.0 * out,
            OpKind::Concat => 0.0,
            OpKind::Add => out,
        }
    }

    /// Total (forward + backward) FLOPs. Backward re-runs roughly two
    /// convolution-shaped passes (data grad + weight grad), the standard
    /// 3x-forward approximation for training compute.
    pub fn train_flops(&self) -> f64 {
        match &self.op {
            OpKind::Input => 0.0,
            _ => 3.0 * self.fwd_flops(),
        }
    }

    /// Bytes of activation output (f32).
    pub fn out_bytes(&self) -> f64 {
        self.out_shape.iter().product::<usize>() as f64 * 4.0
    }

    /// Bytes touched per training step (inputs + output + params, fwd+bwd).
    /// Used for the memory-bound roofline of cheap layers.
    pub fn mem_bytes(&self) -> f64 {
        let ins: f64 =
            self.in_shapes.iter().map(|s| s.iter().product::<usize>() as f64 * 4.0).sum();
        // fwd reads ins writes out; bwd reads grads writes grads: ~3x.
        3.0 * (ins + self.out_bytes()) + 2.0 * self.param_bytes()
    }

    /// Does this layer carry trainable parameters?
    pub fn has_params(&self) -> bool {
        matches!(self.op, OpKind::Conv2d { .. } | OpKind::FullyConnected { .. })
    }
}

/// Spatial output extent of a convolution/pooling window, or the reason
/// it is degenerate (zero kernel/stride, kernel beyond the padded
/// extent). The former `assert!` here is now a plain-message error the
/// caller wraps with layer context, so a degenerate conv in a wire spec
/// is a one-line rejection, not a panic (and a zero stride is not a
/// divide-by-zero).
fn conv_out(hw: usize, k: usize, s: usize, p: usize) -> std::result::Result<usize, String> {
    if k == 0 || s == 0 {
        return Err(format!("kernel ({k}) and stride ({s}) must be at least 1"));
    }
    let padded = p
        .checked_mul(2)
        .and_then(|pp| hw.checked_add(pp))
        .ok_or_else(|| format!("padded extent overflows ({hw} + 2 x {p})"))?;
    if padded < k {
        return Err(format!("kernel {k} larger than padded extent {padded}"));
    }
    Ok((padded - k) / s + 1)
}

/// The output shape `op` produces from `in_shapes` — the one shape
/// inference shared by [`GraphBuilder`] and [`CompGraph::validate`], so
/// a spec-declared shape can never disagree with what the builder would
/// have inferred. `name` labels errors. [`OpKind::Input`] has no inputs
/// to infer from and is handled by the callers.
fn infer_out_shape(name: &str, op: &OpKind, in_shapes: &[Vec<usize>]) -> Result<Vec<usize>> {
    let (min, max) = op.arity();
    if in_shapes.len() < min || max.is_some_and(|m| in_shapes.len() > m) {
        let want = match max {
            Some(m) if m == min => format!("{min}"),
            Some(m) => format!("{min}..={m}"),
            None => format!(">= {min}"),
        };
        return Err(invalid(format!(
            "layer `{name}` ({}) takes {want} input(s), got {}",
            op.mnemonic(),
            in_shapes.len()
        )));
    }
    let need_4d = |s: &[usize]| -> Result<()> {
        if s.len() != 4 {
            return Err(invalid(format!(
                "layer `{name}` ({}) needs a 4-D input, got {s:?}",
                op.mnemonic()
            )));
        }
        Ok(())
    };
    match op {
        // callers skip layer 0 and reject later inputs before inferring,
        // but stay typed rather than panic if a new caller forgets
        OpKind::Input => Err(invalid(format!(
            "layer `{name}`: input shapes are declared, not inferred"
        ))),
        OpKind::Conv2d { cout, kernel, stride, padding } => {
            let s = &in_shapes[0];
            need_4d(s)?;
            if *cout == 0 {
                return Err(invalid(format!("layer `{name}`: conv cout must be at least 1")));
            }
            Ok(vec![
                s[0],
                *cout,
                conv_out(s[2], kernel.0, stride.0, padding.0)
                    .map_err(|e| invalid(format!("layer `{name}`: {e}")))?,
                conv_out(s[3], kernel.1, stride.1, padding.1)
                    .map_err(|e| invalid(format!("layer `{name}`: {e}")))?,
            ])
        }
        OpKind::Pool2d { kernel, stride, padding, .. } => {
            let s = &in_shapes[0];
            need_4d(s)?;
            Ok(vec![
                s[0],
                s[1],
                conv_out(s[2], kernel.0, stride.0, padding.0)
                    .map_err(|e| invalid(format!("layer `{name}`: {e}")))?,
                conv_out(s[3], kernel.1, stride.1, padding.1)
                    .map_err(|e| invalid(format!("layer `{name}`: {e}")))?,
            ])
        }
        OpKind::FullyConnected { cout } => {
            let s = &in_shapes[0];
            if s.len() < 2 {
                return Err(invalid(format!(
                    "layer `{name}` (fc) needs a rank >= 2 input, got {s:?}"
                )));
            }
            if *cout == 0 {
                return Err(invalid(format!("layer `{name}`: fc cout must be at least 1")));
            }
            Ok(vec![s[0], *cout])
        }
        OpKind::Softmax => {
            let s = &in_shapes[0];
            if s.len() != 2 {
                return Err(invalid(format!(
                    "layer `{name}` (softmax) expects a 2-D input, got {s:?}"
                )));
            }
            Ok(s.clone())
        }
        OpKind::Concat => {
            let first = &in_shapes[0];
            need_4d(first)?;
            let mut c = 0usize;
            for s in in_shapes {
                need_4d(s)?;
                if (s[0], s[2], s[3]) != (first[0], first[2], first[3]) {
                    return Err(invalid(format!(
                        "layer `{name}`: concat NHW mismatch ({s:?} vs {first:?})"
                    )));
                }
                c += s[1];
            }
            Ok(vec![first[0], c, first[2], first[3]])
        }
        OpKind::Add => {
            if in_shapes[0] != in_shapes[1] {
                return Err(invalid(format!(
                    "layer `{name}`: add shape mismatch ({:?} vs {:?})",
                    in_shapes[0], in_shapes[1]
                )));
            }
            Ok(in_shapes[0].clone())
        }
    }
}

/// A computation graph: layers plus directed tensor edges.
#[derive(Debug, Clone)]
pub struct CompGraph {
    pub name: String,
    pub layers: Vec<Layer>,
    pub edges: Vec<(LayerId, LayerId)>,
    /// Lazily computed structural digest (see [`CompGraph::digest`]).
    digest: std::sync::OnceLock<GraphDigest>,
}

impl CompGraph {
    /// Assemble a graph from parts and validate it — the only way to
    /// construct a `CompGraph` outside this module, so every live graph
    /// has passed [`CompGraph::validate`].
    pub fn new(
        name: String,
        layers: Vec<Layer>,
        edges: Vec<(LayerId, LayerId)>,
    ) -> Result<CompGraph> {
        let g = CompGraph { name, layers, edges, digest: std::sync::OnceLock::new() };
        g.validate()?;
        Ok(g)
    }

    /// Re-validate and rebuild, resetting the cached digest. Used when
    /// taking ownership of a graph that may have been mutated after its
    /// digest was computed (`layers`/`edges` are `pub`), so a stale
    /// digest can never alias another graph's cache entries.
    pub fn revalidated(self) -> Result<CompGraph> {
        CompGraph::new(self.name, self.layers, self.edges)
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id]
    }

    /// Ids of layers feeding `id`, in edge order.
    pub fn predecessors(&self, id: LayerId) -> Vec<LayerId> {
        self.edges.iter().filter(|(_, d)| *d == id).map(|(s, _)| *s).collect()
    }

    /// Ids of layers consuming `id`'s output.
    pub fn successors(&self, id: LayerId) -> Vec<LayerId> {
        self.edges.iter().filter(|(s, _)| *s == id).map(|(_, d)| *d).collect()
    }

    /// Total trainable parameters.
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Total per-step training FLOPs.
    pub fn total_train_flops(&self) -> f64 {
        self.layers.iter().map(|l| l.train_flops()).sum()
    }

    /// The graph's global batch size (the sample extent of its input).
    pub fn batch(&self) -> usize {
        self.layers[0].out_shape[0]
    }

    /// Validate every structural invariant the planner, cost model,
    /// simulator, and executor rely on: a single `Input` at id 0, dense
    /// topologically-ordered ids, in-range forward edges (which also
    /// rules out cycles), in-degrees matching declared input shapes,
    /// edge shapes agreeing with their producers, and every layer's
    /// output shape matching what its operator infers from its inputs.
    ///
    /// Formerly a panicking `check()`; now the typed choke point between
    /// untrusted graph sources (wire specs, `--network-file`) and the
    /// rest of the crate.
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            return Err(invalid("graph has no layers".into()));
        }
        if !matches!(self.layers[0].op, OpKind::Input) {
            return Err(invalid("layer 0 must be the graph input".into()));
        }
        for (i, l) in self.layers.iter().enumerate() {
            if l.id != i {
                return Err(invalid(format!(
                    "layer ids must be dense: layer at position {i} carries id {}",
                    l.id
                )));
            }
            if i > 0 && matches!(l.op, OpKind::Input) {
                return Err(invalid(format!(
                    "layer `{}` ({i}) is a second input; graphs have exactly one",
                    l.name
                )));
            }
        }
        for &(s, d) in &self.edges {
            if s >= self.layers.len() || d >= self.layers.len() {
                return Err(invalid(format!("dangling edge ({s}, {d})")));
            }
            if s >= d {
                return Err(invalid(format!(
                    "edges must go forward in topological id order: ({s}, {d})"
                )));
            }
        }
        {
            let input = &self.layers[0];
            if !matches!(input.out_shape.len(), 2 | 4) {
                return Err(invalid(format!(
                    "input shape must be [N, C] or [N, C, H, W], got {:?}",
                    input.out_shape
                )));
            }
            if input.out_shape.iter().any(|&d| d == 0) {
                return Err(invalid(format!(
                    "input shape has a zero extent: {:?}",
                    input.out_shape
                )));
            }
        }
        for l in &self.layers {
            let preds = self.predecessors(l.id);
            if preds.len() != l.in_shapes.len() {
                return Err(invalid(format!(
                    "layer `{}` ({}) has {} in-edge(s) but {} declared input shape(s)",
                    l.name,
                    l.id,
                    preds.len(),
                    l.in_shapes.len()
                )));
            }
            for (k, p) in preds.iter().enumerate() {
                if preds[..k].contains(p) {
                    // `CostModel::edge_in_idx` resolves producers by id,
                    // so duplicate edges would silently alias one input
                    // slot — reject rather than mis-plan
                    return Err(invalid(format!(
                        "layer `{}` ({}) lists input {p} more than once",
                        l.name, l.id
                    )));
                }
                if self.layers[*p].out_shape != l.in_shapes[k] {
                    return Err(invalid(format!(
                        "shape mismatch on edge {} -> {}: {:?} vs {:?}",
                        self.layers[*p].name, l.name, self.layers[*p].out_shape, l.in_shapes[k]
                    )));
                }
            }
            if !matches!(l.op, OpKind::Input) {
                let want = infer_out_shape(&l.name, &l.op, &l.in_shapes)?;
                if want != l.out_shape {
                    return Err(invalid(format!(
                        "layer `{}` ({}) declares shape {:?} but its operator produces {:?}",
                        l.name,
                        l.op.mnemonic(),
                        l.out_shape,
                        want
                    )));
                }
            }
            // `Layer::param_count` multiplies unchecked; prove here that
            // the product fits so spec-reachable sizes can never wrap
            let params_fit = match &l.op {
                OpKind::Conv2d { cout, kernel, .. } => l.in_shapes[0][1]
                    .checked_mul(*cout)
                    .and_then(|x| x.checked_mul(kernel.0))
                    .and_then(|x| x.checked_mul(kernel.1))
                    .and_then(|x| x.checked_add(*cout))
                    .is_some(),
                OpKind::FullyConnected { cout } => l.in_shapes[0][1..]
                    .iter()
                    .try_fold(*cout, |x, &d| x.checked_mul(d))
                    .and_then(|x| x.checked_add(*cout))
                    .is_some(),
                _ => true,
            };
            if !params_fit {
                return Err(invalid(format!(
                    "layer `{}` ({}): parameter count overflows",
                    l.name, l.id
                )));
            }
        }
        Ok(())
    }
}

/// Incremental graph builder with shape inference.
///
/// Every method is fallible: malformed wiring (unknown layer ids, shape
/// mismatches, degenerate windows) returns [`OptError::InvalidGraph`]
/// instead of panicking, so builders can run over untrusted descriptions.
pub struct GraphBuilder {
    name: String,
    layers: Vec<Layer>,
    edges: Vec<(LayerId, LayerId)>,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder { name: name.to_string(), layers: Vec::new(), edges: Vec::new() }
    }

    /// The declared output shape of `id`, or an error naming the bad id.
    fn shape_of(&self, id: LayerId) -> Result<&Vec<usize>> {
        self.layers
            .get(id)
            .map(|l| &l.out_shape)
            .ok_or_else(|| invalid(format!("unknown layer id {id} ({} built)", self.layers.len())))
    }

    fn push(
        &mut self,
        name: String,
        op: OpKind,
        inputs: &[LayerId],
        out_shape: Vec<usize>,
    ) -> LayerId {
        let id = self.layers.len();
        let in_shapes =
            inputs.iter().map(|&i| self.layers[i].out_shape.clone()).collect();
        for &i in inputs {
            self.edges.push((i, id));
        }
        self.layers.push(Layer { id, name, op, out_shape, in_shapes });
        id
    }

    /// Infer the output shape of `op` over `inputs` and append the layer.
    fn infer_push(&mut self, name: &str, op: OpKind, inputs: &[LayerId]) -> Result<LayerId> {
        if self.layers.is_empty() {
            return Err(invalid(format!(
                "layer `{name}` added before the graph input"
            )));
        }
        let mut in_shapes = Vec::with_capacity(inputs.len());
        for &i in inputs {
            in_shapes.push(self.shape_of(i)?.clone());
        }
        let out = infer_out_shape(name, &op, &in_shapes)?;
        Ok(self.push(name.into(), op, inputs, out))
    }

    /// The graph input: `[n, c, h, w]` images.
    pub fn input(&mut self, n: usize, c: usize, h: usize, w: usize) -> Result<LayerId> {
        if !self.layers.is_empty() {
            return Err(invalid("input must be the first layer".into()));
        }
        if n == 0 || c == 0 || h == 0 || w == 0 {
            return Err(invalid(format!(
                "input shape [{n}, {c}, {h}, {w}] has a zero extent"
            )));
        }
        Ok(self.push("input".into(), OpKind::Input, &[], vec![n, c, h, w]))
    }

    pub fn conv2d(
        &mut self,
        name: &str,
        input: LayerId,
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
    ) -> Result<LayerId> {
        self.infer_push(name, OpKind::Conv2d { cout, kernel, stride, padding }, &[input])
    }

    pub fn pool2d(
        &mut self,
        name: &str,
        input: LayerId,
        kind: PoolKind,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
    ) -> Result<LayerId> {
        self.infer_push(name, OpKind::Pool2d { kind, kernel, stride, padding }, &[input])
    }

    pub fn fully_connected(&mut self, name: &str, input: LayerId, cout: usize) -> Result<LayerId> {
        self.infer_push(name, OpKind::FullyConnected { cout }, &[input])
    }

    pub fn softmax(&mut self, name: &str, input: LayerId) -> Result<LayerId> {
        self.infer_push(name, OpKind::Softmax, &[input])
    }

    /// Channel concatenation of 4-D activations with equal N/H/W.
    pub fn concat(&mut self, name: &str, inputs: &[LayerId]) -> Result<LayerId> {
        self.infer_push(name, OpKind::Concat, inputs)
    }

    /// Element-wise residual addition; shapes must match exactly.
    pub fn add(&mut self, name: &str, a: LayerId, b: LayerId) -> Result<LayerId> {
        self.infer_push(name, OpKind::Add, &[a, b])
    }

    pub fn finish(self) -> Result<CompGraph> {
        CompGraph::new(self.name, self.layers, self.edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(n: usize) -> CompGraph {
        let mut b = GraphBuilder::new("tiny");
        let x = b.input(n, 3, 8, 8).unwrap();
        let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), (1, 1)).unwrap();
        let p1 = b.pool2d("p1", c1, PoolKind::Max, (2, 2), (2, 2), (0, 0)).unwrap();
        let f1 = b.fully_connected("f1", p1, 10).unwrap();
        b.softmax("sm", f1).unwrap();
        b.finish().unwrap()
    }

    #[test]
    fn shape_inference_chain() {
        let g = tiny(2);
        assert_eq!(g.layer(1).out_shape, vec![2, 4, 8, 8]); // same-pad conv
        assert_eq!(g.layer(2).out_shape, vec![2, 4, 4, 4]); // 2x2/2 pool
        assert_eq!(g.layer(3).out_shape, vec![2, 10]);
        assert_eq!(g.layer(4).out_shape, vec![2, 10]);
        assert_eq!(g.batch(), 2);
    }

    #[test]
    fn param_counts() {
        let g = tiny(2);
        assert_eq!(g.layer(1).param_count(), 4 * 3 * 3 * 3 + 4);
        assert_eq!(g.layer(3).param_count(), 10 * (4 * 4 * 4) + 10);
        assert_eq!(g.layer(2).param_count(), 0);
    }

    #[test]
    fn flops_scale_with_batch() {
        let f2 = tiny(2).total_train_flops();
        let f4 = tiny(4).total_train_flops();
        assert!((f4 / f2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn conv_flops_formula() {
        let g = tiny(1);
        // conv: 2 * (1*4*8*8) * 3 * 9 fwd
        assert_eq!(g.layer(1).fwd_flops(), 2.0 * (4.0 * 64.0) * 3.0 * 9.0);
        assert_eq!(g.layer(1).train_flops(), 3.0 * g.layer(1).fwd_flops());
    }

    #[test]
    fn concat_and_add_shapes() {
        let mut b = GraphBuilder::new("branchy");
        let x = b.input(1, 8, 4, 4).unwrap();
        let a = b.conv2d("a", x, 8, (1, 1), (1, 1), (0, 0)).unwrap();
        let c = b.conv2d("c", x, 16, (1, 1), (1, 1), (0, 0)).unwrap();
        let cat = b.concat("cat", &[a, c]).unwrap();
        let d = b.conv2d("d", cat, 8, (1, 1), (1, 1), (0, 0)).unwrap();
        let res = b.add("res", a, d).unwrap();
        let g = {
            let f = b.fully_connected("f", res, 10).unwrap();
            b.softmax("sm", f).unwrap();
            b.finish().unwrap()
        };
        assert_eq!(g.layer(cat).out_shape, vec![1, 24, 4, 4]);
        assert_eq!(g.layer(res).out_shape, vec![1, 8, 4, 4]);
        assert_eq!(g.predecessors(res), vec![a, d]);
        assert_eq!(g.successors(x), vec![a, c]);
    }

    #[test]
    fn mismatched_add_is_an_error_not_a_panic() {
        let mut b = GraphBuilder::new("bad");
        let x = b.input(1, 3, 4, 4).unwrap();
        let a = b.conv2d("a", x, 4, (1, 1), (1, 1), (0, 0)).unwrap();
        let err = b.add("bad", x, a).unwrap_err();
        assert!(matches!(err, OptError::InvalidGraph(_)), "{err:?}");
        assert!(err.to_string().contains("add shape mismatch"), "{err}");
    }

    #[test]
    fn degenerate_windows_are_errors() {
        let mut b = GraphBuilder::new("bad");
        let x = b.input(1, 3, 4, 4).unwrap();
        // kernel larger than the padded extent
        let err = b.conv2d("huge", x, 4, (9, 9), (1, 1), (0, 0)).unwrap_err();
        assert!(err.to_string().contains("padded extent"), "{err}");
        // zero stride would otherwise divide by zero
        let err = b.conv2d("still", x, 4, (1, 1), (0, 1), (0, 0)).unwrap_err();
        assert!(matches!(err, OptError::InvalidGraph(_)), "{err:?}");
        // zero-channel conv
        assert!(b.conv2d("empty", x, 0, (1, 1), (1, 1), (0, 0)).is_err());
        // the builder is still usable after rejected layers
        let c = b.conv2d("ok", x, 4, (3, 3), (1, 1), (1, 1)).unwrap();
        assert_eq!(b.layers[c].out_shape, vec![1, 4, 4, 4]);
    }

    #[test]
    fn bad_wiring_is_an_error() {
        let mut b = GraphBuilder::new("bad");
        let x = b.input(1, 3, 4, 4).unwrap();
        assert!(b.conv2d("dangling", 99, 4, (1, 1), (1, 1), (0, 0)).is_err());
        assert!(b.input(1, 3, 4, 4).is_err(), "second input must be rejected");
        assert!(b.softmax("sm4d", x).is_err(), "softmax on 4-D input");
        assert!(b.concat("one", &[x]).is_err(), "concat needs >= 2 inputs");
    }

    #[test]
    fn graph_validate_passes_on_builders() {
        tiny(32).validate().unwrap();
    }

    #[test]
    fn validate_rejects_corrupted_graphs() {
        let good = tiny(2);
        // backward edge (a cycle, expressed against topological order)
        let mut bad = good.clone();
        bad.edges.push((3, 1));
        assert!(matches!(bad.validate(), Err(OptError::InvalidGraph(_))));
        // dangling edge
        let mut bad = good.clone();
        bad.edges.push((1, 99));
        assert!(bad.validate().unwrap_err().to_string().contains("dangling"));
        // declared shape disagreeing with the operator
        let mut bad = good.clone();
        bad.layers[1].out_shape = vec![2, 5, 8, 8];
        assert!(matches!(bad.validate(), Err(OptError::InvalidGraph(_))));
        // non-dense ids
        let mut bad = good;
        bad.layers[2].id = 7;
        assert!(bad.validate().unwrap_err().to_string().contains("dense"));
    }
}
