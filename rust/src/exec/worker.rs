//! Worker threads: one per simulated device.
//!
//! A worker owns its own PJRT [`Engine`] (the client is not `Send`), a
//! parameter-shard store, and an activation stash (forward inputs kept
//! resident for the backward pass, as a real device would). The leader
//! talks to workers over mpsc channels; every tensor crossing a channel
//! is accounted as communication by the leader.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::graph::LayerId;
use crate::runtime::{ArtifactStore, Engine};
use crate::tensor::Tensor;

/// Leader -> worker requests.
pub enum Req {
    /// Install (or replace) this worker's parameter shard for a layer.
    LoadParams { layer: LayerId, params: Vec<Tensor> },
    /// Run a forward artifact. `inputs` are activation inputs; the
    /// worker appends its parameter shard when `with_params`. When
    /// `stash`, `inputs[0]` is kept for the backward pass.
    Forward { layer: LayerId, key: String, inputs: Vec<Tensor>, with_params: bool, stash: bool },
    /// Run a backward artifact with the stashed forward input, the
    /// parameter shard (when `with_params`; `with_bias` controls whether
    /// the bias is an artifact input — linear layers exclude it, matching
    /// the AOT signatures), and the upstream gradient.
    Backward { layer: LayerId, key: String, dy: Tensor, with_params: bool, with_bias: bool },
    Shutdown,
}

/// Worker -> leader responses.
pub enum Resp {
    Out { outputs: Vec<Tensor> },
    Grads { dx: Tensor, dparams: Vec<Tensor> },
    Err(String),
}

/// A handle the leader keeps per worker.
pub struct WorkerHandle {
    pub id: usize,
    pub req: Sender<Req>,
    pub resp: Receiver<Resp>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn a worker thread with its own PJRT engine.
    pub fn spawn(id: usize, store: ArtifactStore) -> WorkerHandle {
        let (req_tx, req_rx) = channel::<Req>();
        let (resp_tx, resp_rx) = channel::<Resp>();
        let join = std::thread::Builder::new()
            .name(format!("optcnn-worker-{id}"))
            .spawn(move || worker_main(store, req_rx, resp_tx))
            .expect("spawning worker thread");
        WorkerHandle { id, req: req_tx, resp: resp_rx, join: Some(join) }
    }

    /// Await one response, turning worker-side errors into `Err`.
    pub fn recv(&self) -> Result<Resp> {
        match self.resp.recv() {
            Ok(Resp::Err(e)) => Err(anyhow!("worker {}: {e}", self.id)),
            Ok(r) => Ok(r),
            Err(_) => Err(anyhow!("worker {} hung up", self.id)),
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.req.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

struct WorkerState {
    engine: Engine,
    /// Parameter shard per layer (w, b order).
    params: Vec<Option<Vec<Tensor>>>,
    /// Stashed forward input per layer (for backward).
    stash: Vec<Option<Tensor>>,
}

fn worker_main(store: ArtifactStore, req: Receiver<Req>, resp: Sender<Resp>) {
    let engine = match Engine::new(store) {
        Ok(e) => e,
        Err(e) => {
            let _ = resp.send(Resp::Err(format!("engine init: {e:#}")));
            return;
        }
    };
    let mut st = WorkerState { engine, params: Vec::new(), stash: Vec::new() };
    while let Ok(msg) = req.recv() {
        match msg {
            Req::Shutdown => break,
            Req::LoadParams { layer, params } => {
                grow(&mut st.params, layer);
                st.params[layer] = Some(params);
            }
            Req::Forward { layer, key, inputs, with_params, stash } => {
                let r = forward(&mut st, layer, &key, inputs, with_params, stash);
                let _ = resp.send(unwrap_out(r));
            }
            Req::Backward { layer, key, dy, with_params, with_bias } => {
                let r = backward(&mut st, layer, &key, dy, with_params, with_bias);
                let _ = resp.send(r.unwrap_or_else(|e| Resp::Err(format!("{e:#}"))));
            }
        }
    }
}

fn unwrap_out(r: Result<Vec<Tensor>>) -> Resp {
    match r {
        Ok(outputs) => Resp::Out { outputs },
        Err(e) => Resp::Err(format!("{e:#}")),
    }
}

fn grow<T>(v: &mut Vec<Option<T>>, idx: usize) {
    if v.len() <= idx {
        v.resize_with(idx + 1, || None);
    }
}

fn forward(
    st: &mut WorkerState,
    layer: LayerId,
    key: &str,
    inputs: Vec<Tensor>,
    with_params: bool,
    stash: bool,
) -> Result<Vec<Tensor>> {
    if stash {
        grow(&mut st.stash, layer);
        st.stash[layer] = Some(inputs.first().cloned().expect("stash needs an input"));
    }
    let mut args = inputs;
    if with_params {
        let shard = st
            .params
            .get(layer)
            .and_then(|p| p.as_ref())
            .ok_or_else(|| anyhow!("layer {layer}: params not loaded"))?;
        args.extend(shard.iter().cloned());
    }
    st.engine.run(key, &args)
}

fn backward(
    st: &mut WorkerState,
    layer: LayerId,
    key: &str,
    dy: Tensor,
    with_params: bool,
    with_bias: bool,
) -> Result<Resp> {
    let x = st
        .stash
        .get(layer)
        .and_then(|s| s.as_ref())
        .ok_or_else(|| anyhow!("layer {layer}: no stashed activation for backward"))?
        .clone();
    let mut args = vec![x];
    if with_params {
        let shard = st
            .params
            .get(layer)
            .and_then(|p| p.as_ref())
            .ok_or_else(|| anyhow!("layer {layer}: params not loaded"))?;
        let take = if with_bias { shard.len() } else { 1 };
        args.extend(shard.iter().take(take).cloned());
    }
    args.push(dy);
    let mut out = st.engine.run(key, &args)?;
    let dx = out.remove(0);
    Ok(Resp::Grads { dx, dparams: out })
}
