//! Partitioned training executor (the end-to-end proof that layer-wise
//! parallelism computes *the same network* as serial training).
//!
//! The leader (this module) owns the master parameters (acting as the
//! parameter server), repartitions activations between differently-
//! configured layers (scatter / halo-slab / gather built on `tensor/`),
//! and drives one [`worker::WorkerHandle`] per simulated device; workers
//! execute the AOT-compiled HLO artifacts through their own PJRT engines.
//!
//! Numerical contract: for ANY legal strategy, `Trainer::step` computes
//! bit-comparable losses and parameter updates to the single-device
//! [`OracleTrainer`] (the full-model JAX train-step artifact) — the
//! executable form of the paper's claim that every configuration
//! "performs the same computation ... and therefore maintains the same
//! network accuracy".
//!
//! Topology note: repartitioning is hub-and-spoke through the leader (a
//! parameter-server-style coordinator), so wall-clock here does not model
//! the paper's p2p cluster — the discrete-event simulator (`sim/`) does
//! that; this module is about numerics, liveness, and the coordinator
//! architecture.
//!
//! Partitioning geometry comes from a materialized
//! [`ExecutionPlan`](crate::plan::ExecutionPlan) built at trainer
//! construction: output tiles, per-tile input regions, and sync shards
//! are read from the plan (the same IR the cost model and simulator
//! consume), never re-derived inline. Two communication counters are
//! kept: [`Trainer::comm`] is the observed hub-and-spoke leader traffic,
//! and [`Trainer::plan_comm`] is the plan's scheduled p2p volume — the
//! number a peer-to-peer runtime would move, and the one that matches
//! `sim::SimReport` byte-for-byte.

pub mod keys;
pub mod worker;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::graph::{CompGraph, LayerId, OpKind};
use crate::parallel::{PConfig, Strategy, DIM_C, DIM_H, DIM_N, DIM_W};
use crate::plan::ExecutionPlan;
use crate::runtime::{ArtifactStore, Engine};
use crate::tensor::{Region, Tensor};
use crate::util::rng::Rng;
use worker::{Req, Resp, WorkerHandle};

/// Communication accounting for the executor's message traffic.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CommStats {
    /// Activation/gradient tensor bytes (the `t_X` analogue).
    pub xfer_bytes: u64,
    /// Parameter + gradient shard bytes (the `t_S` analogue).
    pub sync_bytes: u64,
}

impl CommStats {
    pub fn total(&self) -> u64 {
        self.xfer_bytes + self.sync_bytes
    }

    /// The per-step p2p communication an execution plan schedules —
    /// identical to the simulator's per-step `xfer_bytes`/`sync_bytes`
    /// for the same (graph, strategy, devices) triple.
    pub fn planned(plan: &ExecutionPlan) -> CommStats {
        CommStats {
            xfer_bytes: plan.xfer_bytes().round() as u64,
            sync_bytes: plan.sync_bytes().round() as u64,
        }
    }
}

/// The partitioned trainer (leader + workers).
pub struct Trainer {
    graph: CompGraph,
    strategy: Strategy,
    /// Materialized partitioning consequences (tiles, input regions, sync
    /// shards) — the single source of geometry for scatter/halo/gather.
    plan: ExecutionPlan,
    workers: Vec<WorkerHandle>,
    /// Master copy of each layer's parameters (`[w, b]`), the PS state.
    params: Vec<Option<Vec<Tensor>>>,
    relu: Vec<bool>,
    lr: f32,
    batch: usize,
    /// Observed leader<->worker traffic (hub-and-spoke topology).
    pub comm: CommStats,
    /// The plan's scheduled p2p volume per step (matches the simulator).
    pub plan_comm: CommStats,
    pub steps: u64,
}

impl Trainer {
    /// Build a trainer for `graph` under `strategy` with `ndev` workers.
    ///
    /// Validates that the graph is a supported chain (MiniCNN-class:
    /// conv/pool/fc/softmax) and that every (layer, config) artifact
    /// exists in the store.
    pub fn new(
        store: &ArtifactStore,
        graph: CompGraph,
        strategy: Strategy,
        ndev: usize,
        lr: f32,
        seed: u64,
    ) -> Result<Trainer> {
        ensure!(strategy.configs.len() == graph.num_layers(), "strategy/graph size mismatch");
        let batch = graph.layer(0).out_shape[DIM_N];
        // chain + op support validation
        for l in &graph.layers {
            let preds = graph.predecessors(l.id);
            match l.op {
                OpKind::Input => ensure!(preds.is_empty(), "input with predecessors"),
                OpKind::Conv2d { stride, .. } => {
                    ensure!(stride == (1, 1), "executor supports stride-1 convs");
                    ensure!(preds.len() == 1, "non-chain graph");
                }
                OpKind::Pool2d { kernel, stride, padding, .. } => {
                    ensure!(kernel.0 == kernel.1 && stride == kernel && padding == (0, 0),
                        "executor supports k==s unpadded pooling");
                    ensure!(preds.len() == 1, "non-chain graph");
                }
                OpKind::FullyConnected { .. } | OpKind::Softmax => {
                    ensure!(preds.len() == 1, "non-chain graph")
                }
                _ => bail!("executor does not support op {:?}", l.op.mnemonic()),
            }
            ensure!(
                strategy.config(l.id).total() <= ndev,
                "layer {} config {} exceeds {ndev} devices",
                l.name,
                strategy.config(l.id).label()
            );
        }
        let relu = relu_flags(&graph);
        // Materialize the plan on the executor's topology: one node of
        // `ndev` workers, tile t on worker t (contiguous placement). The
        // plan's byte totals are topology-independent, so `plan_comm`
        // matches a simulation of the same strategy on any cluster shape.
        let plan = {
            let exec_devices = crate::device::DeviceGraph::cluster(
                "exec-workers",
                1,
                ndev,
                1e9,
                1e9,
                1e9,
                crate::device::ComputeModel::p100(),
            )
            .expect("the executor's 1-node worker topology is always valid");
            let cm = crate::cost::CostModel::new(&graph, &exec_devices);
            ExecutionPlan::build(&cm, &strategy)
        };
        let plan_comm = CommStats::planned(&plan);
        let mut t = Trainer {
            workers: (0..ndev).map(|i| WorkerHandle::spawn(i, store.clone())).collect(),
            params: init_params(&graph, seed),
            relu,
            lr,
            batch,
            comm: CommStats::default(),
            plan_comm,
            steps: 0,
            graph,
            strategy,
            plan,
        };
        t.check_artifacts(store)?;
        t.distribute_all_params()?;
        Ok(t)
    }

    /// Snapshot of the master parameters (flat `[w, b]` per param layer,
    /// in layer order) — feedable to the oracle.
    pub fn master_params(&self) -> Vec<Tensor> {
        self.params.iter().flatten().flat_map(|p| p.iter().cloned()).collect()
    }

    /// Verify every artifact this (graph, strategy) pair will request.
    fn check_artifacts(&self, store: &ArtifactStore) -> Result<()> {
        for l in &self.graph.layers {
            for key in self.layer_keys(l.id) {
                ensure!(
                    store.has(&key),
                    "missing artifact `{key}` for layer {} under {} — regenerate with \
                     `make artifacts` (devices >= {})",
                    l.name,
                    self.strategy.config(l.id).label(),
                    self.strategy.config(l.id).total()
                );
            }
        }
        Ok(())
    }

    /// Output tiles of layer `id` from the materialized plan (tile index
    /// == worker id under the executor's contiguous placement).
    fn tiles(&self, id: LayerId) -> Vec<Region> {
        self.plan.layer(id).tiles.clone()
    }

    /// The input region tile `t` of layer `id` consumes from its
    /// predecessor, from the plan's transfer schedule (chain graphs have
    /// exactly one in-edge, and conv/pool/fc/softmax tiles always consume
    /// a nonempty region).
    fn need(&self, id: LayerId, t: usize) -> Region {
        self.plan
            .edge_into(id)
            .and_then(|e| e.needs[t].clone())
            .expect("chain layer tile consumes part of its predecessor")
    }

    /// The artifact keys layer `id` needs under the current strategy.
    fn layer_keys(&self, id: LayerId) -> Vec<String> {
        let l = self.graph.layer(id);
        let tiles = &self.plan.layer(id).tiles;
        let t0 = &tiles[0];
        let (nt, ct) = (t0.end(DIM_N) - t0.start(DIM_N), tile_c(t0));
        match &l.op {
            OpKind::Input => vec![],
            OpKind::Conv2d { kernel, .. } => {
                let cin = l.in_shapes[0][DIM_C];
                let (ht, wt) = (t0.end(DIM_H) - t0.start(DIM_H), t0.end(DIM_W) - t0.start(DIM_W));
                let (hs, ws) = (ht + kernel.0 - 1, wt + kernel.1 - 1);
                vec![
                    keys::conv2d(true, nt, cin, hs, ws, ct, kernel.0, self.relu[id]),
                    keys::conv2d(false, nt, cin, hs, ws, ct, kernel.0, self.relu[id]),
                ]
            }
            OpKind::Pool2d { kernel, .. } => {
                let (ht, wt) = (t0.end(DIM_H) - t0.start(DIM_H), t0.end(DIM_W) - t0.start(DIM_W));
                vec![
                    keys::maxpool(true, nt, ct, ht * kernel.0, wt * kernel.1, kernel.0),
                    keys::maxpool(false, nt, ct, ht * kernel.0, wt * kernel.1, kernel.0),
                ]
            }
            OpKind::FullyConnected { .. } => {
                let cin: usize = l.in_shapes[0][1..].iter().product();
                vec![
                    keys::fc(true, nt, cin, ct, self.relu[id]),
                    keys::fc(false, nt, cin, ct, self.relu[id]),
                ]
            }
            OpKind::Softmax => vec![keys::softmax_xent(nt, l.out_shape[DIM_C])],
            _ => vec![],
        }
    }

    /// Send every layer's parameter shards to the owning workers.
    fn distribute_all_params(&mut self) -> Result<()> {
        for id in 0..self.graph.num_layers() {
            if self.params[id].is_some() {
                self.send_params(id)?;
            }
        }
        Ok(())
    }

    fn send_params(&mut self, id: LayerId) -> Result<()> {
        let tiles = self.tiles(id);
        for (t, tile) in tiles.iter().enumerate() {
            let shard = self.param_shard(id, tile)?;
            self.comm.sync_bytes += shard.iter().map(|p| p.len() as u64 * 4).sum::<u64>();
            self.workers[t]
                .req
                .send(Req::LoadParams { layer: id, params: shard })
                .map_err(|_| anyhow!("worker {t} gone"))?;
        }
        Ok(())
    }

    /// Slice the master parameters for the tile's channel range.
    fn param_shard(&self, id: LayerId, tile: &Region) -> Result<Vec<Tensor>> {
        let master = self.params[id].as_ref().ok_or_else(|| anyhow!("no params"))?;
        let (c0, c1) = (tile.start(DIM_C), tile.end(DIM_C));
        let l = self.graph.layer(id);
        Ok(match &l.op {
            OpKind::Conv2d { .. } => {
                // w: [cout, cin, kh, kw] -> rows c0..c1; b: [cout]
                let w = &master[0];
                let mut r = Region::full(w.shape());
                r.set(0, c0, c1);
                let b = &master[1];
                vec![w.slice(&r), b.slice(&Region::new(&[(c0, c1)]))]
            }
            OpKind::FullyConnected { .. } => {
                // w: [cin, cout] -> cols c0..c1
                let w = &master[0];
                let mut r = Region::full(w.shape());
                r.set(1, c0, c1);
                let b = &master[1];
                vec![w.slice(&r), b.slice(&Region::new(&[(c0, c1)]))]
            }
            _ => bail!("layer {} has no params", l.name),
        })
    }

    /// Run one synchronous training step; returns the mean loss.
    pub fn step(&mut self, x: &Tensor, y: &Tensor) -> Result<f32> {
        ensure!(x.shape() == self.graph.layer(0).out_shape.as_slice(), "bad input shape");
        let n_layers = self.graph.num_layers();
        // ---------------- forward ----------------
        let mut acts: Vec<Option<Tensor>> = vec![None; n_layers];
        acts[0] = Some(x.clone());
        let mut loss_sum = 0.0f32;
        let mut head_grad: Option<Tensor> = None;
        for id in 1..n_layers {
            let pred = self.graph.predecessors(id)[0];
            let input = acts[pred].take().expect("chain order");
            let (out, keep) = self.forward_layer(id, &input, y, &mut loss_sum)?;
            acts[pred] = Some(input); // conv backward needs it? no — workers stash; restore for shape info
            if let Some(out) = out {
                acts[id] = Some(out);
            } else {
                head_grad = keep;
            }
        }
        // ---------------- backward ----------------
        let mut d = head_grad.ok_or_else(|| anyhow!("no softmax head in graph"))?;
        d.scale(1.0 / self.batch as f32); // mean loss
        for id in (1..n_layers).rev() {
            if matches!(self.graph.layer(id).op, OpKind::Softmax | OpKind::Input) {
                continue;
            }
            d = self.backward_layer(id, d)?;
        }
        self.steps += 1;
        Ok(loss_sum / self.batch as f32)
    }

    /// Forward one layer. Returns `(Some(full output), None)` for normal
    /// layers, `(None, Some(dlogits))` for the softmax head.
    fn forward_layer(
        &mut self,
        id: LayerId,
        input: &Tensor,
        labels: &Tensor,
        loss_sum: &mut f32,
    ) -> Result<(Option<Tensor>, Option<Tensor>)> {
        let l = self.graph.layer(id).clone();
        let tiles = self.tiles(id);
        let key = self.layer_keys(id);
        match &l.op {
            OpKind::Softmax => {
                let mut dlogits = Tensor::zeros(&l.out_shape);
                // dispatch: each tile consumes its plan-scheduled input
                // rows (the sample range, all classes)
                for t in 0..tiles.len() {
                    let rows = self.need(id, t);
                    let logit_rows = input.slice(&rows);
                    let label_rows = labels.slice(&rows);
                    self.comm.xfer_bytes += (logit_rows.len() + label_rows.len()) as u64 * 4;
                    self.workers[t]
                        .req
                        .send(Req::Forward {
                            layer: id,
                            key: key[0].clone(),
                            inputs: vec![logit_rows, label_rows],
                            with_params: false,
                            stash: false,
                        })
                        .map_err(|_| anyhow!("worker {t} gone"))?;
                }
                for t in 0..tiles.len() {
                    let Resp::Out { outputs } = self.workers[t].recv()? else {
                        bail!("unexpected response")
                    };
                    *loss_sum += outputs[0].data()[0];
                    let rows = self.need(id, t);
                    self.comm.xfer_bytes += outputs[1].len() as u64 * 4 + 4;
                    dlogits.insert(&rows, &outputs[1]);
                }
                Ok((None, Some(dlogits)))
            }
            _ => {
                let mut out = Tensor::zeros(&l.out_shape);
                let (slabs, with_params) = self.make_slabs(id, input)?;
                for (t, slab) in slabs.into_iter().enumerate() {
                    self.comm.xfer_bytes += slab.len() as u64 * 4;
                    self.workers[t]
                        .req
                        .send(Req::Forward {
                            layer: id,
                            key: key[0].clone(),
                            inputs: vec![slab],
                            with_params,
                            stash: true,
                        })
                        .map_err(|_| anyhow!("worker {t} gone"))?;
                }
                for (t, tile) in tiles.iter().enumerate() {
                    let Resp::Out { outputs } = self.workers[t].recv()? else {
                        bail!("unexpected response")
                    };
                    self.comm.xfer_bytes += outputs[0].len() as u64 * 4;
                    out.insert(tile, &outputs[0]);
                }
                Ok((Some(out), None))
            }
        }
    }

    /// Input slabs for each tile of layer `id` (leader-side scatter with
    /// halo/zero-padding), plus whether the layer carries params.
    fn make_slabs(&self, id: LayerId, input: &Tensor) -> Result<(Vec<Tensor>, bool)> {
        let l = self.graph.layer(id);
        let tiles = self.tiles(id);
        match &l.op {
            OpKind::Conv2d { kernel, padding, .. } => {
                let p = *padding;
                let in_sh = &l.in_shapes[0];
                // zero-padded input, once
                let mut padded = Tensor::zeros(&[
                    in_sh[0],
                    in_sh[1],
                    in_sh[2] + 2 * p.0,
                    in_sh[3] + 2 * p.1,
                ]);
                let inner = Region::new(&[
                    (0, in_sh[0]),
                    (0, in_sh[1]),
                    (p.0, p.0 + in_sh[2]),
                    (p.1, p.1 + in_sh[3]),
                ]);
                padded.insert(&inner, input);
                let slabs = tiles
                    .iter()
                    .map(|t| {
                        padded.slice(&Region::new(&[
                            (t.start(DIM_N), t.end(DIM_N)),
                            (0, in_sh[1]),
                            (t.start(DIM_H), t.end(DIM_H) + kernel.0 - 1),
                            (t.start(DIM_W), t.end(DIM_W) + kernel.1 - 1),
                        ]))
                    })
                    .collect();
                Ok((slabs, true))
            }
            OpKind::Pool2d { .. } => {
                // non-overlapping k==s pooling: each tile's slab is
                // exactly the plan's scheduled input region
                let slabs =
                    (0..tiles.len()).map(|t| input.slice(&self.need(id, t))).collect();
                Ok((slabs, false))
            }
            OpKind::FullyConnected { .. } => {
                let cin: usize = l.in_shapes[0][1..].iter().product();
                let flat = input.clone().reshape(&[l.in_shapes[0][0], cin]);
                let slabs = tiles
                    .iter()
                    .map(|t| {
                        flat.slice(&Region::new(&[(t.start(DIM_N), t.end(DIM_N)), (0, cin)]))
                    })
                    .collect();
                Ok((slabs, true))
            }
            _ => bail!("make_slabs: unsupported op"),
        }
    }

    /// Backward one layer: dispatch dy tiles, gather dx (scatter-add over
    /// halos), run the parameter-server update. Returns the gradient for
    /// the predecessor's output.
    fn backward_layer(&mut self, id: LayerId, d: Tensor) -> Result<Tensor> {
        let l = self.graph.layer(id).clone();
        let cfg = *self.strategy.config(id);
        let tiles = self.tiles(id);
        let key = &self.layer_keys(id)[1];
        let in_sh = l.in_shapes[0].clone();
        let with_params = l.has_params();
        // dispatch dy tiles
        for (t, tile) in tiles.iter().enumerate() {
            let dy = match l.op {
                OpKind::FullyConnected { .. } | OpKind::Softmax => d.slice(&Region::new(&[
                    (tile.start(DIM_N), tile.end(DIM_N)),
                    (tile.start(DIM_C), tile.end(DIM_C)),
                ])),
                _ => d.slice(tile),
            };
            self.comm.xfer_bytes += dy.len() as u64 * 4;
            self.workers[t]
                .req
                .send(Req::Backward {
                    layer: id,
                    key: key.clone(),
                    dy,
                    with_params,
                    with_bias: self.relu[id],
                })
                .map_err(|_| anyhow!("worker {t} gone"))?;
        }
        // gather
        let shards = cfg.deg[DIM_C];
        let mut grad_shards: Vec<Option<Vec<Tensor>>> = vec![None; shards];
        let (mut dx_full, crop): (Tensor, Option<Region>) = match &l.op {
            OpKind::Conv2d { padding, .. } => {
                let padded = [
                    in_sh[0],
                    in_sh[1],
                    in_sh[2] + 2 * padding.0,
                    in_sh[3] + 2 * padding.1,
                ];
                let inner = Region::new(&[
                    (0, in_sh[0]),
                    (0, in_sh[1]),
                    (padding.0, padding.0 + in_sh[2]),
                    (padding.1, padding.1 + in_sh[3]),
                ]);
                (Tensor::zeros(&padded), Some(inner))
            }
            OpKind::FullyConnected { .. } => {
                let cin: usize = in_sh[1..].iter().product();
                (Tensor::zeros(&[in_sh[0], cin]), None)
            }
            _ => (Tensor::zeros(&in_sh), None),
        };
        for (t, tile) in tiles.iter().enumerate() {
            let Resp::Grads { dx, dparams } = self.workers[t].recv()? else {
                bail!("unexpected response")
            };
            self.comm.xfer_bytes += dx.len() as u64 * 4;
            self.comm.sync_bytes += dparams.iter().map(|p| p.len() as u64 * 4).sum::<u64>();
            // scatter dx into the producer-gradient accumulator
            let dst = match &l.op {
                OpKind::Conv2d { kernel, .. } => Region::new(&[
                    (tile.start(DIM_N), tile.end(DIM_N)),
                    (0, in_sh[1]),
                    (tile.start(DIM_H), tile.end(DIM_H) + kernel.0 - 1),
                    (tile.start(DIM_W), tile.end(DIM_W) + kernel.1 - 1),
                ]),
                // the gradient slab goes back where the plan's scheduled
                // input region came from
                OpKind::Pool2d { .. } => self.need(id, t),
                OpKind::FullyConnected { .. } => Region::new(&[
                    (tile.start(DIM_N), tile.end(DIM_N)),
                    (0, in_sh[1..].iter().product::<usize>()),
                ]),
                _ => bail!("unsupported backward op"),
            };
            dx_full.insert_add(&dst, &dx);
            if with_params {
                let shard = crate::cost::shard_of_tile(&cfg, t);
                match &mut grad_shards[shard] {
                    None => grad_shards[shard] = Some(dparams),
                    Some(acc) => {
                        for (a, g) in acc.iter_mut().zip(dparams.iter()) {
                            a.add_assign(g);
                        }
                    }
                }
            }
        }
        // parameter-server update (SGD) + redistribute
        if with_params {
            self.apply_update(id, &cfg, grad_shards)?;
            self.send_params(id)?;
        }
        // crop conv padding / restore producer rank
        let mut dx = match crop {
            Some(inner) => dx_full.slice(&inner),
            None => dx_full,
        };
        if dx.shape() != in_sh.as_slice() {
            dx = dx.reshape(&in_sh);
        }
        Ok(dx)
    }

    /// SGD on the master params: `w -= lr * dw` per channel shard.
    fn apply_update(
        &mut self,
        id: LayerId,
        cfg: &PConfig,
        grad_shards: Vec<Option<Vec<Tensor>>>,
    ) -> Result<()> {
        let l = self.graph.layer(id).clone();
        let shards = cfg.deg[DIM_C];
        let master = self.params[id].as_mut().ok_or_else(|| anyhow!("no params"))?;
        let cout = match &l.op {
            OpKind::Conv2d { cout, .. } | OpKind::FullyConnected { cout } => *cout,
            _ => bail!("no params"),
        };
        let ct = cout / shards;
        for (s, grads) in grad_shards.into_iter().enumerate() {
            let mut grads = grads.ok_or_else(|| anyhow!("missing grads for shard {s}"))?;
            for g in &mut grads {
                g.scale(self.lr);
            }
            let (c0, c1) = (s * ct, (s + 1) * ct);
            // w
            let wr = match l.op {
                OpKind::Conv2d { .. } => {
                    let mut r = Region::full(master[0].shape());
                    r.set(0, c0, c1);
                    r
                }
                _ => {
                    let mut r = Region::full(master[0].shape());
                    r.set(1, c0, c1);
                    r
                }
            };
            let mut w_shard = master[0].slice(&wr);
            for (a, g) in w_shard.data_mut().iter_mut().zip(grads[0].data()) {
                *a -= g;
            }
            master[0].insert(&wr, &w_shard);
            // b
            let br = Region::new(&[(c0, c1)]);
            let mut b_shard = master[1].slice(&br);
            for (a, g) in b_shard.data_mut().iter_mut().zip(grads[1].data()) {
                *a -= g;
            }
            master[1].insert(&br, &b_shard);
        }
        Ok(())
    }
}

/// Which layers fold a relu: convs and every FC not feeding the softmax
/// head (mirrors `python/compile/model.ARCH`).
fn relu_flags(g: &CompGraph) -> Vec<bool> {
    g.layers
        .iter()
        .map(|l| match l.op {
            OpKind::Conv2d { .. } => true,
            OpKind::FullyConnected { .. } => !g
                .successors(l.id)
                .iter()
                .any(|&s| matches!(g.layer(s).op, OpKind::Softmax)),
            _ => false,
        })
        .collect()
}

/// He-initialized master parameters, deterministic in `seed`.
fn init_params(g: &CompGraph, seed: u64) -> Vec<Option<Vec<Tensor>>> {
    g.layers
        .iter()
        .map(|l| match &l.op {
            OpKind::Conv2d { cout, kernel, .. } => {
                let cin = l.in_shapes[0][DIM_C];
                let mut rng = Rng::new(seed ^ (l.id as u64) << 8);
                let fan_in = (cin * kernel.0 * kernel.1) as f64;
                let std = (2.0 / fan_in).sqrt() as f32;
                let w = Tensor::from_fn(&[*cout, cin, kernel.0, kernel.1], |_| {
                    rng.next_gaussian() as f32 * std
                });
                Some(vec![w, Tensor::zeros(&[*cout])])
            }
            OpKind::FullyConnected { cout } => {
                let cin: usize = l.in_shapes[0][1..].iter().product();
                let mut rng = Rng::new(seed ^ (l.id as u64) << 8);
                let std = (2.0 / cin as f64).sqrt() as f32;
                let w = Tensor::from_fn(&[cin, *cout], |_| rng.next_gaussian() as f32 * std);
                Some(vec![w, Tensor::zeros(&[*cout])])
            }
            _ => None,
        })
        .collect()
}

fn tile_c(tile: &Region) -> usize {
    tile.end(DIM_C) - tile.start(DIM_C)
}

/// Single-device oracle: executes the full-model train-step artifact.
pub struct OracleTrainer {
    engine: Engine,
    key: String,
    params: Vec<Tensor>,
    lr: f32,
}

impl OracleTrainer {
    /// `params` must be the flat `[w, b]` list in layer order (use
    /// [`Trainer::master_params`] for parity runs).
    pub fn new(
        store: &ArtifactStore,
        network: &str,
        batch: usize,
        params: Vec<Tensor>,
        lr: f32,
    ) -> Result<OracleTrainer> {
        let key = keys::train_step(network, batch);
        ensure!(store.has(&key), "missing oracle artifact `{key}`");
        Ok(OracleTrainer { engine: Engine::new(store.clone())?, key, params, lr })
    }

    /// One SGD step; returns the mean loss.
    pub fn step(&mut self, x: &Tensor, y: &Tensor) -> Result<f32> {
        let mut inputs = vec![x.clone(), y.clone(), Tensor::from_vec(&[], vec![self.lr])];
        inputs.extend(self.params.iter().cloned());
        let mut out = self.engine.run(&self.key, &inputs).context("oracle step")?;
        let loss = out.remove(0).data()[0];
        self.params = out;
        Ok(loss)
    }

    pub fn params(&self) -> &[Tensor] {
        &self.params
    }
}
