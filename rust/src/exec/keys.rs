//! Artifact key construction — the shared contract with
//! `python/compile/aot.py` (`spec_entries`). Any change here must be
//! mirrored there; `rust/tests/artifact_parity.rs` pins the agreement
//! against a generated manifest.

/// Key of a conv2d artifact: VALID conv over a pre-padded slab.
/// `nt` samples, `cin` full input channels, slab `hs x ws`, `ct` output
/// channels, square kernel `k`, stride 1, relu flag.
pub fn conv2d(
    fwd: bool,
    nt: usize,
    cin: usize,
    hs: usize,
    ws: usize,
    ct: usize,
    k: usize,
    relu: bool,
) -> String {
    format!(
        "conv2d_{}_n{nt}_ci{cin}_h{hs}_w{ws}_co{ct}_k{k}x{k}_s1x1_r{}",
        if fwd { "fwd" } else { "bwd" },
        relu as u8
    )
}

/// Key of a max-pool artifact (kernel == stride == `k`, no halo).
pub fn maxpool(fwd: bool, nt: usize, ct: usize, hs: usize, ws: usize, k: usize) -> String {
    format!(
        "maxpool_{}_n{nt}_c{ct}_h{hs}_w{ws}_k{k}_s{k}",
        if fwd { "fwd" } else { "bwd" }
    )
}

/// Key of a fully-connected artifact.
pub fn fc(fwd: bool, nt: usize, cin: usize, ct: usize, relu: bool) -> String {
    format!(
        "fc_{}_n{nt}_ci{cin}_co{ct}_r{}",
        if fwd { "fwd" } else { "bwd" },
        relu as u8
    )
}

/// Key of the softmax + cross-entropy head artifact.
pub fn softmax_xent(nt: usize, classes: usize) -> String {
    format!("softmax_xent_n{nt}_c{classes}")
}

/// Key of the single-device full-model train-step oracle.
pub fn train_step(network: &str, batch: usize) -> String {
    format!("{network}_train_step_n{batch}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_match_python_format() {
        // pinned against strings observed in a generated manifest
        assert_eq!(
            conv2d(false, 16, 3, 18, 34, 8, 3, true),
            "conv2d_bwd_n16_ci3_h18_w34_co8_k3x3_s1x1_r1"
        );
        assert_eq!(maxpool(true, 8, 8, 32, 32, 2), "maxpool_fwd_n8_c8_h32_w32_k2_s2");
        assert_eq!(fc(true, 8, 1024, 16, true), "fc_fwd_n8_ci1024_co16_r1");
        assert_eq!(softmax_xent(8, 10), "softmax_xent_n8_c10");
        assert_eq!(train_step("minicnn", 32), "minicnn_train_step_n32");
    }
}
