//! Synthetic training data (the ImageNet substitute — see DESIGN.md §2).
//!
//! Deterministic, separable multi-class image generator: each class `k`
//! gets a fixed random "prototype" image; a sample is its prototype plus
//! Gaussian pixel noise. A linear-ish decision boundary exists, so a small
//! CNN's loss curve visibly decreases within a few hundred steps — which
//! is what the end-to-end driver validates. Runtime metrics (throughput,
//! communication) depend only on tensor shapes, which callers choose to
//! match the paper's datasets.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A deterministic synthetic labeled-image dataset.
pub struct SyntheticDataset {
    pub classes: usize,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    prototypes: Vec<Tensor>,
    noise: f32,
    seed: u64,
}

impl SyntheticDataset {
    pub fn new(
        classes: usize,
        channels: usize,
        height: usize,
        width: usize,
        noise: f32,
        seed: u64,
    ) -> SyntheticDataset {
        let mut rng = Rng::new(seed);
        let prototypes = (0..classes)
            .map(|_| {
                Tensor::from_fn(&[channels, height, width], |_| {
                    rng.next_gaussian() as f32
                })
            })
            .collect();
        SyntheticDataset { classes, channels, height, width, prototypes, noise, seed }
    }

    /// The `idx`-th batch: images `[n, c, h, w]` and one-hot labels
    /// `[n, classes]`. Batches are a pure function of (seed, idx).
    pub fn batch(&self, idx: usize, n: usize) -> (Tensor, Tensor) {
        let mut rng = Rng::new(self.seed ^ (idx as u64).wrapping_mul(0xD1B5_4A32_D192_ED03));
        let mut x = Tensor::zeros(&[n, self.channels, self.height, self.width]);
        let mut y = Tensor::zeros(&[n, self.classes]);
        let img = self.channels * self.height * self.width;
        for s in 0..n {
            let class = rng.below(self.classes);
            y.data_mut()[s * self.classes + class] = 1.0;
            let proto = self.prototypes[class].data();
            let dst = &mut x.data_mut()[s * img..(s + 1) * img];
            for (d, p) in dst.iter_mut().zip(proto.iter()) {
                *d = p + self.noise * rng.next_gaussian() as f32;
            }
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_deterministic() {
        let d = SyntheticDataset::new(10, 3, 8, 8, 0.1, 7);
        let (x1, y1) = d.batch(3, 4);
        let (x2, y2) = d.batch(3, 4);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        // different batch index differs
        let (x3, _) = d.batch(4, 4);
        assert_ne!(x1, x3);
    }

    #[test]
    fn labels_are_one_hot() {
        let d = SyntheticDataset::new(5, 1, 4, 4, 0.1, 1);
        let (_, y) = d.batch(0, 16);
        for s in 0..16 {
            let row = &y.data()[s * 5..(s + 1) * 5];
            assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(row.iter().filter(|&&v| v == 0.0).count(), 4);
        }
    }

    #[test]
    fn classes_are_separable() {
        // nearest-prototype classification on clean-ish samples should be
        // nearly perfect at low noise
        let d = SyntheticDataset::new(4, 2, 6, 6, 0.2, 42);
        let (x, y) = d.batch(0, 32);
        let img = 2 * 6 * 6;
        let mut correct = 0;
        for s in 0..32 {
            let sample = &x.data()[s * img..(s + 1) * img];
            let (mut best, mut best_d) = (0usize, f32::INFINITY);
            for (k, p) in d.prototypes.iter().enumerate() {
                let dist: f32 =
                    sample.iter().zip(p.data()).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best_d {
                    best_d = dist;
                    best = k;
                }
            }
            let label = y.data()[s * 4..(s + 1) * 4].iter().position(|&v| v == 1.0).unwrap();
            if best == label {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/32 nearest-prototype correct");
    }
}
