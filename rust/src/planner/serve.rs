//! `optcnn serve`: the TCP front end over a shared [`PlanService`].
//!
//! The wire protocol is newline-delimited JSON over plain TCP via
//! `std::net` — the offline registry carries no HTTP/async stack, and
//! line framing keeps a client one `nc` invocation away (DESIGN.md §6):
//!
//! ```text
//! request:  {"net": "vgg16", "devices": 4, "batch": 32,
//!            "strategy": "layerwise", "want": "plan"}
//!         | {"graph": {"version": 1, "name": "mine", "layers": [...]},
//!            "devices": 4, "want": "evaluate"}
//!         | {"plan": {...}, "want": "verify"}
//! response: {"ok": true, "plan": {...}}
//!         | {"ok": true, "evaluation": {...}}
//!         | {"ok": true, "analysis": {...}}
//!         | {"ok": true, "stats": {...}}
//!         | {"ok": true, "verified": true, "cached": false, "checks": [...]}
//!         | {"ok": false, "error": "one-line message"}
//! ```
//!
//! The network is either `"net"` (a builtin preset name) or an inline
//! `"graph"` object — a [`GraphSpec`](crate::graph::spec) document
//! describing an arbitrary network (exactly one of the two). A custom
//! graph carries its own batch size in its input shape, so `"batch"`
//! only combines with `"net"`. Instead of `"devices"` (the paper's P100
//! preset) a request may carry `"cluster": {"nodes": 2, "gpus_per_node":
//! 8, ...}` with the same keys as the TOML `[cluster]` section. `"want"`
//! defaults to `"plan"`; `"strategy"` defaults to `"layerwise"`;
//! `"batch"` defaults to the paper's per-GPU 32. An optional
//! `"mem_limit"` (bytes per device) constrains the layer-wise search to
//! memory-feasible configurations; an unsatisfiable budget answers
//! `{"ok": false, "error": "infeasible: ..."}`. Evaluation replies
//! report the plan's per-device high-water memory as
//! `"peak_mem_per_dev"` (plan replies carry the same vector inside the
//! plan JSON itself). A bare `{"want": "stats"}` probe answers the
//! service's aggregate counters ([`ServiceStats`]) — cache hit/miss
//! totals, single-flight builds, and the per-layer cost-table memo's
//! `memo_hits`/`memo_misses` — without planning anything.
//!
//! `{"want": "analyze"}` answers the pre-planning static analysis of
//! the request's (network, cluster, budget) — reducibility class, exact
//! search-cost certificate, memory precheck, and graph lints
//! ([`crate::analyze`], DESIGN.md §11) — without building any cost
//! tables. `"strategy"` does not combine with it (analysis is about the
//! search space, not one strategy). The probe itself is never capped:
//! it is how a client discovers whether a graph *would* be rejected by
//! the service's residual-enumeration cap
//! ([`MAX_RESIDUAL_SPACE_LOG2`](super::MAX_RESIDUAL_SPACE_LOG2)), which
//! plan/evaluate requests enforce before any table is built.
//!
//! `{"want": "audit"}` statically audits the request's cost tables
//! (DESIGN.md §12): the typed table-invariant checks, the per-layer
//! dominance certificates, and the differential backend cross-check
//! ([`crate::audit`]). Like `analyze`, `"strategy"` does not combine
//! with it; unlike `analyze`, the probe builds (unpruned) cost tables,
//! so the pre-planning enumeration cap applies to it exactly as it does
//! to planning requests.
//!
//! `{"want": "verify"}` is the server's plan-ingestion trust boundary
//! (DESIGN.md §10): the required `"plan"` object is an execution-plan
//! document (the exact JSON `optcnn plan --out` writes), statically
//! verified against the request's network and cluster via
//! [`PlanService::ingest`] before being admitted into the plan cache —
//! a violated invariant answers `{"ok": false, "error": "invalid plan
//! [check-name]: ..."}`. The network defaults to the plan's recorded
//! `net` (which must then name a builtin preset) and the cluster to the
//! P100 preset at the plan's recorded device count; an inline `"graph"`
//! or an explicit `"net"` / `"devices"` / `"cluster"` overrides either
//! side. The batch size is read off the plan's own input tiling, so
//! `"batch"` (like `"strategy"` and `"mem_limit"`) does not combine
//! with a verify probe. Re-verifying a plan equal to one already
//! resident answers `"cached": true` without re-running the checks, and
//! a server started with `--no-verify` admits plans unchecked
//! (`"verified": false`).
//!
//! A bare `{"want": "metrics"}` probe answers the wire-level serving
//! metrics (DESIGN.md §13): request count and p50/p99/max latency from
//! the lock-free histogram ([`crate::metrics::latency`]), the in-flight
//! and open-connection gauges, the shed and accept-error counters, and
//! the plan-store counters — without planning anything.
//!
//! **Serving model.** Connections are handled by a bounded
//! [`WorkerPool`](super::pool::WorkerPool) (`--workers` threads pulling
//! from a `--queue-cap`-bounded queue) instead of one unbounded thread
//! per connection, and all workers share one [`PlanService`], so a plan
//! primed by any client is a cache hit for every other. When the queue
//! is full — or more than `--max-conns` connections are open — the
//! accept loop **sheds load** with the typed reply
//! `{"ok": false, "error": "overloaded", "retry_after_ms": N}` and
//! closes, instead of queueing unboundedly. Every accepted stream gets
//! `TCP_NODELAY` plus read/write deadlines (`--request-timeout`): a
//! client that stalls mid-line, or never reads its reply, is
//! disconnected rather than parking a worker forever (the planning work
//! itself is already bounded by the pre-planning search-space cap).
//! Accept errors are counted ([`ServiceStats::accept_errors`]), never
//! silently swallowed. Shutdown is graceful: in-flight requests finish
//! and their replies are written; parked connections are closed.
//! Malformed requests answer `{"ok": false, ...}` on the same
//! connection instead of dropping it.

// Wire-facing request path: a malformed or hostile request must come
// back as a typed `OptError`, never a panic in a serving thread.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::device::ComputeModel;
use crate::error::{OptError, Result};
use crate::graph::CompGraph;
use crate::metrics::{Gauge, LatencyHistogram};
use crate::plan::ExecutionPlan;
use crate::util::json::Json;
use crate::util::sync::lock;

use super::pool::WorkerPool;
use super::service::{PlanRequest, PlanService, ServiceStats, VerifyOutcome};
use super::{ClusterSpec, Network, NetworkSpec, StrategyKind, PER_GPU_BATCH};

/// One parsed request line: what the server should do, with the typed
/// payload each action needs — so `respond` can never reach for a field
/// the parser did not prove present.
#[derive(Debug)]
pub enum Request {
    /// Return the materialized execution plan (the exact JSON `optcnn
    /// plan --out` writes).
    Plan(PlanRequest),
    /// Return the evaluation: estimate, simulated step, throughput, comm.
    Evaluate(PlanRequest),
    /// Return the pre-planning static analysis ([`crate::analyze`])
    /// of the request's (network, cluster, budget) — no tables built.
    Analyze(PlanRequest),
    /// Statically audit the request's cost tables ([`crate::audit`]):
    /// table invariants, dominance certificates, backend cross-check.
    Audit(PlanRequest),
    /// Return the service's aggregate counters ([`ServiceStats`]);
    /// carries no plan request at all.
    Stats,
    /// Return the wire-level serving metrics ([`ServeMetrics`]) plus the
    /// plan-store and accept-error counters; carries no plan request.
    Metrics,
    /// Statically verify the carried plan document against the request's
    /// (network, cluster) and admit it into the plan cache
    /// ([`PlanService::ingest`]).
    Verify(PlanRequest, Box<ExecutionPlan>),
}

/// A request-shaped [`OptError`]: every malformed field is the client's
/// mistake, reported as one line.
fn bad(msg: &str) -> OptError {
    OptError::InvalidArgument(msg.to_string())
}

/// Strict non-negative integer off the wire ([`Json::as_exact_usize`]).
fn as_uint(v: &Json) -> Option<usize> {
    v.as_exact_usize()
}

/// Hard caps on network-supplied sizes, split per field so each limit's
/// error names the cap that was exceeded. The planning library itself
/// has no limits (callers are trusted), but a TCP client must not be
/// able to make the server allocate an `ndev x ndev` bandwidth matrix, a
/// billion-sample graph, or an unbounded layer list out of one request
/// line.
const MAX_TOTAL_DEVICES: usize = 1024;
/// Cap on the per-GPU batch a request may ask for.
const MAX_PER_GPU_BATCH: usize = 4096;
/// Cap on an inline `graph` object, measured on its serialized spec
/// form. 1 MiB holds specs far past Inception-v3's 102 layers — a
/// spec near the layer cap below already overruns the old blanket
/// 64 KiB *line* cap, which is why the limits are split per field.
const MAX_GRAPH_BYTES: usize = 1024 * 1024;
/// Cap on an inline graph's layer count.
const MAX_GRAPH_LAYERS: usize = 512;
/// Cap on one request line (the graph cap plus generous headroom for
/// the rest of the request); longer lines cannot be resynced and close
/// the connection.
const MAX_LINE_BYTES: u64 = 2 * 1024 * 1024;

/// Parse the inline `graph` object, enforcing its per-field caps before
/// the spec is validated.
fn graph_from_json(v: &Json) -> Result<NetworkSpec> {
    if let Some(layers) = v.get("layers").and_then(Json::as_arr) {
        if layers.len() > MAX_GRAPH_LAYERS {
            return Err(bad(&format!(
                "`graph` capped at {MAX_GRAPH_LAYERS} layers, got {}",
                layers.len()
            )));
        }
    }
    let bytes = v.to_string().len();
    if bytes > MAX_GRAPH_BYTES {
        return Err(bad(&format!(
            "`graph` capped at {MAX_GRAPH_BYTES} spec bytes, got {bytes}"
        )));
    }
    NetworkSpec::custom(CompGraph::from_spec(v)?)
}

/// Parse one request line into the typed [`Request`] the server acts on.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).map_err(|e| bad(&format!("malformed request JSON: {e}")))?;
    let want = v.get("want").map(Json::as_str);
    match want {
        Some(Some(probe @ ("stats" | "metrics"))) => {
            // a counter probe carries no planning fields — reject them so a
            // mangled plan request cannot silently answer as a counter dump
            let keys =
                ["net", "graph", "devices", "cluster", "strategy", "batch", "mem_limit", "plan"];
            for key in keys {
                if v.get(key).is_some() {
                    return Err(bad(&format!("`{key}` does not combine with want=\"{probe}\"")));
                }
            }
            Ok(if probe == "stats" { Request::Stats } else { Request::Metrics })
        }
        Some(Some("verify")) => Ok(parse_verify(&v)?),
        Some(Some("analyze")) => {
            if v.get("plan").is_some() {
                return Err(bad("`plan` only combines with want=\"verify\""));
            }
            if v.get("strategy").is_some() {
                return Err(bad(
                    "`strategy` does not combine with want=\"analyze\" — analysis \
                     is about the search space, not one strategy",
                ));
            }
            Ok(Request::Analyze(parse_plan_request(&v)?))
        }
        Some(Some("audit")) => {
            if v.get("plan").is_some() {
                return Err(bad("`plan` only combines with want=\"verify\""));
            }
            if v.get("strategy").is_some() {
                return Err(bad(
                    "`strategy` does not combine with want=\"audit\" — the audit \
                     is about the cost tables, not one strategy",
                ));
            }
            Ok(Request::Audit(parse_plan_request(&v)?))
        }
        None | Some(Some("plan")) | Some(Some("evaluate")) => {
            if v.get("plan").is_some() {
                return Err(bad("`plan` only combines with want=\"verify\""));
            }
            let req = parse_plan_request(&v)?;
            match want {
                Some(Some("evaluate")) => Ok(Request::Evaluate(req)),
                _ => Ok(Request::Plan(req)),
            }
        }
        Some(other) => Err(bad(&format!(
            "`want` must be \"plan\", \"evaluate\", \"analyze\", \"audit\", \
             \"stats\", \"metrics\", or \"verify\", got {other:?}"
        ))),
    }
}

/// Parse the planning fields of a `plan`/`evaluate` request.
fn parse_plan_request(v: &Json) -> Result<PlanRequest> {
    let network: NetworkSpec = match (v.get("net"), v.get("graph")) {
        (Some(_), Some(_)) => {
            return Err(bad("`net` and `graph` are mutually exclusive"));
        }
        (Some(n), None) => {
            let name = n.as_str().ok_or_else(|| bad("`net` must be a string"))?;
            NetworkSpec::Preset(name.parse::<Network>()?)
        }
        (None, Some(g)) => {
            if v.get("batch").is_some() {
                return Err(bad(
                    "`batch` applies to `net` presets; a `graph` carries its own batch size",
                ));
            }
            graph_from_json(g)?
        }
        (None, None) => {
            return Err(bad("request needs a `net` string or an inline `graph` object"));
        }
    };
    let cluster = parse_cluster(v, 4)?;
    let strategy: StrategyKind = match v.get("strategy") {
        None => StrategyKind::Layerwise,
        Some(s) => {
            let name = s.as_str().ok_or_else(|| bad("`strategy` must be a string"))?;
            name.parse()?
        }
    };
    let per_gpu_batch = match v.get("batch") {
        None => PER_GPU_BATCH,
        Some(b) => as_uint(b).ok_or_else(|| bad("`batch` must be a whole number"))?,
    };
    if per_gpu_batch > MAX_PER_GPU_BATCH {
        return Err(bad(&format!("`batch` capped at {MAX_PER_GPU_BATCH}, got {per_gpu_batch}")));
    }
    let mut req = PlanRequest::with_cluster(network, cluster)
        .strategy(strategy)
        .per_gpu_batch(per_gpu_batch);
    if let Some(m) = v.get("mem_limit") {
        // bytes fit u64 exactly only up to 2^53 off an f64 wire — more
        // HBM than any cluster; reject the rest rather than round
        let bytes = m
            .as_exact_u64()
            .filter(|b| *b >= 1 && *b <= 1u64 << 53)
            .ok_or_else(|| bad("`mem_limit` must be a whole number of bytes (>= 1)"))?;
        req = req.mem_limit(bytes);
    }
    Ok(req)
}

/// The request's cluster: `devices` (P100 preset), an inline `cluster`
/// object, or the P100 preset at `default_devices`.
fn parse_cluster(v: &Json, default_devices: usize) -> Result<ClusterSpec> {
    match (v.get("devices"), v.get("cluster")) {
        (Some(_), Some(_)) => Err(bad("`devices` and `cluster` are mutually exclusive")),
        (Some(d), None) => {
            let n = as_uint(d).ok_or_else(|| bad("`devices` must be a whole number"))?;
            if n > MAX_TOTAL_DEVICES {
                return Err(bad(&format!("`devices` capped at {MAX_TOTAL_DEVICES}, got {n}")));
            }
            ClusterSpec::p100(n)
        }
        (None, Some(c)) => cluster_from_json(c),
        (None, None) => ClusterSpec::p100(default_devices),
    }
}

/// Parse a `{"want": "verify"}` probe: the plan document plus the
/// network/cluster context to verify it against, defaulted from the
/// plan's own recorded `net` and `ndev` (see the module docs).
fn parse_verify(v: &Json) -> Result<Request> {
    for key in ["strategy", "batch", "mem_limit"] {
        if v.get(key).is_some() {
            return Err(bad(&format!(
                "`{key}` does not combine with want=\"verify\" — the plan \
                 document carries its own strategy and batch"
            )));
        }
    }
    let doc = v.get("plan").ok_or_else(|| bad("want=\"verify\" needs a `plan` object"))?;
    let plan = ExecutionPlan::from_json(doc).map_err(|e| bad(&e))?;
    let network: NetworkSpec = match (v.get("net"), v.get("graph")) {
        (Some(_), Some(_)) => {
            return Err(bad("`net` and `graph` are mutually exclusive"));
        }
        (Some(n), None) => {
            let name = n.as_str().ok_or_else(|| bad("`net` must be a string"))?;
            NetworkSpec::Preset(name.parse::<Network>()?)
        }
        (None, Some(g)) => graph_from_json(g)?,
        (None, None) => {
            let preset = plan.net.parse::<Network>().map_err(|_| {
                bad(&format!(
                    "plan records net `{}`, which is not a builtin preset; \
                     supply a `net` name or an inline `graph` to verify against",
                    plan.net
                ))
            })?;
            NetworkSpec::Preset(preset)
        }
    };
    let cluster = parse_cluster(v, plan.ndev)?;
    // A preset graph is rebuilt at the plan's own global batch (read off
    // its input tiling); a custom graph carries its batch in the spec.
    let per_gpu_batch = match network.fixed_batch() {
        Some(_) => PER_GPU_BATCH, // unused for custom graphs
        None => {
            let global = plan
                .global_batch()
                .ok_or_else(|| bad("`plan` has no layer tiles to read a batch size from"))?;
            let ndev = cluster.num_devices();
            if ndev == 0 || global % ndev != 0 {
                return Err(bad(&format!(
                    "plan batch {global} does not divide across {ndev} devices"
                )));
            }
            global / ndev
        }
    };
    if per_gpu_batch > MAX_PER_GPU_BATCH {
        return Err(bad(&format!("`batch` capped at {MAX_PER_GPU_BATCH}, got {per_gpu_batch}")));
    }
    let req = PlanRequest::with_cluster(network, cluster).per_gpu_batch(per_gpu_batch);
    Ok(Request::Verify(req, Box::new(plan)))
}

/// Build a [`ClusterSpec`] from a request's `cluster` object. Keys
/// mirror the TOML `[cluster]` section: `nodes`, `gpus_per_node`,
/// `intra_bw_gbps`, `inter_bw_gbps`, `host_bw_gbps`, `compute`,
/// `peak_tflops`, `mem_bw_gbps`, `name`. Unknown keys are errors, never
/// silently ignored.
fn cluster_from_json(v: &Json) -> Result<ClusterSpec> {
    let obj = v
        .as_obj()
        .ok_or_else(|| OptError::InvalidArgument("`cluster` must be an object".into()))?;
    const KNOWN: [&str; 9] = [
        "nodes",
        "gpus_per_node",
        "intra_bw_gbps",
        "inter_bw_gbps",
        "host_bw_gbps",
        "compute",
        "peak_tflops",
        "mem_bw_gbps",
        "name",
    ];
    for key in obj.keys() {
        if !KNOWN.contains(&key.as_str()) {
            return Err(OptError::InvalidArgument(format!(
                "unknown cluster key `{key}` (known: {})",
                KNOWN.join(", ")
            )));
        }
    }
    let uint = |key: &str, default: usize| -> Result<usize> {
        match v.get(key) {
            None => Ok(default),
            Some(n) => {
                as_uint(n).ok_or_else(|| bad(&format!("cluster.{key} must be a whole number")))
            }
        }
    };
    let float = |key: &str| -> Result<Option<f64>> {
        match v.get(key) {
            None => Ok(None),
            Some(n) => match n.as_f64() {
                Some(x) => Ok(Some(x)),
                None => Err(bad(&format!("cluster.{key} must be a number"))),
            },
        }
    };
    let nodes = uint("nodes", 1)?;
    let gpus_per_node = uint("gpus_per_node", 4)?;
    let total = nodes.checked_mul(gpus_per_node).unwrap_or(usize::MAX);
    if total > MAX_TOTAL_DEVICES {
        return Err(bad(&format!(
            "cluster capped at {MAX_TOTAL_DEVICES} devices, got {nodes} x {gpus_per_node}"
        )));
    }
    let mut spec = ClusterSpec::new(nodes, gpus_per_node);
    if let Some(bw) = float("intra_bw_gbps")? {
        spec = spec.intra_bw(bw * 1e9);
    }
    if let Some(bw) = float("inter_bw_gbps")? {
        spec = spec.inter_bw(bw * 1e9);
    }
    if let Some(bw) = float("host_bw_gbps")? {
        spec = spec.host_bw(bw * 1e9);
    }
    // compute model: named preset (default p100), then the same
    // field-level overrides the TOML form supports
    let mut compute = match v.get("compute") {
        None => ComputeModel::p100(),
        Some(c) => {
            let name = c.as_str().ok_or_else(|| bad("cluster.compute must be a string"))?;
            ComputeModel::named(name)?
        }
    };
    if let Some(x) = float("peak_tflops")? {
        compute.peak_flops = x * 1e12;
    }
    if let Some(x) = float("mem_bw_gbps")? {
        compute.mem_bw = x * 1e9;
    }
    spec = spec.compute(compute);
    if let Some(n) = v.get("name") {
        spec = spec.name(n.as_str().ok_or_else(|| bad("cluster.name must be a string"))?);
    }
    Ok(spec)
}

/// JSON form of an [`Evaluation`](crate::planner::Evaluation).
fn evaluation_json(eval: &crate::planner::Evaluation) -> Json {
    Json::obj(vec![
        ("estimate_s", Json::Num(eval.estimate)),
        ("sim_step_s", Json::Num(eval.sim.step_time)),
        ("throughput_img_s", Json::Num(eval.throughput)),
        ("sim_throughput_img_s", Json::Num(eval.sim_throughput)),
        ("xfer_bytes", Json::Num(eval.comm.xfer_bytes)),
        ("sync_bytes", Json::Num(eval.comm.sync_bytes)),
        (
            "peak_mem_per_dev",
            Json::Arr(eval.peak_mem_per_dev.iter().map(|&b| Json::Num(b)).collect()),
        ),
    ])
}

/// JSON form of [`ServiceStats`] — the `{"want": "stats"}` payload.
/// Counters are exact: every value is well under `f64`'s 2^53 integer
/// range for any realistic server lifetime.
fn stats_json(s: &ServiceStats) -> Json {
    Json::obj(vec![
        ("plan_hits", Json::Num(s.plan_hits as f64)),
        ("plan_misses", Json::Num(s.plan_misses as f64)),
        ("table_builds", Json::Num(s.table_builds as f64)),
        ("searches", Json::Num(s.searches as f64)),
        ("build_waits", Json::Num(s.build_waits as f64)),
        ("plans_cached", Json::Num(s.plans_cached as f64)),
        ("states_cached", Json::Num(s.states_cached as f64)),
        ("memo_hits", Json::Num(s.memo_hits as f64)),
        ("memo_misses", Json::Num(s.memo_misses as f64)),
        ("build_workers", Json::Num(s.build_workers as f64)),
        ("pruned_configs", Json::Num(s.pruned_configs as f64)),
        ("store_hits", Json::Num(s.store_hits as f64)),
        ("store_misses", Json::Num(s.store_misses as f64)),
        ("store_writes", Json::Num(s.store_writes as f64)),
        ("store_rejects", Json::Num(s.store_rejects as f64)),
        ("store_errors", Json::Num(s.store_errors as f64)),
        ("accept_errors", Json::Num(s.accept_errors as f64)),
    ])
}

/// Wire-level serving metrics (DESIGN.md §13), shared by the accept
/// loop, the workers, and the `{"want": "metrics"}` probe. Every field
/// is lock-free — recording a latency or bumping a gauge never blocks a
/// serving thread, and the probe reads a consistent-enough snapshot
/// without stopping the world.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Per-request wall latency: the `handle_line` span, parse to reply.
    pub requests: LatencyHistogram,
    /// Requests being handled right now.
    pub in_flight: Gauge,
    /// Connections currently open (queued or active).
    pub open_conns: Gauge,
    /// Connections accepted over the server's lifetime.
    pub connections: AtomicU64,
    /// Connections refused with the typed `overloaded` reply.
    pub shed: AtomicU64,
}

/// JSON form of [`ServeMetrics`] + the service's store/accept counters —
/// the `{"want": "metrics"}` payload. Latency quantiles are `null`
/// until the first request has been recorded.
fn metrics_json(m: &ServeMetrics, s: &ServiceStats) -> Json {
    let quant = |q: f64| m.requests.quantile(q).map_or(Json::Null, |us| Json::Num(us as f64));
    Json::obj(vec![
        ("requests", Json::Num(m.requests.count() as f64)),
        ("p50_us", quant(0.50)),
        ("p99_us", quant(0.99)),
        ("max_us", Json::Num(m.requests.max_us() as f64)),
        ("in_flight", Json::Num(m.in_flight.get() as f64)),
        ("open_conns", Json::Num(m.open_conns.get() as f64)),
        ("connections", Json::Num(m.connections.load(Ordering::Relaxed) as f64)),
        ("shed", Json::Num(m.shed.load(Ordering::Relaxed) as f64)),
        ("accept_errors", Json::Num(s.accept_errors as f64)),
        ("store_hits", Json::Num(s.store_hits as f64)),
        ("store_misses", Json::Num(s.store_misses as f64)),
        ("store_writes", Json::Num(s.store_writes as f64)),
        ("store_rejects", Json::Num(s.store_rejects as f64)),
        ("store_errors", Json::Num(s.store_errors as f64)),
    ])
}

fn respond(service: &PlanService, metrics: &ServeMetrics, line: &str) -> Result<Json> {
    match parse_request(line)? {
        Request::Stats => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("stats", stats_json(&service.stats())),
        ])),
        Request::Metrics => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", metrics_json(metrics, &service.stats())),
        ])),
        Request::Plan(req) => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("plan", service.plan(&req)?.to_json()),
        ])),
        Request::Evaluate(req) => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("evaluation", evaluation_json(&service.evaluate(&req)?)),
        ])),
        Request::Analyze(req) => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("analysis", service.analyze(&req)?.to_json()),
        ])),
        Request::Audit(req) => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("audit", service.audit(&req)?.to_json()),
        ])),
        Request::Verify(req, plan) => {
            let outcome = service.ingest(&req, &plan)?;
            let (verified, cached, report) = match outcome {
                VerifyOutcome::Verified(report) => (true, false, Some(report)),
                VerifyOutcome::CachedVerified => (true, true, None),
                VerifyOutcome::AcceptedUnchecked => (false, false, None),
            };
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("verified", Json::Bool(verified)),
                ("cached", Json::Bool(cached)),
            ];
            if let Some(report) = report {
                let names = report
                    .checks
                    .iter()
                    .map(|c| Json::Str(c.check.name().to_string()))
                    .collect();
                fields.push(("checks", Json::Arr(names)));
            }
            Ok(Json::obj(fields))
        }
    }
}

/// The `{"ok": false, "error": ...}` reply for `msg`.
fn error_reply(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.to_string()))]).to_string()
}

/// Handle one request line, always producing a single-line JSON reply —
/// the pure core of the server, also usable without a socket. The span
/// is recorded into `metrics` (latency histogram + in-flight gauge), and
/// a `{"want": "metrics"}` line answers from the same `metrics`.
pub fn handle_line(service: &PlanService, metrics: &ServeMetrics, line: &str) -> String {
    let start = Instant::now();
    metrics.in_flight.inc();
    let reply = match respond(service, metrics, line) {
        Ok(body) => body.to_string(),
        Err(e) => error_reply(&e.to_string()),
    };
    metrics.in_flight.dec();
    metrics.requests.record(start.elapsed());
    reply
}

/// Serve one connection until EOF, I/O error, or deadline. Runs on a
/// pool worker; `registry` lets [`ServeHandle::shutdown`] unpark the
/// blocking read so drain never waits out a `--request-timeout`.
fn handle_conn(
    stream: TcpStream,
    service: &PlanService,
    metrics: &ServeMetrics,
    registry: &ConnRegistry,
) {
    let id = registry.register(&stream);
    conn_loop(stream, service, metrics);
    if let Some(id) = id {
        registry.deregister(id);
    }
}

fn conn_loop(stream: TcpStream, service: &PlanService, metrics: &ServeMetrics) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    loop {
        // Bounded line read: a client streaming bytes with no newline
        // must not grow an unbounded String inside the server.
        let mut raw = Vec::new();
        match (&mut reader).take(MAX_LINE_BYTES).read_until(b'\n', &mut raw) {
            Ok(0) | Err(_) => return, // clean EOF or I/O error
            Ok(n) if n as u64 >= MAX_LINE_BYTES && !raw.ends_with(b"\n") => {
                // the line was truncated mid-stream: reply and drop the
                // connection — there is no way to resync to the next line
                let reply = error_reply(&format!(
                    "request line exceeds {MAX_LINE_BYTES} bytes"
                ));
                let _ = writer
                    .write_all(reply.as_bytes())
                    .and_then(|()| writer.write_all(b"\n"))
                    .and_then(|()| writer.flush());
                return;
            }
            Ok(_) => {}
        }
        let text = String::from_utf8_lossy(&raw);
        let line = text.trim();
        if line.is_empty() {
            continue;
        }
        let reply = handle_line(service, metrics, line);
        let io = writer
            .write_all(reply.as_bytes())
            .and_then(|()| writer.write_all(b"\n"))
            .and_then(|()| writer.flush());
        if io.is_err() {
            return;
        }
    }
}

/// How long a shed client should wait before retrying, carried in the
/// typed overload reply as `retry_after_ms`.
pub const RETRY_AFTER_MS: u64 = 100;

/// The typed backpressure reply the accept loop sheds load with.
fn overloaded_reply() -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str("overloaded".to_string())),
        ("retry_after_ms", Json::Num(RETRY_AFTER_MS as f64)),
    ])
    .to_string()
}

/// Refuse `stream` with the overload reply and close it. Runs on the
/// accept thread — the whole point is that shedding never waits for a
/// worker.
fn shed(mut stream: TcpStream, metrics: &ServeMetrics) {
    metrics.shed.fetch_add(1, Ordering::Relaxed);
    let reply = overloaded_reply();
    let _ = stream
        .write_all(reply.as_bytes())
        .and_then(|()| stream.write_all(b"\n"))
        .and_then(|()| stream.flush());
}

/// Tuning knobs for [`spawn_opts`] — the CLI flags of `optcnn serve`.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker threads handling connections; `0` means one per core.
    pub workers: usize,
    /// Bound on connections accepted but not yet picked up by a worker;
    /// `0` is a rendezvous queue (accept only if a worker is idle).
    pub queue_cap: usize,
    /// Bound on open connections (queued + active); connections beyond
    /// it are shed even if the queue has room.
    pub max_conns: usize,
    /// Read/write deadline on every connection: a client that stalls
    /// mid-line or never drains its reply is disconnected.
    pub request_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            workers: 0,
            queue_cap: 64,
            max_conns: 1024,
            request_timeout: Duration::from_secs(30),
        }
    }
}

impl ServeOptions {
    /// The worker count after resolving `0` to the core count.
    fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism().map_or(4, std::num::NonZeroUsize::get)
    }
}

/// Open-connection registry: a read-shutdown handle per live connection,
/// so graceful shutdown can unpark workers blocked in `read_until`
/// without killing in-flight replies (`Shutdown::Read` leaves the write
/// half alone — a reply being computed is still delivered).
struct ConnRegistry {
    draining: AtomicBool,
    conns: Mutex<HashMap<u64, TcpStream>>,
    next: AtomicU64,
}

impl ConnRegistry {
    fn new() -> ConnRegistry {
        ConnRegistry {
            draining: AtomicBool::new(false),
            conns: Mutex::new(HashMap::new()),
            next: AtomicU64::new(0),
        }
    }

    /// Track `stream`; returns `None` (untracked) only if the fd cannot
    /// be duplicated. A registration after drain has begun is read-shut
    /// immediately, closing the race with [`ConnRegistry::drain`].
    fn register(&self, stream: &TcpStream) -> Option<u64> {
        let clone = stream.try_clone().ok()?;
        if self.draining.load(Ordering::SeqCst) {
            let _ = clone.shutdown(Shutdown::Read);
        }
        let id = self.next.fetch_add(1, Ordering::SeqCst);
        lock(&self.conns).insert(id, clone);
        Some(id)
    }

    fn deregister(&self, id: u64) {
        lock(&self.conns).remove(&id);
    }

    /// Read-shutdown every live connection: parked reads return EOF, so
    /// workers finish their current request and exit their conn loops.
    fn drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        for stream in lock(&self.conns).values() {
            let _ = stream.shutdown(Shutdown::Read);
        }
    }
}

/// Decrements the open-connection gauge when the connection ends, on
/// every exit path — including a job dropped unrun by a dying pool.
struct ConnGuard {
    metrics: Arc<ServeMetrics>,
}

impl ConnGuard {
    fn new(metrics: &Arc<ServeMetrics>) -> ConnGuard {
        metrics.open_conns.inc();
        ConnGuard { metrics: Arc::clone(metrics) }
    }
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.metrics.open_conns.dec();
    }
}

/// A running server: the accept-loop thread feeding a bounded
/// [`WorkerPool`], all sharing one [`PlanService`].
pub struct ServeHandle {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    registry: Arc<ConnRegistry>,
}

impl ServeHandle {
    /// The bound address (useful with `--addr 127.0.0.1:0`, which picks
    /// an ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// The server's live wire metrics — what `{"want": "metrics"}`
    /// reads, for callers holding the handle.
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Block until the accept loop exits — i.e. forever, for the CLI.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop accepting, unpark blocked reads
    /// (in-flight requests still finish and their replies are written),
    /// then join the accept thread — which drains the worker pool.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.registry.drain();
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.local);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// [`spawn_opts`] with default [`ServeOptions`].
pub fn spawn(addr: &str, service: Arc<PlanService>) -> Result<ServeHandle> {
    spawn_opts(addr, service, ServeOptions::default())
}

/// Bind `addr` (e.g. `127.0.0.1:7878`; port 0 for ephemeral) and answer
/// requests against `service` until [`ServeHandle::shutdown`], on a
/// bounded worker pool per `opts` (see the module docs' serving model).
pub fn spawn_opts(
    addr: &str,
    service: Arc<PlanService>,
    opts: ServeOptions,
) -> Result<ServeHandle> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| OptError::Io(format!("bind {addr}: {e}")))?;
    let local = listener
        .local_addr()
        .map_err(|e| OptError::Io(format!("local addr of {addr}: {e}")))?;
    let stop = Arc::new(AtomicBool::new(false));
    let metrics = Arc::new(ServeMetrics::default());
    let registry = Arc::new(ConnRegistry::new());
    let stop_flag = Arc::clone(&stop);
    let shared_metrics = Arc::clone(&metrics);
    let shared_registry = Arc::clone(&registry);
    let mut pool = WorkerPool::new(opts.resolved_workers(), opts.queue_cap);
    let accept = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop_flag.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(stream) => stream,
                Err(_) => {
                    // count it — a persistent accept failure (fd
                    // exhaustion, say) must be visible on the stats
                    // probe, and the pause keeps a hard error from
                    // spinning this loop at 100% CPU
                    service.note_accept_error();
                    std::thread::sleep(Duration::from_millis(5));
                    continue;
                }
            };
            shared_metrics.connections.fetch_add(1, Ordering::Relaxed);
            let _ = stream.set_nodelay(true);
            let _ = stream.set_read_timeout(Some(opts.request_timeout));
            let _ = stream.set_write_timeout(Some(opts.request_timeout));
            if shared_metrics.open_conns.get() >= opts.max_conns as u64 {
                shed(stream, &shared_metrics);
                continue;
            }
            // duplicate handle so a queue-full rejection can still write
            // the overload reply after the stream has moved into the job
            let shed_handle = stream.try_clone();
            let guard = ConnGuard::new(&shared_metrics);
            let svc = Arc::clone(&service);
            let m = Arc::clone(&shared_metrics);
            let reg = Arc::clone(&shared_registry);
            let job: super::pool::Job = Box::new(move || {
                let _open = guard;
                handle_conn(stream, &svc, &m, &reg);
            });
            if let Err(job) = pool.try_execute(job) {
                if let Ok(stream) = shed_handle {
                    shed(stream, &shared_metrics);
                }
                drop(job); // closes the moved stream, releases the guard
            }
        }
        // graceful drain: accepted connections are still answered
        pool.shutdown();
    });
    Ok(ServeHandle { local, stop, accept: Some(accept), metrics, registry })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    /// Drive the pure core without a socket, with a throwaway metrics
    /// sink — shadows the glob-imported [`super::handle_line`] so the
    /// existing protocol tests stay signature-free.
    fn handle_line(service: &PlanService, line: &str) -> String {
        super::handle_line(service, &ServeMetrics::default(), line)
    }

    /// The planning payload of a line that must parse as plan/evaluate.
    fn planning(line: &str) -> PlanRequest {
        match parse_request(line).unwrap() {
            Request::Plan(req) | Request::Evaluate(req) => req,
            other => panic!("expected a planning request, got {other:?}"),
        }
    }

    #[test]
    fn parse_request_applies_defaults() {
        let req = match parse_request(r#"{"net": "lenet5"}"#).unwrap() {
            Request::Plan(req) => req,
            other => panic!("the default want is plan, got {other:?}"),
        };
        assert_eq!(req.network.preset(), Some(Network::LeNet5));
        assert_eq!(req.cluster.num_devices(), 4);
        assert_eq!(req.per_gpu_batch, PER_GPU_BATCH);
        assert_eq!(req.strategy, StrategyKind::Layerwise);
    }

    #[test]
    fn parse_request_reads_cluster_objects() {
        let parsed = parse_request(
            r#"{"net": "alexnet", "batch": 16, "strategy": "data", "want": "evaluate",
                "cluster": {"nodes": 2, "gpus_per_node": 8, "compute": "v100",
                            "intra_bw_gbps": 130.0, "inter_bw_gbps": 6.0}}"#,
        )
        .unwrap();
        let req = match parsed {
            Request::Evaluate(req) => req,
            other => panic!("want=evaluate must parse as Evaluate, got {other:?}"),
        };
        assert_eq!(req.network.preset(), Some(Network::AlexNet));
        assert_eq!(req.cluster.num_devices(), 16);
        assert_eq!(req.per_gpu_batch, 16);
        assert_eq!(req.strategy, StrategyKind::Data);
        let d = req.cluster.device_graph().unwrap();
        assert_eq!(d.bandwidth(0, 1), 130e9);
        assert_eq!(d.bandwidth(0, 8), 6e9);
    }

    #[test]
    fn cluster_objects_support_the_toml_compute_overrides() {
        let req = planning(
            r#"{"net": "lenet5",
                "cluster": {"nodes": 1, "gpus_per_node": 2, "compute": "v100",
                            "peak_tflops": 30.0, "mem_bw_gbps": 2000}}"#,
        );
        let d = req.cluster.device_graph().unwrap();
        assert_eq!(d.compute.peak_flops, 30e12);
        assert_eq!(d.compute.mem_bw, 2000e9);
    }

    #[test]
    fn mem_limit_rides_the_wire_and_reports_peaks() {
        let service = PlanService::new();
        // a roomy budget: the reply must succeed and carry the peak vector
        let reply = handle_line(
            &service,
            r#"{"net": "lenet5", "devices": 2, "want": "evaluate",
                "mem_limit": 16000000000}"#,
        );
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        let peaks = match v.get("evaluation").unwrap().get("peak_mem_per_dev").unwrap() {
            Json::Arr(a) => a.clone(),
            other => panic!("peak_mem_per_dev must be an array, got {other:?}"),
        };
        assert_eq!(peaks.len(), 2);
        assert!(peaks.iter().all(|p| p.as_f64().unwrap() > 0.0));
        // an unsatisfiable budget is a one-line infeasibility, not a panic
        let reply = handle_line(
            &service,
            r#"{"net": "lenet5", "devices": 2, "mem_limit": 1}"#,
        );
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        let msg = v.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.starts_with("infeasible"), "unexpected error: {msg}");
    }

    /// A tiny valid spec document for the inline-graph tests.
    fn tiny_spec(batch: usize) -> String {
        crate::graph::nets::minicnn(batch).unwrap().to_spec().to_string()
    }

    #[test]
    fn inline_graphs_plan_and_evaluate() {
        let service = PlanService::new();
        // evaluate an inline custom graph end to end
        let reply = handle_line(
            &service,
            &format!(r#"{{"graph": {}, "devices": 2, "want": "evaluate"}}"#, tiny_spec(64)),
        );
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        let eval = v.get("evaluation").unwrap();
        assert!(eval.get("throughput_img_s").unwrap().as_f64().unwrap() > 0.0);
        // the plan reply for the same graph matches the builtin's: an
        // inline spec of minicnn IS minicnn, content-addressed
        let reply = handle_line(
            &service,
            &format!(r#"{{"graph": {}, "devices": 2, "want": "plan"}}"#, tiny_spec(64)),
        );
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        let direct = service
            .plan(&PlanRequest::new(Network::MiniCnn, 2).unwrap().per_gpu_batch(32))
            .unwrap();
        assert_eq!(v.get("plan").unwrap().to_string(), direct.to_json().to_string());
        // ... and the builtin request above hit the spec-primed caches
        assert_eq!(service.stats().table_builds, 1, "digest dedup across spec/builtin");
    }

    #[test]
    fn inline_graph_caps_are_split_and_named() {
        // a realistic deep net rides inline untruncated
        let wide = crate::graph::nets::inception_v3(32).unwrap().to_spec().to_string();
        let req = planning(&format!(r#"{{"graph": {wide}, "devices": 2}}"#));
        assert_eq!(req.network.name(), "inception_v3");

        // a request beyond the old blanket 64 KiB *line* cap but within
        // the new per-field caps must now parse (the point of splitting)
        let padded = tiny_spec(8)
            .replace(r#""name":"conv1""#, &format!(r#""name":"{}""#, "x".repeat(70_000)));
        let line = format!(r#"{{"graph": {padded}, "devices": 2}}"#);
        assert!(line.len() > 64 * 1024, "padded request is {}B", line.len());
        assert!(parse_request(&line).is_ok(), "64 KiB is no longer a request ceiling");

        // too many layers: the error names the layer cap
        let mut layers = vec![
            r#"{"op": "input", "inputs": [], "shape": [1, 3, 64, 64]}"#.to_string()
        ];
        for i in 1..=MAX_GRAPH_LAYERS {
            layers.push(format!(
                r#"{{"op": "conv", "cout": 3, "kernel": [1, 1], "stride": [1, 1],
                     "padding": [0, 0], "inputs": [{}], "shape": [1, 3, 64, 64]}}"#,
                i - 1
            ));
        }
        let deep = format!(
            r#"{{"graph": {{"version": 1, "name": "deep", "layers": [{}]}}}}"#,
            layers.join(",")
        );
        let err = parse_request(&deep).unwrap_err();
        assert!(err.to_string().contains(&MAX_GRAPH_LAYERS.to_string()), "{err}");

        // oversized spec bytes: the error names the byte cap
        let huge_name = "n".repeat(MAX_GRAPH_BYTES);
        let fat = format!(
            r#"{{"graph": {{"version": 1, "name": "{huge_name}", "layers": [
                {{"op": "input", "inputs": [], "shape": [1, 3, 4, 4]}}]}}, "devices": 2}}"#
        );
        let err = parse_request(&fat).unwrap_err();
        assert!(err.to_string().contains("spec bytes"), "{err}");

        // mutually exclusive / misplaced fields
        for raw in [
            format!(r#"{{"net": "lenet5", "graph": {}}}"#, tiny_spec(8)),
            format!(r#"{{"graph": {}, "batch": 64}}"#, tiny_spec(8)),
        ] {
            let err = parse_request(&raw).unwrap_err();
            assert!(!err.to_string().is_empty());
        }

        // a malformed inline spec is a one-line typed rejection
        let err = parse_request(
            r#"{"graph": {"version": 1, "name": "x", "layers": [
                {"op": "input", "inputs": [], "shape": [1, 3, 4, 4]},
                {"op": "softmax", "inputs": [99], "shape": [1, 3]}]}, "devices": 2}"#,
        )
        .unwrap_err();
        assert!(matches!(err, OptError::InvalidGraph(_)), "{err:?}");
        assert!(err.to_string().contains("dangling"), "{err}");
    }

    #[test]
    fn bad_requests_get_one_line_error_replies() {
        let service = PlanService::new();
        for raw in [
            "not json at all",
            r#"{"devices": 2}"#,
            r#"{"net": "not-a-net", "devices": 2}"#,
            r#"{"net": "lenet5", "devices": 2, "cluster": {"nodes": 1}}"#,
            r#"{"net": "lenet5", "devices": 2, "want": "poem"}"#,
            r#"{"net": "lenet5", "cluster": {"sprockets": 3}}"#,
            r#"{"net": "lenet5", "devices": "two"}"#,
            r#"{"net": "lenet5", "devices": 4.9}"#,
            r#"{"net": "lenet5", "devices": -4}"#,
            r#"{"net": "lenet5", "devices": 2, "batch": 2.5}"#,
            r#"{"net": "lenet5", "cluster": {"gpus_per_node": 2.5}}"#,
            r#"{"net": "lenet5", "devices": 2, "mem_limit": 0}"#,
            r#"{"net": "lenet5", "devices": 2, "mem_limit": 1.5}"#,
            r#"{"net": "lenet5", "devices": 2, "mem_limit": "lots"}"#,
        ] {
            let reply = handle_line(&service, raw);
            let v = Json::parse(&reply)
                .unwrap_or_else(|e| panic!("unparsable reply for {raw}: {e}"));
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{raw}");
            let msg = v.get("error").and_then(Json::as_str).unwrap();
            assert!(!msg.is_empty() && !msg.contains('\n'), "{msg:?}");
        }
    }

    #[test]
    fn oversized_numeric_fields_are_rejected() {
        // cluster dims and batch come off the wire: each must be capped
        // before anything sized by them is allocated
        assert!(parse_request(r#"{"net": "lenet5", "devices": 100000}"#).is_err());
        assert!(parse_request(
            r#"{"net": "lenet5", "cluster": {"nodes": 100000, "gpus_per_node": 100000}}"#
        )
        .is_err());
        assert!(parse_request(r#"{"net": "lenet5", "devices": 2, "batch": 1000000}"#).is_err());
        // at the caps everything still parses
        assert!(parse_request(r#"{"net": "lenet5", "devices": 1024, "batch": 4096}"#).is_ok());
    }

    #[test]
    fn stats_want_reports_service_counters() {
        let service = PlanService::new();
        // a cold probe parses to no request and all-zero counters
        assert!(matches!(parse_request(r#"{"want": "stats"}"#).unwrap(), Request::Stats));
        let v = Json::parse(&handle_line(&service, r#"{"want": "stats"}"#)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        let stats = v.get("stats").unwrap();
        assert_eq!(stats.get("table_builds").and_then(Json::as_f64), Some(0.0));
        assert_eq!(stats.get("memo_misses").and_then(Json::as_f64), Some(0.0));
        // planning fields do not combine with a stats probe
        let reply = handle_line(&service, r#"{"net": "lenet5", "want": "stats"}"#);
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{reply}");
        // after one real plan the counters move, memo included
        handle_line(&service, r#"{"net": "lenet5", "devices": 2}"#);
        let v = Json::parse(&handle_line(&service, r#"{"want": "stats"}"#)).unwrap();
        let stats = v.get("stats").unwrap();
        assert_eq!(stats.get("table_builds").and_then(Json::as_f64), Some(1.0));
        assert!(stats.get("memo_misses").and_then(Json::as_f64).unwrap() > 0.0);
        let direct = service.stats();
        assert_eq!(
            stats.get("memo_misses").and_then(Json::as_f64),
            Some(direct.memo_misses as f64)
        );
    }

    #[test]
    fn metrics_want_reports_wire_counters() {
        let service = PlanService::new();
        let metrics = ServeMetrics::default();
        // a cold probe parses and answers all-zero wire counters
        assert!(matches!(parse_request(r#"{"want": "metrics"}"#).unwrap(), Request::Metrics));
        let v =
            Json::parse(&super::handle_line(&service, &metrics, r#"{"want": "metrics"}"#)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        let m = v.get("metrics").unwrap();
        // the probe itself was in flight while the snapshot was taken
        assert_eq!(m.get("in_flight").and_then(Json::as_f64), Some(1.0));
        assert_eq!(m.get("requests").and_then(Json::as_f64), Some(0.0));
        assert!(matches!(m.get("p50_us"), Some(Json::Null)), "no latency before any request");
        assert_eq!(m.get("shed").and_then(Json::as_f64), Some(0.0));
        assert_eq!(m.get("store_hits").and_then(Json::as_f64), Some(0.0));
        // after a real request the histogram has a sample and quantiles
        super::handle_line(&service, &metrics, r#"{"net": "lenet5", "devices": 2}"#);
        let v =
            Json::parse(&super::handle_line(&service, &metrics, r#"{"want": "metrics"}"#)).unwrap();
        let m = v.get("metrics").unwrap();
        assert_eq!(m.get("requests").and_then(Json::as_f64), Some(2.0));
        let p50 = m.get("p50_us").and_then(Json::as_f64).unwrap();
        let p99 = m.get("p99_us").and_then(Json::as_f64).unwrap();
        let max = m.get("max_us").and_then(Json::as_f64).unwrap();
        assert!(p50 >= 1.0 && p50 <= p99 && p99 >= max, "p50 {p50}, p99 {p99}, max {max}");
        assert_eq!(m.get("in_flight").and_then(Json::as_f64), Some(1.0));
        // planning fields do not combine with a metrics probe
        let reply = handle_line(&service, r#"{"net": "lenet5", "want": "metrics"}"#);
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{reply}");
    }

    #[test]
    fn overload_reply_is_typed_and_parseable() {
        let v = Json::parse(&overloaded_reply()).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(v.get("error").and_then(Json::as_str), Some("overloaded"));
        assert_eq!(v.get("retry_after_ms").and_then(Json::as_f64), Some(RETRY_AFTER_MS as f64));
    }

    #[test]
    fn analyze_want_answers_the_report_without_building_tables() {
        let service = PlanService::new();
        let reply = handle_line(
            &service,
            r#"{"net": "lenet5", "devices": 2, "want": "analyze",
                "mem_limit": 16000000000}"#,
        );
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        let analysis = v.get("analysis").unwrap();
        assert_eq!(
            analysis.get("reducibility").and_then(Json::as_str),
            Some("fully-reducible")
        );
        let cert = analysis.get("certificate").unwrap();
        // the exact residual size rides as a decimal string (u128 does
        // not fit a JSON number) next to the always-numeric log2
        let exact: u128 =
            cert.get("residual_space").and_then(Json::as_str).unwrap().parse().unwrap();
        assert!(exact >= 1);
        assert!(cert.get("residual_space_log2").unwrap().as_f64().unwrap() >= 0.0);
        assert!(matches!(
            analysis.get("memory").unwrap().get("infeasible"),
            Some(Json::Null)
        ));
        // the whole probe is structural: nothing expensive was built
        let s = service.stats();
        assert_eq!((s.table_builds, s.searches, s.states_cached), (0, 0, 0));
        // inline graphs analyze too
        let reply = handle_line(
            &service,
            &format!(r#"{{"graph": {}, "devices": 2, "want": "analyze"}}"#, tiny_spec(64)),
        );
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        assert_eq!(service.stats().table_builds, 0);
    }

    #[test]
    fn analyze_want_field_rules() {
        let service = PlanService::new();
        for raw in [
            r#"{"net": "lenet5", "devices": 2, "want": "analyze", "strategy": "data"}"#
                .to_string(),
            format!(
                r#"{{"want": "analyze", "plan": {}}}"#,
                service.plan(&PlanRequest::new(Network::LeNet5, 2).unwrap()).unwrap().to_json()
            ),
            r#"{"want": "analyze"}"#.to_string(),
        ] {
            let v = Json::parse(&handle_line(&service, &raw)).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{raw}");
            let msg = v.get("error").and_then(Json::as_str).unwrap();
            assert!(!msg.is_empty() && !msg.contains('\n'), "{msg:?}");
        }
    }

    #[test]
    fn audit_want_certifies_the_tables_over_the_wire() {
        let service = PlanService::new();
        let reply = handle_line(&service, r#"{"net": "lenet5", "devices": 2, "want": "audit"}"#);
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        let audit = v.get("audit").unwrap();
        let checks = match audit.get("checks").unwrap() {
            Json::Arr(a) => a.clone(),
            other => panic!("checks must be an array, got {other:?}"),
        };
        assert_eq!(checks.len(), 5);
        assert!(checks.iter().all(|c| c.get("ok").and_then(Json::as_bool) == Some(true)));
        let cross = v.get("audit").unwrap().get("cross_check").unwrap();
        assert_eq!(cross.get("complete").and_then(Json::as_bool), Some(true));
        // the probe builds its own tables outside the state memo
        assert_eq!(service.stats().states_cached, 0);
        // field rules: no strategy, no plan document
        for raw in [
            r#"{"net": "lenet5", "devices": 2, "want": "audit", "strategy": "data"}"#,
            r#"{"want": "audit", "plan": {"version": 1}}"#,
        ] {
            let v = Json::parse(&handle_line(&service, raw)).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{raw}");
        }
    }

    #[test]
    fn verify_want_round_trips_plans_over_the_wire() {
        // produce a plan over the wire...
        let producer = PlanService::new();
        let reply = handle_line(&producer, r#"{"net": "lenet5", "devices": 2}"#);
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        let plan = v.get("plan").unwrap().to_string();
        // ...and feed it to a server that has never seen it: the cold
        // ingestion path runs all five checks (context — net, devices,
        // batch — is read off the plan document itself)
        let fresh = PlanService::new();
        let line = format!(r#"{{"want": "verify", "plan": {plan}}}"#);
        let v = Json::parse(&handle_line(&fresh, &line)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("verified").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("cached").and_then(Json::as_bool), Some(false));
        let checks = match v.get("checks").unwrap() {
            Json::Arr(a) => a.len(),
            other => panic!("checks must be an array, got {other:?}"),
        };
        assert_eq!(checks, 5, "all five invariants reported");
        // re-verifying the identical artifact is a warm cache hit
        let v = Json::parse(&handle_line(&fresh, &line)).unwrap();
        assert_eq!(v.get("verified").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("cached").and_then(Json::as_bool), Some(true));
        // the producer itself primed its cache when planning, so even the
        // first verify there is the warm path
        let v = Json::parse(&handle_line(&producer, &line)).unwrap();
        assert_eq!(v.get("cached").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn verify_want_rejects_corrupt_plans_with_the_check_name() {
        let service = PlanService::new();
        let req = PlanRequest::new(Network::LeNet5, 2).unwrap();
        let mut plan = service.plan(&req).unwrap().as_ref().clone();
        plan.cost_s += 1.0;
        let line = format!(r#"{{"want": "verify", "plan": {}}}"#, plan.to_json());
        let v = Json::parse(&handle_line(&PlanService::new(), &line)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        let msg = v.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.contains("cost-coherence"), "error must name the check: {msg}");
    }

    #[test]
    fn verify_want_field_rules() {
        let service = PlanService::new();
        let plan = service
            .plan(&PlanRequest::new(Network::LeNet5, 2).unwrap())
            .unwrap()
            .to_json()
            .to_string();
        // `plan` belongs to verify alone; verify rejects planning knobs
        // the plan document already encodes
        for raw in [
            format!(r#"{{"net": "lenet5", "devices": 2, "plan": {plan}}}"#),
            format!(r#"{{"want": "verify", "plan": {plan}, "batch": 32}}"#),
            format!(r#"{{"want": "verify", "plan": {plan}, "strategy": "data"}}"#),
            format!(r#"{{"want": "verify", "plan": {plan}, "mem_limit": 1000}}"#),
            r#"{"want": "verify"}"#.to_string(),
            r#"{"want": "verify", "plan": {"version": 99}}"#.to_string(),
        ] {
            let v = Json::parse(&handle_line(&service, &raw)).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{raw}");
            let msg = v.get("error").and_then(Json::as_str).unwrap();
            assert!(!msg.is_empty() && !msg.contains('\n'), "{msg:?}");
        }
    }

    #[test]
    fn evaluate_reply_carries_the_planner_numbers() {
        let service = PlanService::new();
        let reply = handle_line(
            &service,
            r#"{"net": "lenet5", "devices": 2, "strategy": "data", "want": "evaluate"}"#,
        );
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        let eval = v.get("evaluation").unwrap();
        let throughput = eval.get("throughput_img_s").unwrap().as_f64().unwrap();
        assert!(throughput > 0.0);
        let req = PlanRequest::new(Network::LeNet5, 2).unwrap().strategy(StrategyKind::Data);
        let direct = service.evaluate(&req).unwrap();
        assert_eq!(throughput, direct.throughput);
    }
}
