//! The public planning API: a typed, fallible session facade.
//!
//! This is the crate's front door (DESIGN.md §4). A [`Planner`] is a
//! long-lived session bound to one (network, cluster) pair:
//!
//! ```
//! use optcnn::planner::{Network, Planner, StrategyKind};
//!
//! # fn main() -> optcnn::Result<()> {
//! let mut planner = Planner::builder(Network::AlexNet).devices(4).build()?;
//! let eval = planner.evaluate(StrategyKind::Layerwise)?;
//! assert!(eval.throughput > 0.0);
//! // repeated queries reuse the session's cost tables and plans
//! let again = planner.evaluate(StrategyKind::Layerwise)?;
//! assert_eq!(eval.estimate, again.estimate);
//! assert_eq!(planner.session_stats().table_builds, 1);
//! # Ok(())
//! # }
//! ```
//!
//! Design points:
//!
//! * **Typed names.** [`Network`] and [`StrategyKind`] replace stringly
//!   lookups; both round-trip through [`std::str::FromStr`] /
//!   [`std::fmt::Display`] for CLI and config use, and unknown names
//!   surface as [`OptError`] values, never panics.
//! * **Arbitrary networks.** Sessions and requests take a
//!   [`NetworkSpec`]: a builtin [`Network`] preset *or* any validated
//!   [`CompGraph`] — typically loaded from a
//!   [`GraphSpec`](crate::graph::spec) JSON document (`--network-file`,
//!   the `graph` wire field). Cache identity is the graph's structural
//!   content digest ([`CompGraph::digest`]), so equal structures share
//!   state no matter how they were named or spelled (DESIGN.md §5).
//! * **Pluggable search.** The optimization algorithm is a
//!   [`SearchBackend`] chosen at build time: [`Elimination`]
//!   (Algorithm 1) by default, [`ExhaustiveDfs`] for ground truth.
//! * **Arbitrary clusters.** A [`ClusterSpec`] describes any
//!   `nodes x gpus_per_node` topology with custom bandwidths and compute
//!   models; [`PlannerBuilder::devices`] is shorthand for the paper's
//!   P100 preset.
//! * **Memory-aware planning.** [`PlannerBuilder::mem_limit`] (or
//!   [`PlannerBuilder::mem_limit_device`], which reads the cluster's own
//!   HBM capacity) constrains the search to configurations whose
//!   per-device peak bytes fit the budget ([`crate::memory`],
//!   DESIGN.md §3); unsatisfiable budgets surface as
//!   [`OptError::Infeasible`], and with no budget planning is
//!   byte-identical to the unconstrained search.
//! * **Amortized sessions.** Cost tables are built once per session, the
//!   layer-wise search runs once, and materialized [`ExecutionPlan`]s are
//!   kept in an LRU [`PlanCache`] — repeated queries against the same
//!   (network, cluster) pair skip all of that work ([`SessionStats`]
//!   exposes the counters; the `planner_session` bench measures it).
//! * **Concurrent serving.** A [`Planner`] is a single-caller session —
//!   every method takes `&mut self`. For many concurrent callers,
//!   [`service::PlanService`] fronts the same pipeline behind `&self`
//!   with a sharded plan cache and single-flight state building, and
//!   [`serve`] speaks it over TCP (`optcnn serve`). DESIGN.md §6.

#![warn(missing_docs)]

pub mod backend;
pub mod cluster;
pub mod pool;
pub mod serve;
pub mod service;

pub use backend::{Elimination, ExhaustiveDfs, SearchBackend};
pub use cluster::ClusterSpec;
pub use service::{
    PlanRequest, PlanService, ServiceStats, VerifyOutcome, MAX_RESIDUAL_SPACE_LOG2,
};

use std::collections::HashMap;
use std::fmt;
use std::str::FromStr;
use std::sync::Arc;

use crate::audit::AuditReport;
use crate::cost::{resolved_build_workers, BuildOptions, CostModel, CostTables, TableMemo};
use crate::device::DeviceGraph;
use crate::error::{OptError, Result};
use crate::graph::{nets, CompGraph};
use crate::memory::MemBudget;
use crate::metrics::CommBreakdown;
use crate::optimizer::{strategies, Optimized, SearchStats};
use crate::parallel::Strategy;
use crate::plan::{ExecutionPlan, PlanCache};
use crate::sim::{steady_state_step_plan, SimReport};

/// The paper's default per-GPU batch size.
pub const PER_GPU_BATCH: usize = 32;

/// The benchmark networks the planner knows how to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Network {
    /// LeNet-5 (LeCun et al.) — the small sanity-check net.
    LeNet5,
    /// AlexNet (Krizhevsky et al. 2012), single-tower variant.
    AlexNet,
    /// VGG-16 configuration D (Simonyan & Zisserman 2014).
    Vgg16,
    /// Inception-v3 (Szegedy et al. 2016).
    InceptionV3,
    /// ResNet-18 (He et al. 2016).
    ResNet18,
    /// ResNet-50 (He et al. 2016).
    ResNet50,
    /// The 8-layer CNN with AOT execution artifacts (`make artifacts`).
    MiniCnn,
}

impl Network {
    /// Every known network, in display order.
    pub const ALL: [Network; 7] = [
        Network::LeNet5,
        Network::AlexNet,
        Network::Vgg16,
        Network::InceptionV3,
        Network::ResNet18,
        Network::ResNet50,
        Network::MiniCnn,
    ];

    /// Canonical name; `name().parse::<Network>()` round-trips.
    pub fn name(self) -> &'static str {
        match self {
            Network::LeNet5 => "lenet5",
            Network::AlexNet => "alexnet",
            Network::Vgg16 => "vgg16",
            Network::InceptionV3 => "inception_v3",
            Network::ResNet18 => "resnet18",
            Network::ResNet50 => "resnet50",
            Network::MiniCnn => "minicnn",
        }
    }

    /// Build the computation graph at a **global** batch size.
    pub fn graph(self, global_batch: usize) -> Result<CompGraph> {
        match self {
            Network::LeNet5 => nets::lenet5(global_batch),
            Network::AlexNet => nets::alexnet(global_batch),
            Network::Vgg16 => nets::vgg16(global_batch),
            Network::InceptionV3 => nets::inception_v3(global_batch),
            Network::ResNet18 => nets::resnet18(global_batch),
            Network::ResNet50 => nets::resnet50(global_batch),
            Network::MiniCnn => nets::minicnn(global_batch),
        }
    }
}

/// The network a planning session or request is about: a builtin
/// [`Network`] preset (built at the session's global batch), or an
/// arbitrary user graph (a validated [`CompGraph`], typically loaded
/// from a [`GraphSpec`](crate::graph::spec) via `--network-file` or the
/// `graph` wire field).
///
/// This is the seam that opens the closed `Network` enum: everything
/// downstream — cost tables, search, plans, caches — works off the
/// materialized graph, and cache identity is the graph's structural
/// [`digest`](CompGraph::digest), so a preset and a spec describing the
/// same network share cached state.
///
/// A custom graph carries its own global batch size in its input shape;
/// per-GPU batch settings apply to presets only.
#[derive(Debug, Clone)]
pub enum NetworkSpec {
    /// A builtin benchmark network, built at `per_gpu_batch x devices`.
    Preset(Network),
    /// An arbitrary computation graph, used as-is. Constructing this
    /// variant directly asserts the graph is valid and unmutated since
    /// its digest was computed — prefer [`NetworkSpec::custom`], which
    /// enforces both (wire and file specs always go through it).
    Custom(Arc<CompGraph>),
}

impl NetworkSpec {
    /// Wrap a user graph as a custom network, validating it first.
    /// Rebuilds the graph ([`CompGraph::revalidated`]) so a digest
    /// cached before any caller-side mutation cannot alias another
    /// graph's cache entries.
    pub fn custom(graph: CompGraph) -> Result<NetworkSpec> {
        Ok(NetworkSpec::Custom(Arc::new(graph.revalidated()?)))
    }

    /// Load a custom network from a `GraphSpec` JSON file — the one
    /// loader behind `--network-file` and the `network_file` config key.
    /// Errors carry the path: unreadable files are [`OptError::Io`],
    /// malformed documents [`OptError::InvalidGraph`].
    pub fn from_spec_file(path: &str) -> Result<NetworkSpec> {
        let text =
            std::fs::read_to_string(path).map_err(|e| OptError::Io(format!("{path}: {e}")))?;
        let json = crate::util::json::Json::parse(&text)
            .map_err(|e| OptError::InvalidGraph(format!("{path}: {e}")))?;
        NetworkSpec::custom(CompGraph::from_spec(&json).map_err(|e| match e {
            OptError::InvalidGraph(msg) => OptError::InvalidGraph(format!("{path}: {msg}")),
            other => other,
        })?)
    }

    /// The network's display name.
    pub fn name(&self) -> &str {
        match self {
            NetworkSpec::Preset(net) => net.name(),
            NetworkSpec::Custom(g) => &g.name,
        }
    }

    /// The underlying preset, if this is one.
    pub fn preset(&self) -> Option<Network> {
        match self {
            NetworkSpec::Preset(net) => Some(*net),
            NetworkSpec::Custom(_) => None,
        }
    }

    /// The fixed global batch a custom graph carries (`None` for
    /// presets, which are built at any requested batch).
    pub fn fixed_batch(&self) -> Option<usize> {
        match self {
            NetworkSpec::Preset(_) => None,
            NetworkSpec::Custom(g) => Some(g.batch()),
        }
    }

    /// Materialize the graph: presets build at `global_batch`, custom
    /// graphs are shared as-is (their own batch governs).
    pub fn build_graph(&self, global_batch: usize) -> Result<Arc<CompGraph>> {
        match self {
            NetworkSpec::Preset(net) => Ok(Arc::new(net.graph(global_batch)?)),
            NetworkSpec::Custom(g) => Ok(Arc::clone(g)),
        }
    }
}

impl From<Network> for NetworkSpec {
    fn from(net: Network) -> NetworkSpec {
        NetworkSpec::Preset(net)
    }
}

impl fmt::Display for NetworkSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Network {
    type Err = OptError;

    /// Accepts canonical names plus the historical aliases (`lenet`,
    /// `vgg`, `inception`, `inceptionv3`, `resnet`).
    fn from_str(s: &str) -> Result<Network> {
        match s {
            "lenet5" | "lenet" => Ok(Network::LeNet5),
            "alexnet" => Ok(Network::AlexNet),
            "vgg16" | "vgg" => Ok(Network::Vgg16),
            "inception_v3" | "inception" | "inceptionv3" => Ok(Network::InceptionV3),
            "resnet18" | "resnet" => Ok(Network::ResNet18),
            "resnet50" => Ok(Network::ResNet50),
            "minicnn" => Ok(Network::MiniCnn),
            other => Err(OptError::UnknownNetwork(other.to_string())),
        }
    }
}

impl fmt::Display for Network {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The parallelization strategies the planner can resolve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// Data parallelism: every layer partitions the sample dimension.
    Data,
    /// Model parallelism: parameter layers partition output channels.
    Model,
    /// "One weird trick": data-parallel convs, model-parallel FCs.
    Owt,
    /// The per-layer optimum found by the session's [`SearchBackend`].
    Layerwise,
}

impl StrategyKind {
    /// Every strategy, in the paper's comparison order.
    pub const ALL: [StrategyKind; 4] =
        [StrategyKind::Data, StrategyKind::Model, StrategyKind::Owt, StrategyKind::Layerwise];

    /// Canonical name; `name().parse::<StrategyKind>()` round-trips.
    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::Data => "data",
            StrategyKind::Model => "model",
            StrategyKind::Owt => "owt",
            StrategyKind::Layerwise => "layerwise",
        }
    }
}

impl FromStr for StrategyKind {
    type Err = OptError;

    fn from_str(s: &str) -> Result<StrategyKind> {
        match s {
            "data" => Ok(StrategyKind::Data),
            "model" => Ok(StrategyKind::Model),
            "owt" => Ok(StrategyKind::Owt),
            "layerwise" | "optimal" => Ok(StrategyKind::Layerwise),
            other => Err(OptError::UnknownStrategy(other.to_string())),
        }
    }
}

impl fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Evaluation of one strategy on the session's (network, cluster) pair.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// Equation 1 estimate (seconds/step) — the paper's validated cost
    /// model (their Table 4 shows it within 10% of the real cluster), and
    /// therefore the primary throughput predictor here.
    pub estimate: f64,
    /// Discrete-event steady-state simulation of the same step (the
    /// independent check; it overlaps communication more aggressively
    /// than the serial-sum estimate).
    pub sim: SimReport,
    /// Per-step communication volume.
    pub comm: CommBreakdown,
    /// Cost-model training throughput (images/s) = batch / estimate.
    pub throughput: f64,
    /// Simulated training throughput (images/s) = batch / sim step.
    pub sim_throughput: f64,
    /// Per-device high-water memory (bytes) recorded on the plan
    /// (`ExecutionPlan::peak_mem_per_dev`).
    pub peak_mem_per_dev: Vec<f64>,
}

impl Evaluation {
    /// The worst device's high-water memory (bytes) — what a per-device
    /// budget is compared against (mirrors [`ExecutionPlan::peak_mem`]).
    pub fn peak_mem(&self) -> f64 {
        self.peak_mem_per_dev.iter().fold(0.0, |a, &b| a.max(b))
    }
}

/// Derive an [`Evaluation`] from a materialized plan — the one kernel
/// behind [`Planner::evaluate_strategy`] and [`PlanService::evaluate`],
/// so the session and service paths produce identical numbers by
/// construction (pinned by `tests/service.rs`).
fn evaluate_plan(
    cm: &CostModel<'_>,
    plan: &ExecutionPlan,
    strategy: &Strategy,
    global_batch: usize,
) -> Evaluation {
    let estimate = cm.t_o(strategy);
    let sim = steady_state_step_plan(plan, cm);
    let comm = plan.comm();
    let throughput = global_batch as f64 / estimate;
    let sim_throughput = sim.throughput(global_batch);
    let peak_mem_per_dev = plan.peak_mem_per_dev.clone();
    Evaluation { estimate, sim, comm, throughput, sim_throughput, peak_mem_per_dev }
}

/// Work counters for one [`Planner`] session: how much expensive state
/// was built versus reused. A warm session answering a repeated query
/// increments only `plan_hits`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Times the session built its [`CostTables`] (at most 1).
    pub table_builds: u64,
    /// Times the search backend actually ran (at most 1).
    pub searches: u64,
    /// Plan-cache lookups served without rebuilding.
    pub plan_hits: u64,
    /// Plan-cache lookups that had to materialize a plan.
    pub plan_misses: u64,
    /// Per-layer/per-edge cost-table memo lookups answered from cache
    /// ([`TableMemo`]; DESIGN.md §7).
    pub memo_hits: u64,
    /// Per-layer/per-edge cost-table memo lookups that ran a build.
    pub memo_misses: u64,
    /// Worker threads the cost-table build resolved to (`0` until the
    /// tables are built; [`crate::cost::resolved_build_workers`]).
    pub build_workers: u64,
    /// Configurations removed by dominance pruning
    /// ([`PlannerBuilder::prune_dominated`]; `0` unless enabled).
    pub pruned_configs: u64,
}

/// How the session's per-device memory budget is specified.
enum MemLimit {
    /// An explicit byte count.
    Bytes(u64),
    /// The cluster's own HBM capacity (`ComputeModel::hbm_bytes`):
    /// 16 GB for the p100 preset, 32 GB v100, 40 GB a100.
    DeviceCapacity,
}

/// Configures and validates a [`Planner`] session.
///
/// Obtained from [`Planner::builder`]; every setter is chainable and
/// validation happens in [`PlannerBuilder::build`].
pub struct PlannerBuilder {
    network: NetworkSpec,
    per_gpu_batch: Option<usize>,
    cluster: Option<ClusterSpec>,
    devices: Option<usize>,
    backend: Box<dyn SearchBackend>,
    plan_cache_cap: usize,
    mem_limit: Option<MemLimit>,
    build_threads: usize,
    prune_dominated: bool,
}

impl PlannerBuilder {
    /// Shorthand for the paper's P100 testbed at `n` devices. Mutually
    /// exclusive with [`PlannerBuilder::cluster`].
    pub fn devices(mut self, n: usize) -> PlannerBuilder {
        self.devices = Some(n);
        self
    }

    /// Plan against an explicit cluster description. Mutually exclusive
    /// with [`PlannerBuilder::devices`].
    pub fn cluster(mut self, spec: ClusterSpec) -> PlannerBuilder {
        self.cluster = Some(spec);
        self
    }

    /// Per-GPU batch size (default: the paper's 32). The network's global
    /// batch is `per_gpu_batch x num_devices`. Applies to preset
    /// networks only — a custom graph carries its own batch, and setting
    /// this alongside one is an error.
    pub fn per_gpu_batch(mut self, batch: usize) -> PlannerBuilder {
        self.per_gpu_batch = Some(batch);
        self
    }

    /// Select the strategy-search algorithm (default: [`Elimination`]).
    pub fn backend(mut self, backend: impl SearchBackend + 'static) -> PlannerBuilder {
        self.backend = Box::new(backend);
        self
    }

    /// Select a boxed backend (the CLI path through
    /// [`backend::by_name`]).
    pub fn backend_boxed(mut self, backend: Box<dyn SearchBackend>) -> PlannerBuilder {
        self.backend = backend;
        self
    }

    /// Capacity of the session's LRU plan cache (default 8).
    pub fn plan_cache_capacity(mut self, cap: usize) -> PlannerBuilder {
        self.plan_cache_cap = cap;
        self
    }

    /// Worker threads for cost-table construction (DESIGN.md §7).
    /// `0` (the default) uses one thread per available core; `1` builds
    /// serially on the calling thread. Any value produces bit-identical
    /// tables — the knob trades wall time only.
    pub fn build_threads(mut self, threads: usize) -> PlannerBuilder {
        self.build_threads = threads;
        self
    }

    /// Constrain the layer-wise search to a per-device memory budget of
    /// `bytes`: configurations whose per-device peak
    /// ([`crate::memory::layer_peak_bytes`]) exceeds it are dropped from
    /// the cost tables before the search runs, and a layer with no
    /// feasible configuration surfaces as [`OptError::Infeasible`]. With
    /// no budget (the default) planning is byte-identical to the
    /// unconstrained search.
    pub fn mem_limit(mut self, bytes: u64) -> PlannerBuilder {
        self.mem_limit = Some(MemLimit::Bytes(bytes));
        self
    }

    /// Remove dominance-certified configurations from the session's cost
    /// tables before the search ([`crate::audit::prune_tables`],
    /// DESIGN.md §12). Exact: a dominated configuration can never appear
    /// in a first-minimum optimum, so the searched strategy is
    /// byte-identical with or without pruning — only the enumerated
    /// space shrinks. Off by default (`--prune-dominated` on the CLI).
    pub fn prune_dominated(mut self, on: bool) -> PlannerBuilder {
        self.prune_dominated = on;
        self
    }

    /// [`PlannerBuilder::mem_limit`] set from the cluster's own HBM
    /// capacity (`ComputeModel::hbm_bytes`; the presets carry 16 GB for
    /// p100, 32 GB v100, 40 GB a100).
    pub fn mem_limit_device(mut self) -> PlannerBuilder {
        self.mem_limit = Some(MemLimit::DeviceCapacity);
        self
    }

    /// Validate the configuration and open the session: materializes the
    /// device graph and the network graph at the session's global batch.
    pub fn build(self) -> Result<Planner> {
        if self.per_gpu_batch == Some(0) {
            return Err(OptError::InvalidArgument(
                "per-GPU batch size must be at least 1".into(),
            ));
        }
        if self.plan_cache_cap == 0 {
            return Err(OptError::InvalidArgument(
                "plan cache capacity must be at least 1".into(),
            ));
        }
        let spec = match (self.cluster, self.devices) {
            (Some(_), Some(_)) => {
                return Err(OptError::InvalidArgument(
                    "specify either .devices(n) or .cluster(spec), not both".into(),
                ))
            }
            (Some(spec), None) => spec,
            (None, Some(n)) => ClusterSpec::p100(n)?,
            (None, None) => ClusterSpec::p100(4)?,
        };
        let devices = spec.device_graph()?;
        let mem_limit = match self.mem_limit {
            None => None,
            Some(MemLimit::Bytes(b)) => {
                if b == 0 {
                    return Err(OptError::InvalidArgument(
                        "memory limit must be at least 1 byte".into(),
                    ));
                }
                Some(b)
            }
            Some(MemLimit::DeviceCapacity) => Some(devices.compute.hbm_bytes as u64),
        };
        let global_batch = match self.network.fixed_batch() {
            None => {
                let per_gpu = self.per_gpu_batch.unwrap_or(PER_GPU_BATCH);
                per_gpu.checked_mul(devices.num_devices()).ok_or_else(|| {
                    OptError::InvalidArgument(format!(
                        "global batch overflows: {per_gpu} per GPU x {} devices",
                        devices.num_devices()
                    ))
                })?
            }
            Some(batch) => {
                if self.per_gpu_batch.is_some() {
                    return Err(OptError::InvalidArgument(
                        "a custom graph carries its own batch size; per_gpu_batch \
                         applies to preset networks only"
                            .into(),
                    ));
                }
                batch
            }
        };
        let graph = self.network.build_graph(global_batch)?;
        Ok(Planner {
            network: self.network,
            global_batch,
            graph,
            devices,
            backend: self.backend,
            mem_limit,
            build_threads: self.build_threads,
            prune_dominated: self.prune_dominated,
            memo: Arc::new(TableMemo::new()),
            tables: None,
            layerwise: None,
            baselines: HashMap::new(),
            plans: PlanCache::new(self.plan_cache_cap),
            table_builds: 0,
            searches: 0,
            pruned_configs: 0,
        })
    }
}

/// A planning session: one network on one cluster, with cost tables,
/// the layer-wise search result, and materialized plans cached across
/// queries. See the [module docs](self) for the full design.
pub struct Planner {
    network: NetworkSpec,
    global_batch: usize,
    graph: Arc<CompGraph>,
    devices: DeviceGraph,
    backend: Box<dyn SearchBackend>,
    mem_limit: Option<u64>,
    build_threads: usize,
    prune_dominated: bool,
    memo: Arc<TableMemo>,
    tables: Option<CostTables>,
    layerwise: Option<Optimized>,
    baselines: HashMap<StrategyKind, Strategy>,
    plans: PlanCache,
    table_builds: u64,
    searches: u64,
    pruned_configs: u64,
}

impl Planner {
    /// Start configuring a session for `network` — a [`Network`] preset
    /// or any [`NetworkSpec`] (see [`PlannerBuilder`]).
    pub fn builder(network: impl Into<NetworkSpec>) -> PlannerBuilder {
        PlannerBuilder {
            network: network.into(),
            per_gpu_batch: None,
            cluster: None,
            devices: None,
            backend: Box::new(Elimination),
            plan_cache_cap: 8,
            mem_limit: None,
            build_threads: 0,
            prune_dominated: false,
        }
    }

    /// The session's network.
    pub fn network(&self) -> &NetworkSpec {
        &self.network
    }

    /// The session's computation graph (built at the global batch).
    pub fn graph(&self) -> &CompGraph {
        &self.graph
    }

    /// The session's device graph.
    pub fn device_graph(&self) -> &DeviceGraph {
        &self.devices
    }

    /// Devices in the session's cluster.
    pub fn num_devices(&self) -> usize {
        self.devices.num_devices()
    }

    /// Per-GPU batch size (`global_batch / num_devices`, rounded down
    /// for custom graphs whose batch is not a device multiple).
    pub fn per_gpu_batch(&self) -> usize {
        self.global_batch / self.devices.num_devices()
    }

    /// Global batch size: `per_gpu_batch x num_devices` for presets, the
    /// graph's own input batch for custom networks.
    pub fn global_batch(&self) -> usize {
        self.global_batch
    }

    /// The name of the session's search backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Replace the session's search backend — how `--backend auto`
    /// binds the choice its certificate made ([`backend::auto`]) after
    /// the session (and therefore the graph) exists. Clears the cached
    /// layer-wise optimum, which belonged to the old backend; the cost
    /// tables are backend-independent and stay.
    pub fn set_backend_boxed(&mut self, backend: Box<dyn SearchBackend>) {
        self.backend = backend;
        self.layerwise = None;
    }

    /// The session's per-device memory budget in bytes, if any.
    pub fn mem_limit(&self) -> Option<u64> {
        self.mem_limit
    }

    /// The session's cost tables, built on first use and cached for the
    /// session's lifetime (the expensive per-session step). Under a
    /// [`PlannerBuilder::mem_limit`] the build masks memory-infeasible
    /// configurations and can fail with [`OptError::Infeasible`]; with no
    /// budget it cannot fail. With
    /// [`PlannerBuilder::prune_dominated`] the cached tables are the
    /// dominance-pruned ones every search consumes.
    pub fn tables(&mut self) -> Result<&CostTables> {
        if self.tables.is_none() {
            let cm = CostModel::new(&self.graph, &self.devices);
            let budget = self.mem_limit.map(MemBudget::new);
            let opts = BuildOptions { threads: self.build_threads, memo: Some(&self.memo) };
            let mut built =
                CostTables::build_opts(&cm, self.devices.num_devices(), budget, &opts)?;
            if self.prune_dominated {
                let (pruned, removed) = crate::audit::prune_tables(&cm, &built);
                built = pruned;
                self.pruned_configs = removed as u64;
            }
            self.tables = Some(built);
            self.table_builds += 1;
        }
        Ok(self.tables.as_ref().expect("tables just built"))
    }

    /// Run the session's search backend over the cost tables, returning
    /// the optimal strategy with cost and search statistics. Cached: the
    /// search runs at most once per session.
    pub fn optimize(&mut self) -> Result<Optimized> {
        if let Some(opt) = &self.layerwise {
            return Ok(opt.clone());
        }
        self.tables()?;
        let tables = self.tables.as_ref().expect("tables just built");
        let opt = self.backend.search(tables)?;
        self.searches += 1;
        self.layerwise = Some(opt.clone());
        Ok(opt)
    }

    /// Search statistics of the layer-wise optimization, if it ran.
    pub fn search_stats(&self) -> Option<&SearchStats> {
        self.layerwise.as_ref().map(|o| &o.stats)
    }

    /// The pre-planning static analysis of this session's (graph,
    /// cluster, budget): reducibility class, exact search-cost
    /// certificate, memory precheck, and lints (DESIGN.md §11). Takes
    /// `&self` — the pass is purely structural, builds no cost tables,
    /// and leaves [`SessionStats::table_builds`] untouched.
    pub fn analyze(&self) -> crate::analyze::AnalysisReport {
        crate::analyze::analyze(
            &self.graph,
            &self.devices,
            self.devices.num_devices(),
            self.mem_limit.map(MemBudget::new),
        )
    }

    /// Statically audit this session's cost tables (DESIGN.md §12):
    /// prove every [`crate::error::TableCheck`] invariant, compute the
    /// per-layer dominance certificates, and differentially cross-check
    /// the two search backends over the elimination-reduced residual
    /// kernel. The audit always runs over freshly built **unpruned**
    /// tables — the budget-mask check re-derives the canonical
    /// enumeration, which dominance-pruned tables intentionally fail —
    /// so it neither consumes nor populates the session's table cache
    /// (the shared [`TableMemo`] still makes the build cheap after
    /// [`Planner::tables`] ran). An incomplete cross-check (the DFS hit
    /// its [`backend::AUTO_DFS_BUDGET`]) certifies nothing and comes
    /// back as a report warning, not an error.
    pub fn audit(&mut self) -> Result<AuditReport> {
        let cm = CostModel::new(&self.graph, &self.devices);
        let budget = self.mem_limit.map(MemBudget::new);
        let opts = BuildOptions { threads: self.build_threads, memo: Some(&self.memo) };
        let tables = CostTables::build_opts(&cm, self.devices.num_devices(), budget, &opts)?;
        let mut report = crate::audit::audit_tables(&cm, &tables)?;
        let cross = crate::audit::cross_check(&cm, &tables, Some(backend::AUTO_DFS_BUDGET))?;
        if !cross.complete {
            report.warnings.push(format!(
                "backend cross-check incomplete: exhaustive DFS hit its {:?} budget after \
                 {} search-tree nodes, so backend agreement is not certified",
                backend::AUTO_DFS_BUDGET, cross.visited
            ));
        }
        report.cross = Some(cross);
        Ok(report)
    }

    /// Resolve a strategy: baselines are derived from the graph shape,
    /// `Layerwise` runs (or reuses) the backend search.
    pub fn strategy(&mut self, kind: StrategyKind) -> Result<Strategy> {
        if kind == StrategyKind::Layerwise {
            return Ok(self.optimize()?.strategy);
        }
        if let Some(s) = self.baselines.get(&kind) {
            return Ok(s.clone());
        }
        let ndev = self.devices.num_devices();
        let s = match kind {
            StrategyKind::Data => strategies::data_parallel(&self.graph, ndev),
            StrategyKind::Model => strategies::model_parallel(&self.graph, ndev),
            StrategyKind::Owt => strategies::owt(&self.graph, ndev),
            StrategyKind::Layerwise => unreachable!("handled above"),
        };
        self.baselines.insert(kind, s.clone());
        Ok(s)
    }

    /// The materialized execution plan for a strategy kind, served from
    /// the session's LRU cache.
    pub fn plan(&mut self, kind: StrategyKind) -> Result<Arc<ExecutionPlan>> {
        let s = self.strategy(kind)?;
        Ok(self.plan_for(&s))
    }

    /// The materialized execution plan for an arbitrary (possibly
    /// hand-built) strategy, served from the session's LRU cache.
    pub fn plan_for(&mut self, strategy: &Strategy) -> Arc<ExecutionPlan> {
        let cm = CostModel::new(&self.graph, &self.devices);
        self.plans.get_or_build(&cm, strategy)
    }

    /// Evaluate a strategy kind: Eq. 1 estimate, steady-state simulation,
    /// and communication volume, all derived from the cached plan.
    pub fn evaluate(&mut self, kind: StrategyKind) -> Result<Evaluation> {
        let s = self.strategy(kind)?;
        Ok(self.evaluate_strategy(&s))
    }

    /// [`Planner::evaluate`] for an arbitrary strategy.
    pub fn evaluate_strategy(&mut self, strategy: &Strategy) -> Evaluation {
        let plan = self.plan_for(strategy);
        let cm = CostModel::new(&self.graph, &self.devices);
        evaluate_plan(&cm, &plan, strategy, self.global_batch())
    }

    /// How much expensive state this session has built versus reused.
    pub fn session_stats(&self) -> SessionStats {
        let memo = self.memo.stats();
        SessionStats {
            table_builds: self.table_builds,
            searches: self.searches,
            plan_hits: self.plans.hits(),
            plan_misses: self.plans.misses(),
            memo_hits: memo.hits,
            memo_misses: memo.misses,
            build_workers: if self.table_builds > 0 {
                resolved_build_workers(self.build_threads) as u64
            } else {
                0
            },
            pruned_configs: self.pruned_configs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn network_round_trips() {
        for net in Network::ALL {
            assert_eq!(net.name().parse::<Network>().unwrap(), net);
            assert_eq!(net.to_string(), net.name());
        }
        assert!(matches!("resnet1001".parse::<Network>(), Err(OptError::UnknownNetwork(_))));
    }

    #[test]
    fn strategy_kind_round_trips() {
        for kind in StrategyKind::ALL {
            assert_eq!(kind.name().parse::<StrategyKind>().unwrap(), kind);
            assert_eq!(kind.to_string(), kind.name());
        }
        assert!(matches!("zigzag".parse::<StrategyKind>(), Err(OptError::UnknownStrategy(_))));
    }

    #[test]
    fn builder_validates() {
        assert!(Planner::builder(Network::LeNet5).devices(2).per_gpu_batch(0).build().is_err());
        assert!(Planner::builder(Network::LeNet5).devices(6).build().is_err());
        assert!(Planner::builder(Network::LeNet5)
            .devices(2)
            .plan_cache_capacity(0)
            .build()
            .is_err());
        assert!(Planner::builder(Network::LeNet5)
            .devices(2)
            .cluster(ClusterSpec::new(1, 2))
            .build()
            .is_err());
        assert!(Planner::builder(Network::LeNet5).devices(2).mem_limit(0).build().is_err());
    }

    #[test]
    fn custom_graphs_carry_their_own_batch() {
        let g = nets::minicnn(48).unwrap();
        let spec = NetworkSpec::custom(g).unwrap();
        assert_eq!(spec.fixed_batch(), Some(48));
        assert!(spec.preset().is_none());
        let mut p = Planner::builder(spec.clone()).devices(2).build().unwrap();
        assert_eq!(p.global_batch(), 48);
        assert_eq!(p.network().name(), "minicnn");
        assert!(p.evaluate(StrategyKind::Data).unwrap().throughput > 0.0);
        // explicit per-GPU batch does not combine with a fixed-batch graph
        assert!(matches!(
            Planner::builder(spec).devices(2).per_gpu_batch(16).build(),
            Err(OptError::InvalidArgument(_))
        ));
    }

    #[test]
    fn mem_limit_device_reads_the_cluster_hbm() {
        use crate::device::ComputeModel;
        let spec = ClusterSpec::new(1, 2).compute(ComputeModel::v100());
        let p = Planner::builder(Network::LeNet5)
            .cluster(spec)
            .mem_limit_device()
            .build()
            .unwrap();
        assert_eq!(p.mem_limit(), Some(32_000_000_000));
        let free = Planner::builder(Network::LeNet5).devices(2).build().unwrap();
        assert_eq!(free.mem_limit(), None);
    }

    #[test]
    fn unsatisfiable_mem_limit_is_infeasible_not_a_panic() {
        let mut p = Planner::builder(Network::LeNet5).devices(2).mem_limit(1).build().unwrap();
        match p.evaluate(StrategyKind::Layerwise) {
            Err(OptError::Infeasible { layer, overshoot }) => {
                assert!(!layer.is_empty());
                assert!(overshoot > 0);
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
    }

    #[test]
    fn session_reuses_tables_and_search() {
        let mut p = Planner::builder(Network::LeNet5).devices(2).build().unwrap();
        assert_eq!(p.session_stats(), SessionStats::default());
        let a = p.evaluate(StrategyKind::Layerwise).unwrap();
        let s1 = p.session_stats();
        assert_eq!((s1.table_builds, s1.searches, s1.plan_misses), (1, 1, 1));
        let b = p.evaluate(StrategyKind::Layerwise).unwrap();
        let s2 = p.session_stats();
        assert_eq!((s2.table_builds, s2.searches, s2.plan_misses), (1, 1, 1));
        assert_eq!(s2.plan_hits, 1);
        assert_eq!(a.estimate, b.estimate);
        assert_eq!(a.sim.step_time, b.sim.step_time);
    }

    #[test]
    fn pruned_session_matches_unpruned_and_reports_workers() {
        let mut plain = Planner::builder(Network::AlexNet).devices(2).build().unwrap();
        let mut pruned = Planner::builder(Network::AlexNet)
            .devices(2)
            .prune_dominated(true)
            .build()
            .unwrap();
        let a = plain.optimize().unwrap();
        let b = pruned.optimize().unwrap();
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.strategy.configs, b.strategy.configs);
        let (sp, sq) = (plain.session_stats(), pruned.session_stats());
        assert_eq!(sp.pruned_configs, 0);
        assert!(sq.pruned_configs > 0, "alexnet@2 has dominated configs");
        assert!(sp.build_workers >= 1 && sq.build_workers >= 1);
    }

    #[test]
    fn audit_certifies_a_session() {
        let mut p = Planner::builder(Network::LeNet5).devices(2).build().unwrap();
        let report = p.audit().unwrap();
        assert!(report.cross.as_ref().is_some_and(|c| c.complete));
        assert!(report.warnings.is_empty());
        // auditing builds its own unpruned tables without touching the
        // session's cache
        assert_eq!(p.session_stats().table_builds, 0);
    }

    #[test]
    fn layerwise_beats_baselines() {
        let mut p = Planner::builder(Network::AlexNet).devices(4).build().unwrap();
        let lw = p.evaluate(StrategyKind::Layerwise).unwrap().throughput;
        for kind in [StrategyKind::Data, StrategyKind::Model, StrategyKind::Owt] {
            let t = p.evaluate(kind).unwrap().throughput;
            assert!(lw >= t * (1.0 - 1e-9), "layerwise {lw} < {kind} {t}");
        }
    }

    #[test]
    fn custom_cluster_changes_the_answer() {
        let mut p100 = Planner::builder(Network::AlexNet).devices(4).build().unwrap();
        let slow = ClusterSpec::new(1, 4).name("slow").intra_bw(1e9);
        let mut degraded =
            Planner::builder(Network::AlexNet).cluster(slow).build().unwrap();
        let fast = p100.evaluate(StrategyKind::Data).unwrap();
        let throttled = degraded.evaluate(StrategyKind::Data).unwrap();
        assert!(
            throttled.estimate > fast.estimate,
            "slower links must slow the step: {} vs {}",
            throttled.estimate,
            fast.estimate
        );
    }
}
