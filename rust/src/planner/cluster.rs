//! Typed cluster specifications.
//!
//! [`ClusterSpec`] is the builder the planner consumes: device counts,
//! per-device compute model, and intra-/inter-node bandwidths, with the
//! paper's P100 testbed as a preset and a TOML form (`[cluster]` section,
//! see `config/` at the repo root) for non-P100 and custom topologies.
//! All validation happens in [`ClusterSpec::device_graph`], which is the
//! single choke point between user-described hardware and the cost model.

use crate::config::Toml;
use crate::device::{p100, ComputeModel, DeviceGraph};
use crate::error::{OptError, Result};

/// A declarative cluster description: what the user asks for, before any
/// validation. Turn it into hardware with [`ClusterSpec::device_graph`].
///
/// ```
/// use optcnn::planner::ClusterSpec;
/// use optcnn::device::ComputeModel;
///
/// let d = ClusterSpec::new(2, 8)
///     .name("v100-pod")
///     .compute(ComputeModel::v100())
///     .intra_bw(50e9)
///     .inter_bw(6e9)
///     .device_graph()
///     .unwrap();
/// assert_eq!(d.num_devices(), 16);
/// ```
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    name: String,
    nodes: usize,
    gpus_per_node: usize,
    intra_bw: f64,
    inter_bw: f64,
    host_bw: f64,
    compute: ComputeModel,
}

impl ClusterSpec {
    /// A `nodes x gpus_per_node` cluster with the paper's P100 link and
    /// compute defaults (the inter-node default fans the NIC bandwidth
    /// out across the node's GPUs, like the preset does); override any
    /// field with the builder methods. Degenerate shapes are reported by
    /// [`ClusterSpec::device_graph`], not here, so specs can be
    /// assembled freely.
    pub fn new(nodes: usize, gpus_per_node: usize) -> ClusterSpec {
        ClusterSpec {
            name: format!("{nodes}x{gpus_per_node}"),
            nodes,
            gpus_per_node,
            intra_bw: p100::INTRA_BW,
            inter_bw: p100::NIC_BW / gpus_per_node.max(1) as f64,
            host_bw: p100::HOST_BW,
            compute: ComputeModel::p100(),
        }
    }

    /// The paper's testbed preset scaled to `ngpus` devices (1, 2, 4 or a
    /// multiple of 4): up to 4 P100s per node, NVLink intra-node, the
    /// node NIC's bandwidth fanned out across its GPUs inter-node. The
    /// shape rule and link constants are [`crate::device::p100`]'s, so
    /// this spec always matches [`DeviceGraph::p100_cluster`].
    pub fn p100(ngpus: usize) -> Result<ClusterSpec> {
        let (nodes, gpus_per_node) = p100::shape(ngpus)?;
        Ok(ClusterSpec {
            name: format!("p100x{ngpus}"),
            nodes,
            gpus_per_node,
            intra_bw: p100::INTRA_BW,
            inter_bw: p100::NIC_BW / gpus_per_node as f64,
            host_bw: p100::HOST_BW,
            compute: ComputeModel::p100(),
        })
    }

    /// Set the cluster's display name.
    pub fn name(mut self, name: &str) -> ClusterSpec {
        self.name = name.to_string();
        self
    }

    /// Set the effective intra-node point-to-point bandwidth, bytes/s.
    pub fn intra_bw(mut self, bw: f64) -> ClusterSpec {
        self.intra_bw = bw;
        self
    }

    /// Set the effective inter-node point-to-point bandwidth, bytes/s.
    pub fn inter_bw(mut self, bw: f64) -> ClusterSpec {
        self.inter_bw = bw;
        self
    }

    /// Set the device-to-host (PCIe) bandwidth, bytes/s.
    pub fn host_bw(mut self, bw: f64) -> ClusterSpec {
        self.host_bw = bw;
        self
    }

    /// Set the per-device compute model (see [`ComputeModel::named`] for
    /// the presets).
    pub fn compute(mut self, compute: ComputeModel) -> ClusterSpec {
        self.compute = compute;
        self
    }

    /// Total device count this spec describes.
    pub fn num_devices(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Validate the spec and materialize the device graph the cost model,
    /// simulator, and plans consume.
    pub fn device_graph(&self) -> Result<DeviceGraph> {
        DeviceGraph::cluster(
            &self.name,
            self.nodes,
            self.gpus_per_node,
            self.intra_bw,
            self.inter_bw,
            self.host_bw,
            self.compute,
        )
    }

    /// Read a spec from the `[cluster]` section of a parsed TOML document
    /// (bandwidths in GB/s, `compute = "p100" | "v100" | "a100"` with
    /// optional `peak_tflops` / `mem_bw_gbps` overrides). Missing keys
    /// fall back to the P100 defaults of [`ClusterSpec::new`]; present
    /// keys of the wrong type are config errors, never silent defaults.
    pub fn from_toml(doc: &Toml) -> Result<ClusterSpec> {
        let nodes = doc.try_usize_or("cluster", "nodes", 1)?;
        let gpus_per_node = doc.try_usize_or("cluster", "gpus_per_node", 4)?;
        let mut spec = ClusterSpec::new(nodes, gpus_per_node);
        spec.intra_bw = doc.try_f64_or("cluster", "intra_bw_gbps", spec.intra_bw / 1e9)? * 1e9;
        spec.inter_bw = doc.try_f64_or("cluster", "inter_bw_gbps", spec.inter_bw / 1e9)? * 1e9;
        spec.host_bw = doc.try_f64_or("cluster", "host_bw_gbps", spec.host_bw / 1e9)? * 1e9;
        if let Some(v) = doc.get("cluster", "compute") {
            let name = v.as_str().ok_or_else(|| {
                OptError::Config("cluster.compute must be a string".into())
            })?;
            spec.compute = ComputeModel::named(name)?;
        }
        if let Some(v) = doc.get("cluster", "peak_tflops") {
            spec.compute.peak_flops = v.as_f64().ok_or_else(|| {
                OptError::Config("cluster.peak_tflops must be a number".into())
            })? * 1e12;
        }
        if let Some(v) = doc.get("cluster", "mem_bw_gbps") {
            spec.compute.mem_bw = v.as_f64().ok_or_else(|| {
                OptError::Config("cluster.mem_bw_gbps must be a number".into())
            })? * 1e9;
        }
        if let Some(v) = doc.get("cluster", "name") {
            spec.name = v
                .as_str()
                .ok_or_else(|| OptError::Config("cluster.name must be a string".into()))?
                .to_string();
        }
        Ok(spec)
    }

    /// Load a spec from a TOML file (see `config/` for examples).
    pub fn load(path: &str) -> Result<ClusterSpec> {
        let text =
            std::fs::read_to_string(path).map_err(|e| OptError::Io(format!("{path}: {e}")))?;
        ClusterSpec::from_toml(&Toml::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p100_preset_matches_device_preset() {
        for n in [1usize, 2, 4, 8, 16] {
            let from_spec = ClusterSpec::p100(n).unwrap().device_graph().unwrap();
            let preset = DeviceGraph::p100_cluster(n).unwrap();
            assert_eq!(from_spec.num_devices(), preset.num_devices());
            assert_eq!(from_spec.num_nodes(), preset.num_nodes());
            assert_eq!(from_spec.host_bw, preset.host_bw);
            assert_eq!(from_spec.node_bw, preset.node_bw);
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(from_spec.bandwidth(i, j), preset.bandwidth(i, j));
                }
            }
        }
        assert!(ClusterSpec::p100(6).is_err());
    }

    #[test]
    fn builder_overrides_apply() {
        let d = ClusterSpec::new(2, 2)
            .name("tiny")
            .intra_bw(40e9)
            .inter_bw(5e9)
            .host_bw(16e9)
            .compute(ComputeModel::a100())
            .device_graph()
            .unwrap();
        assert_eq!(d.name, "tiny");
        assert_eq!(d.num_devices(), 4);
        assert_eq!(d.bandwidth(0, 1), 40e9);
        assert_eq!(d.bandwidth(0, 2), 5e9);
        assert_eq!(d.compute.peak_flops, ComputeModel::a100().peak_flops);
    }

    #[test]
    fn validation_happens_at_materialization() {
        // assembling a bad spec is fine; materializing it is not
        let spec = ClusterSpec::new(0, 4);
        assert!(spec.device_graph().is_err());
        assert!(ClusterSpec::new(1, 4).intra_bw(0.0).device_graph().is_err());
    }

    #[test]
    fn toml_round_trip() {
        let doc = Toml::parse(
            r#"
[cluster]
name = "v100-pod"
nodes = 2
gpus_per_node = 8
intra_bw_gbps = 130.0
inter_bw_gbps = 6.0
compute = "v100"
"#,
        )
        .unwrap();
        let spec = ClusterSpec::from_toml(&doc).unwrap();
        assert_eq!(spec.num_devices(), 16);
        let d = spec.device_graph().unwrap();
        assert_eq!(d.name, "v100-pod");
        assert_eq!(d.bandwidth(0, 1), 130e9);
        assert_eq!(d.bandwidth(0, 8), 6e9);
        assert_eq!(d.compute.peak_flops, ComputeModel::v100().peak_flops);
    }

    #[test]
    fn toml_rejects_unknown_compute() {
        let doc = Toml::parse("[cluster]\ncompute = \"tpu\"\n").unwrap();
        assert!(ClusterSpec::from_toml(&doc).is_err());
    }

    #[test]
    fn toml_compute_overrides() {
        let doc = Toml::parse("[cluster]\npeak_tflops = 30.0\nmem_bw_gbps = 2000\n").unwrap();
        let spec = ClusterSpec::from_toml(&doc).unwrap();
        assert_eq!(spec.device_graph().unwrap().compute.peak_flops, 30e12);
        assert_eq!(spec.device_graph().unwrap().compute.mem_bw, 2000e9);
    }
}
