//! A bounded worker pool with explicit backpressure (DESIGN.md §13).
//!
//! `optcnn serve` used to spawn one unbounded thread per connection — a
//! burst of N clients meant N threads and N in-flight table builds, with
//! the queueing happening implicitly (and invisibly) in the kernel's
//! scheduler. This pool makes both resources explicit: a fixed number of
//! worker threads pull jobs from a bounded queue, and when the queue is
//! full [`try_execute`](WorkerPool::try_execute) **fails fast**, handing
//! the job back so the caller can shed load with a typed overload reply
//! instead of queueing unboundedly. Built on
//! [`std::sync::mpsc::sync_channel`] — no new dependencies, and the
//! rendezvous semantics at capacity 0 are exactly the "no queue at all"
//! degenerate case.
//!
//! Shutdown is graceful by construction: dropping the sender disconnects
//! the channel, workers drain every job already accepted, then exit —
//! so a request the server said yes to is always answered, and a request
//! it cannot take is refused *loudly* at the accept loop.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::util::sync::lock;

/// A queued unit of work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed worker threads over a bounded job queue. See the [module
/// docs](self).
pub struct WorkerPool {
    /// `Some` while accepting; taken (dropped) to initiate drain.
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads (at least 1) over a queue holding at most
    /// `queue_cap` pending jobs. `queue_cap == 0` is a rendezvous: a job
    /// is accepted only if a worker is ready to take it right now.
    pub fn new(workers: usize, queue_cap: usize) -> WorkerPool {
        let (tx, rx) = sync_channel::<Job>(queue_cap);
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || worker_loop(&rx))
            })
            .collect();
        WorkerPool { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue `job`, or hand it back if the queue is full — the
    /// backpressure signal. The caller owns the rejected job again and
    /// decides what shedding means (for the server: an `overloaded`
    /// reply). Also rejects after [`shutdown`](WorkerPool::shutdown)
    /// has begun.
    pub fn try_execute(&self, job: Job) -> std::result::Result<(), Job> {
        let Some(tx) = &self.tx else { return Err(job) };
        match tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(job) | TrySendError::Disconnected(job)) => Err(job),
        }
    }

    /// Stop accepting, drain every queued job, and join the workers.
    /// Blocks until in-flight and queued work has finished.
    pub fn shutdown(&mut self) {
        drop(self.tx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One worker: pull until the channel is closed *and* drained. The lock
/// guard is a temporary inside the `recv` expression, so it is released
/// before the job runs — dequeueing is serialized, execution is not.
fn worker_loop(rx: &Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = lock(rx).recv();
        match job {
            Ok(job) => job(),
            Err(_) => return, // sender dropped and queue empty
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;
    use std::time::Duration;

    #[test]
    fn runs_jobs_and_drains_on_shutdown() {
        let done = Arc::new(AtomicUsize::new(0));
        let mut pool = WorkerPool::new(4, 64);
        assert_eq!(pool.workers(), 4);
        for _ in 0..50 {
            let done = Arc::clone(&done);
            pool.try_execute(Box::new(move || {
                done.fetch_add(1, Ordering::SeqCst);
            }))
            .unwrap_or_else(|_| panic!("queue of 64 rejected a burst of 50"));
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 50, "shutdown drains accepted jobs");
        // after shutdown, new jobs are refused, not lost silently
        assert!(pool.try_execute(Box::new(|| {})).is_err());
    }

    #[test]
    fn full_queue_hands_the_job_back() {
        // one worker parked on a gate + capacity-1 queue: the 3rd job
        // must come back as backpressure, deterministically
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let mut pool = WorkerPool::new(1, 1);
        pool.try_execute(Box::new(move || {
            started_tx.send(()).unwrap();
            gate_rx.recv().unwrap();
        }))
        .unwrap_or_else(|_| panic!("first job must be accepted"));
        // wait until the worker holds job 1, so job 2 sits in the queue
        started_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        pool.try_execute(Box::new(|| {})).unwrap_or_else(|_| panic!("queue slot is free"));
        let ran = Arc::new(AtomicUsize::new(0));
        let ran2 = Arc::clone(&ran);
        let rejected = pool.try_execute(Box::new(move || {
            ran2.fetch_add(1, Ordering::SeqCst);
        }));
        assert!(rejected.is_err(), "full queue must reject");
        // the rejected closure is handed back intact and still runnable
        rejected.unwrap_err()();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        gate_tx.send(()).unwrap();
        pool.shutdown();
    }

    #[test]
    fn zero_workers_still_means_one() {
        let mut pool = WorkerPool::new(0, 1);
        assert_eq!(pool.workers(), 1);
        let (tx, rx) = mpsc::channel();
        pool.try_execute(Box::new(move || tx.send(7).unwrap()))
            .unwrap_or_else(|_| panic!("accepted"));
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)).unwrap(), 7);
        pool.shutdown();
    }

    #[test]
    fn jobs_run_concurrently_across_workers() {
        // two workers must be able to hold two jobs at once: each job
        // waits for the other via a barrier — impossible serially
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let (tx, rx) = mpsc::channel();
        let mut pool = WorkerPool::new(2, 2);
        for _ in 0..2 {
            let barrier = Arc::clone(&barrier);
            let tx = tx.clone();
            pool.try_execute(Box::new(move || {
                barrier.wait();
                tx.send(()).unwrap();
            }))
            .unwrap_or_else(|_| panic!("accepted"));
        }
        for _ in 0..2 {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        pool.shutdown();
    }
}
