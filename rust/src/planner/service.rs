//! Concurrent plan serving: a thread-safe, shareable front end over the
//! planning pipeline (DESIGN.md §6).
//!
//! A [`Planner`](crate::planner::Planner) is a single-caller session —
//! every method takes `&mut self`. A [`PlanService`] is its concurrent
//! counterpart: `Send + Sync`, shared as `Arc<PlanService>` across any
//! number of threads, answering the same queries with the same bytes
//! (pinned by `tests/service.rs`). Two mechanisms make that concurrency
//! cheap rather than merely safe:
//!
//! * **Sharded plan cache.** Materialized
//!   [`ExecutionPlan`](crate::plan::ExecutionPlan)s live in N
//!   independently mutex-guarded [`PlanCache`] shards selected by
//!   [`PlanKey`] hash, so unrelated queries never contend on one lock.
//!   Hit/miss counters are atomics ([`PlanCache::hits`]), summed across
//!   shards by [`PlanService::stats`].
//! * **Single-flight state building.** The expensive per-(graph,
//!   cluster, memory-budget) state — [`CostTables`] plus the search backend's
//!   Algorithm 1 optimum — is memoized behind one single-flight cell per
//!   key (the [`SingleFlightLru`] facade from `util::sync`, model-checked
//!   under loom by the `rust/modelcheck` crate): when many threads miss
//!   on the same key at once, exactly one runs the build and the rest
//!   block until it finishes, instead of all
//!   redundantly rebuilding tables. Keys are content-addressed: the
//!   graph by its structural [`digest`](CompGraph::digest) (so identical
//!   custom specs dedupe with each other and with presets) and the full
//!   cluster structure by value (never a lossy hash). The memo is
//!   LRU-bounded ([`PlanServiceBuilder::state_capacity`]) so a
//!   long-running server cannot grow without limit, and failed builds
//!   are *not* memoized — a later request retries.
//!
//! ```
//! use std::sync::Arc;
//! use optcnn::planner::{Network, PlanRequest, PlanService, StrategyKind};
//!
//! # fn main() -> optcnn::Result<()> {
//! let service = Arc::new(PlanService::new());
//! let req = PlanRequest::new(Network::LeNet5, 2)?.strategy(StrategyKind::Data);
//! let eval = service.evaluate(&req)?;
//! assert!(eval.throughput > 0.0);
//! # Ok(())
//! # }
//! ```

// Wire-facing request path: a malformed or hostile request must come
// back as a typed `OptError`, never a panic in a serving thread.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::audit::AuditReport;
use crate::cost::{resolved_build_workers, BuildOptions, CostModel, CostTables, TableMemo};
use crate::device::{ClusterFingerprint, DeviceGraph};
use crate::error::{OptError, Result};
use crate::graph::{CompGraph, GraphDigest};
use crate::memory::MemBudget;
use crate::optimizer::{strategies, Optimized};
use crate::parallel::Strategy;
use crate::plan::{ExecutionPlan, PlanCache, PlanKey};
use crate::store::{PlanStore, StoreKey};
use crate::util::sync::{lock, SingleFlightLru};
use crate::verify::{verify_plan, VerifyReport};

use crate::analyze::{self, AnalysisReport};

use super::backend::{Elimination, SearchBackend};
use super::cluster::ClusterSpec;
use super::{evaluate_plan, Evaluation, NetworkSpec, StrategyKind, PER_GPU_BATCH};

/// The largest residual enumeration (log2 of complete assignments) a
/// [`PlanService`] will attempt. The pre-planning certificate
/// (`analyze`, DESIGN.md §11) predicts the final-enumeration size
/// exactly; a request above this cap is rejected with
/// [`OptError::SearchSpaceExceeded`] *before* any cost table is built,
/// so a hostile or merely unlucky custom graph POSTed to `optcnn serve`
/// cannot pin a worker thread. 2^32 leaves is minutes of
/// `enumerate_final` — generous for legitimate graphs (every builtin's
/// residual space is far smaller) while bounding the worst case.
pub const MAX_RESIDUAL_SPACE_LOG2: f64 = 32.0;

/// One plan query: which network (preset or custom graph), on what
/// cluster, at what per-GPU batch, under which strategy — the unit of
/// work a [`PlanService`] answers. Requests are plain data (`Clone` —
/// custom graphs are shared behind an `Arc`), cheap to build per call.
#[derive(Debug, Clone)]
pub struct PlanRequest {
    /// The network to plan: a builtin preset or an arbitrary graph.
    pub network: NetworkSpec,
    /// The cluster to plan against.
    pub cluster: ClusterSpec,
    /// Per-GPU batch size (the global batch is `per_gpu_batch x
    /// devices`). Presets only: a custom graph carries its own batch and
    /// ignores this field.
    pub per_gpu_batch: usize,
    /// The strategy to resolve and evaluate.
    pub strategy: StrategyKind,
    /// Optional per-device memory budget in bytes: the layer-wise search
    /// drops configurations whose per-device peak exceeds it (see
    /// [`crate::memory`]); an unsatisfiable budget answers
    /// [`OptError::Infeasible`]. `None` plans unconstrained.
    pub mem_limit: Option<u64>,
}

impl PlanRequest {
    /// A request against the paper's P100 preset at `devices` GPUs, with
    /// the paper's per-GPU batch and the layer-wise optimal strategy.
    pub fn new(network: impl Into<NetworkSpec>, devices: usize) -> Result<PlanRequest> {
        Ok(PlanRequest::with_cluster(network, ClusterSpec::p100(devices)?))
    }

    /// A request against an arbitrary cluster description.
    pub fn with_cluster(network: impl Into<NetworkSpec>, cluster: ClusterSpec) -> PlanRequest {
        PlanRequest {
            network: network.into(),
            cluster,
            per_gpu_batch: PER_GPU_BATCH,
            strategy: StrategyKind::Layerwise,
            mem_limit: None,
        }
    }

    /// Select the strategy to resolve (default: layerwise optimal).
    pub fn strategy(mut self, kind: StrategyKind) -> PlanRequest {
        self.strategy = kind;
        self
    }

    /// Set the per-GPU batch size (default: the paper's 32).
    pub fn per_gpu_batch(mut self, batch: usize) -> PlanRequest {
        self.per_gpu_batch = batch;
        self
    }

    /// Constrain the layer-wise search to a per-device memory budget of
    /// `bytes` (default: unconstrained).
    pub fn mem_limit(mut self, bytes: u64) -> PlanRequest {
        self.mem_limit = Some(bytes);
        self
    }
}

/// Identity of the expensive per-(graph, cluster, budget) state.
/// Compared by value, never by a lossy hash, so two distinct graphs or
/// clusters cannot alias one memo entry. The graph is named by its
/// structural content [`digest`](CompGraph::digest) — not the old
/// `Network` enum discriminant — so a custom spec structurally identical
/// to a preset (or to another spec, however it was spelled) shares one
/// entry, and the batch size rides along inside the digest via the input
/// shape. The memory budget is part of the key because it masks the
/// config space the tables enumerate.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct StateKey {
    graph: GraphDigest,
    cluster: ClusterFingerprint,
    mem_limit: Option<u64>,
}

/// The memoized expensive state for one [`StateKey`]: the exhaustive
/// cost tables and the search backend's optimum over them.
struct TableState {
    tables: CostTables,
    optimized: Optimized,
}

/// The bounded single-flight memo: an LRU of build cells from the
/// model-checked [`SingleFlightLru`] facade. Evicting an entry is always
/// safe — requests already waiting on its cell hold their own `Arc` and
/// complete normally; only the memoization is lost.
type StateMemo = SingleFlightLru<StateKey, Result<Arc<TableState>>>;

/// Configures a [`PlanService`]; obtained from [`PlanService::builder`].
pub struct PlanServiceBuilder {
    shards: usize,
    shard_capacity: usize,
    state_capacity: usize,
    backend: Box<dyn SearchBackend>,
    build_threads: usize,
    verify_loaded: bool,
    prune_dominated: bool,
    store_dir: Option<PathBuf>,
}

impl PlanServiceBuilder {
    /// Number of independent plan-cache shards (default 8). More shards
    /// mean less lock contention between unrelated queries.
    pub fn shards(mut self, n: usize) -> PlanServiceBuilder {
        self.shards = n;
        self
    }

    /// LRU capacity of each shard (default 8 plans).
    pub fn shard_capacity(mut self, cap: usize) -> PlanServiceBuilder {
        self.shard_capacity = cap;
        self
    }

    /// LRU capacity of the single-flight state memo — how many
    /// (network, batch, cluster) cost-table/search results stay resident
    /// (default 32). The memo would otherwise grow without bound in a
    /// long-running server answering many distinct keys.
    pub fn state_capacity(mut self, cap: usize) -> PlanServiceBuilder {
        self.state_capacity = cap;
        self
    }

    /// The strategy-search backend used for layer-wise requests
    /// (default: [`Elimination`]). One backend serves all threads.
    pub fn backend(mut self, backend: impl SearchBackend + 'static) -> PlanServiceBuilder {
        self.backend = Box::new(backend);
        self
    }

    /// Worker threads per cost-table build (DESIGN.md §7). `0` (the
    /// default) uses one thread per available core; `1` builds serially
    /// on the requesting thread. Any value produces bit-identical
    /// tables — the knob trades wall time only.
    pub fn build_threads(mut self, threads: usize) -> PlanServiceBuilder {
        self.build_threads = threads;
        self
    }

    /// Remove dominance-certified configurations from every memoized
    /// cost table before its search ([`crate::audit::prune_tables`],
    /// DESIGN.md §12). Exact — searched strategies are byte-identical
    /// with or without it. Off by default.
    pub fn prune_dominated(mut self, on: bool) -> PlanServiceBuilder {
        self.prune_dominated = on;
        self
    }

    /// Whether externally supplied plans are statically verified before
    /// being admitted into the plan cache (default `true`; see
    /// [`PlanService::ingest`]). Disabling this trusts the artifact —
    /// only sensible when every client is the planner itself. Also
    /// governs the on-disk [`plan_store`](PlanServiceBuilder::plan_store)
    /// load gate (a store entry is an external artifact too).
    pub fn verify_loaded(mut self, verify: bool) -> PlanServiceBuilder {
        self.verify_loaded = verify;
        self
    }

    /// Persist plans in (and serve them from) a content-addressed
    /// on-disk store rooted at `dir` ([`crate::store`], DESIGN.md §13).
    /// The plan path becomes shards → disk → build: a warm restart
    /// answers previously planned requests byte-identically with zero
    /// table builds. Loaded entries pass the [`verify_plan`] gate before
    /// being served (unless verify-on-load is disabled); entries that
    /// fail it are evicted, never served. Off by default.
    pub fn plan_store(mut self, dir: impl Into<PathBuf>) -> PlanServiceBuilder {
        self.store_dir = Some(dir.into());
        self
    }

    /// Validate the configuration and assemble the service.
    pub fn build(self) -> Result<PlanService> {
        if self.shards == 0 {
            return Err(OptError::InvalidArgument(
                "plan service needs at least one cache shard".into(),
            ));
        }
        if self.shard_capacity == 0 {
            return Err(OptError::InvalidArgument(
                "shard capacity must be at least 1".into(),
            ));
        }
        if self.state_capacity == 0 {
            return Err(OptError::InvalidArgument(
                "state memo capacity must be at least 1".into(),
            ));
        }
        let store = match &self.store_dir {
            Some(dir) => Some(PlanStore::open(dir.clone())?),
            None => None,
        };
        Ok(self.assemble(store))
    }

    /// Assemble without validating. Callers guarantee the counts are
    /// nonzero (`build` validates; `PlanService::new` uses the default
    /// configuration, which is nonzero by construction) and hand in the
    /// already-opened store (`build` opens it; `new` has none).
    fn assemble(self, store: Option<PlanStore>) -> PlanService {
        // index every plan the shards can hold: a resident plan whose
        // request key fell out of the index would be re-read from disk
        let index_cap = self.shards.saturating_mul(self.shard_capacity).max(1);
        PlanService {
            backend: self.backend,
            shards: (0..self.shards)
                .map(|_| Mutex::new(PlanCache::new(self.shard_capacity)))
                .collect(),
            states: Mutex::new(StateMemo::new(self.state_capacity)),
            memo: Arc::new(TableMemo::new()),
            build_threads: self.build_threads,
            verify_loaded: self.verify_loaded,
            prune_dominated: self.prune_dominated,
            store,
            plan_index: Mutex::new(PlanIndex::new(index_cap)),
            table_builds: AtomicU64::new(0),
            searches: AtomicU64::new(0),
            build_waits: AtomicU64::new(0),
            pruned_configs: AtomicU64::new(0),
            store_hits: AtomicU64::new(0),
            store_misses: AtomicU64::new(0),
            store_writes: AtomicU64::new(0),
            store_rejects: AtomicU64::new(0),
            store_errors: AtomicU64::new(0),
            accept_errors: AtomicU64::new(0),
        }
    }
}

/// Request-level identity of a plan query: everything that determines
/// the served bytes. Unlike [`PlanKey`] (which needs the resolved
/// strategy's per-layer degrees), this key is computable *before* any
/// table is built — which is what lets the disk fast path skip the
/// resolve step entirely. Mirrors the on-disk [`StoreKey`] minus the
/// service-constant pruning flag.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct RequestKey {
    graph: GraphDigest,
    cluster: ClusterFingerprint,
    mem_limit: Option<u64>,
    strategy: StrategyKind,
}

/// A bounded LRU from [`RequestKey`] to the [`PlanKey`] that answered it
/// — the bridge between "what the client asked" and "where the plan
/// lives", so warm requests go straight to their shard without resolving
/// a strategy (and without re-reading the store).
struct PlanIndex {
    cap: usize,
    tick: u64,
    map: HashMap<RequestKey, (u64, PlanKey)>,
}

impl PlanIndex {
    fn new(cap: usize) -> PlanIndex {
        PlanIndex { cap, tick: 0, map: HashMap::new() }
    }

    fn get(&mut self, key: &RequestKey) -> Option<PlanKey> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|(last_used, pkey)| {
            *last_used = tick;
            pkey.clone()
        })
    }

    fn put(&mut self, key: RequestKey, pkey: PlanKey) {
        self.tick += 1;
        if self.map.len() >= self.cap && !self.map.contains_key(&key) {
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, (last_used, _))| *last_used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&lru);
            }
        }
        self.map.insert(key, (self.tick, pkey));
    }
}

/// Aggregate work counters across the whole service: shard hit/miss
/// totals plus single-flight memo activity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Plan-cache lookups served from a shard without building (summed
    /// over shards).
    pub plan_hits: u64,
    /// Plan-cache lookups that materialized a plan (summed over shards).
    pub plan_misses: u64,
    /// Times the expensive (cost tables + search) state was actually
    /// built — with single flight, once per distinct key no matter how
    /// many threads raced for it.
    pub table_builds: u64,
    /// Times a search backend actually ran (== `table_builds` unless a
    /// search failed).
    pub searches: u64,
    /// Requests that blocked on another thread's in-flight state build
    /// instead of duplicating it — the single-flight savings. (Counted
    /// best-effort: a request that lost the race so narrowly that the
    /// build finished first is indistinguishable from a memo hit.)
    pub build_waits: u64,
    /// Plans currently resident across all shards.
    pub plans_cached: usize,
    /// (Tables + optimum) states currently resident in the memo.
    pub states_cached: usize,
    /// Per-layer/per-edge cost-table memo lookups answered from cache
    /// ([`TableMemo`]; DESIGN.md §7) — reuse *across* whole-graph state
    /// builds, e.g. two graphs sharing all but one layer.
    pub memo_hits: u64,
    /// Per-layer/per-edge cost-table memo lookups that ran a build —
    /// with single flight, exactly one per distinct layer/edge key.
    pub memo_misses: u64,
    /// Worker threads each cost-table build resolves to (`0` until the
    /// first build; [`crate::cost::resolved_build_workers`]).
    pub build_workers: u64,
    /// Configurations removed by dominance pruning, summed over state
    /// builds ([`PlanServiceBuilder::prune_dominated`]; `0` unless
    /// enabled).
    pub pruned_configs: u64,
    /// Plans served from the on-disk store, verified on load — each one
    /// a whole (tables + search + build) pipeline skipped
    /// ([`PlanServiceBuilder::plan_store`]; `0` without a store).
    pub store_hits: u64,
    /// Disk lookups that found no entry (counted only when a store is
    /// configured; the request fell through to a build).
    pub store_misses: u64,
    /// Freshly built plans persisted to the store.
    pub store_writes: u64,
    /// Store entries rejected — unreadable, truncated, tampered, or
    /// failing a [`verify_plan`] check on load — and evicted from disk,
    /// so a bad entry is rebuilt once, never retried forever.
    pub store_rejects: u64,
    /// Store write failures (full disk, permissions): the plan was still
    /// served from memory; only the persistence was lost.
    pub store_errors: u64,
    /// TCP accept errors observed by `optcnn serve`'s listener
    /// ([`PlanService::note_accept_error`]; `0` off the wire).
    pub accept_errors: u64,
}

/// A thread-safe plan-serving façade over the planning pipeline.
///
/// Share it as `Arc<PlanService>`; every method takes `&self`. See the
/// [module docs](self) for the sharding and single-flight design, and
/// `optcnn serve` ([`serve`](crate::planner::serve)) for the TCP front
/// end.
pub struct PlanService {
    backend: Box<dyn SearchBackend>,
    shards: Vec<Mutex<PlanCache>>,
    states: Mutex<StateMemo>,
    /// The per-layer/per-edge cost-table memo shared by every state
    /// build this service runs (DESIGN.md §7).
    memo: Arc<TableMemo>,
    build_threads: usize,
    verify_loaded: bool,
    prune_dominated: bool,
    /// The optional on-disk plan store (DESIGN.md §13); the second tier
    /// of the shards → disk → build lookup order.
    store: Option<PlanStore>,
    /// Request-key → plan-key bridge for the warm fast path (see
    /// [`PlanIndex`]).
    plan_index: Mutex<PlanIndex>,
    table_builds: AtomicU64,
    searches: AtomicU64,
    build_waits: AtomicU64,
    pruned_configs: AtomicU64,
    store_hits: AtomicU64,
    store_misses: AtomicU64,
    store_writes: AtomicU64,
    store_rejects: AtomicU64,
    store_errors: AtomicU64,
    accept_errors: AtomicU64,
}

/// How [`PlanService::ingest`] admitted an externally supplied plan.
#[derive(Debug, Clone, PartialEq)]
pub enum VerifyOutcome {
    /// All five static checks ran now and passed (DESIGN.md §10).
    Verified(VerifyReport),
    /// The plan equals one already resident in the cache — built by this
    /// service or verified on an earlier load — so no re-check was
    /// needed. This is the warm ingestion path: one shard lookup.
    CachedVerified,
    /// Admitted without checks because verify-on-load is disabled
    /// ([`PlanServiceBuilder::verify_loaded`]).
    AcceptedUnchecked,
}

impl PlanService {
    /// A service with the default configuration: 8 shards of 8 plans, a
    /// 32-entry state memo, [`Elimination`] search.
    pub fn new() -> PlanService {
        // The defaults are nonzero by construction, so this skips
        // `build`'s validation and cannot fail (no store to open).
        PlanService::builder().assemble(None)
    }

    /// Start configuring a service.
    pub fn builder() -> PlanServiceBuilder {
        PlanServiceBuilder {
            shards: 8,
            shard_capacity: 8,
            state_capacity: 32,
            backend: Box::new(Elimination),
            build_threads: 0,
            verify_loaded: true,
            prune_dominated: false,
            store_dir: None,
        }
    }

    /// Validate the request and materialize its (graph, devices, global
    /// batch) triple — the cheap per-request state (custom graphs are an
    /// `Arc` clone).
    fn session(&self, req: &PlanRequest) -> Result<(Arc<CompGraph>, DeviceGraph, usize)> {
        if req.per_gpu_batch == 0 {
            return Err(OptError::InvalidArgument(
                "per-GPU batch size must be at least 1".into(),
            ));
        }
        if req.mem_limit == Some(0) {
            return Err(OptError::InvalidArgument(
                "memory limit must be at least 1 byte".into(),
            ));
        }
        let devices = req.cluster.device_graph()?;
        let global = match req.network.fixed_batch() {
            Some(batch) => batch,
            None => req.per_gpu_batch.checked_mul(devices.num_devices()).ok_or_else(|| {
                OptError::InvalidArgument(format!(
                    "global batch overflows: {} per GPU x {} devices",
                    req.per_gpu_batch,
                    devices.num_devices()
                ))
            })?,
        };
        let graph = req.network.build_graph(global)?;
        Ok((graph, devices, global))
    }

    /// Resolve the request's strategy: baselines are derived from the
    /// graph shape; `Layerwise` comes from the single-flight memo.
    pub fn strategy(&self, req: &PlanRequest) -> Result<Strategy> {
        let (graph, devices, _) = self.session(req)?;
        self.resolve(req, &graph, &devices)
    }

    fn resolve(
        &self,
        req: &PlanRequest,
        graph: &CompGraph,
        devices: &DeviceGraph,
    ) -> Result<Strategy> {
        let ndev = devices.num_devices();
        Ok(match req.strategy {
            StrategyKind::Data => strategies::data_parallel(graph, ndev),
            StrategyKind::Model => strategies::model_parallel(graph, ndev),
            StrategyKind::Owt => strategies::owt(graph, ndev),
            StrategyKind::Layerwise => {
                self.state_for(req, graph, devices)?.optimized.strategy.clone()
            }
        })
    }

    /// The memoized (tables + optimum) state for the request's key,
    /// built single-flight on first use.
    fn state_for(
        &self,
        req: &PlanRequest,
        graph: &CompGraph,
        devices: &DeviceGraph,
    ) -> Result<Arc<TableState>> {
        let key = StateKey {
            graph: graph.digest().clone(),
            cluster: devices.fingerprint(),
            mem_limit: req.mem_limit,
        };
        let cell = lock(&self.states).cell(&key);
        // Single flight: the map lock is already released, so the build
        // below never blocks unrelated keys. Exactly one thread runs the
        // closure; concurrent requesters of the same key block inside
        // `get_or_init` until it finishes.
        let was_set = cell.is_set();
        let (result, ran) = cell.get_or_init(|| -> Result<Arc<TableState>> {
            let budget = req.mem_limit.map(MemBudget::new);
            // Pre-planning static gate (DESIGN.md §11): certify the
            // residual enumeration is within the service's cap and
            // fast-fail unsatisfiable budgets — both *before* the
            // table-build counter ticks or any table is constructed.
            analyze::precheck(graph, devices.num_devices(), budget, MAX_RESIDUAL_SPACE_LOG2)?;
            self.table_builds.fetch_add(1, Ordering::Relaxed);
            let cm = CostModel::new(graph, devices);
            let opts = BuildOptions { threads: self.build_threads, memo: Some(&self.memo) };
            let mut tables = CostTables::build_opts(&cm, devices.num_devices(), budget, &opts)?;
            if self.prune_dominated {
                let (pruned, removed) = crate::audit::prune_tables(&cm, &tables);
                tables = pruned;
                self.pruned_configs.fetch_add(removed as u64, Ordering::Relaxed);
            }
            let optimized = self.backend.search(&tables)?;
            self.searches.fetch_add(1, Ordering::Relaxed);
            Ok(Arc::new(TableState { tables, optimized }))
        });
        if !ran && !was_set {
            self.build_waits.fetch_add(1, Ordering::Relaxed);
        }
        if result.is_err() {
            // Failed builds are not memoized: drop the cell (only if it
            // is still the one we used) so a later request can retry.
            lock(&self.states).forget(&key, &cell);
        }
        result
    }

    /// The shard owning `key` (stable hash of the structural plan key).
    fn shard_of(&self, key: &PlanKey) -> &Mutex<PlanCache> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// The plan lookup order: **shards → disk → build** (DESIGN.md §13).
    ///
    /// 1. *Shards.* The [`PlanIndex`] maps the request key to the
    ///    structural [`PlanKey`] that answered it before; a resident
    ///    plan returns without resolving a strategy or touching disk.
    /// 2. *Disk.* With a [`plan_store`](PlanServiceBuilder::plan_store)
    ///    configured, a stored entry is loaded, re-verified, admitted
    ///    into its shard, and served — **zero table builds**: this is
    ///    the warm-restart path.
    /// 3. *Build.* Resolve the strategy (tables + search for
    ///    layer-wise), build through the sharded cache — whose mutex
    ///    spans the build, so concurrent misses on one key build once —
    ///    and persist the result for the next restart or replica.
    fn fetch_plan(
        &self,
        req: &PlanRequest,
        graph: &CompGraph,
        devices: &DeviceGraph,
    ) -> Result<Arc<ExecutionPlan>> {
        let rkey = RequestKey {
            graph: graph.digest().clone(),
            cluster: devices.fingerprint(),
            mem_limit: req.mem_limit,
            strategy: req.strategy,
        };
        if let Some(pkey) = lock(&self.plan_index).get(&rkey) {
            if let Some(plan) = lock(self.shard_of(&pkey)).lookup(&pkey) {
                return Ok(plan);
            }
        }
        if let Some(plan) = self.load_stored(&rkey, graph, devices) {
            return Ok(plan);
        }
        let strategy = self.resolve(req, graph, devices)?;
        let cm = CostModel::new(graph, devices);
        let pkey = PlanKey::of(&cm, &strategy);
        let plan = lock(self.shard_of(&pkey)).get_or_build(&cm, &strategy);
        lock(&self.plan_index).put(rkey.clone(), pkey);
        self.persist(&rkey, &plan);
        Ok(plan)
    }

    /// The on-disk [`StoreKey`] for a request against this service (the
    /// service-level pruning flag completes the content address).
    fn store_key_of(&self, rkey: &RequestKey) -> StoreKey {
        StoreKey::new(
            &rkey.graph,
            &rkey.cluster,
            rkey.mem_limit,
            rkey.strategy.name(),
            self.prune_dominated,
        )
    }

    /// The disk tier of [`fetch_plan`](Self::fetch_plan): load the
    /// stored entry, gate it through [`verify_plan`] (the same trust
    /// boundary as [`ingest`](Self::ingest), unless verify-on-load is
    /// disabled), and admit it into its shard. Every failure mode —
    /// absent, corrupt, tampered — degrades to `None` so the build path
    /// always remains available; bad entries are evicted, never retried.
    fn load_stored(
        &self,
        rkey: &RequestKey,
        graph: &CompGraph,
        devices: &DeviceGraph,
    ) -> Option<Arc<ExecutionPlan>> {
        let store = self.store.as_ref()?;
        let skey = self.store_key_of(rkey);
        let loaded = match store.load(&skey) {
            Ok(Some(plan)) => plan,
            Ok(None) => {
                self.store_misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            Err(_) => {
                // unreadable or corrupt: the store evicted it already
                self.store_rejects.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        let cm = CostModel::new(graph, devices);
        if self.verify_loaded && verify_plan(&cm, &loaded).is_err() {
            store.evict(&skey);
            self.store_rejects.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let plan = Arc::new(loaded);
        let pkey = PlanKey::of(&cm, &plan.strategy());
        lock(self.shard_of(&pkey)).insert(pkey.clone(), Arc::clone(&plan));
        lock(&self.plan_index).put(rkey.clone(), pkey);
        self.store_hits.fetch_add(1, Ordering::Relaxed);
        Some(plan)
    }

    /// Best-effort persistence after a fresh build: the plan is already
    /// in hand, so a full disk or bad permissions must not fail the
    /// request — the loss is counted, not propagated.
    fn persist(&self, rkey: &RequestKey, plan: &ExecutionPlan) {
        let Some(store) = &self.store else { return };
        match store.save_if_absent(&self.store_key_of(rkey), plan) {
            Ok(true) => {
                self.store_writes.fetch_add(1, Ordering::Relaxed);
            }
            Ok(false) => {}
            Err(_) => {
                self.store_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Record one TCP accept failure (called by the `optcnn serve`
    /// listener, which must count errors rather than silently retry).
    pub fn note_accept_error(&self) {
        self.accept_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Admit an externally supplied plan at the service's trust boundary
    /// (the `{"want":"verify"}` probe of `optcnn serve`): statically
    /// verify it against the request's (graph, cluster) — DESIGN.md §10 —
    /// and on success cache it as verified, so re-loading the identical
    /// artifact is a warm hit that skips every check. A plan that fails a
    /// check answers [`OptError::InvalidPlan`] and is *not* cached. With
    /// verify-on-load disabled ([`PlanServiceBuilder::verify_loaded`])
    /// the plan is admitted unchecked and the outcome says so.
    pub fn ingest(&self, req: &PlanRequest, plan: &ExecutionPlan) -> Result<VerifyOutcome> {
        let (graph, devices, _) = self.session(req)?;
        let cm = CostModel::new(&graph, &devices);
        let key = PlanKey::of(&cm, &plan.strategy());
        {
            let mut shard = lock(self.shard_of(&key));
            if let Some(cached) = shard.lookup(&key) {
                if *cached == *plan {
                    return Ok(VerifyOutcome::CachedVerified);
                }
                // Same key, different bytes: the plan disagrees with what
                // this service would build, so fall through and let the
                // checks name the violated invariant.
            }
        }
        if !self.verify_loaded {
            lock(self.shard_of(&key)).insert(key, Arc::new(plan.clone()));
            return Ok(VerifyOutcome::AcceptedUnchecked);
        }
        let report = verify_plan(&cm, plan)?;
        lock(self.shard_of(&key)).insert(key, Arc::new(plan.clone()));
        Ok(VerifyOutcome::Verified(report))
    }

    /// The materialized execution plan for a request, served shards →
    /// disk → build ([`fetch_plan`](Self::fetch_plan)).
    pub fn plan(&self, req: &PlanRequest) -> Result<Arc<ExecutionPlan>> {
        let (graph, devices, _) = self.session(req)?;
        self.fetch_plan(req, &graph, &devices)
    }

    /// Evaluate a request: Eq. 1 estimate, steady-state simulation, and
    /// communication volume — the same numbers a single-threaded
    /// [`Planner`](crate::planner::Planner) produces for the same query.
    /// The strategy is read off the plan itself ([`ExecutionPlan::strategy`]
    /// is exact — a plan records every per-layer configuration), so a
    /// plan served from the store evaluates without resolving anything.
    pub fn evaluate(&self, req: &PlanRequest) -> Result<Evaluation> {
        let (graph, devices, global_batch) = self.session(req)?;
        let plan = self.fetch_plan(req, &graph, &devices)?;
        let cm = CostModel::new(&graph, &devices);
        let strategy = plan.strategy();
        Ok(evaluate_plan(&cm, &plan, &strategy, global_batch))
    }

    /// The pre-planning static analysis of a request (DESIGN.md §11):
    /// reducibility class, exact search-cost certificate, memory
    /// precheck (when the request carries a budget), and graph lints —
    /// computed from structure alone, building no cost tables and never
    /// touching the state memo. The enumeration cap is deliberately
    /// *not* applied here: analysis is how a caller finds out whether a
    /// graph would trip it.
    pub fn analyze(&self, req: &PlanRequest) -> Result<AnalysisReport> {
        let (graph, devices, _) = self.session(req)?;
        let budget = req.mem_limit.map(MemBudget::new);
        Ok(analyze::analyze(&graph, &devices, devices.num_devices(), budget))
    }

    /// Statically audit a request's cost tables (DESIGN.md §12): table
    /// invariants, dominance certificates, and the differential backend
    /// cross-check — the `{"want":"audit"}` wire probe. The audit always
    /// builds fresh **unpruned** tables (a dominance-pruned table
    /// legitimately fails the budget-mask re-derivation), so it bypasses
    /// the state memo; the shared per-layer [`TableMemo`] still dedupes
    /// the work against prior builds. The same pre-planning gate as a
    /// planning request applies first, so a hostile graph cannot pin a
    /// worker in the cross-check's enumeration.
    pub fn audit(&self, req: &PlanRequest) -> Result<AuditReport> {
        let (graph, devices, _) = self.session(req)?;
        let budget = req.mem_limit.map(MemBudget::new);
        analyze::precheck(&graph, devices.num_devices(), budget, MAX_RESIDUAL_SPACE_LOG2)?;
        let cm = CostModel::new(&graph, &devices);
        let opts = BuildOptions { threads: self.build_threads, memo: Some(&self.memo) };
        let tables = CostTables::build_opts(&cm, devices.num_devices(), budget, &opts)?;
        let mut report = crate::audit::audit_tables(&cm, &tables)?;
        let cross = crate::audit::cross_check(
            &cm,
            &tables,
            Some(super::backend::AUTO_DFS_BUDGET),
        )?;
        if !cross.complete {
            report.warnings.push(format!(
                "backend cross-check incomplete: exhaustive DFS hit its {:?} budget after \
                 {} search-tree nodes, so backend agreement is not certified",
                super::backend::AUTO_DFS_BUDGET,
                cross.visited
            ));
        }
        report.cross = Some(cross);
        Ok(report)
    }

    /// The memoized layer-wise optimum (strategy, cost, search stats)
    /// for the request's (network, batch, cluster), built on first use.
    pub fn optimized(&self, req: &PlanRequest) -> Result<Optimized> {
        let (graph, devices, _) = self.session(req)?;
        Ok(self.state_for(req, &graph, &devices)?.optimized.clone())
    }

    /// Largest per-layer configuration count (`C` in the paper's
    /// Table 2) of the memoized cost tables for this request; builds the
    /// state on first use like any layer-wise query.
    pub fn max_configs(&self, req: &PlanRequest) -> Result<usize> {
        let (graph, devices, _) = self.session(req)?;
        Ok(self.state_for(req, &graph, &devices)?.tables.max_configs())
    }

    /// Aggregate counters: atomic loads plus a brief lock per shard.
    pub fn stats(&self) -> ServiceStats {
        let mut plan_hits = 0;
        let mut plan_misses = 0;
        let mut plans_cached = 0;
        for shard in &self.shards {
            let s = lock(shard);
            plan_hits += s.hits();
            plan_misses += s.misses();
            plans_cached += s.len();
        }
        let states_cached = lock(&self.states).len();
        let memo = self.memo.stats();
        let table_builds = self.table_builds.load(Ordering::Relaxed);
        ServiceStats {
            plan_hits,
            plan_misses,
            table_builds,
            searches: self.searches.load(Ordering::Relaxed),
            build_waits: self.build_waits.load(Ordering::Relaxed),
            plans_cached,
            states_cached,
            memo_hits: memo.hits,
            memo_misses: memo.misses,
            build_workers: if table_builds > 0 {
                resolved_build_workers(self.build_threads) as u64
            } else {
                0
            },
            pruned_configs: self.pruned_configs.load(Ordering::Relaxed),
            store_hits: self.store_hits.load(Ordering::Relaxed),
            store_misses: self.store_misses.load(Ordering::Relaxed),
            store_writes: self.store_writes.load(Ordering::Relaxed),
            store_rejects: self.store_rejects.load(Ordering::Relaxed),
            store_errors: self.store_errors.load(Ordering::Relaxed),
            accept_errors: self.accept_errors.load(Ordering::Relaxed),
        }
    }

    /// Counters of the shared per-layer/per-edge table memo alone.
    pub fn memo_stats(&self) -> crate::cost::MemoStats {
        self.memo.stats()
    }
}

impl Default for PlanService {
    /// [`PlanService::new`].
    fn default() -> PlanService {
        PlanService::new()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::planner::{Network, Planner};

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn service_is_send_and_sync() {
        assert_send_sync::<PlanService>();
        assert_send_sync::<Arc<PlanService>>();
    }

    #[test]
    fn builder_validates() {
        assert!(PlanService::builder().shards(0).build().is_err());
        assert!(PlanService::builder().shard_capacity(0).build().is_err());
        assert!(PlanService::builder().state_capacity(0).build().is_err());
        assert!(PlanService::builder().shards(3).shard_capacity(2).build().is_ok());
    }

    #[test]
    fn state_memo_is_lru_bounded() {
        // `optimized` always consults the state memo (no plan-index fast
        // path in front of it), so alternation shows the LRU bound
        let service = PlanService::builder().state_capacity(1).build().unwrap();
        let small = PlanRequest::new(Network::LeNet5, 2).unwrap();
        let big = PlanRequest::new(Network::LeNet5, 2).unwrap().per_gpu_batch(16);
        service.optimized(&small).unwrap(); // build #1
        service.optimized(&big).unwrap(); // evicts `small`'s state: build #2
        service.optimized(&small).unwrap(); // re-entered the memo: build #3
        let s = service.stats();
        assert_eq!(s.table_builds, 3, "capacity 1 forces re-builds on alternation");
        assert_eq!(s.states_cached, 1, "the memo never exceeds its capacity");
    }

    #[test]
    fn warm_plans_skip_the_state_memo_entirely() {
        // the request->plan index answers repeat plans without touching
        // the (capacity-1) state memo: no rebuild on alternation
        let service = PlanService::builder().state_capacity(1).build().unwrap();
        let small = PlanRequest::new(Network::LeNet5, 2).unwrap();
        let big = PlanRequest::new(Network::LeNet5, 2).unwrap().per_gpu_batch(16);
        let first = service.plan(&small).unwrap(); // build #1
        service.plan(&big).unwrap(); // evicts `small`'s state: build #2
        let again = service.plan(&small).unwrap(); // plan-index hit: no build
        assert!(Arc::ptr_eq(&first, &again), "served the resident plan object");
        assert_eq!(service.stats().table_builds, 2, "warm plans never rebuild state");
    }

    #[test]
    fn serves_the_same_numbers_as_a_planner_session() {
        let service = PlanService::new();
        for kind in StrategyKind::ALL {
            let req = PlanRequest::new(Network::LeNet5, 2).unwrap().strategy(kind);
            let a = service.evaluate(&req).unwrap();
            let mut p = Planner::builder(Network::LeNet5).devices(2).build().unwrap();
            let b = p.evaluate(kind).unwrap();
            assert_eq!(a.estimate, b.estimate, "{kind}");
            assert_eq!(a.sim.step_time, b.sim.step_time, "{kind}");
            assert_eq!(a.comm.total(), b.comm.total(), "{kind}");
        }
    }

    #[test]
    fn repeated_queries_reuse_memo_and_cache() {
        let service = PlanService::new();
        let req = PlanRequest::new(Network::LeNet5, 2).unwrap();
        let a = service.plan(&req).unwrap();
        let b = service.plan(&req).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "warm plan must be the cached object");
        let s = service.stats();
        assert_eq!((s.table_builds, s.searches), (1, 1));
        assert_eq!((s.plan_hits, s.plan_misses), (1, 1));
        assert_eq!(s.plans_cached, 1);
        // reading table metadata reuses the memo instead of rebuilding
        assert!(service.max_configs(&req).unwrap() > 1);
        assert_eq!(service.stats().table_builds, 1);
    }

    #[test]
    fn mem_limits_key_the_state_memo_separately() {
        let service = PlanService::new();
        let free = PlanRequest::new(Network::LeNet5, 2).unwrap();
        service.plan(&free).unwrap(); // build #1
        // an enormous budget masks nothing but is a distinct key: the
        // constrained tables must never be served for the free request
        let roomy = PlanRequest::new(Network::LeNet5, 2).unwrap().mem_limit(u64::MAX);
        let a = service.plan(&roomy).unwrap(); // build #2
        assert_eq!(service.stats().table_builds, 2);
        let b = service.plan(&free).unwrap(); // still memoized
        assert_eq!(service.stats().table_builds, 2);
        // ...and an unconstrained budget changes no answer
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn infeasible_budgets_error_and_are_not_memoized() {
        let service = PlanService::new();
        let req = PlanRequest::new(Network::LeNet5, 2).unwrap().mem_limit(1);
        for _ in 0..2 {
            match service.evaluate(&req) {
                Err(OptError::Infeasible { .. }) => {}
                other => panic!("expected Infeasible, got {other:?}"),
            }
        }
        // the static precheck fast-fails before the build counter ticks
        // (PR 4 built the tables twice to reach the same verdict)
        assert_eq!(service.stats().table_builds, 0);
        assert_eq!(service.stats().states_cached, 0);
    }

    #[test]
    fn analyze_builds_no_tables() {
        let service = PlanService::new();
        let req = PlanRequest::new(Network::LeNet5, 2).unwrap().mem_limit(u64::MAX);
        let report = service.analyze(&req).unwrap();
        assert_eq!(report.ndev, 2);
        assert!(report.certificate.residual_space.is_some());
        assert!(report.memory.unwrap().infeasible.is_none());
        let s = service.stats();
        assert_eq!((s.table_builds, s.searches, s.states_cached), (0, 0, 0));
    }

    #[test]
    fn invalid_requests_error_cleanly() {
        let service = PlanService::new();
        let zero_batch = PlanRequest::new(Network::LeNet5, 2).unwrap().per_gpu_batch(0);
        assert!(service.plan(&zero_batch).is_err());
        let zero_mem = PlanRequest::new(Network::LeNet5, 2).unwrap().mem_limit(0);
        assert!(service.plan(&zero_mem).is_err());
        let bad_cluster =
            PlanRequest::with_cluster(Network::LeNet5, ClusterSpec::new(0, 4));
        assert!(service.evaluate(&bad_cluster).is_err());
        assert!(PlanRequest::new(Network::LeNet5, 7).is_err(), "preset cannot shape 7");
    }

    #[test]
    fn ingest_verifies_then_serves_from_cache() {
        let service = PlanService::new();
        let req = PlanRequest::new(Network::LeNet5, 2).unwrap();
        // An "external" artifact: a plan this service has never seen.
        let plan = Planner::builder(Network::LeNet5)
            .devices(2)
            .build()
            .unwrap()
            .plan(StrategyKind::Layerwise)
            .unwrap();
        match service.ingest(&req, &plan).unwrap() {
            VerifyOutcome::Verified(report) => assert_eq!(report.checks.len(), 5),
            other => panic!("cold ingest must run the checks, got {other:?}"),
        }
        // The verified plan is cached as verified: re-loading the same
        // artifact is a lookup, not a re-verification.
        assert_eq!(service.ingest(&req, &plan).unwrap(), VerifyOutcome::CachedVerified);
        // ...and the planning path now hits the same cache entry.
        let served = service.plan(&req).unwrap();
        assert_eq!(*served, *plan);
    }

    #[test]
    fn ingest_rejects_corrupt_plans_and_keeps_them_out_of_the_cache() {
        let service = PlanService::new();
        let req = PlanRequest::new(Network::LeNet5, 2).unwrap();
        let mut plan = Planner::builder(Network::LeNet5)
            .devices(2)
            .build()
            .unwrap()
            .plan(StrategyKind::Layerwise)
            .unwrap()
            .as_ref()
            .clone();
        plan.cost_s *= 2.0;
        match service.ingest(&req, &plan) {
            Err(OptError::InvalidPlan { check, .. }) => {
                assert_eq!(check, crate::error::PlanCheck::CostCoherence);
            }
            other => panic!("expected InvalidPlan, got {other:?}"),
        }
        assert_eq!(service.stats().plans_cached, 0, "rejected plans are not cached");
        // Same corrupt artifact, verify-on-load opted out: admitted.
        let trusting = PlanService::builder().verify_loaded(false).build().unwrap();
        assert_eq!(
            trusting.ingest(&req, &plan).unwrap(),
            VerifyOutcome::AcceptedUnchecked
        );
    }

    #[test]
    fn pruned_service_serves_identical_strategies() {
        let plain = PlanService::new();
        let pruned = PlanService::builder().prune_dominated(true).build().unwrap();
        let req = PlanRequest::new(Network::AlexNet, 2).unwrap();
        let a = plain.optimized(&req).unwrap();
        let b = pruned.optimized(&req).unwrap();
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.strategy.configs, b.strategy.configs);
        assert_eq!(plain.stats().pruned_configs, 0);
        assert!(pruned.stats().pruned_configs > 0);
        assert!(plain.stats().build_workers >= 1);
    }

    #[test]
    fn audit_probe_certifies_without_touching_the_state_memo() {
        let service = PlanService::new();
        let req = PlanRequest::new(Network::LeNet5, 2).unwrap();
        let report = service.audit(&req).unwrap();
        assert!(report.cross.as_ref().is_some_and(|c| c.complete));
        assert!(report.warnings.is_empty());
        let s = service.stats();
        assert_eq!((s.table_builds, s.states_cached), (0, 0));
    }

    #[test]
    fn cluster_fingerprint_distinguishes_topologies() {
        // The state key shares `DeviceGraph::fingerprint` with the
        // cost-table memo, so one identity governs both cache layers.
        let two_by_four = ClusterSpec::p100(8).unwrap().device_graph().unwrap();
        let one_by_eight = ClusterSpec::new(1, 8).device_graph().unwrap();
        assert_ne!(two_by_four.fingerprint(), one_by_eight.fingerprint());
        let again = ClusterSpec::p100(8).unwrap().device_graph().unwrap();
        assert_eq!(two_by_four.fingerprint(), again.fingerprint());
        // the cosmetic name is excluded: equal shapes share a memo entry
        let renamed =
            ClusterSpec::p100(8).unwrap().name("other").device_graph().unwrap();
        assert_eq!(two_by_four.fingerprint(), renamed.fingerprint());
    }
}
