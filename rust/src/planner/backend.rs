//! Pluggable strategy-search backends.
//!
//! The planner treats "find the best per-layer parallelization" as an
//! interchangeable component: Algorithm 1's graph-elimination dynamic
//! program ([`Elimination`], the paper's contribution) and the exhaustive
//! depth-first baseline it is measured against ([`ExhaustiveDfs`],
//! Table 3's comparison point) both implement [`SearchBackend`], selected
//! when the [`crate::planner::Planner`] is built.

use std::time::Duration;

use crate::cost::CostTables;
use crate::error::{OptError, Result};
use crate::optimizer::{self, dfs, Optimized, SearchStats};

/// A strategy-search algorithm over precomputed [`CostTables`].
///
/// Implementations must return the globally optimal strategy for the
/// tables — or an error if they cannot (a truncated search with no
/// complete leaf). Backends are stateless between calls; the planner owns
/// all caching. `Send + Sync` is part of the contract so one backend can
/// serve concurrent searches (the `PlanService` shares a single boxed
/// backend across its worker threads).
pub trait SearchBackend: Send + Sync {
    /// Short name for logs and CLI selection (`--backend <name>`).
    fn name(&self) -> &'static str;

    /// Search the tables for a minimum-cost strategy.
    fn search(&self, tables: &CostTables) -> Result<Optimized>;
}

/// Algorithm 1 (paper §5.2): node/edge elimination to a small final graph,
/// enumerate, reconstruct. `O(E·C³ + K·C^K)` — the production default.
#[derive(Debug, Clone, Copy, Default)]
pub struct Elimination;

impl SearchBackend for Elimination {
    fn name(&self) -> &'static str {
        "elimination"
    }

    fn search(&self, tables: &CostTables) -> Result<Optimized> {
        Ok(optimizer::optimize(tables))
    }
}

/// The exhaustive `O(E·C^N)` depth-first baseline with branch-and-bound
/// pruning and an optional wall-clock budget — the algorithm the paper
/// reports taking `> 24 hours` on VGG-16. Only sensible for small graphs
/// or bounded runs.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExhaustiveDfs {
    /// Wall-clock budget; `None` runs to completion. A search that hits
    /// its budget before exploring the full space errors
    /// ([`OptError::SearchFailed`]) — it cannot certify an optimum, and
    /// the [`SearchBackend`] contract is optimal-or-error.
    pub budget: Option<Duration>,
}

impl ExhaustiveDfs {
    /// An exhaustive search capped at `budget` of wall-clock time.
    pub fn with_budget(budget: Duration) -> ExhaustiveDfs {
        ExhaustiveDfs { budget: Some(budget) }
    }
}

impl SearchBackend for ExhaustiveDfs {
    fn name(&self) -> &'static str {
        "dfs"
    }

    fn search(&self, tables: &CostTables) -> Result<Optimized> {
        // the certificate's number for this backend: the full per-layer
        // config product (DFS never eliminates anything)
        let space_size = (0..tables.configs.len())
            .try_fold(1u128, |acc, l| acc.checked_mul(tables.num_configs(l) as u128));
        let r = dfs::dfs_optimal(tables, self.budget);
        if !r.complete {
            let predicted = match space_size {
                Some(s) => format!("{s}"),
                None => "over 2^128".to_string(),
            };
            return Err(OptError::SearchFailed(format!(
                "exhaustive DFS hit its budget ({:?}) after {} search-tree nodes of a \
                 predicted {}-leaf space; raise the budget or use the elimination backend",
                self.budget, r.visited, predicted
            )));
        }
        let strategy = r.strategy.ok_or_else(|| {
            OptError::SearchFailed("exhaustive DFS explored an empty search space".into())
        })?;
        Ok(Optimized {
            strategy,
            cost: r.cost,
            stats: SearchStats {
                node_eliminations: 0,
                edge_eliminations: 0,
                final_nodes: tables.configs.len(),
                enumerated: r.visited,
                space_size,
            },
        })
    }
}

/// Residual-enumeration size (log2 of leaves) up to which `auto` trusts
/// the elimination backend's brute-force tail. ~1M leaves is
/// milliseconds of `enumerate_final`; beyond it `auto` switches to a
/// wall-clock-budgeted DFS so planning time stays bounded (the DFS
/// errors cleanly at its budget instead of pinning the process).
pub const AUTO_ELIMINATION_MAX_LOG2: f64 = 20.0;

/// The DFS budget `auto` applies when the caller did not pass one.
pub const AUTO_DFS_BUDGET: Duration = Duration::from_secs(10);

/// Resolve `--backend auto` from a pre-planning certificate: the
/// elimination backend when the certified residual enumeration is small
/// enough to brute-force ([`AUTO_ELIMINATION_MAX_LOG2`]), otherwise a
/// budgeted [`ExhaustiveDfs`] so the request fails in bounded time
/// rather than hanging (see `analyze::SearchCertificate`).
pub fn auto(residual_space_log2: f64, dfs_budget: Option<Duration>) -> Box<dyn SearchBackend> {
    if residual_space_log2 <= AUTO_ELIMINATION_MAX_LOG2 {
        Box::new(Elimination)
    } else {
        Box::new(ExhaustiveDfs { budget: Some(dfs_budget.unwrap_or(AUTO_DFS_BUDGET)) })
    }
}

/// Resolve a backend by CLI name: `elimination` (the default) or `dfs`
/// (optionally budgeted). `auto` is certificate-driven and cannot be
/// resolved from a name alone — the CLI routes it through
/// [`auto`] after analyzing the graph; asking for it here reports that.
pub fn by_name(name: &str, dfs_budget: Option<Duration>) -> Result<Box<dyn SearchBackend>> {
    match name {
        "elimination" => Ok(Box::new(Elimination)),
        "dfs" => Ok(Box::new(ExhaustiveDfs { budget: dfs_budget })),
        "auto" => Err(OptError::InvalidArgument(
            "--backend auto is resolved from the graph's analysis certificate; it is \
             available on the optcnn command line but not as a fixed service backend"
                .to_string(),
        )),
        other => Err(OptError::UnknownBackend(other.to_string())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostModel;
    use crate::device::DeviceGraph;
    use crate::graph::nets;

    fn lenet_tables() -> CostTables {
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        // tables only borrow the graph/devices during build
        CostTables::build(&CostModel::new(&g, &d), 2).unwrap()
    }

    #[test]
    fn backends_agree_on_small_graphs() {
        let t = lenet_tables();
        let a = Elimination.search(&t).unwrap();
        let b = ExhaustiveDfs::default().search(&t).unwrap();
        assert!(
            (a.cost - b.cost).abs() <= 1e-9 * a.cost,
            "elimination {} vs dfs {}",
            a.cost,
            b.cost
        );
    }

    #[test]
    fn dfs_with_zero_budget_errors() {
        let t = lenet_tables();
        let r = ExhaustiveDfs::with_budget(Duration::from_nanos(0)).search(&t);
        // either it reached a leaf before the first deadline check or it
        // reports a clean SearchFailed — never a panic
        if let Err(e) = r {
            assert!(matches!(e, OptError::SearchFailed(_)));
        }
    }

    #[test]
    fn by_name_resolves() {
        assert_eq!(by_name("elimination", None).unwrap().name(), "elimination");
        assert_eq!(by_name("dfs", None).unwrap().name(), "dfs");
        assert!(matches!(by_name("anneal", None), Err(OptError::UnknownBackend(_))));
        // `auto` needs a graph to resolve: typed usage error, not unknown
        assert!(matches!(by_name("auto", None), Err(OptError::InvalidArgument(_))));
    }

    #[test]
    fn auto_picks_elimination_below_the_threshold_and_dfs_above() {
        assert_eq!(auto(AUTO_ELIMINATION_MAX_LOG2, None).name(), "elimination");
        assert_eq!(auto(AUTO_ELIMINATION_MAX_LOG2 + 1.0, None).name(), "dfs");
    }

    #[test]
    fn dfs_stats_carry_the_full_space_size() {
        let t = lenet_tables();
        let r = ExhaustiveDfs::default().search(&t).unwrap();
        let full: u128 =
            (0..t.configs.len()).map(|l| t.num_configs(l) as u128).product();
        assert_eq!(r.stats.space_size, Some(full));
        // DFS visits every complete assignment's prefix at least once,
        // so the leaf space bounds nothing here — but it must be the
        // same number the analyze certificate reports (pinned end to
        // end in tests/analyze.rs)
    }
}
