//! Pluggable strategy-search backends.
//!
//! The planner treats "find the best per-layer parallelization" as an
//! interchangeable component: Algorithm 1's graph-elimination dynamic
//! program ([`Elimination`], the paper's contribution) and the exhaustive
//! depth-first baseline it is measured against ([`ExhaustiveDfs`],
//! Table 3's comparison point) both implement [`SearchBackend`], selected
//! when the [`crate::planner::Planner`] is built.

use std::time::Duration;

use crate::cost::CostTables;
use crate::error::{OptError, Result};
use crate::optimizer::{self, dfs, Optimized, SearchStats};

/// A strategy-search algorithm over precomputed [`CostTables`].
///
/// Implementations must return the globally optimal strategy for the
/// tables — or an error if they cannot (a truncated search with no
/// complete leaf). Backends are stateless between calls; the planner owns
/// all caching. `Send + Sync` is part of the contract so one backend can
/// serve concurrent searches (the `PlanService` shares a single boxed
/// backend across its worker threads).
pub trait SearchBackend: Send + Sync {
    /// Short name for logs and CLI selection (`--backend <name>`).
    fn name(&self) -> &'static str;

    /// Search the tables for a minimum-cost strategy.
    fn search(&self, tables: &CostTables) -> Result<Optimized>;
}

/// Algorithm 1 (paper §5.2): node/edge elimination to a small final graph,
/// enumerate, reconstruct. `O(E·C³ + K·C^K)` — the production default.
#[derive(Debug, Clone, Copy, Default)]
pub struct Elimination;

impl SearchBackend for Elimination {
    fn name(&self) -> &'static str {
        "elimination"
    }

    fn search(&self, tables: &CostTables) -> Result<Optimized> {
        Ok(optimizer::optimize(tables))
    }
}

/// The exhaustive `O(E·C^N)` depth-first baseline with branch-and-bound
/// pruning and an optional wall-clock budget — the algorithm the paper
/// reports taking `> 24 hours` on VGG-16. Only sensible for small graphs
/// or bounded runs.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExhaustiveDfs {
    /// Wall-clock budget; `None` runs to completion. A search that hits
    /// its budget before exploring the full space errors
    /// ([`OptError::SearchFailed`]) — it cannot certify an optimum, and
    /// the [`SearchBackend`] contract is optimal-or-error.
    pub budget: Option<Duration>,
}

impl ExhaustiveDfs {
    /// An exhaustive search capped at `budget` of wall-clock time.
    pub fn with_budget(budget: Duration) -> ExhaustiveDfs {
        ExhaustiveDfs { budget: Some(budget) }
    }
}

impl SearchBackend for ExhaustiveDfs {
    fn name(&self) -> &'static str {
        "dfs"
    }

    fn search(&self, tables: &CostTables) -> Result<Optimized> {
        let r = dfs::dfs_optimal(tables, self.budget);
        if !r.complete {
            return Err(OptError::SearchFailed(format!(
                "exhaustive DFS hit its budget ({:?}) after {} search-tree nodes without \
                 exploring the full space; raise the budget or use the elimination backend",
                self.budget, r.visited
            )));
        }
        let strategy = r.strategy.ok_or_else(|| {
            OptError::SearchFailed("exhaustive DFS explored an empty search space".into())
        })?;
        Ok(Optimized {
            strategy,
            cost: r.cost,
            stats: SearchStats {
                node_eliminations: 0,
                edge_eliminations: 0,
                final_nodes: tables.configs.len(),
                enumerated: r.visited,
            },
        })
    }
}

/// Resolve a backend by CLI name: `elimination` (the default) or `dfs`
/// (optionally budgeted).
pub fn by_name(name: &str, dfs_budget: Option<Duration>) -> Result<Box<dyn SearchBackend>> {
    match name {
        "elimination" => Ok(Box::new(Elimination)),
        "dfs" => Ok(Box::new(ExhaustiveDfs { budget: dfs_budget })),
        other => Err(OptError::UnknownBackend(other.to_string())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostModel;
    use crate::device::DeviceGraph;
    use crate::graph::nets;

    fn lenet_tables() -> CostTables {
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        // tables only borrow the graph/devices during build
        CostTables::build(&CostModel::new(&g, &d), 2)
    }

    #[test]
    fn backends_agree_on_small_graphs() {
        let t = lenet_tables();
        let a = Elimination.search(&t).unwrap();
        let b = ExhaustiveDfs::default().search(&t).unwrap();
        assert!(
            (a.cost - b.cost).abs() <= 1e-9 * a.cost,
            "elimination {} vs dfs {}",
            a.cost,
            b.cost
        );
    }

    #[test]
    fn dfs_with_zero_budget_errors() {
        let t = lenet_tables();
        let r = ExhaustiveDfs::with_budget(Duration::from_nanos(0)).search(&t);
        // either it reached a leaf before the first deadline check or it
        // reports a clean SearchFailed — never a panic
        if let Err(e) = r {
            assert!(matches!(e, OptError::SearchFailed(_)));
        }
    }

    #[test]
    fn by_name_resolves() {
        assert_eq!(by_name("elimination", None).unwrap().name(), "elimination");
        assert_eq!(by_name("dfs", None).unwrap().name(), "dfs");
        assert!(matches!(by_name("anneal", None), Err(OptError::UnknownBackend(_))));
    }
}
