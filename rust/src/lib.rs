//! # OptCNN — layer-wise parallelism for CNN training
//!
//! Production-style reproduction of *"Exploring Hidden Dimensions in
//! Parallelizing Convolutional Neural Networks"* (Jia, Lin, Qi, Aiken —
//! ICML 2018).
//!
//! The library is organized around the paper's pipeline:
//!
//! 1. build a computation graph ([`graph`]) and a device graph
//!    ([`device`]);
//! 2. enumerate per-layer parallelization configurations ([`parallel`]);
//! 3. evaluate candidate strategies with the cost model ([`cost`]) and
//!    mask memory-infeasible configurations with the per-device memory
//!    model ([`memory`]);
//! 4. find a globally optimal strategy with the elimination-based dynamic
//!    program ([`optimizer`]), or use the data/model/OWT baselines;
//! 5. materialize the chosen strategy into an [`plan::ExecutionPlan`] —
//!    tiles, transfer schedules, sync shards, derived once and shared;
//! 6. validate with the discrete-event cluster simulator ([`sim`]) and/or
//!    execute for real through the AOT-compiled HLO artifacts
//!    ([`runtime`], [`exec`]), both driven by the same plan.
//!
//! The public entry point for all of this is the [`planner`] module — a
//! typed, fallible [`planner::Planner`] session that owns steps 1-6 and
//! amortizes the expensive ones across queries (DESIGN.md §4):
//!
//! ```
//! use optcnn::planner::{Network, Planner, StrategyKind};
//!
//! # fn main() -> optcnn::Result<()> {
//! let mut planner = Planner::builder(Network::LeNet5).devices(2).build()?;
//! let eval = planner.evaluate(StrategyKind::Layerwise)?;
//! assert!(eval.throughput > 0.0);
//! # Ok(())
//! # }
//! ```
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod analyze;
pub mod audit;
pub mod config;
pub mod cost;
pub mod data;
pub mod device;
pub mod error;
pub mod exec;
pub mod graph;
pub mod memory;
pub mod metrics;
pub mod optimizer;
pub mod parallel;
pub mod pipeline;
pub mod plan;
pub mod planner;
pub mod prop;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod tensor;
pub mod util;
pub mod verify;

pub use error::{OptError, PlanCheck, Result, TableCheck};
