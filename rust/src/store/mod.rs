//! The content-addressed on-disk plan store (DESIGN.md §13).
//!
//! A materialized [`ExecutionPlan`] is the expensive artifact of the
//! whole pipeline — tables, search, plan build — yet it is exact and
//! deterministic for its inputs, so it is worth persisting: a restarted
//! or horizontally scaled `optcnn serve` can answer a previously planned
//! request with **zero table builds** by reading the plan back instead
//! of re-deriving it. The store is a flat directory of single-plan JSON
//! documents (the exact plan-JSON-v3 `optcnn plan --out` writes, wrapped
//! in a small envelope), content-addressed by everything that determines
//! the plan's bytes:
//!
//! * the graph's structural [`GraphDigest`] canonical form (which
//!   already encodes the global batch via the input shape),
//! * the cluster's [`ClusterFingerprint`] canonical form,
//! * the optional per-device memory limit,
//! * the strategy kind, and
//! * whether dominance pruning was enabled (exact either way, but part
//!   of the key so the provenance of an entry is never ambiguous).
//!
//! The file name is an FNV-1a 128 hash of that canonical key string —
//! hand-rolled because `DefaultHasher` promises nothing across Rust
//! versions, and a store must outlive the binary that wrote it. The full
//! key string is embedded in the envelope and compared on load, so even
//! a hash collision (or a misfiled entry) reads back as "not this plan",
//! never as the wrong plan.
//!
//! **Durability and concurrency.** Writes go to a temp file in the store
//! directory followed by an atomic `rename`, so readers and concurrent
//! writers only ever observe complete entries — two servers racing to
//! persist the same plan both write valid bytes and the last rename
//! wins, losing nothing (the bytes are identical by determinism).
//!
//! **Trust boundary.** The store itself only checks well-formedness and
//! the content address. A loaded plan is *served* only after the caller
//! re-verifies it against the freshly built cost model
//! ([`crate::verify::verify_plan`], DESIGN.md §10) — the same gate
//! externally supplied plans pass through — so a tampered entry is
//! rejected and [`evicted`](PlanStore::evict), never served and never
//! retried forever. [`PlanService`](crate::planner::PlanService) wires
//! this up behind [`plan_store`](crate::planner::service::PlanServiceBuilder::plan_store);
//! its lookup order is shards → disk → build.

// Disk-facing load path: a corrupt or hostile store entry must come
// back as a typed `OptError`, never a panic in a serving thread.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::device::ClusterFingerprint;
use crate::error::{OptError, Result};
use crate::graph::GraphDigest;
use crate::plan::ExecutionPlan;
use crate::util::json::Json;

/// Version of the on-disk envelope (the `plan` payload inside it is
/// versioned separately by the plan-JSON document itself).
const FORMAT_VERSION: usize = 1;

/// The content address of one stored plan: the canonical key string
/// (embedded in the entry and compared on load) plus the file name
/// derived from its stable hash.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreKey {
    canon: String,
    file: String,
}

impl StoreKey {
    /// Build the key for a (graph, cluster, memory limit, strategy,
    /// pruning) request. `strategy` is the canonical
    /// [`StrategyKind::name`](crate::planner::StrategyKind::name).
    pub fn new(
        graph: &GraphDigest,
        cluster: &ClusterFingerprint,
        mem_limit: Option<u64>,
        strategy: &str,
        prune_dominated: bool,
    ) -> StoreKey {
        let mem = match mem_limit {
            None => "none".to_string(),
            Some(b) => format!("{b:016x}"),
        };
        let canon = format!(
            "v{FORMAT_VERSION};strategy={strategy};mem={mem};prune={};cluster={};graph={}",
            u8::from(prune_dominated),
            cluster.canonical(),
            graph.canonical(),
        );
        let file = format!("plan-{:032x}.json", fnv1a_128(canon.as_bytes()));
        StoreKey { canon, file }
    }

    /// The canonical key string this entry is addressed by.
    pub fn canonical(&self) -> &str {
        &self.canon
    }

    /// The entry's file name inside the store directory.
    pub fn file_name(&self) -> &str {
        &self.file
    }
}

/// FNV-1a, 128-bit: stable across processes, architectures, and Rust
/// versions (the reason `DefaultHasher` is not used here), and wide
/// enough that accidental collisions are negligible — deliberate ones
/// are caught by the embedded-key comparison on load.
fn fnv1a_128(bytes: &[u8]) -> u128 {
    const OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
    const PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= u128::from(b);
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// A directory of content-addressed plan entries. `Send + Sync`; all
/// methods take `&self`, so one store is shared by every serving thread.
#[derive(Debug)]
pub struct PlanStore {
    dir: PathBuf,
    /// Uniquifies temp-file names within this process (the pid
    /// distinguishes processes), so concurrent writers never share a
    /// temp file even for the same key.
    seq: AtomicU64,
}

impl PlanStore {
    /// Open (creating if needed) the store rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<PlanStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .map_err(|e| OptError::Io(format!("plan store mkdir {}: {e}", dir.display())))?;
        Ok(PlanStore { dir, seq: AtomicU64::new(0) })
    }

    /// The store's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The path an entry for `key` lives at (whether or not it exists).
    pub fn path(&self, key: &StoreKey) -> PathBuf {
        self.dir.join(&key.file)
    }

    /// Whether an entry for `key` exists on disk (without reading it).
    pub fn contains(&self, key: &StoreKey) -> bool {
        self.path(key).exists()
    }

    /// Number of plan entries currently on disk (temp files excluded).
    pub fn len(&self) -> usize {
        let Ok(entries) = fs::read_dir(&self.dir) else { return 0 };
        entries
            .filter_map(|e| e.ok())
            .filter(|e| {
                let name = e.file_name();
                let name = name.to_string_lossy();
                name.starts_with("plan-") && name.ends_with(".json")
            })
            .count()
    }

    /// Whether the store holds no plan entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Load the entry for `key`.
    ///
    /// * `Ok(Some(plan))` — present, well-formed, and its embedded key
    ///   matches. **Not yet verified**: callers must gate it through
    ///   [`verify_plan`](crate::verify::verify_plan) before serving.
    /// * `Ok(None)` — no entry.
    /// * `Err(_)` — the entry was unreadable, malformed, truncated, or
    ///   carried a mismatched key; it has been evicted from disk so the
    ///   next request rebuilds instead of retrying the same bad bytes.
    pub fn load(&self, key: &StoreKey) -> Result<Option<ExecutionPlan>> {
        let path = self.path(key);
        let text = match fs::read_to_string(&path) {
            Ok(text) => text,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(OptError::Io(format!("plan store read {}: {e}", path.display())));
            }
        };
        match decode(&text, key) {
            Ok(plan) => Ok(Some(plan)),
            Err(why) => {
                let _ = fs::remove_file(&path);
                Err(OptError::Io(format!("plan store entry {}: {why}; evicted", key.file)))
            }
        }
    }

    /// Persist `plan` under `key` via temp file + atomic rename.
    /// Overwrites any existing entry (by determinism the bytes are the
    /// same unless the old entry was corrupt — either way the new write
    /// is the truth).
    pub fn save(&self, key: &StoreKey, plan: &ExecutionPlan) -> Result<()> {
        let doc = Json::obj(vec![
            ("version", Json::Num(FORMAT_VERSION as f64)),
            ("key", Json::Str(key.canon.clone())),
            ("plan", plan.to_json()),
        ]);
        let mut text = doc.to_string();
        text.push('\n');
        let tmp = self.dir.join(format!(
            ".{}.tmp-{}-{}",
            key.file,
            std::process::id(),
            self.seq.fetch_add(1, Ordering::Relaxed),
        ));
        fs::write(&tmp, text.as_bytes())
            .map_err(|e| OptError::Io(format!("plan store write {}: {e}", tmp.display())))?;
        fs::rename(&tmp, self.path(key)).map_err(|e| {
            let _ = fs::remove_file(&tmp);
            OptError::Io(format!("plan store commit {}: {e}", key.file))
        })
    }

    /// [`save`](PlanStore::save) only when no entry exists yet; returns
    /// whether a write happened. The existence check is advisory (a racy
    /// duplicate write is harmless — identical bytes, atomic rename);
    /// its purpose is to keep warm traffic from re-serializing plans.
    pub fn save_if_absent(&self, key: &StoreKey, plan: &ExecutionPlan) -> Result<bool> {
        if self.contains(key) {
            return Ok(false);
        }
        self.save(key, plan)?;
        Ok(true)
    }

    /// Remove the entry for `key`; reports whether one existed. Used by
    /// the service when a loaded plan fails verification — the entry
    /// must not be retried forever.
    pub fn evict(&self, key: &StoreKey) -> bool {
        fs::remove_file(self.path(key)).is_ok()
    }
}

/// Decode and authenticate one entry against the key it was looked up
/// under. String errors here become the eviction reason.
fn decode(text: &str, key: &StoreKey) -> std::result::Result<ExecutionPlan, String> {
    let v = Json::parse(text).map_err(|e| format!("malformed JSON: {e}"))?;
    match v.get("version").and_then(Json::as_exact_usize) {
        Some(FORMAT_VERSION) => {}
        other => return Err(format!("unsupported store version {other:?}")),
    }
    let embedded = v.get("key").and_then(Json::as_str).ok_or("missing `key` string")?;
    if embedded != key.canon {
        return Err("content-address mismatch (collision or misfiled entry)".to_string());
    }
    let doc = v.get("plan").ok_or("missing `plan` object")?;
    ExecutionPlan::from_json(doc)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_published_vectors() {
        // the canonical 128-bit FNV-1a test vectors (fnvhash.com)
        assert_eq!(fnv1a_128(b""), 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d);
        assert_eq!(fnv1a_128(b"a"), 0xd228_cb69_6f1a_8caf_78912b704e4a8964);
    }

    #[test]
    fn keys_are_stable_and_field_sensitive() {
        let d = crate::device::DeviceGraph::p100_cluster(2).unwrap();
        let g = crate::graph::nets::lenet5(64).unwrap();
        let base = StoreKey::new(g.digest(), &d.fingerprint(), None, "layerwise", false);
        let again = StoreKey::new(g.digest(), &d.fingerprint(), None, "layerwise", false);
        assert_eq!(base, again, "key construction is deterministic");
        assert!(base.file_name().starts_with("plan-") && base.file_name().ends_with(".json"));
        // every key ingredient separates the address
        for other in [
            StoreKey::new(g.digest(), &d.fingerprint(), Some(1 << 30), "layerwise", false),
            StoreKey::new(g.digest(), &d.fingerprint(), None, "data", false),
            StoreKey::new(g.digest(), &d.fingerprint(), None, "layerwise", true),
            StoreKey::new(
                crate::graph::nets::lenet5(128).unwrap().digest(),
                &d.fingerprint(),
                None,
                "layerwise",
                false,
            ),
            StoreKey::new(
                g.digest(),
                &crate::device::DeviceGraph::p100_cluster(4).unwrap().fingerprint(),
                None,
                "layerwise",
                false,
            ),
        ] {
            assert_ne!(base.file_name(), other.file_name());
            assert_ne!(base.canonical(), other.canonical());
        }
    }
}
