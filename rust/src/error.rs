//! Crate-wide error boundary.
//!
//! Everything user-controlled — network/strategy names, cluster shapes,
//! CLI flags, config files — flows through [`OptError`] instead of
//! panicking. The CLI maps [`OptError::exit_code`] onto process exit
//! codes so bad input produces a one-line message, never a backtrace.

#![warn(missing_docs)]

use std::fmt;

/// The named invariants the static plan verifier proves over an
/// [`crate::plan::ExecutionPlan`] (see `verify::verify_plan` and
/// DESIGN.md §10). Each failed check reports its name through
/// [`OptError::InvalidPlan`] so callers (and the mutation-corpus tests)
/// can pin *which* invariant a corrupted plan violates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlanCheck {
    /// Each layer's tiles exactly partition its output tensor:
    /// disjoint, gap-free, in-bounds, placed on in-range devices.
    TileCoverage,
    /// Every consumer tile's input region is covered by its transfer
    /// schedule plus device-local data, and no transfer references a
    /// device outside the cluster's placement shape.
    TransferCompleteness,
    /// Parameter shard groups partition each layer's parameters with
    /// no overlapping or orphaned shards.
    SyncGroups,
    /// Recorded `peak_mem_per_dev` matches re-derivation through
    /// `memory::peak_per_device`, bit-for-bit.
    MemoryConsistency,
    /// The plan's recorded step cost equals the cost model's
    /// re-derivation, bit-for-bit.
    CostCoherence,
}

impl PlanCheck {
    /// Every check, in the order the verifier runs them.
    pub const ALL: [PlanCheck; 5] = [
        PlanCheck::TileCoverage,
        PlanCheck::TransferCompleteness,
        PlanCheck::SyncGroups,
        PlanCheck::MemoryConsistency,
        PlanCheck::CostCoherence,
    ];

    /// Stable kebab-case name used in diagnostics and CLI output.
    pub fn name(self) -> &'static str {
        match self {
            PlanCheck::TileCoverage => "tile-coverage",
            PlanCheck::TransferCompleteness => "transfer-completeness",
            PlanCheck::SyncGroups => "sync-groups",
            PlanCheck::MemoryConsistency => "memory-consistency",
            PlanCheck::CostCoherence => "cost-coherence",
        }
    }
}

impl fmt::Display for PlanCheck {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The named invariants the cost-table auditor proves over a built
/// [`crate::cost::CostTables`] (see `audit::audit_tables` and
/// DESIGN.md §12). Each failed check reports its name through
/// [`OptError::InvalidTables`] so callers (and the mutation-corpus
/// tests) can pin *which* invariant a corrupted table violates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TableCheck {
    /// Every `t_c`/`t_x`/`t_s` entry is finite and non-negative.
    FiniteCosts,
    /// Per-layer config lists are canonical: sorted, deduplicated,
    /// every degree divides its extent and the degree product is ≤ the
    /// device count.
    ConfigCanonical,
    /// Edge tables have exactly producer-configs × consumer-configs
    /// entries and reference in-range nodes in graph edge order.
    EdgeDims,
    /// Closed-form physical lower bounds hold: `t_x` is at least the
    /// transferred bytes over the fastest link, `t_s` at least the
    /// round-trip shard bytes over the fastest path.
    LowerBounds,
    /// A budgeted table is bitwise the surviving-index subset of the
    /// unbudgeted build under the same budget mask.
    BudgetMask,
}

impl TableCheck {
    /// Every check, in the order the auditor runs them.
    pub const ALL: [TableCheck; 5] = [
        TableCheck::FiniteCosts,
        TableCheck::ConfigCanonical,
        TableCheck::EdgeDims,
        TableCheck::LowerBounds,
        TableCheck::BudgetMask,
    ];

    /// Stable kebab-case name used in diagnostics and CLI output.
    pub fn name(self) -> &'static str {
        match self {
            TableCheck::FiniteCosts => "finite-costs",
            TableCheck::ConfigCanonical => "config-canonical",
            TableCheck::EdgeDims => "edge-dims",
            TableCheck::LowerBounds => "lower-bounds",
            TableCheck::BudgetMask => "budget-mask",
        }
    }
}

impl fmt::Display for TableCheck {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Any error the planning library reports to its caller.
///
/// Variants carry a human-readable payload; [`fmt::Display`] renders the
/// one-line message shown to CLI users.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OptError {
    /// A network name that [`crate::planner::Network`] does not know.
    UnknownNetwork(String),
    /// A strategy name that [`crate::planner::StrategyKind`] does not know.
    UnknownStrategy(String),
    /// A search-backend name the planner does not know.
    UnknownBackend(String),
    /// A cluster specification that cannot describe real hardware
    /// (zero devices, nonpositive bandwidth, ...).
    InvalidCluster(String),
    /// A malformed argument: CLI flag, builder parameter, or batch size.
    InvalidArgument(String),
    /// A computation graph that violates structural invariants: bad
    /// wiring (dangling/backward edges), shape disagreements, degenerate
    /// operator parameters, or a malformed `GraphSpec` document. Graphs
    /// arrive over TCP and from `--network-file`, so these are typed
    /// usage errors (exit 2), never panics.
    InvalidGraph(String),
    /// A malformed configuration file.
    Config(String),
    /// An I/O failure (missing file, unwritable path).
    Io(String),
    /// The search backend could not produce a complete strategy (e.g. the
    /// exhaustive DFS hit its budget before reaching any leaf).
    SearchFailed(String),
    /// An execution plan that failed static verification: one of the
    /// [`PlanCheck`] invariants does not hold. Plans arrive over TCP,
    /// from `--out` artifacts, and (eventually) from an on-disk store,
    /// so a corrupted plan is a typed usage error (exit 2), never a
    /// panic and never silently executed.
    InvalidPlan {
        /// The named invariant that failed.
        check: PlanCheck,
        /// Human-readable detail locating the violation.
        detail: String,
    },
    /// A planning request whose residual search space is too large to
    /// enumerate: the pre-planning certificate (`analyze::analyze`, see
    /// DESIGN.md §11) predicts a final enumeration beyond the service's
    /// cap, so the request is rejected *before* any cost table is built
    /// instead of pinning a worker thread. Sizes are carried as `log2`
    /// (rounded up to whole bits, which is all the message needs and
    /// keeps this type `Eq`).
    SearchSpaceExceeded {
        /// Certified residual enumeration size, as ceil(log2(bits)).
        space_log2: u32,
        /// The service's cap, as log2 bits.
        cap_log2: u32,
    },
    /// Memory-infeasible request: some layer has *no* configuration whose
    /// per-device peak fits the memory budget, so no strategy can exist
    /// (see `memory::layer_peak_bytes` and DESIGN.md §3).
    Infeasible {
        /// Name of the layer that cannot fit.
        layer: String,
        /// Bytes by which the layer's smallest-footprint configuration
        /// still exceeds the per-device budget.
        overshoot: u64,
    },
    /// A cost table that failed static auditing: one of the
    /// [`TableCheck`] invariants does not hold (see `audit::audit_tables`
    /// and DESIGN.md §12). A corrupted or mispriced table is a typed
    /// usage error (exit 2) so it is never silently searched.
    InvalidTables {
        /// The named invariant that failed.
        check: TableCheck,
        /// Human-readable detail locating the violation.
        detail: String,
    },
    /// The two search backends disagreed over the same residual kernel
    /// (see `audit::cross_check` and DESIGN.md §12). Either backend —
    /// or the tables they share — is wrong, so planning must not
    /// proceed on either answer.
    BackendMismatch {
        /// Name of the first layer whose optimal assignment diverges
        /// (or a summary location when the costs alone differ).
        layer: String,
        /// Human-readable detail of the divergence.
        detail: String,
    },
    /// An internal invariant that should be unreachable was observed
    /// (e.g. a staged build left a cell unset). Reported as a typed
    /// error instead of a panic so long-lived services survive it.
    Internal(String),
}

impl OptError {
    /// The process exit code the CLI uses for this error: `2` for bad
    /// user input (the Unix usage-error convention), `1` for runtime
    /// failures such as I/O.
    pub fn exit_code(&self) -> i32 {
        match self {
            OptError::Io(_) | OptError::SearchFailed(_) => 1,
            _ => 2,
        }
    }
}

impl fmt::Display for OptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OptError::UnknownNetwork(name) => write!(
                f,
                "unknown network `{name}` (known: lenet5, alexnet, vgg16, \
                 inception_v3, resnet18, resnet50, minicnn; arbitrary graphs \
                 load from a GraphSpec via --network-file or the `graph` wire field)"
            ),
            OptError::UnknownStrategy(name) => {
                write!(f, "unknown strategy `{name}` (known: data, model, owt, layerwise)")
            }
            OptError::UnknownBackend(name) => {
                write!(f, "unknown search backend `{name}` (known: elimination, dfs, auto)")
            }
            OptError::InvalidCluster(msg) => write!(f, "invalid cluster: {msg}"),
            OptError::InvalidArgument(msg) => write!(f, "{msg}"),
            OptError::InvalidGraph(msg) => write!(f, "invalid graph: {msg}"),
            OptError::Config(msg) => write!(f, "config error: {msg}"),
            OptError::Io(msg) => write!(f, "{msg}"),
            OptError::SearchFailed(msg) => write!(f, "search failed: {msg}"),
            OptError::InvalidPlan { check, detail } => {
                write!(f, "invalid plan [{check}]: {detail}")
            }
            OptError::SearchSpaceExceeded { space_log2, cap_log2 } => write!(
                f,
                "search space too large: the residual enumeration is ~2^{space_log2} \
                 strategies, above this service's 2^{cap_log2} cap; simplify the graph \
                 or plan it offline with a budgeted backend"
            ),
            OptError::Infeasible { layer, overshoot } => write!(
                f,
                "infeasible: layer `{layer}` needs {overshoot} more bytes than the \
                 per-device memory budget even at its most-partitioned configuration"
            ),
            OptError::InvalidTables { check, detail } => {
                write!(f, "invalid tables [{check}]: {detail}")
            }
            OptError::BackendMismatch { layer, detail } => {
                write!(f, "backend mismatch at layer `{layer}`: {detail}")
            }
            OptError::Internal(msg) => write!(f, "internal error: {msg}"),
        }
    }
}

impl std::error::Error for OptError {}

/// Crate-wide result alias over [`OptError`].
pub type Result<T> = std::result::Result<T, OptError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_are_one_line() {
        let errs = [
            OptError::UnknownNetwork("resnet1001".into()),
            OptError::UnknownStrategy("zigzag".into()),
            OptError::UnknownBackend("sa".into()),
            OptError::InvalidCluster("0 nodes".into()),
            OptError::InvalidArgument("--devices: expected an integer".into()),
            OptError::InvalidGraph("dangling edge (9, 2)".into()),
            OptError::Config("line 3: expected key = value".into()),
            OptError::Io("plan.json: permission denied".into()),
            OptError::SearchFailed("budget exhausted".into()),
            OptError::InvalidPlan {
                check: PlanCheck::TileCoverage,
                detail: "layer 3: tile 1 overlaps tile 2".into(),
            },
            OptError::SearchSpaceExceeded { space_log2: 57, cap_log2: 32 },
            OptError::Infeasible { layer: "fc6".into(), overshoot: 123_456 },
            OptError::InvalidTables {
                check: TableCheck::FiniteCosts,
                detail: "layer 2 config 3: t_c is NaN".into(),
            },
            OptError::BackendMismatch {
                layer: "softmax".into(),
                detail: "elimination picked (1,1,1,1), dfs picked (4,1,1,1)".into(),
            },
            OptError::Internal("layer stage left a cell unset".into()),
        ];
        for e in errs {
            let msg = e.to_string();
            assert!(!msg.contains('\n'), "multi-line message: {msg:?}");
            assert!(!msg.is_empty());
        }
    }

    #[test]
    fn usage_errors_exit_2_runtime_errors_exit_1() {
        assert_eq!(OptError::UnknownNetwork("x".into()).exit_code(), 2);
        assert_eq!(OptError::InvalidArgument("x".into()).exit_code(), 2);
        // a malformed graph off the wire is the client's mistake: exit 2
        assert_eq!(OptError::InvalidGraph("x".into()).exit_code(), 2);
        assert_eq!(OptError::Io("x".into()).exit_code(), 1);
        // a corrupted plan artifact is the supplier's mistake: exit 2
        let bad_plan = OptError::InvalidPlan {
            check: PlanCheck::CostCoherence,
            detail: "x".into(),
        };
        assert_eq!(bad_plan.exit_code(), 2);
        assert!(bad_plan.to_string().contains("cost-coherence"));
        // an unsatisfiable memory budget is a usage error: exit 2
        assert_eq!(OptError::Infeasible { layer: "fc6".into(), overshoot: 1 }.exit_code(), 2);
        // an over-cap graph is the client's to simplify: exit 2
        let cap = OptError::SearchSpaceExceeded { space_log2: 57, cap_log2: 32 };
        assert_eq!(cap.exit_code(), 2);
        assert!(cap.to_string().contains("2^57") && cap.to_string().contains("2^32"));
        // a corrupted cost table is the supplier's mistake: exit 2
        let bad_tables = OptError::InvalidTables {
            check: TableCheck::LowerBounds,
            detail: "x".into(),
        };
        assert_eq!(bad_tables.exit_code(), 2);
        assert!(bad_tables.to_string().contains("invalid tables [lower-bounds]"));
        // a backend divergence means neither answer is trustworthy: exit 2
        let mismatch = OptError::BackendMismatch { layer: "fc6".into(), detail: "x".into() };
        assert_eq!(mismatch.exit_code(), 2);
        assert_eq!(OptError::Internal("x".into()).exit_code(), 2);
    }

    #[test]
    fn plan_check_names_are_stable_and_distinct() {
        let names: Vec<&str> = PlanCheck::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(
            names,
            [
                "tile-coverage",
                "transfer-completeness",
                "sync-groups",
                "memory-consistency",
                "cost-coherence"
            ]
        );
    }

    #[test]
    fn table_check_names_are_stable_and_distinct() {
        let names: Vec<&str> = TableCheck::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(
            names,
            [
                "finite-costs",
                "config-canonical",
                "edge-dims",
                "lower-bounds",
                "budget-mask"
            ]
        );
    }
}
