//! Crate-wide error boundary.
//!
//! Everything user-controlled — network/strategy names, cluster shapes,
//! CLI flags, config files — flows through [`OptError`] instead of
//! panicking. The CLI maps [`OptError::exit_code`] onto process exit
//! codes so bad input produces a one-line message, never a backtrace.

#![warn(missing_docs)]

use std::fmt;

/// Any error the planning library reports to its caller.
///
/// Variants carry a human-readable payload; [`fmt::Display`] renders the
/// one-line message shown to CLI users.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OptError {
    /// A network name that [`crate::planner::Network`] does not know.
    UnknownNetwork(String),
    /// A strategy name that [`crate::planner::StrategyKind`] does not know.
    UnknownStrategy(String),
    /// A search-backend name the planner does not know.
    UnknownBackend(String),
    /// A cluster specification that cannot describe real hardware
    /// (zero devices, nonpositive bandwidth, ...).
    InvalidCluster(String),
    /// A malformed argument: CLI flag, builder parameter, or batch size.
    InvalidArgument(String),
    /// A computation graph that violates structural invariants: bad
    /// wiring (dangling/backward edges), shape disagreements, degenerate
    /// operator parameters, or a malformed `GraphSpec` document. Graphs
    /// arrive over TCP and from `--network-file`, so these are typed
    /// usage errors (exit 2), never panics.
    InvalidGraph(String),
    /// A malformed configuration file.
    Config(String),
    /// An I/O failure (missing file, unwritable path).
    Io(String),
    /// The search backend could not produce a complete strategy (e.g. the
    /// exhaustive DFS hit its budget before reaching any leaf).
    SearchFailed(String),
    /// Memory-infeasible request: some layer has *no* configuration whose
    /// per-device peak fits the memory budget, so no strategy can exist
    /// (see `memory::layer_peak_bytes` and DESIGN.md §3).
    Infeasible {
        /// Name of the layer that cannot fit.
        layer: String,
        /// Bytes by which the layer's smallest-footprint configuration
        /// still exceeds the per-device budget.
        overshoot: u64,
    },
}

impl OptError {
    /// The process exit code the CLI uses for this error: `2` for bad
    /// user input (the Unix usage-error convention), `1` for runtime
    /// failures such as I/O.
    pub fn exit_code(&self) -> i32 {
        match self {
            OptError::Io(_) | OptError::SearchFailed(_) => 1,
            _ => 2,
        }
    }
}

impl fmt::Display for OptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OptError::UnknownNetwork(name) => write!(
                f,
                "unknown network `{name}` (known: lenet5, alexnet, vgg16, \
                 inception_v3, resnet18, resnet50, minicnn; arbitrary graphs \
                 load from a GraphSpec via --network-file or the `graph` wire field)"
            ),
            OptError::UnknownStrategy(name) => {
                write!(f, "unknown strategy `{name}` (known: data, model, owt, layerwise)")
            }
            OptError::UnknownBackend(name) => {
                write!(f, "unknown search backend `{name}` (known: elimination, dfs)")
            }
            OptError::InvalidCluster(msg) => write!(f, "invalid cluster: {msg}"),
            OptError::InvalidArgument(msg) => write!(f, "{msg}"),
            OptError::InvalidGraph(msg) => write!(f, "invalid graph: {msg}"),
            OptError::Config(msg) => write!(f, "config error: {msg}"),
            OptError::Io(msg) => write!(f, "{msg}"),
            OptError::SearchFailed(msg) => write!(f, "search failed: {msg}"),
            OptError::Infeasible { layer, overshoot } => write!(
                f,
                "infeasible: layer `{layer}` needs {overshoot} more bytes than the \
                 per-device memory budget even at its most-partitioned configuration"
            ),
        }
    }
}

impl std::error::Error for OptError {}

/// Crate-wide result alias over [`OptError`].
pub type Result<T> = std::result::Result<T, OptError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_are_one_line() {
        let errs = [
            OptError::UnknownNetwork("resnet1001".into()),
            OptError::UnknownStrategy("zigzag".into()),
            OptError::UnknownBackend("sa".into()),
            OptError::InvalidCluster("0 nodes".into()),
            OptError::InvalidArgument("--devices: expected an integer".into()),
            OptError::InvalidGraph("dangling edge (9, 2)".into()),
            OptError::Config("line 3: expected key = value".into()),
            OptError::Io("plan.json: permission denied".into()),
            OptError::SearchFailed("budget exhausted".into()),
            OptError::Infeasible { layer: "fc6".into(), overshoot: 123_456 },
        ];
        for e in errs {
            let msg = e.to_string();
            assert!(!msg.contains('\n'), "multi-line message: {msg:?}");
            assert!(!msg.is_empty());
        }
    }

    #[test]
    fn usage_errors_exit_2_runtime_errors_exit_1() {
        assert_eq!(OptError::UnknownNetwork("x".into()).exit_code(), 2);
        assert_eq!(OptError::InvalidArgument("x".into()).exit_code(), 2);
        // a malformed graph off the wire is the client's mistake: exit 2
        assert_eq!(OptError::InvalidGraph("x".into()).exit_code(), 2);
        assert_eq!(OptError::Io("x".into()).exit_code(), 1);
        // an unsatisfiable memory budget is a usage error: exit 2
        assert_eq!(OptError::Infeasible { layer: "fc6".into(), overshoot: 1 }.exit_code(), 2);
    }
}
