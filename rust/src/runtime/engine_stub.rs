//! Stub engine for builds without the `pjrt` feature.
//!
//! Mirrors the real [`super::engine`] API so the executor, workers, and
//! profiler compile unchanged; every construction fails with a clear
//! message pointing at the feature flag. Artifact-dependent tests already
//! self-skip when `artifacts/` is absent, so the default build's test
//! suite never reaches these paths.

use anyhow::{bail, Result};

use super::ArtifactStore;
use crate::tensor::Tensor;

/// API-compatible stand-in for the PJRT engine.
pub struct Engine {
    store: ArtifactStore,
    /// Executions performed (always 0 on the stub).
    pub executions: u64,
}

impl Engine {
    pub fn new(store: ArtifactStore) -> Result<Engine> {
        // Keep the field wiring identical to the real engine so the
        // accessors below stay meaningful if construction ever succeeds.
        let _ = &store;
        bail!(
            "optcnn was built without the `pjrt` feature: PJRT execution of AOT \
             artifacts is unavailable (vendor the `xla` crate and rebuild with \
             `--features pjrt`; see DESIGN.md §14)"
        )
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    pub fn run(&mut self, key: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        bail!("pjrt feature disabled: cannot execute artifact `{key}`")
    }

    /// Number of artifacts compiled so far.
    pub fn compiled(&self) -> usize {
        0
    }
}
