//! The real PJRT execution engine (`--features pjrt`).

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use super::ArtifactStore;
use crate::tensor::Tensor;

/// A PJRT execution engine: one CPU client + compiled-executable cache.
/// One per worker thread (the client is reference-counted, not `Send`).
pub struct Engine {
    client: xla::PjRtClient,
    store: ArtifactStore,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed (for metrics/tests).
    pub executions: u64,
}

impl Engine {
    pub fn new(store: ArtifactStore) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, store, cache: HashMap::new(), executions: 0 })
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// Compile (or fetch from cache) the artifact for `key`.
    fn executable(&mut self, key: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(key) {
            let path = self.store.path_of(key)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact `{key}`"))?;
            self.cache.insert(key.to_string(), exe);
        }
        Ok(&self.cache[key])
    }

    /// Execute artifact `key` on `inputs`, returning the output tensors
    /// (the artifact's return tuple, flattened).
    pub fn run(&mut self, key: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .store
            .meta(key)
            .ok_or_else(|| anyhow!("artifact `{key}` not in manifest"))?;
        if meta.inputs.len() != inputs.len() {
            bail!(
                "artifact `{key}` expects {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, expect)) in inputs.iter().zip(meta.inputs.iter()).enumerate() {
            if t.shape() != expect.as_slice() {
                bail!(
                    "artifact `{key}` input {i}: shape {:?} != manifest {:?}",
                    t.shape(),
                    expect
                );
            }
        }
        let out_shapes = meta.outputs.clone();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let lit = xla::Literal::vec1(t.data());
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).context("reshaping input literal")
            })
            .collect::<Result<_>>()?;
        let exe = self.executable(key)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing `{key}`"))?;
        self.executions += 1;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching `{key}` result"))?;
        let parts = tuple.to_tuple().with_context(|| format!("untupling `{key}` result"))?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, lit) in parts.into_iter().enumerate() {
            let data = lit.to_vec::<f32>().context("reading output literal")?;
            // prefer manifest shapes; fall back to the literal's own shape
            let shape: Vec<usize> = match out_shapes.get(i) {
                Some(s) => s.clone(),
                None => lit
                    .array_shape()
                    .map(|s| s.dims().iter().map(|&d| d as usize).collect())
                    .unwrap_or_else(|_| vec![data.len()]),
            };
            out.push(Tensor::from_vec(&shape, data));
        }
        Ok(out)
    }

    /// Number of artifacts compiled so far.
    pub fn compiled(&self) -> usize {
        self.cache.len()
    }
}
