//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin). The interchange format
//! is HLO **text** — `HloModuleProto::from_text_file` reassigns
//! instruction ids, which sidesteps xla_extension 0.5.1's rejection of
//! jax≥0.5's 64-bit-id protos (see /opt/xla-example/README.md).
//!
//! [`ArtifactStore`] is thread-safe metadata (the parsed manifest);
//! [`Engine`] owns a PJRT client plus a lazily-populated executable cache
//! and is deliberately `!Send` (the client is `Rc`-based) — the
//! partitioned executor creates one `Engine` per worker thread.
//!
//! The PJRT engine is gated behind the `pjrt` cargo feature (the `xla`
//! bindings crate is not in the offline registry); the default build
//! ships a stub [`Engine`] with the same API that errors at construction,
//! so the rest of the system — cost model, optimizer, simulator, plans —
//! builds and tests with zero external native dependencies (DESIGN.md §14).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[cfg(feature = "pjrt")]
mod engine;
#[cfg(feature = "pjrt")]
pub use engine::Engine;

#[cfg(not(feature = "pjrt"))]
mod engine_stub;
#[cfg(not(feature = "pjrt"))]
pub use engine_stub::Engine;

/// Parsed `artifacts/manifest.json`: artifact keys -> files and shapes.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub batch: usize,
    pub devices: usize,
    entries: BTreeMap<String, ArtifactMeta>,
}

/// One artifact's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactStore {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactStore> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let doc = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let arts = doc
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest.json: missing artifacts object"))?;
        let mut entries = BTreeMap::new();
        for (key, meta) in arts {
            let file = meta
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("artifact {key}: missing file"))?
                .to_string();
            let shapes = |field: &str| -> Vec<Vec<usize>> {
                meta.get(field)
                    .and_then(|v| v.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|s| {
                                s.as_arr()
                                    .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            entries.insert(
                key.clone(),
                ArtifactMeta { file, inputs: shapes("inputs"), outputs: shapes("outputs") },
            );
        }
        Ok(ArtifactStore {
            dir,
            batch: doc.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
            devices: doc.get("devices").and_then(|v| v.as_usize()).unwrap_or(0),
            entries,
        })
    }

    pub fn has(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    pub fn meta(&self, key: &str) -> Option<&ArtifactMeta> {
        self.entries.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Absolute path of the artifact file backing `key`.
    pub fn path_of(&self, key: &str) -> Result<PathBuf> {
        let meta = self
            .entries
            .get(key)
            .ok_or_else(|| anyhow!("artifact `{key}` not in manifest (re-run `make artifacts`)"))?;
        Ok(self.dir.join(&meta.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join(format!("optcnn_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"batch":32,"devices":4,"artifacts":{
                "fc_fwd_n8_ci64_co10_r0":{"file":"a.hlo.txt","inputs":[[8,64],[64,10],[10]],"outputs":[[8,10]]}
            }}"#,
        )
        .unwrap();
        let s = ArtifactStore::load(&dir).unwrap();
        assert_eq!(s.batch, 32);
        assert!(s.has("fc_fwd_n8_ci64_co10_r0"));
        let m = s.meta("fc_fwd_n8_ci64_co10_r0").unwrap();
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.outputs[0], vec![8, 10]);
        assert!(!s.has("nope"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = ArtifactStore::load("/nonexistent/path").unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("make artifacts"), "{chain}");
    }
}
