//! Per-device memory model: the feasibility half of the planning problem.
//!
//! The paper's search minimizes time alone and silently assumes every
//! configuration fits in device memory — but the configurations it
//! prefers (low-degree FC splits, replicated conv stacks) are exactly
//! the ones that blow past a real GPU's HBM at production batch sizes.
//! Related work (PaSE; Dryden et al.) treats per-device memory as a
//! first-class constraint on the strategy space; this module supplies
//! the model (DESIGN.md §3):
//!
//! * [`tile_bytes`] — resident bytes one tile of a (layer, config) pins
//!   on its device: the parameter shard + its gradient buffer, plus the
//!   stashed activations (the input regions the tile consumes — which is
//!   where channel-partitioned FC layers pay for their all-gather — and
//!   the output tile), counted twice for the forward stash and the
//!   backward activation gradients.
//! * [`layer_peak_bytes`] — the worst tile of a configuration (what the
//!   feasibility mask in [`CostTables::build_budgeted`] compares against
//!   a [`MemBudget`]).
//! * [`peak_per_device`] — the high-water aggregation over a whole
//!   strategy: every layer's tiles mapped to devices through the same
//!   placement the cost model and [`ExecutionPlan`] use (so the totals
//!   agree with `ExecutionPlan.tile_dev` by construction), summed per
//!   device. Training keeps every layer's weights and stashed
//!   activations resident simultaneously (the backward pass revisits
//!   all of them), so the per-device high water is the sum, not a max.
//!
//! **Sync staging.** The sharded-PS exchange is modeled *in place* over
//! the gradient buffer: each replica's send slices are gradient shards
//! it already holds, and the reduced slices overwrite them (what
//! bucketed allreduce implementations achieve with O(bucket) scratch).
//! Synchronization therefore stages through the gradient term rather
//! than adding resident bytes of its own — which also keeps the model
//! monotone: raising any partition degree never increases a layer's
//! per-device peak (weights/gradients shrink with the channel degree
//! and are constant in the others; activation tiles and their input
//! regions shrink in every degree). `tests/memory.rs` pins that
//! property.
//!
//! [`CostTables::build_budgeted`]: crate::cost::CostTables::build_budgeted
//! [`ExecutionPlan`]: crate::plan::ExecutionPlan

#![warn(missing_docs)]

use crate::cost::CostModel;
use crate::graph::Layer;
use crate::parallel::{
    enumerate_configs, input_region, output_tiles, param_sharding, per_dim_divisors, PConfig,
    Strategy,
};
use crate::tensor::Region;

/// Bytes per f32 element.
const ELEM_BYTES: f64 = 4.0;

/// Activations are resident twice: the forward stash (kept for the
/// backward pass) and the backward activation-gradient buffers.
const ACT_RESIDENCY: f64 = 2.0;

/// A per-device memory budget (bytes of HBM available to one device).
///
/// Passed to [`CostTables::build_budgeted`] to mask configurations whose
/// [`layer_peak_bytes`] exceed it before the search runs. An infinite
/// budget masks nothing and reproduces the unconstrained tables exactly.
///
/// [`CostTables::build_budgeted`]: crate::cost::CostTables::build_budgeted
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemBudget {
    /// Usable bytes per device.
    pub bytes_per_dev: f64,
}

impl MemBudget {
    /// A budget of `bytes` per device.
    pub fn new(bytes: u64) -> MemBudget {
        MemBudget { bytes_per_dev: bytes as f64 }
    }

    /// The no-op budget: admits every configuration.
    pub fn unlimited() -> MemBudget {
        MemBudget { bytes_per_dev: f64::INFINITY }
    }

    /// Does a peak of `bytes` fit this budget?
    pub fn admits(&self, bytes: f64) -> bool {
        bytes <= self.bytes_per_dev
    }
}

/// Resident bytes one output tile of `layer` under `cfg` pins on its
/// device: parameter shard + gradient buffer (which doubles as the sync
/// staging, see the [module docs](self)) + stashed activations (input
/// regions and the output tile, × [`ACT_RESIDENCY`]).
pub fn tile_bytes(layer: &Layer, cfg: &PConfig, tile: &Region) -> f64 {
    let params = if layer.has_params() {
        // one shard copy + its gradient buffer per replica device
        2.0 * param_sharding(layer, cfg).shard_bytes
    } else {
        0.0
    };
    let mut act_elems = tile.volume();
    for in_idx in 0..layer.in_shapes.len() {
        if let Some(r) = input_region(layer, in_idx, tile) {
            act_elems += r.volume();
        }
    }
    params + ACT_RESIDENCY * ELEM_BYTES * act_elems as f64
}

/// The per-device peak of one (layer, configuration): the most expensive
/// tile (interior tiles carry the largest halo windows). This is the
/// quantity the feasibility mask compares against a [`MemBudget`].
pub fn layer_peak_bytes(layer: &Layer, cfg: &PConfig) -> f64 {
    output_tiles(&layer.out_shape, cfg)
        .iter()
        .map(|t| tile_bytes(layer, cfg, t))
        .fold(0.0, f64::max)
}

/// The smallest [`layer_peak_bytes`] any legal configuration of `layer`
/// achieves at `ndev` devices — the exact feasibility frontier a
/// [`MemBudget`] is compared against, computed *without* scanning the
/// whole configuration space. The peak is monotone non-increasing in
/// every partition degree (see the [module docs](self)), so the global
/// minimum is attained at a configuration where no single degree can be
/// raised to its next divisor within the device budget; only those
/// locally-maximal configurations are evaluated. The value is
/// bit-identical to `min over enumerate_configs` (the minimizing
/// configuration itself is in the scanned subset), which is what lets
/// the pre-planning precheck ([`crate::analyze`]) reproduce
/// `CostTables::build_budgeted`'s `Infeasible` verdict exactly.
pub fn min_layer_peak_bytes(layer: &Layer, ndev: usize) -> f64 {
    let per_dim = per_dim_divisors(layer, ndev);
    // a config is locally maximal when no dimension's degree can be
    // bumped to its next divisor without overrunning `ndev`
    let maximal = |c: &PConfig| {
        (0..4).all(|d| match per_dim[d].iter().find(|&&v| v > c.deg[d]) {
            Some(&next) => c.total() / c.deg[d] * next > ndev,
            None => true,
        })
    };
    enumerate_configs(layer, ndev)
        .iter()
        .filter(|c| maximal(c))
        .map(|c| layer_peak_bytes(layer, c))
        .fold(f64::INFINITY, f64::min)
}

/// Per-device high-water bytes of a whole strategy: each layer's tiles
/// are mapped to devices through `cm`'s placement (the same mapping
/// [`ExecutionPlan::build`] records in `tile_dev`) and their
/// [`tile_bytes`] summed per device.
///
/// [`ExecutionPlan::build`]: crate::plan::ExecutionPlan::build
pub fn peak_per_device(cm: &CostModel<'_>, strategy: &Strategy) -> Vec<f64> {
    let mut peak = vec![0.0f64; cm.devices.num_devices()];
    for l in &cm.graph.layers {
        let cfg = strategy.config(l.id);
        for (t, tile) in output_tiles(&l.out_shape, cfg).iter().enumerate() {
            peak[cm.dev_of(t)] += tile_bytes(l, cfg, tile);
        }
    }
    peak
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::graph::nets;
    use crate::optimizer::strategies;

    #[test]
    fn channel_split_shards_fc_params() {
        let g = nets::vgg16(128).unwrap();
        let fc = g.layers.iter().find(|l| l.name == "fc6").unwrap();
        let serial = layer_peak_bytes(fc, &PConfig::serial());
        let channel = layer_peak_bytes(fc, &PConfig::channel(4));
        // fc6 is parameter-dominated: sharding 4 ways must shed most of it
        assert!(channel < serial / 2.0, "channel {channel} vs serial {serial}");
        // but data parallelism replicates the full parameter block
        let data = layer_peak_bytes(fc, &PConfig::data(4));
        assert!(data > channel, "replication must cost more than sharding for fc6");
    }

    #[test]
    fn params_never_below_shard_and_acts_positive() {
        let g = nets::alexnet(64).unwrap();
        for l in &g.layers {
            let p = layer_peak_bytes(l, &PConfig::serial());
            assert!(p > 0.0, "{} has zero footprint", l.name);
            if l.has_params() {
                assert!(p >= 2.0 * l.param_bytes(), "{} omits weights+grads", l.name);
            }
        }
    }

    #[test]
    fn per_device_aggregation_conserves_tile_totals() {
        let g = nets::alexnet(32 * 4).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::data_parallel(&g, 4);
        let per_dev = peak_per_device(&cm, &s);
        assert_eq!(per_dev.len(), 4);
        let total: f64 = per_dev.iter().sum();
        let expect: f64 = g
            .layers
            .iter()
            .map(|l| {
                let cfg = s.config(l.id);
                output_tiles(&l.out_shape, cfg)
                    .iter()
                    .map(|t| tile_bytes(l, cfg, t))
                    .sum::<f64>()
            })
            .sum();
        assert!((total - expect).abs() <= 1e-6 * expect);
        // data parallelism is symmetric: every device carries the same load
        for &p in &per_dev {
            assert!((p - per_dev[0]).abs() <= 1e-6 * per_dev[0]);
        }
    }

    #[test]
    fn min_peak_over_maximal_configs_equals_global_min() {
        // the locally-maximal shortcut must be bit-identical to the
        // exhaustive minimum — that is what lets the analyze precheck
        // reproduce build_budgeted's Infeasible verdict exactly
        let g = nets::alexnet(64).unwrap();
        for l in &g.layers {
            for ndev in [1usize, 2, 4, 8] {
                let brute = enumerate_configs(l, ndev)
                    .iter()
                    .map(|c| layer_peak_bytes(l, c))
                    .fold(f64::INFINITY, f64::min);
                let fast = min_layer_peak_bytes(l, ndev);
                assert_eq!(fast.to_bits(), brute.to_bits(), "{} at {ndev}", l.name);
            }
        }
    }

    #[test]
    fn budget_admits_boundary() {
        let b = MemBudget::new(100);
        assert!(b.admits(100.0));
        assert!(!b.admits(100.5));
        assert!(MemBudget::unlimited().admits(f64::MAX));
    }
}
