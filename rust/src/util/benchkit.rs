//! Micro-benchmark harness (no `criterion` in the offline registry).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that uses
//! `bench()` for wall-clock measurements and the `Table` renderer for
//! paper-style output. Measurements do warmup, then adaptively pick an
//! iteration count targeting ~200ms of sampling, and report median and
//! median-absolute-deviation over samples.

use std::time::Instant;

/// Result of one benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Median seconds per iteration.
    pub median: f64,
    /// Median absolute deviation (robust spread), seconds.
    pub mad: f64,
    /// Iterations per sample.
    pub iters: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{:>10}, {} samples x {} iters)",
            self.name,
            crate::util::fmt_secs(self.median),
            crate::util::fmt_secs(self.mad),
            self.samples,
            self.iters
        )
    }
}

/// Measure `f`, returning robust per-iteration timing. `f` should perform
/// one logical iteration per call and return a value that is consumed via
/// `std::hint::black_box` to defeat dead-code elimination.
pub fn bench<T>(name: &str, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup + calibration: find how many iters fit ~20ms.
    let mut iters: u64 = 1;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.02 || iters >= 1 << 24 {
            break;
        }
        iters = if dt <= 0.0 { iters * 16 } else { ((0.025 / dt) as u64).max(2) * iters };
    }
    // Sampling: up to 10 samples or ~300ms, whichever first.
    let mut per_iter: Vec<f64> = Vec::new();
    let budget = Instant::now();
    while per_iter.len() < 10 && (budget.elapsed().as_secs_f64() < 0.3 || per_iter.len() < 3) {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = per_iter[per_iter.len() / 2];
    let mut devs: Vec<f64> = per_iter.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    let r = BenchResult {
        name: name.to_string(),
        median,
        mad,
        iters,
        samples: per_iter.len(),
    };
    println!("{}", r.report());
    r
}

/// Time a single execution of `f` (for long-running, once-off measurements
/// such as whole-optimizer runs in the Table 3 bench).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median > 0.0);
        assert!(r.median < 1e-3);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
