//! Micro-benchmark harness (no `criterion` in the offline registry).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that uses
//! `bench()` for wall-clock measurements and the `Table` renderer for
//! paper-style output. Measurements do warmup, then adaptively pick an
//! iteration count targeting ~200ms of sampling, and report median and
//! median-absolute-deviation over samples.

use std::time::Instant;

/// Result of one benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Median seconds per iteration.
    pub median: f64,
    /// Median absolute deviation (robust spread), seconds.
    pub mad: f64,
    /// Iterations per sample.
    pub iters: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{:>10}, {} samples x {} iters)",
            self.name,
            crate::util::fmt_secs(self.median),
            crate::util::fmt_secs(self.mad),
            self.samples,
            self.iters
        )
    }
}

/// Measure `f`, returning robust per-iteration timing. `f` should perform
/// one logical iteration per call and return a value that is consumed via
/// `std::hint::black_box` to defeat dead-code elimination.
pub fn bench<T>(name: &str, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup + calibration: find how many iters fit ~20ms.
    let mut iters: u64 = 1;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.02 || iters >= 1 << 24 {
            break;
        }
        iters = if dt <= 0.0 { iters * 16 } else { ((0.025 / dt) as u64).max(2) * iters };
    }
    // Sampling: up to 10 samples or ~300ms, whichever first.
    let mut per_iter: Vec<f64> = Vec::new();
    let budget = Instant::now();
    while per_iter.len() < 10 && (budget.elapsed().as_secs_f64() < 0.3 || per_iter.len() < 3) {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = per_iter[per_iter.len() / 2];
    let mut devs: Vec<f64> = per_iter.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    let r = BenchResult {
        name: name.to_string(),
        median,
        mad,
        iters,
        samples: per_iter.len(),
    };
    println!("{}", r.report());
    r
}

/// Time a single execution of `f` (for long-running, once-off measurements
/// such as whole-optimizer runs in the Table 3 bench).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Render named wall-clock measurements as a machine-readable JSON
/// document, for bench output published as a CI artifact (see the
/// `bench-artifacts` job). Records the bench name, the host's thread
/// count (parallel speedups are only meaningful relative to it), and one
/// `{name, seconds}` entry per measurement in the order given; object
/// keys serialize sorted, so the document is byte-stable across runs up
/// to the timings themselves.
///
/// An empty `results` slice is an error, not an empty document: the one
/// way a bench emits nothing is a wiring bug (a filter that matched no
/// rows, a loop that never ran), and a dead artifact that still uploads
/// hides it.
pub fn bench_json(
    bench: &str,
    results: &[(String, f64)],
) -> crate::Result<crate::util::json::Json> {
    use crate::util::json::Json;
    if results.is_empty() {
        return Err(crate::OptError::InvalidArgument(format!(
            "bench `{bench}` produced no results; refusing to emit an empty artifact"
        )));
    }
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let entries = results
        .iter()
        .map(|(name, secs)| {
            Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("seconds", Json::Num(*secs)),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("bench", Json::Str(bench.to_string())),
        ("host_threads", Json::Num(host as f64)),
        ("results", Json::Arr(entries)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median > 0.0);
        assert!(r.median < 1e-3);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn bench_json_rejects_empty_results() {
        let err = bench_json("cold_plan", &[]).unwrap_err();
        assert!(err.to_string().contains("no results"), "{err}");
    }

    #[test]
    fn bench_json_round_trips() {
        let doc = bench_json("cold_plan", &[("vgg16/serial".to_string(), 1.25)]).unwrap();
        let text = doc.to_string();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.get("bench").and_then(|j| j.as_str()), Some("cold_plan"));
        assert!(back.get("host_threads").and_then(|j| j.as_usize()).unwrap() >= 1);
        let results = back.get("results").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").and_then(|j| j.as_str()), Some("vgg16/serial"));
        assert_eq!(results[0].get("seconds").and_then(|j| j.as_f64()), Some(1.25));
    }
}
