//! ASCII table renderer used by the bench harnesses to print paper-style
//! tables/figure series on stdout (and CSV for downstream plotting).

/// A simple column-aligned table with a title.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header width"
        );
        self.rows.push(cells);
    }

    /// Render with box-drawing separators, column-aligned.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alexnet".into(), "1.0".into()]);
        t.row(vec!["vgg".into(), "123.45".into()]);
        let s = t.render();
        assert!(s.contains("| alexnet | 1.0    |"));
        assert!(s.contains("== demo =="));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["cfg"]);
        t.row(vec!["{n=1, c=4}".into()]);
        assert_eq!(t.to_csv(), "cfg\n\"{n=1, c=4}\"\n");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
