//! Tiny CLI argument parser (no `clap` in the offline registry).
//!
//! Grammar: `optcnn <subcommand> [--flag] [--key value]... [positional]...`

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, bare `--flag`
/// switches, and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `flag_names` lists switches that take no value; everything else
    /// starting with `--` consumes the next token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    args.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    let v = v.clone();
                    it.next();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string), flags)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("optimize --network vgg16 --devices 4 extra", &[]);
        assert_eq!(a.subcommand.as_deref(), Some("optimize"));
        assert_eq!(a.get("network"), Some("vgg16"));
        assert_eq!(a.get_usize("devices", 1), 4);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn flags_and_equals_form() {
        let a = parse("train --verbose --steps=100", &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("steps", 0), 100);
    }

    #[test]
    fn trailing_option_without_value_becomes_flag() {
        let a = parse("sim --dry-run", &[]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x", &[]);
        assert_eq!(a.get_or("net", "alexnet"), "alexnet");
        assert_eq!(a.get_f64("bw", 1.5), 1.5);
    }
}
