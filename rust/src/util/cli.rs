//! Tiny CLI argument parser (no `clap` in the offline registry).
//!
//! Grammar: `optcnn <subcommand> [--flag] [--key value]... [positional]...`
//!
//! Typed accessors are fallible: a *present but malformed* value is an
//! [`OptError::InvalidArgument`] (the CLI turns it into a one-line
//! message and exit code 2), while an absent option takes its default.

use std::collections::BTreeMap;

use crate::error::{OptError, Result};

/// Parsed command line: a subcommand, `--key value` options, bare `--flag`
/// switches, and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `flag_names` lists switches that take no value; everything else
    /// starting with `--` consumes the next token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    args.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    let v = v.clone();
                    it.next();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` as usize: `default` when absent, error when malformed.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                OptError::InvalidArgument(format!("--{name}: expected an integer, got `{s}`"))
            }),
        }
    }

    /// `--name` as f64: `default` when absent, error when malformed.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                OptError::InvalidArgument(format!("--{name}: expected a number, got `{s}`"))
            }),
        }
    }

    /// A comma-separated `--name` list parsed element-wise; `default`
    /// (also comma-separated) when absent, error on any malformed item.
    pub fn list_or<T: std::str::FromStr>(&self, name: &str, default: &str) -> Result<Vec<T>> {
        self.get_or(name, default)
            .split(',')
            .map(|item| {
                let item = item.trim();
                item.parse().map_err(|_| {
                    OptError::InvalidArgument(format!("--{name}: cannot parse `{item}`"))
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string), flags)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("optimize --network vgg16 --devices 4 extra", &[]);
        assert_eq!(a.subcommand.as_deref(), Some("optimize"));
        assert_eq!(a.get("network"), Some("vgg16"));
        assert_eq!(a.usize_or("devices", 1).unwrap(), 4);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn flags_and_equals_form() {
        let a = parse("train --verbose --steps=100", &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
    }

    #[test]
    fn trailing_option_without_value_becomes_flag() {
        let a = parse("sim --dry-run", &[]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x", &[]);
        assert_eq!(a.get_or("net", "alexnet"), "alexnet");
        assert_eq!(a.f64_or("bw", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn malformed_values_error_instead_of_defaulting() {
        let a = parse("optimize --devices four --lr fast", &[]);
        let err = a.usize_or("devices", 4).unwrap_err();
        assert!(err.to_string().contains("four"), "{err}");
        assert_eq!(err.exit_code(), 2);
        assert!(a.f64_or("lr", 0.01).is_err());
    }

    #[test]
    fn lists_parse_or_error() {
        let a = parse("sweep --devices 1,2,4", &[]);
        assert_eq!(a.list_or::<usize>("devices", "8").unwrap(), vec![1, 2, 4]);
        let b = parse("sweep --devices 1,x", &[]);
        assert!(b.list_or::<usize>("devices", "8").is_err());
        // defaults parse through the same path
        assert_eq!(a.list_or::<usize>("steps", "5,10").unwrap(), vec![5, 10]);
    }
}
