//! Deterministic xorshift/splitmix PRNG.
//!
//! The offline registry has no `rand` crate; every stochastic component in
//! the library (synthetic data, property tests, random graph generation,
//! simulator jitter experiments) goes through this seeded generator so runs
//! are reproducible bit-for-bit.

/// A small, fast, deterministic PRNG (xorshift64* seeded via splitmix64).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        // splitmix64 step to avoid pathological low-entropy seeds (0 would
        // lock xorshift at 0 forever).
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        Rng { state: z ^ (z >> 31) | 1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform usize in [0, bound). `bound` must be > 0.
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal sample (Box–Muller).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn gaussian_moments_roughly_standard() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
