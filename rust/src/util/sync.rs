//! Concurrency primitives behind the memo/cache layer — single-flight
//! build cells and their LRU container — factored into one facade so the
//! exact production source also compiles against `loom::sync` for
//! exhaustive model checking (DESIGN.md §6).
//!
//! Normal builds resolve the aliases below to `std::sync`; the
//! `rust/modelcheck` crate includes this file verbatim via `#[path]` and
//! builds it with `RUSTFLAGS="--cfg loom"`, swapping in loom's
//! instrumented primitives. Whatever interleavings loom proves correct
//! are therefore proven about *this* code, not a test double. To keep
//! that inclusion sound the module is deliberately self-contained: std
//! (plus the cfg-gated loom shim) only, no crate-internal imports.

use std::collections::HashMap;
use std::hash::Hash;

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex, MutexGuard};

/// Lock `m`, continuing through poisoning: every consumer holds these
/// locks only around small map operations, so a panicking holder leaves
/// the map consistent and the data (counters, cached cells) remains
/// meaningful to other threads.
#[cfg(not(loom))]
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Lock `m` (loom build: model-checked closures never panic, so
/// poisoning cannot occur).
#[cfg(loom)]
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap()
}

/// A write-once cell that runs at most one initializer: concurrent
/// `get_or_init` callers block until the winning closure finishes, then
/// all observe its value. The `bool` in the return reports whether *this*
/// call ran the initializer — the signal the memo layers turn into
/// hit/miss counters.
#[cfg(not(loom))]
#[derive(Debug)]
pub struct OnceCell<T>(std::sync::OnceLock<T>);

#[cfg(not(loom))]
impl<T> OnceCell<T> {
    /// An empty cell.
    pub fn new() -> OnceCell<T> {
        OnceCell(std::sync::OnceLock::new())
    }

    /// Whether the cell already holds a value (a racing initializer may
    /// complete between this answer and a later call).
    pub fn is_set(&self) -> bool {
        self.0.get().is_some()
    }
}

#[cfg(not(loom))]
impl<T: Clone> OnceCell<T> {
    /// The cell's value, initializing it with `f` if empty; the flag is
    /// `true` iff this call ran `f`.
    pub fn get_or_init(&self, f: impl FnOnce() -> T) -> (T, bool) {
        let mut ran = false;
        let v = self.0.get_or_init(|| {
            ran = true;
            f()
        });
        (v.clone(), ran)
    }
}

#[cfg(not(loom))]
impl<T> Default for OnceCell<T> {
    fn default() -> OnceCell<T> {
        OnceCell::new()
    }
}

/// Loom model of [`OnceCell`]: a mutex-guarded three-state machine
/// (empty / initializer running / done) with a condvar for waiters —
/// semantically the blocking `OnceLock` contract, expressed in
/// primitives loom can exhaustively interleave.
#[cfg(loom)]
pub struct OnceCell<T> {
    state: Mutex<OnceState<T>>,
    cv: Condvar,
}

#[cfg(loom)]
enum OnceState<T> {
    Empty,
    Running,
    Done(T),
}

#[cfg(loom)]
impl<T> OnceCell<T> {
    /// An empty cell.
    pub fn new() -> OnceCell<T> {
        OnceCell { state: Mutex::new(OnceState::Empty), cv: Condvar::new() }
    }

    /// Whether the cell already holds a value.
    pub fn is_set(&self) -> bool {
        matches!(&*lock(&self.state), OnceState::Done(_))
    }
}

#[cfg(loom)]
impl<T: Clone> OnceCell<T> {
    /// The cell's value, initializing it with `f` if empty; the flag is
    /// `true` iff this call ran `f`.
    pub fn get_or_init(&self, f: impl FnOnce() -> T) -> (T, bool) {
        let mut g = lock(&self.state);
        loop {
            match &*g {
                OnceState::Done(v) => return (v.clone(), false),
                OnceState::Running => g = self.cv.wait(g).unwrap(),
                OnceState::Empty => {
                    *g = OnceState::Running;
                    drop(g);
                    let v = f();
                    let mut g = lock(&self.state);
                    *g = OnceState::Done(v.clone());
                    drop(g);
                    self.cv.notify_all();
                    return (v, true);
                }
            }
        }
    }
}

#[cfg(loom)]
impl<T> Default for OnceCell<T> {
    fn default() -> OnceCell<T> {
        OnceCell::new()
    }
}

/// A bounded LRU of shared single-flight cells — the concurrency shape
/// under both the cost-table memo (`cost::memo::TableMemo`) and the plan
/// service's state memo. The container itself lives behind a `Mutex`
/// held only for map operations; the cells it hands out are initialized
/// *outside* that lock, so one slow build never serializes unrelated
/// keys.
pub struct SingleFlightLru<K, T> {
    cap: usize,
    tick: u64,
    map: HashMap<K, (u64, Arc<OnceCell<T>>)>,
}

impl<K: Eq + Hash + Clone, T> SingleFlightLru<K, T> {
    /// An LRU holding at most `cap` cells (`cap >= 1`).
    pub fn new(cap: usize) -> SingleFlightLru<K, T> {
        assert!(cap >= 1, "single-flight LRU capacity must be positive");
        SingleFlightLru { cap, tick: 0, map: HashMap::new() }
    }

    /// The cell for `key`, created empty on first sight; bumps the key's
    /// recency and evicts the stalest entry when over capacity. Eviction
    /// drops the map's reference only — callers already initializing the
    /// evicted cell keep it alive and complete unaffected.
    pub fn cell(&mut self, key: &K) -> Arc<OnceCell<T>> {
        self.tick += 1;
        let tick = self.tick;
        if let Some((t, cell)) = self.map.get_mut(key) {
            *t = tick;
            return Arc::clone(cell);
        }
        if self.map.len() >= self.cap {
            if let Some(oldest) =
                self.map.iter().min_by_key(|(_, (t, _))| *t).map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        let cell = Arc::new(OnceCell::default());
        self.map.insert(key.clone(), (tick, Arc::clone(&cell)));
        cell
    }

    /// Drop `key`'s entry iff it still holds `cell` — a failed build must
    /// not evict a successor that already replaced it.
    pub fn forget(&mut self, key: &K, cell: &Arc<OnceCell<T>>) {
        if let Some((_, current)) = self.map.get(key) {
            if Arc::ptr_eq(current, cell) {
                self.map.remove(key);
            }
        }
    }

    /// Number of resident cells.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether no cells are resident.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn once_cell_runs_one_initializer() {
        let cell: OnceCell<u32> = OnceCell::new();
        assert!(!cell.is_set());
        let (v, ran) = cell.get_or_init(|| 7);
        assert_eq!((v, ran), (7, true));
        assert!(cell.is_set());
        let (v, ran) = cell.get_or_init(|| 9);
        assert_eq!((v, ran), (7, false), "second initializer must not run");
    }

    #[test]
    fn lru_hands_out_one_cell_per_key_and_bounds_itself() {
        let mut lru: SingleFlightLru<u32, u32> = SingleFlightLru::new(2);
        let a = lru.cell(&1);
        let b = lru.cell(&1);
        assert!(Arc::ptr_eq(&a, &b), "same key, same cell");
        lru.cell(&2);
        lru.cell(&1); // refresh 1
        lru.cell(&3); // evicts 2 (coldest)
        assert_eq!(lru.len(), 2);
        let a2 = lru.cell(&1);
        assert!(Arc::ptr_eq(&a, &a2), "key 1 survived the eviction");
        let c = lru.cell(&2);
        assert!(!Arc::ptr_eq(&a, &c), "key 2 was evicted and recreated");
    }

    #[test]
    fn forget_only_removes_the_same_cell() {
        let mut lru: SingleFlightLru<u32, u32> = SingleFlightLru::new(4);
        let a = lru.cell(&1);
        lru.forget(&1, &a);
        assert_eq!(lru.len(), 0, "failed build evicted");
        let b = lru.cell(&1);
        lru.forget(&1, &a);
        assert_eq!(lru.len(), 1, "stale forget must not evict the successor");
        let b2 = lru.cell(&1);
        assert!(Arc::ptr_eq(&b, &b2));
    }

    #[test]
    fn initializers_run_outside_the_container_lock() {
        // The contract the service relies on: a cell obtained from the
        // LRU can be initialized after the borrow on the LRU ends, and
        // concurrent threads funnel into exactly one build.
        let lru = Mutex::new(SingleFlightLru::<u32, u32>::new(4));
        let builds = std::sync::atomic::AtomicU32::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let cell = lock(&lru).cell(&7);
                    let (v, _) = cell.get_or_init(|| {
                        builds.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        42
                    });
                    assert_eq!(v, 42);
                });
            }
        });
        assert_eq!(builds.load(std::sync::atomic::Ordering::SeqCst), 1);
    }
}
