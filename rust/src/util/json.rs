//! Minimal JSON reader/writer.
//!
//! Used for the artifact manifest exchanged with the Python AOT pipeline
//! and for machine-readable bench output. Hand-rolled because the offline
//! registry carries no `serde` façade crate. Supports the full JSON value
//! grammar; numbers are parsed as f64 (ample for manifests and metrics).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization
/// is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Strict non-negative integer: fractional or negative numbers are
    /// `None`, never silently truncated/saturated the way
    /// [`Json::as_usize`]'s `f64 as usize` cast would. The accessor for
    /// sizes arriving off a wire or an untrusted document.
    pub fn as_exact_usize(&self) -> Option<usize> {
        let n = self.as_f64()?;
        // exclusive upper bound: `usize::MAX as f64` rounds UP to 2^64,
        // which an inclusive check would accept and then saturate
        if n.fract() == 0.0 && n >= 0.0 && n < usize::MAX as f64 {
            Some(n as usize)
        } else {
            None
        }
    }

    /// Strict non-negative integer as `u64`, mirroring
    /// [`Json::as_exact_usize`] for byte counts that are `u64` on every
    /// platform. An `f64` wire value is exact only up to 2^53, so the
    /// practical range is identical; the point of a dedicated accessor
    /// is that the caller never writes the `fract()`/bound dance inline.
    pub fn as_exact_u64(&self) -> Option<u64> {
        let n = self.as_f64()?;
        // exclusive upper bound: `u64::MAX as f64` rounds UP to 2^64,
        // which an inclusive check would accept and then saturate
        if n.fract() == 0.0 && n >= 0.0 && n < u64::MAX as f64 {
            Some(n as u64)
        } else {
            None
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    #[allow(clippy::inherent_to_string)] // no Display: serialization, not formatting
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error message on malformed
    /// input, including nesting deeper than [`MAX_DEPTH`] — the parser
    /// recurses per nesting level, and documents arrive over TCP, so
    /// unbounded depth would be a remote stack-overflow (an abort, not
    /// even an unwindable panic).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Deepest container nesting [`Json::parse`] accepts. Far beyond any
/// legitimate manifest, plan, or graph spec (which nest a handful of
/// levels), and small enough that the recursive parser stays well inside
/// any thread's stack.
pub const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.nested(Parser::object),
            Some(b'[') => self.nested(Parser::array),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    /// Run a container parser one nesting level down, rejecting depth
    /// beyond [`MAX_DEPTH`].
    fn nested(
        &mut self,
        parse: fn(&mut Parser<'a>) -> Result<Json, String>,
    ) -> Result<Json, String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        let v = parse(self)?;
        self.depth -= 1;
        Ok(v)
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": 2.5}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"files": [{"path": "a.hlo", "n": 3}], "ok": true}"#).unwrap();
        let files = v.get("files").unwrap().as_arr().unwrap();
        assert_eq!(files[0].get("path").unwrap().as_str(), Some("a.hlo"));
        assert_eq!(files[0].get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("files").unwrap().as_bool(), None);
    }

    #[test]
    fn exact_usize_never_truncates_or_saturates() {
        assert_eq!(Json::Num(3.0).as_exact_usize(), Some(3));
        assert_eq!(Json::Num(0.0).as_exact_usize(), Some(0));
        assert_eq!(Json::Num(2.5).as_exact_usize(), None);
        assert_eq!(Json::Num(-1.0).as_exact_usize(), None);
        // 2^64 is exactly `usize::MAX as f64` (rounded up): a lenient
        // inclusive bound would saturate it to usize::MAX
        assert_eq!(Json::Num(18446744073709551616.0).as_exact_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_exact_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_exact_usize(), None);
    }

    #[test]
    fn exact_u64_mirrors_exact_usize() {
        assert_eq!(Json::Num(3.0).as_exact_u64(), Some(3));
        assert_eq!(Json::Num(0.0).as_exact_u64(), Some(0));
        assert_eq!(Json::Num(9007199254740992.0).as_exact_u64(), Some(1 << 53));
        assert_eq!(Json::Num(2.5).as_exact_u64(), None);
        assert_eq!(Json::Num(-1.0).as_exact_u64(), None);
        assert_eq!(Json::Num(18446744073709551616.0).as_exact_u64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_exact_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_exact_u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(32.0).to_string(), "32");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn unicode_and_escape_roundtrip() {
        let v = Json::Str("héllo \"w\"\n\tπ".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn negative_and_exponent_numbers() {
        assert_eq!(Json::parse("-2.5e3").unwrap().as_f64(), Some(-2500.0));
    }

    #[test]
    fn pathological_nesting_is_an_error_not_a_stack_overflow() {
        // within the cap: fine
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        // one past the cap: a parse error, long before the stack is at risk
        let deep = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Json::parse(&deep).unwrap_err().contains("nesting"));
        // a wire-sized bomb parses to the same error instead of aborting
        let bomb = "[".repeat(500_000);
        assert!(Json::parse(&bomb).unwrap_err().contains("nesting"));
    }
}
