//! Small self-contained utilities (the offline registry forbids most
//! third-party crates, so PRNG / JSON / CLI / bench plumbing live here).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod rng;
pub mod sync;
pub mod table;

/// Human-readable formatting for byte counts.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    format!("{:.2} {}", v, UNITS[u])
}

/// Human-readable formatting for a duration in seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512.00 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KB");
        assert_eq!(fmt_bytes(3.5 * 1024.0 * 1024.0), "3.50 MB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(2.5e-9), "2.5 ns");
        assert_eq!(fmt_secs(1.5e-4), "150.0 us");
        assert_eq!(fmt_secs(0.25), "250.00 ms");
        assert_eq!(fmt_secs(3.0), "3.000 s");
    }
}
