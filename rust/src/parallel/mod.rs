//! Parallelization configurations (paper §3–4).
//!
//! A configuration describes how a layer's **output tensor** is equally
//! partitioned along its parallelizable dimensions (Table 1); the product
//! of per-dimension degrees is the layer's degree of parallelism. Tiles
//! are assigned to devices contiguously in row-major tile order (device 0
//! first), which keeps equal configs on adjacent layers transfer-free and
//! groups small-degree layers onto one node.

pub mod placement;

pub use placement::Placement;

use crate::graph::{Layer, OpKind};
use crate::tensor::Region;

/// Semantic dimension indices into activation shapes.
pub const DIM_N: usize = 0;
pub const DIM_C: usize = 1;
pub const DIM_H: usize = 2;
pub const DIM_W: usize = 3;

/// Per-dimension parallelism degrees `[n, c, h, w]`. For 2-D activations
/// the h/w entries are fixed at 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PConfig {
    pub deg: [usize; 4],
}

impl PConfig {
    pub fn new(n: usize, c: usize, h: usize, w: usize) -> PConfig {
        assert!(n >= 1 && c >= 1 && h >= 1 && w >= 1);
        PConfig { deg: [n, c, h, w] }
    }

    /// The single-device configuration.
    pub fn serial() -> PConfig {
        PConfig { deg: [1; 4] }
    }

    /// Pure data parallelism at `d` devices.
    pub fn data(d: usize) -> PConfig {
        PConfig::new(d, 1, 1, 1)
    }

    /// Pure channel/model parallelism at `d` devices.
    pub fn channel(d: usize) -> PConfig {
        PConfig::new(1, d, 1, 1)
    }

    /// Total degree of parallelism (number of devices used).
    pub fn total(&self) -> usize {
        self.deg.iter().product()
    }

    /// Paper-style label, e.g. `{n=4, c=1, h=2, w=1}` printed sparsely as
    /// `{n=4, h=2}`; the all-ones config prints `{serial}`.
    pub fn label(&self) -> String {
        let names = ["n", "c", "h", "w"];
        let parts: Vec<String> = (0..4)
            .filter(|&d| self.deg[d] > 1)
            .map(|d| format!("{}={}", names[d], self.deg[d]))
            .collect();
        if parts.is_empty() {
            "{n=1}".to_string()
        } else {
            format!("{{{}}}", parts.join(", "))
        }
    }
}

/// Which dimensions may be partitioned for a given operator (Table 1).
/// Index order `[n, c, h, w]`.
pub fn allowed_dims(op: &OpKind) -> [bool; 4] {
    match op {
        // The input "layer" is the data loader; samples only.
        OpKind::Input => [true, false, false, false],
        OpKind::Conv2d { .. } | OpKind::Pool2d { .. } => [true, true, true, true],
        OpKind::Concat | OpKind::Add => [true, true, true, true],
        OpKind::FullyConnected { .. } => [true, true, false, false],
        // Softmax normalizes over channels; partition samples only.
        OpKind::Softmax => [true, false, false, false],
    }
}

/// Divisors of `n` that are `<= cap`, ascending. The scan stops at `cap`
/// rather than `n`: degrees beyond the device count are never legal, and
/// extents (batch x channels x spatial) run to tens of thousands while
/// `cap` is the device count, so the bounded scan does orders of
/// magnitude less work on the table-build hot path for the same result.
fn divisors_upto(n: usize, cap: usize) -> Vec<usize> {
    (1..=n.min(cap)).filter(|d| n % d == 0).collect()
}

/// Per-dimension candidate degree lists for `layer` at `ndev` devices:
/// divisors of each partitionable extent, `[1]` for disallowed or
/// missing dimensions. The building block [`enumerate_configs`] and
/// [`count_configs`] share, so the materialized list and its counted
/// cardinality can never drift apart.
pub(crate) fn per_dim_divisors(layer: &Layer, ndev: usize) -> [Vec<usize>; 4] {
    let shape = &layer.out_shape;
    let allowed = allowed_dims(&layer.op);
    let rank = shape.len();
    let mut per_dim: [Vec<usize>; 4] = [vec![1], vec![1], vec![1], vec![1]];
    for d in 0..4 {
        if d < rank && allowed[d] {
            // equal extents have equal divisor lists (common: square
            // spatial dims) — reuse instead of re-enumerating
            match (0..d).find(|&e| allowed[e] && shape[e] == shape[d]) {
                Some(e) => per_dim[d] = per_dim[e].clone(),
                None => per_dim[d] = divisors_upto(shape[d], ndev),
            }
        }
    }
    per_dim
}

/// Enumerate every legal configuration for `layer` on at most `ndev`
/// devices: each degree divides the output extent (equal partitioning),
/// disallowed dimensions stay at 1, and the total degree is <= `ndev`.
pub fn enumerate_configs(layer: &Layer, ndev: usize) -> Vec<PConfig> {
    let per_dim = per_dim_divisors(layer, ndev);
    let mut out = Vec::new();
    for &n in &per_dim[0] {
        for &c in &per_dim[1] {
            if n * c > ndev {
                continue;
            }
            for &h in &per_dim[2] {
                if n * c * h > ndev {
                    continue;
                }
                for &w in &per_dim[3] {
                    if n * c * h * w <= ndev {
                        out.push(PConfig::new(n, c, h, w));
                    }
                }
            }
        }
    }
    out
}

/// The cardinality of [`enumerate_configs`] without materializing a
/// single `PConfig`: the same per-dimension divisor lists and the same
/// pruned product walk, counting instead of allocating. This is what
/// the pre-planning search-cost certificate ([`crate::analyze`])
/// composes into the exact final-enumeration size before any cost
/// table exists; `tests/analyze.rs` pins it equal to
/// `enumerate_configs(layer, ndev).len()` across operators and device
/// counts.
pub fn count_configs(layer: &Layer, ndev: usize) -> u64 {
    let per_dim = per_dim_divisors(layer, ndev);
    let mut count = 0u64;
    for &n in &per_dim[0] {
        for &c in &per_dim[1] {
            if n * c > ndev {
                continue;
            }
            for &h in &per_dim[2] {
                if n * c * h > ndev {
                    continue;
                }
                count += per_dim[3].iter().filter(|&&w| n * c * h * w <= ndev).count() as u64;
            }
        }
    }
    count
}

/// The output tiles of a layer under `cfg`, one per participating device,
/// in row-major tile order (tile index == device id). Region rank matches
/// the activation rank.
pub fn output_tiles(out_shape: &[usize], cfg: &PConfig) -> Vec<Region> {
    let rank = out_shape.len();
    debug_assert!(rank == 2 || rank == 4);
    for d in rank..4 {
        debug_assert_eq!(cfg.deg[d], 1, "degree in missing dim must be 1");
    }
    let degs: Vec<usize> = (0..rank).map(|d| cfg.deg[d]).collect();
    for d in 0..rank {
        debug_assert_eq!(out_shape[d] % degs[d], 0, "equal partitioning violated");
    }
    let sizes: Vec<usize> = (0..rank).map(|d| out_shape[d] / degs[d]).collect();
    let total: usize = degs.iter().product();
    let mut tiles = Vec::with_capacity(total);
    let mut idx = vec![0usize; rank];
    for _ in 0..total {
        let ranges: Vec<(usize, usize)> =
            (0..rank).map(|d| (idx[d] * sizes[d], (idx[d] + 1) * sizes[d])).collect();
        tiles.push(Region::new(&ranges));
        for d in (0..rank).rev() {
            idx[d] += 1;
            if idx[d] < degs[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    tiles
}

/// The region of input `in_idx` that a device must hold to compute
/// `out_tile` of `layer`. Returns `None` when that input contributes
/// nothing to the tile (possible for `Concat`). Handles the convolution /
/// pooling receptive-field halo.
pub fn input_region(layer: &Layer, in_idx: usize, out_tile: &Region) -> Option<Region> {
    let in_shape = &layer.in_shapes[in_idx];
    match &layer.op {
        OpKind::Input => unreachable!("input layer has no inputs"),
        OpKind::Conv2d { kernel, stride, padding, .. } => {
            // Output tile rows [h0,h1) need input rows
            // [h0*s - p, (h1-1)*s - p + k) clamped; all input channels.
            let (h0, h1) = spatial_window(
                out_tile.start(DIM_H),
                out_tile.end(DIM_H),
                kernel.0,
                stride.0,
                padding.0,
                in_shape[DIM_H],
            );
            let (w0, w1) = spatial_window(
                out_tile.start(DIM_W),
                out_tile.end(DIM_W),
                kernel.1,
                stride.1,
                padding.1,
                in_shape[DIM_W],
            );
            Some(Region::new(&[
                (out_tile.start(DIM_N), out_tile.end(DIM_N)),
                (0, in_shape[DIM_C]),
                (h0, h1),
                (w0, w1),
            ]))
        }
        OpKind::Pool2d { kernel, stride, padding, .. } => {
            // Pooling is per-channel: same channel range as the out tile.
            let (h0, h1) = spatial_window(
                out_tile.start(DIM_H),
                out_tile.end(DIM_H),
                kernel.0,
                stride.0,
                padding.0,
                in_shape[DIM_H],
            );
            let (w0, w1) = spatial_window(
                out_tile.start(DIM_W),
                out_tile.end(DIM_W),
                kernel.1,
                stride.1,
                padding.1,
                in_shape[DIM_W],
            );
            Some(Region::new(&[
                (out_tile.start(DIM_N), out_tile.end(DIM_N)),
                (out_tile.start(DIM_C), out_tile.end(DIM_C)),
                (h0, h1),
                (w0, w1),
            ]))
        }
        OpKind::FullyConnected { .. } => {
            // Any slice of output features needs the whole (flattened)
            // input for the owned samples.
            let mut ranges = vec![(out_tile.start(DIM_N), out_tile.end(DIM_N))];
            for d in 1..in_shape.len() {
                ranges.push((0, in_shape[d]));
            }
            Some(Region::new(&ranges))
        }
        OpKind::Softmax => {
            // Normalizes over channels: full channel extent per sample.
            Some(Region::new(&[
                (out_tile.start(DIM_N), out_tile.end(DIM_N)),
                (0, in_shape[DIM_C]),
            ]))
        }
        OpKind::Concat => {
            // Input `in_idx` owns channel offsets [off, off + c_k) of the
            // output; intersect with the tile's channel range.
            let off: usize = layer.in_shapes[..in_idx].iter().map(|s| s[DIM_C]).sum();
            let ck = in_shape[DIM_C];
            let lo = out_tile.start(DIM_C).max(off);
            let hi = out_tile.end(DIM_C).min(off + ck);
            if lo >= hi {
                return None;
            }
            Some(Region::new(&[
                (out_tile.start(DIM_N), out_tile.end(DIM_N)),
                (lo - off, hi - off),
                (out_tile.start(DIM_H), out_tile.end(DIM_H)),
                (out_tile.start(DIM_W), out_tile.end(DIM_W)),
            ]))
        }
        OpKind::Add => {
            // Element-wise: identical region on both inputs.
            let ranges: Vec<(usize, usize)> =
                (0..out_tile.rank()).map(|d| (out_tile.start(d), out_tile.end(d))).collect();
            Some(Region::new(&ranges))
        }
    }
}

/// Input window along one spatial dimension for output range [o0, o1).
fn spatial_window(
    o0: usize,
    o1: usize,
    k: usize,
    s: usize,
    p: usize,
    in_extent: usize,
) -> (usize, usize) {
    debug_assert!(o1 > o0);
    let lo = (o0 * s).saturating_sub(p);
    let hi = ((o1 - 1) * s + k).saturating_sub(p).min(in_extent);
    (lo.min(in_extent), hi.max(lo))
}

/// How a layer's parameters relate to a configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParamSharding {
    /// Devices holding a (possibly partial) copy that must be synchronized.
    pub replicas: usize,
    /// Disjoint parameter shards (channel partitioning ⇒ no sync between
    /// shards).
    pub shards: usize,
    /// Bytes per shard.
    pub shard_bytes: f64,
}

/// Parameter replication/sharding under `cfg`: the channel degree shards
/// the (output-channel-major) parameters; sample/height/width degrees
/// replicate them (paper §3, Figure 2).
pub fn param_sharding(layer: &Layer, cfg: &PConfig) -> ParamSharding {
    let bytes = layer.param_bytes();
    let shards = cfg.deg[DIM_C];
    let replicas = cfg.deg[DIM_N] * cfg.deg[DIM_H] * cfg.deg[DIM_W];
    ParamSharding { replicas, shards, shard_bytes: bytes / shards as f64 }
}

/// A full parallelization strategy: one configuration per layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Strategy {
    pub configs: Vec<PConfig>,
}

impl Strategy {
    pub fn uniform(num_layers: usize, cfg: PConfig) -> Strategy {
        Strategy { configs: vec![cfg; num_layers] }
    }

    pub fn config(&self, layer: crate::graph::LayerId) -> &PConfig {
        &self.configs[layer]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{nets, GraphBuilder, PoolKind};

    fn conv_layer() -> Layer {
        let mut b = GraphBuilder::new("t");
        let x = b.input(8, 4, 16, 16).unwrap();
        b.conv2d("c", x, 8, (3, 3), (1, 1), (1, 1)).unwrap();
        b.finish().unwrap().layers[1].clone()
    }

    #[test]
    fn enumerate_respects_device_budget_and_divisibility() {
        let l = conv_layer();
        let cfgs = enumerate_configs(&l, 4);
        assert!(cfgs.iter().all(|c| c.total() <= 4));
        assert!(cfgs.iter().all(|c| {
            8 % c.deg[0] == 0 && 8 % c.deg[1] == 0 && 16 % c.deg[2] == 0 && 16 % c.deg[3] == 0
        }));
        assert!(cfgs.contains(&PConfig::serial()));
        assert!(cfgs.contains(&PConfig::data(4)));
        assert!(cfgs.contains(&PConfig::new(1, 1, 2, 2)));
        // no duplicates
        let mut seen = std::collections::HashSet::new();
        assert!(cfgs.iter().all(|c| seen.insert(*c)));
    }

    #[test]
    fn count_configs_matches_enumeration_cardinality() {
        // the counting twin must track the materializing enumerator
        // exactly: every operator kind, several device counts
        let g = nets::lenet5(64).unwrap();
        for l in &g.layers {
            for ndev in [1usize, 2, 3, 4, 7, 8, 16] {
                assert_eq!(
                    count_configs(l, ndev),
                    enumerate_configs(l, ndev).len() as u64,
                    "{} at {ndev} devices",
                    l.name
                );
            }
        }
    }

    #[test]
    fn fc_configs_are_2d_only() {
        let g = nets::lenet5(8).unwrap();
        let fc = g.layers.iter().find(|l| l.name == "fc3").unwrap();
        let cfgs = enumerate_configs(fc, 4);
        assert!(cfgs.iter().all(|c| c.deg[DIM_H] == 1 && c.deg[DIM_W] == 1));
        assert!(cfgs.contains(&PConfig::channel(4)));
    }

    #[test]
    fn tiles_partition_the_output_exactly() {
        let l = conv_layer();
        let cfg = PConfig::new(2, 1, 2, 1);
        let tiles = output_tiles(&l.out_shape, &cfg);
        assert_eq!(tiles.len(), 4);
        let total: usize = tiles.iter().map(|t| t.volume()).sum();
        assert_eq!(total, l.out_shape.iter().product::<usize>());
        // pairwise disjoint
        for i in 0..tiles.len() {
            for j in i + 1..tiles.len() {
                assert_eq!(tiles[i].overlap_volume(&tiles[j]), 0);
            }
        }
    }

    #[test]
    fn conv_halo_extends_input_window() {
        let l = conv_layer(); // 3x3 stride 1 pad 1, in 16x16
        let tiles = output_tiles(&l.out_shape, &PConfig::new(1, 1, 2, 1));
        // lower half tile: output rows 8..16 need input rows 7..16
        let r = input_region(&l, 0, &tiles[1]).unwrap();
        assert_eq!((r.start(DIM_H), r.end(DIM_H)), (7, 16));
        // upper half: output rows 0..8 need input rows 0..9 (pad clamps 0)
        let r0 = input_region(&l, 0, &tiles[0]).unwrap();
        assert_eq!((r0.start(DIM_H), r0.end(DIM_H)), (0, 9));
        // channel dim: conv needs all input channels
        assert_eq!((r0.start(DIM_C), r0.end(DIM_C)), (0, 4));
    }

    #[test]
    fn pool_keeps_channel_range() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(2, 8, 8, 8).unwrap();
        b.pool2d("p", x, PoolKind::Max, (2, 2), (2, 2), (0, 0)).unwrap();
        let g = b.finish().unwrap();
        let p = &g.layers[1];
        let tiles = output_tiles(&p.out_shape, &PConfig::new(1, 2, 1, 1));
        let r = input_region(p, 0, &tiles[1]).unwrap();
        assert_eq!((r.start(DIM_C), r.end(DIM_C)), (4, 8));
        // non-overlapping 2x2/2 pool: input rows exactly 2x
        assert_eq!((r.start(DIM_H), r.end(DIM_H)), (0, 8));
    }

    #[test]
    fn concat_input_mapping() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(1, 4, 4, 4).unwrap();
        let a = b.conv2d("a", x, 6, (1, 1), (1, 1), (0, 0)).unwrap();
        let c = b.conv2d("c", x, 10, (1, 1), (1, 1), (0, 0)).unwrap();
        b.concat("cat", &[a, c]).unwrap();
        let g = b.finish().unwrap();
        let cat = g.layers.last().unwrap();
        // channel tile 8..16 of the concat output overlaps input0 (ch 0..6)
        // nowhere and input1 (ch 6..16) at local channels 2..10.
        let tile = Region::new(&[(0, 1), (8, 16), (0, 4), (0, 4)]);
        assert!(input_region(cat, 0, &tile).is_none());
        let r1 = input_region(cat, 1, &tile).unwrap();
        assert_eq!((r1.start(DIM_C), r1.end(DIM_C)), (2, 10));
    }

    #[test]
    fn fc_needs_full_input_features() {
        let g = nets::lenet5(8).unwrap();
        let fc = g.layers.iter().find(|l| l.name == "fc3").unwrap();
        let tiles = output_tiles(&fc.out_shape, &PConfig::channel(4));
        let r = input_region(fc, 0, &tiles[2]).unwrap();
        // full 4-D input except sample range
        assert_eq!(r.rank(), 4);
        assert_eq!((r.start(DIM_N), r.end(DIM_N)), (0, 8));
        assert_eq!(r.volume(), fc.in_shapes[0].iter().product::<usize>());
    }

    #[test]
    fn param_sharding_rules() {
        let l = conv_layer();
        let s = param_sharding(&l, &PConfig::data(4));
        assert_eq!((s.replicas, s.shards), (4, 1));
        let s = param_sharding(&l, &PConfig::channel(4));
        assert_eq!((s.replicas, s.shards), (1, 4));
        assert!((s.shard_bytes - l.param_bytes() / 4.0).abs() < 1e-9);
        let s = param_sharding(&l, &PConfig::new(2, 2, 1, 1));
        assert_eq!((s.replicas, s.shards), (2, 2));
    }

    #[test]
    fn labels_render_paper_style() {
        assert_eq!(PConfig::new(4, 1, 1, 1).label(), "{n=4}");
        assert_eq!(PConfig::new(1, 1, 2, 2).label(), "{h=2, w=2}");
        assert_eq!(PConfig::serial().label(), "{n=1}");
    }
}
