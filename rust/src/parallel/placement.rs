//! Tile -> device placement policies.
//!
//! The paper assigns a layer's tiles to devices contiguously (device 0
//! first), which keeps equal-config producer/consumer pairs transfer-free
//! and packs small-degree layers onto one node. This module makes that
//! choice explicit and provides an alternative (round-robin across
//! nodes) so its impact can be measured (`ablation_placement` bench).

/// How a layer's tiles map onto device ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Placement {
    /// Tile `t` runs on device `t` (row-major tile order, node 0 first).
    /// The paper's implicit policy.
    #[default]
    Contiguous,
    /// Tile `t` runs on device `(t % nodes) * gpus_per_node + t / nodes`:
    /// tiles spread across nodes first. Maximizes NIC pressure — a
    /// deliberately adversarial baseline for the ablation.
    RoundRobinNodes,
}

impl Placement {
    /// Device id of tile `t` on a cluster of `nodes x gpus_per_node`.
    pub fn device_of(&self, t: usize, nodes: usize, gpus_per_node: usize) -> usize {
        match self {
            Placement::Contiguous => t,
            Placement::RoundRobinNodes => {
                let node = t % nodes;
                let slot = t / nodes;
                debug_assert!(slot < gpus_per_node, "tile {t} exceeds device count");
                node * gpus_per_node + slot
            }
        }
    }

    pub fn by_name(name: &str) -> Option<Placement> {
        match name {
            "contiguous" => Some(Placement::Contiguous),
            "roundrobin" | "round-robin" => Some(Placement::RoundRobinNodes),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_is_identity() {
        let p = Placement::Contiguous;
        for t in 0..16 {
            assert_eq!(p.device_of(t, 4, 4), t);
        }
    }

    #[test]
    fn roundrobin_spreads_across_nodes() {
        let p = Placement::RoundRobinNodes;
        // 2 nodes x 2 gpus: tiles 0,1,2,3 -> devices 0,2,1,3
        assert_eq!(p.device_of(0, 2, 2), 0);
        assert_eq!(p.device_of(1, 2, 2), 2);
        assert_eq!(p.device_of(2, 2, 2), 1);
        assert_eq!(p.device_of(3, 2, 2), 3);
    }

    #[test]
    fn roundrobin_is_a_permutation() {
        let p = Placement::RoundRobinNodes;
        let mut seen: Vec<usize> = (0..16).map(|t| p.device_of(t, 4, 4)).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..16).collect::<Vec<_>>());
    }
}
