//! The region-overlap kernel shared by plan construction and cost-table
//! evaluation.
//!
//! Regions are flattened to fixed-size `[(start, end); 4]` arrays so the
//! innermost (dst-tile, src-tile) loops — the hottest code in the library
//! — run without allocation or rank branching. Missing trailing dimensions
//! are padded with the unit range `(0, 1)`, which is overlap-neutral, so
//! 2-D (FC) and 4-D (conv) regions compose freely.

use crate::tensor::Region;

/// A rank-≤4 region flattened to a fixed array of half-open ranges.
pub type FlatRegion = [(u32, u32); 4];

/// Flatten a [`Region`] (rank ≤ 4) into a [`FlatRegion`].
#[inline]
pub fn flatten(r: &Region) -> FlatRegion {
    debug_assert!(r.rank() <= 4, "FlatRegion supports rank <= 4");
    let mut a = [(0u32, 1u32); 4];
    for dim in 0..r.rank() {
        a[dim] = (r.start(dim) as u32, r.end(dim) as u32);
    }
    a
}

/// Number of index points in the intersection of two flat regions
/// (0 when disjoint). Equals `Region::overlap_volume` on the originals.
#[inline]
pub fn overlap_elems(a: &FlatRegion, b: &FlatRegion) -> u64 {
    let mut v = 1u64;
    for dim in 0..4 {
        let lo = a[dim].0.max(b[dim].0);
        let hi = a[dim].1.min(b[dim].1);
        if lo >= hi {
            return 0;
        }
        v *= (hi - lo) as u64;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_region_overlap_volume() {
        let a = Region::new(&[(0, 4), (2, 8), (0, 3), (1, 5)]);
        let b = Region::new(&[(2, 6), (0, 4), (1, 3), (0, 2)]);
        assert_eq!(
            overlap_elems(&flatten(&a), &flatten(&b)),
            a.overlap_volume(&b) as u64
        );
    }

    #[test]
    fn rank2_pads_with_unit_ranges() {
        let a = Region::new(&[(0, 8), (0, 10)]);
        let b = Region::new(&[(4, 12), (5, 10)]);
        assert_eq!(
            overlap_elems(&flatten(&a), &flatten(&b)),
            a.overlap_volume(&b) as u64
        );
        assert_eq!(overlap_elems(&flatten(&a), &flatten(&b)), 20);
    }

    #[test]
    fn disjoint_is_zero() {
        let a = flatten(&Region::new(&[(0, 2), (0, 2)]));
        let b = flatten(&Region::new(&[(2, 4), (0, 2)]));
        assert_eq!(overlap_elems(&a, &b), 0);
    }
}
