//! LRU cache of materialized execution plans.
//!
//! A production planner answers many optimize/simulate requests against a
//! small working set of (network, strategy, cluster) triples; plan
//! construction is the per-request tiling/overlap cost that the cache
//! amortizes away (see the `plan_reuse` bench). Keys are structural —
//! the graph's content digest ([`crate::graph::GraphDigest`]), per-layer
//! degrees, device count, placement policy — so equal queries hit
//! regardless of how the strategy object (or the graph itself) was
//! produced: builder, preset, or wire spec.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::ExecutionPlan;
use crate::cost::CostModel;
use crate::graph::GraphDigest;
use crate::parallel::{Placement, Strategy};

/// Structural identity of a plan: everything `ExecutionPlan::build`
/// depends on — the graph (its content digest), the strategy's degrees,
/// and the cluster's node topology/placement (which decide tile devices,
/// transfer routes, and sync-group node spans). The digest compares the
/// graph's full canonical structure by value, never a lossy hash, and
/// excludes cosmetic names — equal queries hit regardless of how the
/// graph object was produced (builder, preset, or wire spec).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Content address of the graph structure (ops, shapes, wiring —
    /// batch size included via the input shape).
    pub digest: GraphDigest,
    /// Per-layer parallelism degrees `[n, c, h, w]`.
    pub degrees: Vec<[usize; 4]>,
    pub ndev: usize,
    /// Node index of each device (2x4 and 1x8 clusters differ here).
    pub node_of: Vec<usize>,
    pub placement: Placement,
}

impl PlanKey {
    /// The key `ExecutionPlan::build(cm, strategy)` would be stored under.
    pub fn of(cm: &CostModel<'_>, strategy: &Strategy) -> PlanKey {
        PlanKey {
            digest: cm.graph.digest().clone(),
            degrees: strategy.configs.iter().map(|c| c.deg).collect(),
            ndev: cm.devices.num_devices(),
            node_of: cm.devices.devices.iter().map(|d| d.node).collect(),
            placement: cm.placement,
        }
    }
}

/// A bounded least-recently-used cache of shared plans.
///
/// The hit/miss counters are atomics behind read accessors
/// ([`PlanCache::hits`] / [`PlanCache::misses`]) rather than public
/// fields: callers cannot corrupt them, and shared owners — the
/// `PlanService` shards, which hold caches behind mutexes — can report
/// them through `&self` without taking a write path.
pub struct PlanCache {
    cap: usize,
    tick: u64,
    map: HashMap<PlanKey, (u64, Arc<ExecutionPlan>)>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// A cache holding at most `cap` plans (`cap >= 1`).
    pub fn new(cap: usize) -> PlanCache {
        assert!(cap >= 1, "cache capacity must be positive");
        PlanCache {
            cap,
            tick: 0,
            map: HashMap::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to build a plan.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fetch the plan for (cm, strategy), building and inserting it on a
    /// miss. Evicts the least-recently-used entry at capacity.
    pub fn get_or_build(&mut self, cm: &CostModel<'_>, strategy: &Strategy) -> Arc<ExecutionPlan> {
        let key = PlanKey::of(cm, strategy);
        if let Some(plan) = self.lookup(&key) {
            return plan;
        }
        let plan = Arc::new(ExecutionPlan::build(cm, strategy));
        self.insert(key, Arc::clone(&plan));
        plan
    }

    /// Fetch a cached plan by key, refreshing its recency. A hit here is
    /// also the verify-on-load fast path: anything in the cache was either
    /// built by us or verified before insertion, so it needs no re-check.
    pub fn lookup(&mut self, key: &PlanKey) -> Option<Arc<ExecutionPlan>> {
        self.tick += 1;
        if let Some((last_used, plan)) = self.map.get_mut(key) {
            *last_used = self.tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(Arc::clone(plan));
        }
        None
    }

    /// Insert a plan built (or verified) outside the cache, counting it
    /// as a miss and evicting the least-recently-used entry at capacity.
    pub fn insert(&mut self, key: PlanKey, plan: Arc<ExecutionPlan>) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        if self.map.len() >= self.cap {
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, (last_used, _))| *last_used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&lru);
            }
        }
        self.map.insert(key, (self.tick, Arc::clone(&plan)));
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl Default for PlanCache {
    /// Eight plans — enough for a sweep's working set of strategies.
    fn default() -> PlanCache {
        PlanCache::new(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::graph::nets;
    use crate::optimizer::strategies;

    #[test]
    fn hit_returns_the_same_plan() {
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::data_parallel(&g, 2);
        let mut cache = PlanCache::new(4);
        let a = cache.get_or_build(&cm, &s);
        let b = cache.get_or_build(&cm, &s);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn distinct_strategies_get_distinct_entries() {
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let mut cache = PlanCache::new(4);
        let a = cache.get_or_build(&cm, &strategies::data_parallel(&g, 2));
        let b = cache.get_or_build(&cm, &strategies::owt(&g, 2));
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn lru_evicts_the_coldest_entry() {
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let data = strategies::data_parallel(&g, 2);
        let model = strategies::model_parallel(&g, 2);
        let owt = strategies::owt(&g, 2);
        let mut cache = PlanCache::new(2);
        cache.get_or_build(&cm, &data); // tick 1
        cache.get_or_build(&cm, &model); // tick 2
        cache.get_or_build(&cm, &data); // tick 3: refresh data
        cache.get_or_build(&cm, &owt); // evicts model (coldest)
        assert_eq!(cache.len(), 2);
        let before = cache.misses();
        cache.get_or_build(&cm, &data); // still cached
        assert_eq!(cache.misses(), before);
        cache.get_or_build(&cm, &model); // was evicted: rebuild
        assert_eq!(cache.misses(), before + 1);
    }

    #[test]
    fn batch_size_is_part_of_the_key() {
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let g1 = nets::lenet5(32).unwrap();
        let g2 = nets::lenet5(64).unwrap();
        let k1 = PlanKey::of(&CostModel::new(&g1, &d), &strategies::data_parallel(&g1, 2));
        let k2 = PlanKey::of(&CostModel::new(&g2, &d), &strategies::data_parallel(&g2, 2));
        assert_ne!(k1, k2);
    }

    #[test]
    fn node_topology_is_part_of_the_key() {
        // Same device count, different node layouts: transfer routes and
        // sync-group spans differ, so the plans must not be shared.
        use crate::device::ComputeModel;
        let g = nets::alexnet(32 * 8).unwrap();
        let s = strategies::model_parallel(&g, 8);
        let two_by_four = DeviceGraph::p100_cluster(8).unwrap();
        let one_by_eight =
            DeviceGraph::cluster("flat8", 1, 8, 15e9, 3e9, 12e9, ComputeModel::p100()).unwrap();
        let k1 = PlanKey::of(&CostModel::new(&g, &two_by_four), &s);
        let k2 = PlanKey::of(&CostModel::new(&g, &one_by_eight), &s);
        assert_ne!(k1, k2);
    }

    #[test]
    fn graph_structure_is_part_of_the_key() {
        // Same name, same input shape, same degrees — different layer
        // widths must still be distinguished.
        use crate::graph::GraphBuilder;
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let build = |name: &str, cout: usize| {
            let mut b = GraphBuilder::new(name);
            let x = b.input(8, 3, 16, 16).unwrap();
            let c = b.conv2d("c", x, cout, (3, 3), (1, 1), (1, 1)).unwrap();
            let f = b.fully_connected("fc", c, 10).unwrap();
            b.softmax("sm", f).unwrap();
            b.finish().unwrap()
        };
        let g1 = build("same-name", 8);
        let g2 = build("same-name", 16);
        let k1 = PlanKey::of(&CostModel::new(&g1, &d), &strategies::data_parallel(&g1, 2));
        let k2 = PlanKey::of(&CostModel::new(&g2, &d), &strategies::data_parallel(&g2, 2));
        assert_ne!(k1, k2);
    }

    #[test]
    fn cosmetic_names_are_not_part_of_the_key() {
        // Content addressing: a renamed but structurally identical graph
        // shares the cached plan (the digest strips names).
        use crate::graph::GraphBuilder;
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let build = |name: &str| {
            let mut b = GraphBuilder::new(name);
            let x = b.input(8, 3, 16, 16).unwrap();
            let c = b.conv2d(&format!("{name}-conv"), x, 8, (3, 3), (1, 1), (1, 1)).unwrap();
            let f = b.fully_connected("fc", c, 10).unwrap();
            b.softmax("sm", f).unwrap();
            b.finish().unwrap()
        };
        let g1 = build("alpha");
        let g2 = build("beta");
        let mut cache = PlanCache::new(4);
        let a = cache.get_or_build(&CostModel::new(&g1, &d), &strategies::data_parallel(&g1, 2));
        let b = cache.get_or_build(&CostModel::new(&g2, &d), &strategies::data_parallel(&g2, 2));
        assert!(Arc::ptr_eq(&a, &b), "structurally identical graphs must share one entry");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }
}
