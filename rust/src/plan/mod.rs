//! Materialized execution plans — the shared IR between cost evaluation,
//! simulation, and execution.
//!
//! A parallelization [`Strategy`](crate::parallel::Strategy) only names a
//! configuration per layer; its *consequences* — output tiles, tile →
//! device placement, per-edge transfer schedules (which src-tile overlaps
//! which dst-tile's input region, how many bytes, over which route), and
//! parameter-sync shard groups — used to be re-derived independently by
//! the cost tables, the discrete-event simulator, and the partitioned
//! executor. An [`ExecutionPlan`] materializes all of it **once** per
//! (graph, strategy, devices) triple:
//!
//! * [`sim`](crate::sim) expands its task DAG straight from the plan
//!   (`simulate_plan`), so repeated simulation queries skip all tiling /
//!   region / overlap math;
//! * [`exec`](crate::exec) drives leader-side scatter / halo / gather from
//!   the same plan and reports the plan's scheduled byte totals;
//! * [`cost::tables`](crate::cost::tables) evaluates `t_X` with the same
//!   flattened-region overlap kernel ([`overlap`]).
//!
//! Plans serialize to JSON (`to_json` / `from_json`) and are cached by a
//! [`PlanCache`] keyed on (net, strategy, device count), which makes them
//! servable artifacts rather than transient in-memory derivations — the
//! property PaSE-style systems rely on to answer many planning queries
//! fast (DESIGN.md §9).

pub mod cache;
mod json;
pub mod overlap;

pub use cache::{PlanCache, PlanKey};

use crate::cost::{shard_of_tile, CostModel};
use crate::graph::LayerId;
use crate::metrics::CommBreakdown;
use crate::parallel::{input_region, output_tiles, param_sharding, PConfig, Strategy};
use crate::tensor::Region;

/// How a transfer travels between devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Route {
    /// Producer and consumer tile share a device: a dependency, no bytes
    /// on any wire.
    Local,
    /// Intra-node point-to-point link (NVLink-class).
    IntraNode,
    /// Crosses a node boundary (NIC-class).
    InterNode,
}

/// One scheduled tile-to-tile movement on a graph edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    /// Producer tile index (== producer device under contiguous placement).
    pub src_tile: usize,
    /// Consumer tile index.
    pub dst_tile: usize,
    pub src_dev: usize,
    pub dst_dev: usize,
    /// Overlap volume in elements (f32); bytes = `elems * 4`.
    pub elems: u64,
    pub route: Route,
}

impl Transfer {
    /// Bytes moved (0 only for degenerate overlaps; local transfers still
    /// carry their overlap bytes — they are free, not empty).
    pub fn bytes(&self) -> f64 {
        self.elems as f64 * 4.0
    }

    pub fn is_remote(&self) -> bool {
        self.route != Route::Local
    }
}

/// The transfer schedule of one graph edge under the plan's strategy.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgePlan {
    pub src: LayerId,
    pub dst: LayerId,
    /// Which input slot of `dst` this edge feeds.
    pub in_idx: usize,
    /// Input region each dst tile needs from the producer's output
    /// (producer coordinates); `None` when the tile consumes nothing from
    /// this input (possible for `Concat`).
    pub needs: Vec<Option<Region>>,
    /// All overlapping (dst tile, src tile) pairs in (dst-major, src-minor)
    /// order — the canonical expansion order shared with the simulator.
    pub transfers: Vec<Transfer>,
}

impl EdgePlan {
    /// Bytes that actually cross a link on this edge.
    pub fn remote_bytes(&self) -> f64 {
        self.transfers.iter().filter(|t| t.is_remote()).map(Transfer::bytes).sum()
    }
}

/// One replica group of a parameter shard: the devices holding copies of
/// the same channel shard, which must exchange gradients/updates.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncGroup {
    /// Channel-shard index.
    pub shard: usize,
    /// Tile indices computing this shard (one per replica).
    pub tiles: Vec<usize>,
    /// Devices of those tiles, aligned with `tiles`.
    pub devices: Vec<usize>,
    /// Bytes each replica moves over its uplink per step
    /// (`2 · shard_bytes · (R-1)/R`, the sharded-PS exchange).
    pub bytes_per_replica: f64,
    /// Whether the group spans compute nodes (NIC vs host link).
    pub spans_nodes: bool,
}

impl SyncGroup {
    pub fn bytes(&self) -> f64 {
        self.bytes_per_replica * self.devices.len() as f64
    }
}

/// Parameter synchronization schedule of one layer (present only when the
/// layer has parameters replicated across >1 device).
#[derive(Debug, Clone, PartialEq)]
pub struct SyncPlan {
    /// Bytes per channel shard.
    pub shard_bytes: f64,
    /// One group per channel shard, in shard order.
    pub groups: Vec<SyncGroup>,
}

impl SyncPlan {
    pub fn bytes(&self) -> f64 {
        self.groups.iter().map(SyncGroup::bytes).sum()
    }
}

/// A layer's materialized partitioning.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub layer: LayerId,
    pub cfg: PConfig,
    /// Output tiles in row-major tile order (tile index == placement slot).
    pub tiles: Vec<Region>,
    /// Device running each tile, aligned with `tiles`.
    pub tile_dev: Vec<usize>,
    pub sync: Option<SyncPlan>,
}

/// The fully materialized consequences of one strategy on one cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    /// Network display name. Cosmetic: plan identity is the graph's
    /// structural [`GraphDigest`](crate::graph::GraphDigest) (names
    /// excluded), so a cached plan shared between structurally identical
    /// graphs carries whichever name first primed the cache.
    pub net: String,
    /// Device count the plan was laid out for.
    pub ndev: usize,
    /// One entry per layer, in layer-id order.
    pub layers: Vec<LayerPlan>,
    /// One entry per graph edge, in graph edge order.
    pub edges: Vec<EdgePlan>,
    /// Per-device high-water memory (bytes) of the whole strategy —
    /// [`memory::peak_per_device`](crate::memory::peak_per_device) over
    /// the same tile→device placement recorded in `tile_dev`, so the
    /// feasibility a caller reads off the plan agrees with where the
    /// plan actually puts the bytes.
    pub peak_mem_per_dev: Vec<f64>,
    /// Per-step execution-time estimate (seconds) recorded at build —
    /// `CostModel::t_o` over the plan's strategy. Serialized (plan JSON
    /// v3) so the verifier's cost-coherence check can prove a loaded
    /// artifact still prices what it claims to (bit-for-bit; f64 round-
    /// trips exactly through the JSON layer).
    pub cost_s: f64,
}

impl ExecutionPlan {
    /// Materialize `strategy` on `cm`'s (graph, devices) pair: tiles,
    /// placements, transfer schedules, and sync groups, computed once.
    pub fn build(cm: &CostModel<'_>, strategy: &Strategy) -> ExecutionPlan {
        let g = cm.graph;
        let devices = cm.devices;
        assert_eq!(
            strategy.configs.len(),
            g.num_layers(),
            "strategy/graph size mismatch"
        );

        let layers: Vec<LayerPlan> = g
            .layers
            .iter()
            .map(|l| {
                let cfg = *strategy.config(l.id);
                let tiles = output_tiles(&l.out_shape, &cfg);
                let tile_dev: Vec<usize> = (0..tiles.len()).map(|t| cm.dev_of(t)).collect();
                let sync = if l.has_params() {
                    let sh = param_sharding(l, &cfg);
                    if sh.replicas > 1 {
                        let groups = (0..sh.shards)
                            .map(|shard| {
                                let shard_tiles: Vec<usize> = (0..cfg.total())
                                    .filter(|&t| shard_of_tile(&cfg, t) == shard)
                                    .collect();
                                let devs: Vec<usize> =
                                    shard_tiles.iter().map(|&t| tile_dev[t]).collect();
                                let r = devs.len() as f64;
                                let node = devices.devices[devs[0]].node;
                                let spans_nodes =
                                    devs.iter().any(|&d| devices.devices[d].node != node);
                                SyncGroup {
                                    shard,
                                    tiles: shard_tiles,
                                    devices: devs,
                                    bytes_per_replica: 2.0 * sh.shard_bytes * (r - 1.0) / r,
                                    spans_nodes,
                                }
                            })
                            .collect();
                        Some(SyncPlan { shard_bytes: sh.shard_bytes, groups })
                    } else {
                        None
                    }
                } else {
                    None
                };
                LayerPlan { layer: l.id, cfg, tiles, tile_dev, sync }
            })
            .collect();

        let edges: Vec<EdgePlan> = g
            .edges
            .iter()
            .map(|&(s, d)| {
                let in_idx = cm.edge_in_idx(s, d);
                let ld = g.layer(d);
                let (sp, dp) = (&layers[s], &layers[d]);
                let src_flat: Vec<overlap::FlatRegion> =
                    sp.tiles.iter().map(overlap::flatten).collect();
                let mut needs = Vec::with_capacity(dp.tiles.len());
                let mut transfers = Vec::new();
                for (m, dtile) in dp.tiles.iter().enumerate() {
                    let need = input_region(ld, in_idx, dtile);
                    if let Some(need) = &need {
                        let need_flat = overlap::flatten(need);
                        let dst_dev = dp.tile_dev[m];
                        for (k, stile) in src_flat.iter().enumerate() {
                            let elems = overlap::overlap_elems(&need_flat, stile);
                            if elems == 0 {
                                continue;
                            }
                            let src_dev = sp.tile_dev[k];
                            let route = if src_dev == dst_dev {
                                Route::Local
                            } else if devices.same_node(src_dev, dst_dev) {
                                Route::IntraNode
                            } else {
                                Route::InterNode
                            };
                            transfers.push(Transfer {
                                src_tile: k,
                                dst_tile: m,
                                src_dev,
                                dst_dev,
                                elems,
                                route,
                            });
                        }
                    }
                    needs.push(need);
                }
                EdgePlan { src: s, dst: d, in_idx, needs, transfers }
            })
            .collect();

        // Per-device high water summed over the tiles/placement *just
        // materialized above*, so the recorded vector agrees with
        // `tile_dev` by construction (`memory::peak_per_device` computes
        // the same sum from scratch; equality is pinned by tests).
        let mut peak_mem_per_dev = vec![0.0f64; devices.num_devices()];
        for (lp, l) in layers.iter().zip(g.layers.iter()) {
            for (tile, &dev) in lp.tiles.iter().zip(lp.tile_dev.iter()) {
                peak_mem_per_dev[dev] += crate::memory::tile_bytes(l, &lp.cfg, tile);
            }
        }

        ExecutionPlan {
            net: g.name.clone(),
            ndev: devices.num_devices(),
            layers,
            edges,
            peak_mem_per_dev,
            cost_s: cm.t_o(strategy),
        }
    }

    /// Reconstruct the per-layer strategy the plan materializes (the
    /// configs are recorded verbatim in each [`LayerPlan`]).
    pub fn strategy(&self) -> Strategy {
        Strategy { configs: self.layers.iter().map(|lp| lp.cfg).collect() }
    }

    /// The global batch size the plan was laid out for: the batch extent
    /// of the first (input) layer's tiling. `None` when the plan has no
    /// layers or rank-0 tiles — possible only for hand-mangled
    /// documents, which the verifier rejects anyway.
    pub fn global_batch(&self) -> Option<usize> {
        let first = self.layers.first()?;
        first.tiles.iter().filter(|t| t.rank() > 0).map(|t| t.end(0)).max()
    }

    pub fn layer(&self, id: LayerId) -> &LayerPlan {
        &self.layers[id]
    }

    /// The edge plan feeding `dst` (first in edge order) — the common
    /// lookup for chain graphs, where every layer has at most one input.
    pub fn edge_into(&self, dst: LayerId) -> Option<&EdgePlan> {
        self.edges.iter().find(|e| e.dst == dst)
    }

    /// Bytes crossing links for tensor repartitioning per step (the `t_X`
    /// traffic). Local overlaps are free and excluded.
    pub fn xfer_bytes(&self) -> f64 {
        self.edges.iter().map(EdgePlan::remote_bytes).sum()
    }

    /// Bytes moved for parameter synchronization per step (the `t_S`
    /// traffic).
    pub fn sync_bytes(&self) -> f64 {
        self.layers.iter().filter_map(|l| l.sync.as_ref()).map(SyncPlan::bytes).sum()
    }

    /// Number of scheduled remote transfers per step.
    pub fn num_transfers(&self) -> usize {
        self.edges.iter().map(|e| e.transfers.iter().filter(|t| t.is_remote()).count()).sum()
    }

    /// Per-step communication volume, in the shared metrics shape.
    pub fn comm(&self) -> CommBreakdown {
        CommBreakdown { xfer_bytes: self.xfer_bytes(), sync_bytes: self.sync_bytes() }
    }

    /// The worst device's high-water memory (bytes) — what a per-device
    /// budget is compared against.
    pub fn peak_mem(&self) -> f64 {
        self.peak_mem_per_dev.iter().fold(0.0, |a, &b| a.max(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::graph::nets;
    use crate::optimizer::strategies;

    fn plan_for(net: &str, ndev: usize, strat: &str) -> ExecutionPlan {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::by_name(strat, &g, ndev).unwrap();
        ExecutionPlan::build(&cm, &s)
    }

    #[test]
    fn layer_plans_cover_all_tiles() {
        let p = plan_for("lenet5", 4, "data");
        let g = nets::lenet5(32 * 4).unwrap();
        for (lp, l) in p.layers.iter().zip(g.layers.iter()) {
            assert_eq!(lp.layer, l.id);
            assert_eq!(lp.tiles.len(), lp.cfg.total());
            assert_eq!(lp.tiles.len(), lp.tile_dev.len());
            let vol: usize = lp.tiles.iter().map(|t| t.volume()).sum();
            assert_eq!(vol, l.out_shape.iter().product::<usize>());
        }
        assert_eq!(p.edges.len(), g.num_edges());
    }

    #[test]
    fn xfer_bytes_match_cost_model_accounting() {
        for (net, ndev, strat) in
            [("lenet5", 2, "owt"), ("alexnet", 4, "model"), ("vgg16", 4, "owt")]
        {
            let g = nets::by_name(net, 32 * ndev).unwrap();
            let d = DeviceGraph::p100_cluster(ndev).unwrap();
            let cm = CostModel::new(&g, &d);
            let s = strategies::by_name(strat, &g, ndev).unwrap();
            let p = ExecutionPlan::build(&cm, &s);
            let expect: f64 = g
                .edges
                .iter()
                .map(|&(a, b)| {
                    cm.x_bytes(
                        g.layer(a),
                        g.layer(b),
                        cm.edge_in_idx(a, b),
                        s.config(a),
                        s.config(b),
                    )
                })
                .sum();
            let got = p.xfer_bytes();
            assert!(
                (got - expect).abs() <= 1e-6 * expect.max(1.0),
                "{net}: plan {got} vs cost model {expect}"
            );
        }
    }

    #[test]
    fn sync_bytes_match_cost_model_accounting() {
        for (net, ndev) in [("lenet5", 2), ("alexnet", 4), ("vgg16", 4)] {
            let g = nets::by_name(net, 32 * ndev).unwrap();
            let d = DeviceGraph::p100_cluster(ndev).unwrap();
            let cm = CostModel::new(&g, &d);
            let s = strategies::data_parallel(&g, ndev);
            let p = ExecutionPlan::build(&cm, &s);
            let expect: f64 = g.layers.iter().map(|l| cm.s_bytes(l, s.config(l.id))).sum();
            let got = p.sync_bytes();
            assert!(
                (got - expect).abs() <= 1e-6 * expect.max(1.0),
                "{net}: plan {got} vs cost model {expect}"
            );
        }
    }

    #[test]
    fn matching_configs_produce_no_remote_transfers() {
        // Data parallelism on a chain: every consumer tile's input region
        // is its own sample range — all overlaps are local.
        let p = plan_for("vgg16", 4, "data");
        assert_eq!(p.xfer_bytes(), 0.0);
        assert_eq!(p.num_transfers(), 0);
        // ... but local dependencies are still scheduled.
        assert!(p.edges.iter().any(|e| !e.transfers.is_empty()));
    }

    #[test]
    fn routes_distinguish_intra_and_inter_node() {
        // 8 devices = 2 nodes of 4; model parallelism forces all-gathers
        // whose transfers cross both link classes.
        let p = plan_for("alexnet", 8, "model");
        let routes: std::collections::HashSet<Route> = p
            .edges
            .iter()
            .flat_map(|e| e.transfers.iter().map(|t| t.route))
            .collect();
        assert!(routes.contains(&Route::IntraNode), "expected intra-node transfers");
        assert!(routes.contains(&Route::InterNode), "expected inter-node transfers");
    }

    #[test]
    fn sync_groups_partition_tiles() {
        let p = plan_for("lenet5", 4, "data");
        let g = nets::lenet5(32 * 4).unwrap();
        for (lp, l) in p.layers.iter().zip(g.layers.iter()) {
            let Some(sync) = &lp.sync else { continue };
            assert!(l.has_params());
            let mut all: Vec<usize> =
                sync.groups.iter().flat_map(|grp| grp.tiles.iter().copied()).collect();
            all.sort_unstable();
            assert_eq!(all, (0..lp.cfg.total()).collect::<Vec<_>>());
            for grp in &sync.groups {
                assert_eq!(grp.tiles.len(), grp.devices.len());
                assert!(grp.bytes_per_replica > 0.0);
            }
        }
    }

    #[test]
    fn single_device_plan_is_quiet() {
        let p = plan_for("lenet5", 1, "data");
        assert_eq!(p.xfer_bytes(), 0.0);
        assert_eq!(p.sync_bytes(), 0.0);
        assert_eq!(p.num_transfers(), 0);
    }

    #[test]
    fn plan_records_the_memory_models_per_device_peak() {
        let g = nets::alexnet(32 * 4).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::owt(&g, 4);
        let p = ExecutionPlan::build(&cm, &s);
        assert_eq!(p.peak_mem_per_dev, crate::memory::peak_per_device(&cm, &s));
        assert_eq!(p.peak_mem_per_dev.len(), 4);
        assert!(p.peak_mem() > 0.0);
        assert!(p.peak_mem_per_dev.iter().all(|&b| b <= p.peak_mem()));
    }

    #[test]
    fn plan_records_the_cost_models_step_time_and_its_strategy() {
        let g = nets::alexnet(32 * 4).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::owt(&g, 4);
        let p = ExecutionPlan::build(&cm, &s);
        // bit-for-bit: same inputs, same summation order
        assert_eq!(p.cost_s, cm.t_o(&s));
        assert!(p.cost_s > 0.0);
        assert_eq!(p.strategy(), s);
    }

    #[test]
    fn dev_of_matches_plan_tile_dev_on_nonsquare_clusters() {
        // Regression for the truncating-division placement: on a 2x3
        // cluster the shared `placement_shape` helper must give the cost
        // model and the materialized plan the same tile->device mapping,
        // under both placement policies.
        use crate::device::ComputeModel;
        use crate::parallel::Placement;
        let d =
            DeviceGraph::cluster("2x3", 2, 3, 15e9, 3e9, 12e9, ComputeModel::p100()).unwrap();
        assert_eq!(d.placement_shape(), (2, 3));
        let g = nets::alexnet(32 * 6).unwrap();
        for placement in [Placement::Contiguous, Placement::RoundRobinNodes] {
            let cm = CostModel::new(&g, &d).with_placement(placement);
            let s = strategies::data_parallel(&g, 6);
            let p = ExecutionPlan::build(&cm, &s);
            for lp in &p.layers {
                for (t, &dev) in lp.tile_dev.iter().enumerate() {
                    assert_eq!(
                        cm.dev_of(t),
                        dev,
                        "{placement:?}: tile {t} of layer {} misplaced",
                        lp.layer
                    );
                    assert!(dev < d.num_devices());
                }
            }
        }
    }
}
