//! JSON (de)serialization of execution plans.
//!
//! Plans are exchange artifacts: `optcnn plan --out plan.json` exports
//! them, services can ship them between planner and executor processes,
//! and the round-trip is exact (`from_json(to_json(p)) == p`). Built on
//! `util::json` (the offline registry carries no serde).

use std::collections::BTreeMap;

use super::{EdgePlan, ExecutionPlan, LayerPlan, Route, SyncGroup, SyncPlan, Transfer};
use crate::parallel::PConfig;
use crate::tensor::Region;
use crate::util::json::Json;

// v2 added `peak_mem_per_dev` (the memory model's per-device high water).
// v3 added `cost_s` (the cost model's step-time estimate, recorded at
// build so the verifier's cost-coherence check has a claim to re-derive).
const VERSION: f64 = 3.0;

impl Route {
    fn tag(&self) -> &'static str {
        match self {
            Route::Local => "local",
            Route::IntraNode => "intra",
            Route::InterNode => "inter",
        }
    }

    fn from_tag(tag: &str) -> Result<Route, String> {
        match tag {
            "local" => Ok(Route::Local),
            "intra" => Ok(Route::IntraNode),
            "inter" => Ok(Route::InterNode),
            other => Err(format!("unknown route `{other}`")),
        }
    }
}

fn region_json(r: &Region) -> Json {
    Json::Arr(
        (0..r.rank())
            .map(|d| Json::Arr(vec![Json::Num(r.start(d) as f64), Json::Num(r.end(d) as f64)]))
            .collect(),
    )
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

impl ExecutionPlan {
    /// Serialize the full plan.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(VERSION)),
            ("net", Json::Str(self.net.clone())),
            ("ndev", Json::Num(self.ndev as f64)),
            ("layers", Json::Arr(self.layers.iter().map(layer_json).collect())),
            ("edges", Json::Arr(self.edges.iter().map(edge_json).collect())),
            (
                "peak_mem_per_dev",
                Json::Arr(self.peak_mem_per_dev.iter().map(|&b| Json::Num(b)).collect()),
            ),
            ("cost_s", Json::Num(self.cost_s)),
        ])
    }

    /// Parse a plan serialized by [`ExecutionPlan::to_json`]. Performs
    /// cross-field index validation so a corrupted or hand-edited plan is
    /// rejected here instead of panicking deep inside the simulator or
    /// executor.
    pub fn from_json(v: &Json) -> Result<ExecutionPlan, String> {
        let obj = v.as_obj().ok_or("plan: expected object")?;
        if get_f64(obj, "version")? != VERSION {
            return Err(format!("plan: unsupported version {:?}", obj.get("version")));
        }
        let plan = ExecutionPlan {
            net: get_str(obj, "net")?.to_string(),
            ndev: get_usize(obj, "ndev")?,
            layers: get_arr(obj, "layers")?.iter().map(layer_from).collect::<Result<_, _>>()?,
            edges: get_arr(obj, "edges")?.iter().map(edge_from).collect::<Result<_, _>>()?,
            peak_mem_per_dev: get_arr(obj, "peak_mem_per_dev")?
                .iter()
                .map(|v| {
                    v.as_f64()
                        .filter(|b| b.is_finite() && *b >= 0.0)
                        .ok_or_else(|| "plan: peak_mem_per_dev must be nonnegative".to_string())
                })
                .collect::<Result<_, _>>()?,
            cost_s: get_f64(obj, "cost_s")?,
        };
        if !plan.cost_s.is_finite() || plan.cost_s < 0.0 {
            return Err("plan: cost_s must be a nonnegative finite number".to_string());
        }
        validate(&plan)?;
        Ok(plan)
    }
}

fn layer_json(l: &LayerPlan) -> Json {
    let sync = match &l.sync {
        None => Json::Null,
        Some(s) => Json::obj(vec![
            ("shard_bytes", Json::Num(s.shard_bytes)),
            (
                "groups",
                Json::Arr(
                    s.groups
                        .iter()
                        .map(|g| {
                            Json::obj(vec![
                                ("shard", Json::Num(g.shard as f64)),
                                ("tiles", usize_arr(&g.tiles)),
                                ("devices", usize_arr(&g.devices)),
                                ("bytes_per_replica", Json::Num(g.bytes_per_replica)),
                                ("spans_nodes", Json::Bool(g.spans_nodes)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    };
    Json::obj(vec![
        ("layer", Json::Num(l.layer as f64)),
        ("cfg", usize_arr(&l.cfg.deg)),
        ("tiles", Json::Arr(l.tiles.iter().map(region_json).collect())),
        ("tile_dev", usize_arr(&l.tile_dev)),
        ("sync", sync),
    ])
}

fn edge_json(e: &EdgePlan) -> Json {
    Json::obj(vec![
        ("src", Json::Num(e.src as f64)),
        ("dst", Json::Num(e.dst as f64)),
        ("in_idx", Json::Num(e.in_idx as f64)),
        (
            "needs",
            Json::Arr(
                e.needs.iter().map(|n| n.as_ref().map_or(Json::Null, region_json)).collect(),
            ),
        ),
        (
            "transfers",
            Json::Arr(
                e.transfers
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("src_tile", Json::Num(t.src_tile as f64)),
                            ("dst_tile", Json::Num(t.dst_tile as f64)),
                            ("src_dev", Json::Num(t.src_dev as f64)),
                            ("dst_dev", Json::Num(t.dst_dev as f64)),
                            ("elems", Json::Num(t.elems as f64)),
                            ("route", Json::Str(t.route.tag().to_string())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Structural invariants every deserialized plan must satisfy before the
/// simulator/executor may index into it.
fn validate(plan: &ExecutionPlan) -> Result<(), String> {
    if plan.peak_mem_per_dev.len() != plan.ndev {
        return Err(format!(
            "plan: peak_mem_per_dev has {} entries for {} devices",
            plan.peak_mem_per_dev.len(),
            plan.ndev
        ));
    }
    for (i, l) in plan.layers.iter().enumerate() {
        if l.layer != i {
            return Err(format!("plan: layer {i} carries id {}", l.layer));
        }
        if l.tiles.len() != l.tile_dev.len() {
            return Err(format!("plan: layer {i} tiles/tile_dev length mismatch"));
        }
        if let Some(&d) = l.tile_dev.iter().find(|&&d| d >= plan.ndev) {
            return Err(format!("plan: layer {i} places a tile on device {d} >= ndev"));
        }
        if let Some(sync) = &l.sync {
            for g in &sync.groups {
                if g.tiles.len() != g.devices.len() {
                    return Err(format!("plan: layer {i} sync group tiles/devices mismatch"));
                }
                if g.tiles.iter().any(|&t| t >= l.tiles.len())
                    || g.devices.iter().any(|&d| d >= plan.ndev)
                {
                    return Err(format!("plan: layer {i} sync group indexes out of range"));
                }
            }
        }
    }
    for e in &plan.edges {
        let (Some(src), Some(dst)) = (plan.layers.get(e.src), plan.layers.get(e.dst)) else {
            return Err(format!("plan: edge ({}, {}) references missing layers", e.src, e.dst));
        };
        if e.needs.len() != dst.tiles.len() {
            return Err(format!("plan: edge ({}, {}) needs/tiles mismatch", e.src, e.dst));
        }
        for t in &e.transfers {
            if t.src_tile >= src.tiles.len()
                || t.dst_tile >= dst.tiles.len()
                || t.src_dev >= plan.ndev
                || t.dst_dev >= plan.ndev
            {
                return Err(format!(
                    "plan: edge ({}, {}) transfer indexes out of range",
                    e.src, e.dst
                ));
            }
        }
    }
    Ok(())
}

// ---- parsing helpers ----

type Obj = BTreeMap<String, Json>;

fn get<'a>(obj: &'a Obj, key: &str) -> Result<&'a Json, String> {
    obj.get(key).ok_or_else(|| format!("plan: missing field `{key}`"))
}

fn get_f64(obj: &Obj, key: &str) -> Result<f64, String> {
    get(obj, key)?.as_f64().ok_or_else(|| format!("plan: `{key}` must be a number"))
}

fn get_usize(obj: &Obj, key: &str) -> Result<usize, String> {
    get(obj, key)?.as_usize().ok_or_else(|| format!("plan: `{key}` must be an integer"))
}

fn get_str<'a>(obj: &'a Obj, key: &str) -> Result<&'a str, String> {
    get(obj, key)?.as_str().ok_or_else(|| format!("plan: `{key}` must be a string"))
}

fn get_arr<'a>(obj: &'a Obj, key: &str) -> Result<&'a [Json], String> {
    get(obj, key)?.as_arr().ok_or_else(|| format!("plan: `{key}` must be an array"))
}

fn as_obj(v: &Json) -> Result<&Obj, String> {
    v.as_obj().ok_or_else(|| "plan: expected object".to_string())
}

fn region_from(v: &Json) -> Result<Region, String> {
    let dims = v.as_arr().ok_or("plan: region must be an array")?;
    let mut ranges = Vec::with_capacity(dims.len());
    for d in dims {
        let pair = d.as_arr().filter(|p| p.len() == 2).ok_or("plan: region dim must be [s, e]")?;
        let s = pair[0].as_usize().ok_or("plan: region start must be an integer")?;
        let e = pair[1].as_usize().ok_or("plan: region end must be an integer")?;
        if s > e {
            return Err(format!("plan: region start {s} > end {e}"));
        }
        ranges.push((s, e));
    }
    Ok(Region::new(&ranges))
}

fn usizes_from(v: &Json) -> Result<Vec<usize>, String> {
    v.as_arr()
        .ok_or("plan: expected integer array")?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| "plan: expected integer".to_string()))
        .collect()
}

fn layer_from(v: &Json) -> Result<LayerPlan, String> {
    let obj = as_obj(v)?;
    let deg = usizes_from(get(obj, "cfg")?)?;
    if deg.len() != 4 {
        return Err("plan: cfg must have 4 degrees".to_string());
    }
    let sync = match get(obj, "sync")? {
        Json::Null => None,
        s => {
            let so = as_obj(s)?;
            let groups = get_arr(so, "groups")?
                .iter()
                .map(|g| {
                    let go = as_obj(g)?;
                    Ok(SyncGroup {
                        shard: get_usize(go, "shard")?,
                        tiles: usizes_from(get(go, "tiles")?)?,
                        devices: usizes_from(get(go, "devices")?)?,
                        bytes_per_replica: get_f64(go, "bytes_per_replica")?,
                        spans_nodes: match get(go, "spans_nodes")? {
                            Json::Bool(b) => *b,
                            _ => return Err("plan: spans_nodes must be a bool".to_string()),
                        },
                    })
                })
                .collect::<Result<_, String>>()?;
            Some(SyncPlan { shard_bytes: get_f64(so, "shard_bytes")?, groups })
        }
    };
    Ok(LayerPlan {
        layer: get_usize(obj, "layer")?,
        cfg: PConfig::new(deg[0], deg[1], deg[2], deg[3]),
        tiles: get_arr(obj, "tiles")?.iter().map(region_from).collect::<Result<_, _>>()?,
        tile_dev: usizes_from(get(obj, "tile_dev")?)?,
        sync,
    })
}

fn edge_from(v: &Json) -> Result<EdgePlan, String> {
    let obj = as_obj(v)?;
    let needs = get_arr(obj, "needs")?
        .iter()
        .map(|n| match n {
            Json::Null => Ok(None),
            r => region_from(r).map(Some),
        })
        .collect::<Result<_, String>>()?;
    let transfers = get_arr(obj, "transfers")?
        .iter()
        .map(|t| {
            let to = as_obj(t)?;
            Ok(Transfer {
                src_tile: get_usize(to, "src_tile")?,
                dst_tile: get_usize(to, "dst_tile")?,
                src_dev: get_usize(to, "src_dev")?,
                dst_dev: get_usize(to, "dst_dev")?,
                elems: get_usize(to, "elems")? as u64,
                route: Route::from_tag(get_str(to, "route")?)?,
            })
        })
        .collect::<Result<_, String>>()?;
    Ok(EdgePlan {
        src: get_usize(obj, "src")?,
        dst: get_usize(obj, "dst")?,
        in_idx: get_usize(obj, "in_idx")?,
        needs,
        transfers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostModel;
    use crate::device::DeviceGraph;
    use crate::graph::nets;
    use crate::optimizer::strategies;

    fn roundtrip(net: &str, ndev: usize, strat: &str) {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::by_name(strat, &g, ndev).unwrap();
        let plan = ExecutionPlan::build(&cm, &s);
        let text = plan.to_json().to_string();
        let back = ExecutionPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, plan, "{net}@{ndev}/{strat} round-trip");
    }

    #[test]
    fn roundtrip_chain_and_branchy_nets() {
        roundtrip("lenet5", 2, "data");
        roundtrip("alexnet", 4, "owt");
        roundtrip("inception_v3", 2, "model");
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(ExecutionPlan::from_json(&Json::Null).is_err());
        assert!(ExecutionPlan::from_json(&Json::parse(r#"{"version":1}"#).unwrap()).is_err());
        let wrong_version =
            r#"{"version":99,"net":"x","ndev":1,"layers":[],"edges":[]}"#;
        assert!(ExecutionPlan::from_json(&Json::parse(wrong_version).unwrap()).is_err());
    }

    #[test]
    fn rejects_out_of_range_indices() {
        let g = nets::lenet5(32).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let plan = ExecutionPlan::build(&cm, &strategies::data_parallel(&g, 2));
        // corrupt a device index beyond ndev and re-parse
        let mut bad = plan.clone();
        bad.layers[1].tile_dev[0] = 99;
        let err = ExecutionPlan::from_json(&Json::parse(&bad.to_json().to_string()).unwrap());
        assert!(err.is_err(), "device index out of range must be rejected");
        // corrupt a transfer's tile index
        let mut bad = plan;
        if let Some(e) = bad.edges.iter_mut().find(|e| !e.transfers.is_empty()) {
            e.transfers[0].dst_tile = 1_000;
            let err =
                ExecutionPlan::from_json(&Json::parse(&bad.to_json().to_string()).unwrap());
            assert!(err.is_err(), "transfer index out of range must be rejected");
        }
    }

    #[test]
    fn rejects_mismatched_peak_mem_vector() {
        let g = nets::lenet5(32).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let mut bad = ExecutionPlan::build(&cm, &strategies::data_parallel(&g, 2));
        bad.peak_mem_per_dev.pop();
        let err = ExecutionPlan::from_json(&Json::parse(&bad.to_json().to_string()).unwrap());
        assert!(err.is_err(), "peak vector shorter than ndev must be rejected");
    }

    #[test]
    fn route_tags_roundtrip() {
        for r in [Route::Local, Route::IntraNode, Route::InterNode] {
            assert_eq!(Route::from_tag(r.tag()).unwrap(), r);
        }
        assert!(Route::from_tag("bogus").is_err());
    }
}
