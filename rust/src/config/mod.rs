//! Experiment configuration files.
//!
//! A TOML-subset parser (tables, string/int/float/bool scalars, and flat
//! arrays — everything the experiment configs need; the offline registry
//! has no `toml` crate) plus typed experiment/cluster config structs used
//! by the CLI launcher.
//!
//! Example (`config/experiment_vgg16.toml` ships with the repo):
//!
//! ```toml
//! [experiment]
//! network = "vgg16"
//! strategy = "layerwise"
//! per_gpu_batch = 32
//!
//! [cluster]
//! nodes = 1
//! gpus_per_node = 4
//! intra_bw_gbps = 15.0
//! inter_bw_gbps = 3.125
//! ```
//!
//! Standalone `[cluster]` files live under `config/` at the repo root and
//! load through [`crate::planner::ClusterSpec::load`].

use std::collections::BTreeMap;

use crate::device::DeviceGraph;
use crate::error::{OptError, Result};
use crate::planner::{ClusterSpec, NetworkSpec, Planner, StrategyKind};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }
}

/// Parsed TOML-subset document: `section.key -> value` (keys outside any
/// section live under the empty section name).
#[derive(Debug, Default, Clone)]
pub struct Toml {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Toml {
    /// Parse a TOML-subset document. Errors carry the line number.
    pub fn parse(text: &str) -> Result<Toml> {
        let mut doc = Toml::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(OptError::Config(format!("line {}: expected key = value", ln + 1)));
            };
            let value = parse_value(v.trim())
                .map_err(|e| OptError::Config(format!("line {}: {}", ln + 1, e)))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// Like [`Toml::str_or`], but a *present* value of the wrong type is
    /// a config error instead of silently taking the default.
    pub fn try_str_or(&self, section: &str, key: &str, default: &str) -> Result<String> {
        match self.get(section, key) {
            None => Ok(default.to_string()),
            Some(v) => v.as_str().map(str::to_string).ok_or_else(|| {
                OptError::Config(format!("{section}.{key} must be a string"))
            }),
        }
    }

    /// Like [`Toml::usize_or`], but a *present* value of the wrong type
    /// is a config error instead of silently taking the default.
    pub fn try_usize_or(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.as_usize().ok_or_else(|| {
                OptError::Config(format!("{section}.{key} must be a nonnegative integer"))
            }),
        }
    }

    /// Like [`Toml::f64_or`], but a *present* value of the wrong type is
    /// a config error instead of silently taking the default.
    pub fn try_f64_or(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .ok_or_else(|| OptError::Config(format!("{section}.{key} must be a number"))),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // no '#' inside our string values; keep it simple but quote-aware
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: std::result::Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

/// Typed experiment configuration assembled from a TOML document (with
/// the paper's defaults for anything unspecified). Unknown network,
/// strategy, or compute-model names are rejected at load time.
///
/// The network is either `network = "<preset>"` or `network_file =
/// "<spec.json>"` (a [`GraphSpec`](crate::graph::spec) document loaded
/// and validated at config-load time); a custom graph carries its own
/// batch, so `per_gpu_batch` only combines with a preset.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// The network to plan for (preset or spec-loaded custom graph).
    pub network: NetworkSpec,
    /// The strategy to resolve.
    pub strategy: StrategyKind,
    /// Per-GPU batch size (presets only).
    pub per_gpu_batch: usize,
    /// The cluster the experiment runs on.
    pub cluster: ClusterSpec,
}

impl ExperimentConfig {
    /// Assemble a config from a parsed TOML document.
    pub fn from_toml(doc: &Toml) -> Result<ExperimentConfig> {
        let network = match (
            doc.get("experiment", "network"),
            doc.get("experiment", "network_file"),
        ) {
            (Some(_), Some(_)) => {
                return Err(OptError::Config(
                    "experiment.network and experiment.network_file are mutually exclusive"
                        .into(),
                ))
            }
            (_, None) => {
                NetworkSpec::Preset(doc.try_str_or("experiment", "network", "vgg16")?.parse()?)
            }
            (None, Some(v)) => {
                let path = v.as_str().ok_or_else(|| {
                    OptError::Config("experiment.network_file must be a string path".into())
                })?;
                if doc.get("experiment", "per_gpu_batch").is_some() {
                    return Err(OptError::Config(
                        "experiment.per_gpu_batch does not combine with network_file \
                         (the spec carries its own batch)"
                            .into(),
                    ));
                }
                NetworkSpec::from_spec_file(path)?
            }
        };
        Ok(ExperimentConfig {
            network,
            strategy: doc.try_str_or("experiment", "strategy", "layerwise")?.parse()?,
            per_gpu_batch: doc.try_usize_or("experiment", "per_gpu_batch", 32)?,
            cluster: ClusterSpec::from_toml(doc)?,
        })
    }

    /// Load and validate a config file.
    pub fn load(path: &str) -> Result<ExperimentConfig> {
        let text =
            std::fs::read_to_string(path).map_err(|e| OptError::Io(format!("{path}: {e}")))?;
        ExperimentConfig::from_toml(&Toml::parse(&text)?)
    }

    /// Devices in the configured cluster.
    pub fn num_devices(&self) -> usize {
        self.cluster.num_devices()
    }

    /// Global batch size across the cluster (a custom graph's own batch,
    /// or `per_gpu_batch x devices` for presets).
    pub fn global_batch(&self) -> usize {
        self.network.fixed_batch().unwrap_or(self.per_gpu_batch * self.num_devices())
    }

    /// Materialize the device graph this config describes.
    pub fn device_graph(&self) -> Result<DeviceGraph> {
        self.cluster.device_graph()
    }

    /// Open a planning session for this config.
    pub fn planner(&self) -> Result<Planner> {
        let mut builder = Planner::builder(self.network.clone()).cluster(self.cluster.clone());
        if self.network.fixed_batch().is_none() {
            builder = builder.per_gpu_batch(self.per_gpu_batch);
        }
        builder.build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::Network;

    const DOC: &str = r#"
# experiment file
[experiment]
network = "alexnet"     # the net
strategy = "owt"
per_gpu_batch = 64

[cluster]
nodes = 2
gpus_per_node = 4
intra_bw_gbps = 20.0
extras = [1, 2.5, "x"]
"#;

    #[test]
    fn parses_sections_scalars_comments() {
        let t = Toml::parse(DOC).unwrap();
        assert_eq!(t.str_or("experiment", "network", ""), "alexnet");
        assert_eq!(t.usize_or("cluster", "nodes", 0), 2);
        assert_eq!(t.f64_or("cluster", "intra_bw_gbps", 0.0), 20.0);
        let arr = t.get("cluster", "extras").unwrap();
        assert_eq!(
            arr,
            &Value::Array(vec![Value::Int(1), Value::Float(2.5), Value::Str("x".into())])
        );
    }

    #[test]
    fn experiment_config_roundtrip() {
        let t = Toml::parse(DOC).unwrap();
        let c = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(c.network.preset(), Some(Network::AlexNet));
        assert_eq!(c.strategy, StrategyKind::Owt);
        assert_eq!(c.num_devices(), 8);
        assert_eq!(c.global_batch(), 512);
        let d = c.device_graph().unwrap();
        assert_eq!(d.num_devices(), 8);
        assert_eq!(d.bandwidth(0, 1), 20e9);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let c = ExperimentConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert_eq!(c.network.preset(), Some(Network::Vgg16));
        assert_eq!(c.per_gpu_batch, 32);
        assert_eq!(c.num_devices(), 4);
    }

    #[test]
    fn network_file_loads_a_custom_graph() {
        let dir = std::env::temp_dir().join("optcnn-config-test");
        std::fs::create_dir_all(&dir).unwrap();
        let spec_path = dir.join("tiny.graph.json");
        let g = crate::graph::nets::minicnn(48).unwrap();
        std::fs::write(&spec_path, g.to_spec().to_string()).unwrap();
        let doc = format!(
            "[experiment]\nnetwork_file = \"{}\"\n\n[cluster]\nnodes = 1\ngpus_per_node = 2\n",
            spec_path.display()
        );
        let c = ExperimentConfig::from_toml(&Toml::parse(&doc).unwrap()).unwrap();
        assert!(c.network.preset().is_none());
        assert_eq!(c.network.name(), "minicnn");
        assert_eq!(c.global_batch(), 48, "the spec's own batch governs");
        let mut p = c.planner().unwrap();
        assert_eq!(p.global_batch(), 48);
        assert!(p.evaluate(StrategyKind::Data).unwrap().throughput > 0.0);
        // the two network keys are mutually exclusive, and per_gpu_batch
        // does not combine with a spec-carried batch
        let both = format!(
            "[experiment]\nnetwork = \"vgg16\"\nnetwork_file = \"{}\"\n",
            spec_path.display()
        );
        assert!(ExperimentConfig::from_toml(&Toml::parse(&both).unwrap()).is_err());
        let batched = format!(
            "[experiment]\nnetwork_file = \"{}\"\nper_gpu_batch = 16\n",
            spec_path.display()
        );
        assert!(ExperimentConfig::from_toml(&Toml::parse(&batched).unwrap()).is_err());
    }

    #[test]
    fn unknown_names_rejected_at_load() {
        let t = Toml::parse("[experiment]\nnetwork = \"resnet1001\"\n").unwrap();
        assert!(matches!(
            ExperimentConfig::from_toml(&t),
            Err(OptError::UnknownNetwork(_))
        ));
        let t = Toml::parse("[experiment]\nstrategy = \"zigzag\"\n").unwrap();
        assert!(matches!(
            ExperimentConfig::from_toml(&t),
            Err(OptError::UnknownStrategy(_))
        ));
    }

    #[test]
    fn wrong_typed_values_rejected_not_defaulted() {
        let t = Toml::parse("[experiment]\nper_gpu_batch = \"many\"\n").unwrap();
        assert!(matches!(ExperimentConfig::from_toml(&t), Err(OptError::Config(_))));
        let t = Toml::parse("[experiment]\nnetwork = 5\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        let t = Toml::parse("[cluster]\nnodes = \"two\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        let t = Toml::parse("[cluster]\nintra_bw_gbps = true\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Toml::parse("not a kv").is_err());
        assert!(Toml::parse("x = @nope").is_err());
    }
}
