//! Experiment configuration files.
//!
//! A TOML-subset parser (tables, string/int/float/bool scalars, and flat
//! arrays — everything the experiment configs need; the offline registry
//! has no `toml` crate) plus typed experiment/cluster config structs used
//! by the CLI launcher.
//!
//! Example (`examples/configs/vgg16_4gpu.toml` ships with the repo):
//!
//! ```toml
//! [experiment]
//! network = "vgg16"
//! strategy = "layerwise"
//! per_gpu_batch = 32
//!
//! [cluster]
//! nodes = 1
//! gpus_per_node = 4
//! intra_bw_gbps = 15.0
//! inter_bw_gbps = 3.125
//! ```

use std::collections::BTreeMap;

use crate::device::{ComputeModel, DeviceGraph};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }
}

/// Parsed TOML-subset document: `section.key -> value` (keys outside any
/// section live under the empty section name).
#[derive(Debug, Default, Clone)]
pub struct Toml {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Toml {
    /// Parse a TOML-subset document. Errors carry the line number.
    pub fn parse(text: &str) -> Result<Toml, String> {
        let mut doc = Toml::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {}: expected key = value", ln + 1));
            };
            let value = parse_value(v.trim()).map_err(|e| format!("line {}: {}", ln + 1, e))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // no '#' inside our string values; keep it simple but quote-aware
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

/// Typed experiment configuration assembled from a TOML document (with
/// the paper's defaults for anything unspecified).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub network: String,
    /// `data`, `model`, `owt`, or `layerwise`.
    pub strategy: String,
    pub per_gpu_batch: usize,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub intra_bw: f64,
    pub inter_bw: f64,
    pub host_bw: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            network: "vgg16".into(),
            strategy: "layerwise".into(),
            per_gpu_batch: 32,
            nodes: 1,
            gpus_per_node: 4,
            intra_bw: 15e9,
            inter_bw: 3.125e9,
            host_bw: 12e9,
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml(doc: &Toml) -> ExperimentConfig {
        let d = ExperimentConfig::default();
        ExperimentConfig {
            network: doc.str_or("experiment", "network", &d.network),
            strategy: doc.str_or("experiment", "strategy", &d.strategy),
            per_gpu_batch: doc.usize_or("experiment", "per_gpu_batch", d.per_gpu_batch),
            nodes: doc.usize_or("cluster", "nodes", d.nodes),
            gpus_per_node: doc.usize_or("cluster", "gpus_per_node", d.gpus_per_node),
            intra_bw: doc.f64_or("cluster", "intra_bw_gbps", d.intra_bw / 1e9) * 1e9,
            inter_bw: doc.f64_or("cluster", "inter_bw_gbps", d.inter_bw / 1e9) * 1e9,
            host_bw: doc.f64_or("cluster", "host_bw_gbps", d.host_bw / 1e9) * 1e9,
        }
    }

    pub fn load(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Ok(ExperimentConfig::from_toml(&Toml::parse(&text)?))
    }

    pub fn num_devices(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    pub fn global_batch(&self) -> usize {
        self.per_gpu_batch * self.num_devices()
    }

    /// Materialize the device graph this config describes.
    pub fn device_graph(&self) -> DeviceGraph {
        DeviceGraph::cluster(
            &format!("{}x{}", self.nodes, self.gpus_per_node),
            self.nodes,
            self.gpus_per_node,
            self.intra_bw,
            self.inter_bw,
            self.host_bw,
            ComputeModel::p100(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# experiment file
[experiment]
network = "alexnet"     # the net
strategy = "owt"
per_gpu_batch = 64

[cluster]
nodes = 2
gpus_per_node = 4
intra_bw_gbps = 20.0
extras = [1, 2.5, "x"]
"#;

    #[test]
    fn parses_sections_scalars_comments() {
        let t = Toml::parse(DOC).unwrap();
        assert_eq!(t.str_or("experiment", "network", ""), "alexnet");
        assert_eq!(t.usize_or("cluster", "nodes", 0), 2);
        assert_eq!(t.f64_or("cluster", "intra_bw_gbps", 0.0), 20.0);
        let arr = t.get("cluster", "extras").unwrap();
        assert_eq!(
            arr,
            &Value::Array(vec![Value::Int(1), Value::Float(2.5), Value::Str("x".into())])
        );
    }

    #[test]
    fn experiment_config_roundtrip() {
        let t = Toml::parse(DOC).unwrap();
        let c = ExperimentConfig::from_toml(&t);
        assert_eq!(c.network, "alexnet");
        assert_eq!(c.num_devices(), 8);
        assert_eq!(c.global_batch(), 512);
        let d = c.device_graph();
        assert_eq!(d.num_devices(), 8);
        assert_eq!(d.bandwidth(0, 1), 20e9);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let c = ExperimentConfig::from_toml(&Toml::parse("").unwrap());
        assert_eq!(c.network, "vgg16");
        assert_eq!(c.per_gpu_batch, 32);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Toml::parse("not a kv").is_err());
        assert!(Toml::parse("x = @nope").is_err());
    }
}
