//! `optcnn` — the L3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `optimize`  — run the strategy search and print the per-layer strategy
//! * `analyze`   — pre-planning static analysis: reducibility, search-cost
//!   certificate, memory precheck, graph lints (DESIGN.md §11)
//! * `audit`     — static soundness audit of the cost tables plus a
//!   differential cross-check of both search backends (DESIGN.md §12)
//! * `simulate`  — evaluate a strategy on the simulated cluster
//! * `plan`      — materialize a strategy's ExecutionPlan (print/export)
//! * `verify`    — statically check an exported plan artifact against the
//!   (graph, cluster) it claims to schedule (DESIGN.md §10)
//! * `graph`     — export, validate, and render GraphSpec documents
//! * `sweep`     — the full Figure 7/8 grid (networks x devices x strategies),
//!   fanned across a thread pool through one shared `PlanService`
//! * `serve`     — answer plan/evaluate requests over TCP (NDJSON)
//! * `train`     — real partitioned training of MiniCNN through PJRT
//! * `info`      — networks, artifact status, cluster presets
//!
//! Every planning subcommand takes the network either as `--network
//! <name>` (a builtin preset) or `--network-file <spec.json>` (an
//! arbitrary GraphSpec document; see `optcnn graph`).
//!
//! Every subcommand goes through the typed [`Planner`] session API (or
//! its concurrent counterpart, the `PlanService`); bad user input
//! (unknown names, malformed flags, impossible clusters) exits 2 with a
//! one-line message, runtime failures exit 1.

use std::sync::Arc;
use std::time::Duration;

use optcnn::config::ExperimentConfig;
use optcnn::data::SyntheticDataset;
use optcnn::error::{OptError, Result};
use optcnn::exec::Trainer;
use optcnn::planner::{backend, ClusterSpec, Network, NetworkSpec, Planner, StrategyKind};
use optcnn::runtime::ArtifactStore;
use optcnn::util::cli::Args;
use optcnn::util::table::Table;
use optcnn::util::{fmt_bytes, fmt_secs};

const USAGE: &str = "\
optcnn — layer-wise parallelism for CNN training (ICML'18 reproduction)

USAGE:
  optcnn optimize --network <net> --devices <n> [--backend elimination|dfs|auto]
                  [--budget-ms <ms>] [--cluster <file.toml>] [--mem-limit <b>]
                  [--build-threads <n>] [--prune-dominated]
  optcnn analyze  (<spec.json> | --network <net> | --network-file <spec.json>)
                  [--devices <n> | --cluster <file.toml>] [--mem-limit <b>]
                  [--json] [--deny-warnings]
  optcnn audit    (<spec.json> | --network <net> | --network-file <spec.json>)
                  [--devices <n> | --cluster <file.toml>] [--mem-limit <b>]
                  [--build-threads <n>] [--json] [--deny-warnings]
  optcnn simulate --network <net> --devices <n> --strategy <s>
                  [--cluster <file.toml>] [--trace out.json] [--mem-limit <b>]
  optcnn plan     --network <net> --devices <n> [--strategy <s>]
                  [--cluster <file.toml>] [--out plan.json] [--mem-limit <b>]
                  [--prune-dominated]
  optcnn verify   <plan.json> [--network <net> | --network-file <spec.json>]
                  [--devices <n> | --cluster <file.toml>]
  optcnn graph    (--network <net> [--batch <global>] | --network-file <spec.json>)
                  [--validate] [--out spec.json] [--dot graph.dot]
  optcnn sweep    [--networks a,b] [--network-file <spec.json>]
                  [--devices 1,2,4,8,16] [--threads N] [--mem-limit <b>]
                  [--prune-dominated]
  optcnn serve    [--addr 127.0.0.1:7878] [--shards 8] [--cache-cap 8]
                  [--build-threads <n>] [--no-verify] [--prune-dominated]
                  [--workers <n>] [--queue-cap 64] [--max-conns 1024]
                  [--request-timeout <ms>] [--plan-store <dir>]
  optcnn train    [--steps 100] [--devices 4] [--strategy layerwise]
                  [--lr 0.01] [--artifacts artifacts]
  optcnn profile  [--devices 4] [--reps 3]   (measured-t_C search, minicnn)
  optcnn info
  optcnn run      --config <file.toml>

NETWORKS:   lenet5 alexnet vgg16 inception_v3 resnet18 resnet50 minicnn —
            or any GraphSpec JSON via --network-file (exclusive with
            --network/--batch; the spec carries its own global batch)
STRATEGIES: data model owt layerwise
CLUSTERS:   P100 preset via --devices, arbitrary via --cluster (see config/)
MEM LIMIT:  per-device budget for the layer-wise search: bytes, a KB/MB/GB
            suffix (16GB), or `device` for the cluster's own HBM capacity
THREADS:    --build-threads <n> fans the cost-table build across n worker
            threads (0 = all cores, 1 = serial); output is bit-identical
PRUNING:    --prune-dominated drops provably dominated layer configurations
            from the tables before the search; the optimum (cost and plan)
            is byte-identical, certified by `optcnn audit`
";

/// Parse a `--mem-limit` value: a whole number of bytes or a number with
/// a decimal KB/MB/GB/TB suffix (case-insensitive), e.g. `16GB` = 16e9.
/// The `device` keyword is handled by the caller (it needs the cluster).
fn parse_mem_bytes(s: &str) -> Result<u64> {
    let err = || {
        OptError::InvalidArgument(format!(
            "--mem-limit must be bytes, a KB/MB/GB/TB value like 16GB, or `device`; got `{s}`"
        ))
    };
    let lower = s.trim().to_ascii_lowercase();
    let (num, scale) = match lower.strip_suffix("kb") {
        Some(n) => (n, 1e3),
        None => match lower.strip_suffix("mb") {
            Some(n) => (n, 1e6),
            None => match lower.strip_suffix("gb") {
                Some(n) => (n, 1e9),
                None => match lower.strip_suffix("tb") {
                    Some(n) => (n, 1e12),
                    None => (lower.as_str(), 1.0),
                },
            },
        },
    };
    let x: f64 = num.trim().parse().map_err(|_| err())?;
    let bytes = x * scale;
    if !(bytes.is_finite() && bytes >= 1.0 && bytes <= (1u64 << 53) as f64) {
        return Err(err());
    }
    Ok(bytes as u64)
}

fn main() {
    let args = Args::parse(
        std::env::args().skip(1),
        &["verbose", "csv", "validate", "no-verify", "json", "deny-warnings", "prune-dominated"],
    );
    let code = match dispatch(&args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e}");
            e.exit_code()
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<i32> {
    match args.subcommand.as_deref() {
        Some("optimize") => cmd_optimize(args),
        Some("analyze") => cmd_analyze(args),
        Some("audit") => cmd_audit(args),
        Some("simulate") => cmd_simulate(args),
        Some("plan") => cmd_plan(args),
        Some("verify") => cmd_verify(args),
        Some("graph") => cmd_graph(args),
        Some("sweep") => cmd_sweep(args),
        Some("serve") => cmd_serve(args),
        Some("train") => cmd_train(args),
        Some("info") => cmd_info(args),
        Some("profile") => cmd_profile(args),
        Some("run") => cmd_run(args),
        _ => {
            print!("{USAGE}");
            Ok(2)
        }
    }
}

/// Resolve `--network`/`--network-file` into a [`NetworkSpec`]: `None`
/// when neither flag is present (callers pick their own default), an
/// error when both are, and `--batch` rejected alongside a spec file
/// (the spec carries its own global batch).
fn network_from_args(args: &Args) -> Result<Option<NetworkSpec>> {
    match (args.get("network"), args.get("network-file")) {
        (Some(_), Some(_)) => Err(OptError::InvalidArgument(
            "--network and --network-file are mutually exclusive".into(),
        )),
        (Some(name), None) => Ok(Some(NetworkSpec::Preset(name.parse()?))),
        (None, Some(path)) => {
            if args.get("batch").is_some() {
                return Err(OptError::InvalidArgument(
                    "--batch applies to --network presets; a spec file carries its \
                     own global batch"
                        .into(),
                ));
            }
            Ok(Some(NetworkSpec::from_spec_file(path)?))
        }
        (None, None) => Ok(None),
    }
}

/// Shared `--network[-file]/--devices/--cluster/--batch/--backend`
/// handling: the one place CLI flags become a typed [`Planner`] session.
fn planner_from_args(args: &Args) -> Result<Planner> {
    let network =
        network_from_args(args)?.unwrap_or(NetworkSpec::Preset(Network::Vgg16));
    let mut builder = Planner::builder(network);
    match args.get("cluster") {
        Some(path) => {
            if args.get("devices").is_some() {
                return Err(OptError::InvalidArgument(
                    "--devices and --cluster are mutually exclusive".into(),
                ));
            }
            builder = builder.cluster(ClusterSpec::load(path)?);
        }
        None => builder = builder.devices(args.usize_or("devices", 4)?),
    }
    if args.get("batch").is_some() {
        // only thread an explicit batch through: a custom graph carries
        // its own, and the builder rejects the combination
        builder = builder.per_gpu_batch(args.usize_or("batch", 0)?);
    }
    match args.get("mem-limit") {
        None => {}
        Some("device") => builder = builder.mem_limit_device(),
        Some(v) => builder = builder.mem_limit(parse_mem_bytes(v)?),
    }
    builder = builder.build_threads(args.usize_or("build-threads", 0)?);
    builder = builder.prune_dominated(args.flag("prune-dominated"));
    let backend_name = args.get_or("backend", "elimination");
    let budget = match args.usize_or("budget-ms", 0)? {
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    if budget.is_some() && backend_name != "dfs" && backend_name != "auto" {
        return Err(OptError::InvalidArgument(
            "--budget-ms only applies to --backend dfs or auto".into(),
        ));
    }
    if backend_name == "auto" {
        // certificate-driven choice (DESIGN.md §11): the session must
        // exist first so the graph can be analyzed, then the backend the
        // certificate picked is bound in place of the default
        let mut planner = builder.build()?;
        let report = planner.analyze();
        planner
            .set_backend_boxed(backend::auto(report.certificate.residual_space_log2, budget));
        return Ok(planner);
    }
    builder = builder.backend_boxed(backend::by_name(backend_name, budget)?);
    builder.build()
}

fn cmd_optimize(args: &Args) -> Result<i32> {
    let mut p = planner_from_args(args)?;
    let t0 = std::time::Instant::now();
    let opt = p.optimize()?;
    let dt = t0.elapsed().as_secs_f64();
    let eval = p.evaluate(StrategyKind::Layerwise)?;
    let mut table = Table::new(
        &format!("optimal strategy: {} on {} device(s)", p.network(), p.num_devices()),
        &["layer", "op", "configuration"],
    );
    for l in &p.graph().layers {
        table.row(vec![
            l.name.clone(),
            l.op.mnemonic().to_string(),
            opt.strategy.config(l.id).label(),
        ]);
    }
    table.print();
    let s = &opt.stats;
    if p.backend_name() == "dfs" {
        // the exhaustive baseline has no elimination phase: report the
        // search-tree size instead of elimination/K statistics
        println!("search[dfs]: {} search-tree nodes visited, {dt:.3}s", s.enumerated);
    } else {
        println!(
            "search[{}]: {} node elims, {} edge elims, K={}, {:.3}s",
            p.backend_name(),
            s.node_eliminations,
            s.edge_eliminations,
            s.final_nodes,
            dt
        );
    }
    println!(
        "estimated step {}  simulated step {}  throughput {:.0} img/s  comm {}/step",
        fmt_secs(eval.estimate),
        fmt_secs(eval.sim.step_time),
        eval.throughput,
        fmt_bytes(eval.comm.total())
    );
    Ok(0)
}

/// Pre-planning static analysis (DESIGN.md §11): reducibility class, the
/// exact search-cost certificate, the memory precheck under
/// `--mem-limit`, and graph lints — computed from structure alone,
/// building no cost tables. `--json` prints the machine-readable report;
/// `--deny-warnings` turns warning lints into exit 2 (CI runs it over
/// every checked-in spec). Error lints always exit 2.
fn cmd_analyze(args: &Args) -> Result<i32> {
    // `optcnn analyze <spec.json>` is shorthand for --network-file
    let network = match (args.positional.first(), network_from_args(args)?) {
        (Some(_), Some(_)) => {
            return Err(OptError::InvalidArgument(
                "pass the spec positionally or via --network/--network-file, not both"
                    .into(),
            ));
        }
        (Some(path), None) => NetworkSpec::from_spec_file(path)?,
        (None, Some(spec)) => spec,
        (None, None) => {
            return Err(OptError::InvalidArgument(
                "analyze needs a graph: `optcnn analyze <spec.json>`, --network \
                 <preset>, or --network-file <spec.json>"
                    .into(),
            ));
        }
    };
    let mut builder = Planner::builder(network);
    match args.get("cluster") {
        Some(path) => {
            if args.get("devices").is_some() {
                return Err(OptError::InvalidArgument(
                    "--devices and --cluster are mutually exclusive".into(),
                ));
            }
            builder = builder.cluster(ClusterSpec::load(path)?);
        }
        None => builder = builder.devices(args.usize_or("devices", 4)?),
    }
    if args.get("batch").is_some() {
        builder = builder.per_gpu_batch(args.usize_or("batch", 0)?);
    }
    match args.get("mem-limit") {
        None => {}
        Some("device") => builder = builder.mem_limit_device(),
        Some(v) => builder = builder.mem_limit(parse_mem_bytes(v)?),
    }
    let p = builder.build()?;
    let report = p.analyze();
    debug_assert_eq!(p.session_stats().table_builds, 0, "analysis must build no tables");

    if args.flag("json") {
        println!("{}", report.to_json().to_string());
    } else {
        let with_memory = report.memory.is_some();
        let cols: &[&str] = if with_memory {
            &["layer", "op", "configs", "feasible", "min peak"]
        } else {
            &["layer", "op", "configs"]
        };
        let mut table = Table::new(
            &format!(
                "pre-planning analysis: {} x{} (batch {})",
                p.network(),
                p.num_devices(),
                p.global_batch()
            ),
            cols,
        );
        for l in &p.graph().layers {
            let mut row = vec![
                l.name.clone(),
                l.op.mnemonic().to_string(),
                report.certificate.layer_configs[l.id].to_string(),
            ];
            if let Some(m) = &report.memory {
                let f = &m.per_layer[l.id];
                row.push(format!("{}/{}", f.feasible, f.configs));
                row.push(fmt_bytes(f.min_bytes));
            }
            table.row(row);
        }
        table.print();
        println!(
            "reducibility: {} ({} node elims, {} edge elims, K={})",
            report.reducibility,
            report.kernel.node_eliminations,
            report.kernel.edge_eliminations,
            report.kernel.nodes.len()
        );
        let exact = |space: Option<u128>| match space {
            Some(s) => format!("{s}"),
            None => "over 2^128".to_string(),
        };
        println!(
            "certificate: residual enumeration {} strategies (2^{:.1}), full space \
             2^{:.1} over {} layers",
            exact(report.certificate.residual_space),
            report.certificate.residual_space_log2,
            report.certificate.full_space_log2,
            report.certificate.layer_configs.len()
        );
        if let Some(m) = &report.memory {
            match &m.infeasible {
                Some((layer, overshoot)) => println!(
                    "memory: INFEASIBLE — layer `{layer}` overshoots the budget by {}",
                    fmt_bytes(*overshoot as f64)
                ),
                None => println!(
                    "memory: feasible — every layer keeps at least one configuration \
                     under the budget"
                ),
            }
        }
        if report.diagnostics.is_empty() {
            println!("diagnostics: none");
        } else {
            for d in &report.diagnostics {
                let at = match d.layer {
                    Some(id) => format!(" layer `{}`", p.graph().layers[id].name),
                    None => String::new(),
                };
                println!("{}[{}]{}: {}", d.severity, d.code, at, d.message);
            }
        }
        // `--backend auto` would make the same call from this certificate
        let pick = if report.certificate.residual_space_log2
            <= backend::AUTO_ELIMINATION_MAX_LOG2
        {
            "elimination"
        } else {
            "budgeted dfs"
        };
        println!("backend auto would pick: {pick}");
    }

    if report.errors() > 0 {
        eprintln!("analysis: {} error(s), {} warning(s)", report.errors(), report.warnings());
        return Ok(2);
    }
    if args.flag("deny-warnings") && report.warnings() > 0 {
        eprintln!("analysis: {} warning(s) denied by --deny-warnings", report.warnings());
        return Ok(2);
    }
    Ok(0)
}

/// Static soundness audit of the cost tables (DESIGN.md §12): build the
/// tables the search would use and prove the named invariants over every
/// entry — finiteness, canonical configuration lists, edge dimensions,
/// closed-form physical lower bounds, and budget-mask coherence — then
/// compute the per-layer dominance certificates and run the differential
/// backend cross-check (elimination vs exhaustive DFS over the residual
/// kernel). A violated invariant exits 2 with `invalid tables
/// [check-name]: ...`; a backend disagreement exits 2 naming the first
/// divergent layer. `--json` prints the machine-readable report;
/// `--deny-warnings` turns warnings (e.g. a cross-check that hit its DFS
/// budget before certifying) into exit 2.
fn cmd_audit(args: &Args) -> Result<i32> {
    // `optcnn audit <spec.json>` is shorthand for --network-file
    let network = match (args.positional.first(), network_from_args(args)?) {
        (Some(_), Some(_)) => {
            return Err(OptError::InvalidArgument(
                "pass the spec positionally or via --network/--network-file, not both"
                    .into(),
            ));
        }
        (Some(path), None) => NetworkSpec::from_spec_file(path)?,
        (None, Some(spec)) => spec,
        (None, None) => {
            return Err(OptError::InvalidArgument(
                "audit needs a graph: `optcnn audit <spec.json>`, --network \
                 <preset>, or --network-file <spec.json>"
                    .into(),
            ));
        }
    };
    let mut builder = Planner::builder(network);
    match args.get("cluster") {
        Some(path) => {
            if args.get("devices").is_some() {
                return Err(OptError::InvalidArgument(
                    "--devices and --cluster are mutually exclusive".into(),
                ));
            }
            builder = builder.cluster(ClusterSpec::load(path)?);
        }
        None => builder = builder.devices(args.usize_or("devices", 4)?),
    }
    if args.get("batch").is_some() {
        builder = builder.per_gpu_batch(args.usize_or("batch", 0)?);
    }
    match args.get("mem-limit") {
        None => {}
        Some("device") => builder = builder.mem_limit_device(),
        Some(v) => builder = builder.mem_limit(parse_mem_bytes(v)?),
    }
    builder = builder.build_threads(args.usize_or("build-threads", 0)?);
    let mut p = builder.build()?;
    let report = p.audit()?;

    if args.flag("json") {
        println!("{}", report.to_json().to_string());
    } else {
        println!(
            "cost-table audit: {} x{} (batch {})",
            p.network(),
            p.num_devices(),
            p.global_batch()
        );
        print!("{report}");
    }
    if args.flag("deny-warnings") && !report.warnings.is_empty() {
        eprintln!(
            "audit: {} warning(s) denied by --deny-warnings",
            report.warnings.len()
        );
        return Ok(2);
    }
    Ok(0)
}

fn cmd_simulate(args: &Args) -> Result<i32> {
    let strat: StrategyKind = args.get_or("strategy", "layerwise").parse()?;
    let mut p = planner_from_args(args)?;
    if let Some(path) = args.get("trace") {
        // export the simulated schedule as a Chrome trace
        use optcnn::cost::CostModel;
        use optcnn::sim::trace;
        let s = p.strategy(strat)?;
        let cm = CostModel::new(p.graph(), p.device_graph());
        let events = trace::trace_events(p.graph(), p.device_graph(), &s, &cm);
        std::fs::write(path, trace::to_chrome_trace(&events))
            .map_err(|e| OptError::Io(format!("writing {path}: {e}")))?;
        println!("wrote {} trace events to {path} (open in chrome://tracing)", events.len());
    }
    let eval = p.evaluate(strat)?;
    println!("{} on {} device(s), strategy={strat}", p.network(), p.num_devices());
    println!("  estimate (Eq.1): {}", fmt_secs(eval.estimate));
    println!("  simulated step:  {}", fmt_secs(eval.sim.step_time));
    println!("  throughput:      {:.0} images/s", eval.throughput);
    println!("  utilization:     {:.1}%", eval.sim.utilization() * 100.0);
    println!(
        "  comm: {} ({} tensor moves + {} param sync)",
        fmt_bytes(eval.comm.total()),
        fmt_bytes(eval.comm.xfer_bytes),
        fmt_bytes(eval.comm.sync_bytes)
    );
    let peak = eval.peak_mem();
    match p.mem_limit() {
        Some(b) => println!(
            "  peak memory:     {} / {} budget per device",
            fmt_bytes(peak),
            fmt_bytes(b as f64)
        ),
        None => println!("  peak memory:     {} per device (no budget)", fmt_bytes(peak)),
    }
    Ok(0)
}

/// Materialize a strategy into an `ExecutionPlan`, print its per-layer
/// partitioning and transfer schedule summary, and optionally export the
/// plan as JSON (`--out plan.json`) — the servable-artifact workflow.
fn cmd_plan(args: &Args) -> Result<i32> {
    use optcnn::util::benchkit::time_once;
    let strat: StrategyKind = args.get_or("strategy", "layerwise").parse()?;
    let mut p = planner_from_args(args)?;
    // resolve the strategy first so the cold timing measures plan
    // materialization alone, not the table build + search
    let strategy = p.strategy(strat)?;
    let (plan, cold) = time_once(|| p.plan_for(&strategy));
    let (_, warm) = time_once(|| p.plan_for(&strategy));

    let mut table = Table::new(
        &format!("execution plan: {} x{}, strategy={strat}", p.network(), p.num_devices()),
        &["layer", "op", "config", "tiles", "in-transfers", "sync"],
    );
    for l in &p.graph().layers {
        let lp = plan.layer(l.id);
        let inbound: usize = plan
            .edges
            .iter()
            .filter(|ep| ep.dst == l.id)
            .map(|ep| ep.transfers.iter().filter(|t| t.is_remote()).count())
            .sum();
        let sync = match &lp.sync {
            Some(s) => fmt_bytes(s.bytes()),
            None => "-".to_string(),
        };
        table.row(vec![
            l.name.clone(),
            l.op.mnemonic().to_string(),
            lp.cfg.label(),
            lp.tiles.len().to_string(),
            inbound.to_string(),
            sync,
        ]);
    }
    table.print();
    println!(
        "totals: {} remote transfers, {} tensor movement + {} parameter sync per step",
        plan.num_transfers(),
        fmt_bytes(plan.xfer_bytes()),
        fmt_bytes(plan.sync_bytes())
    );
    match p.mem_limit() {
        Some(b) => println!(
            "memory: {} per-device high water, {} budget",
            fmt_bytes(plan.peak_mem()),
            fmt_bytes(b as f64)
        ),
        None => println!(
            "memory: {} per-device high water (no budget)",
            fmt_bytes(plan.peak_mem())
        ),
    }
    let stats = p.session_stats();
    println!(
        "plan build {} cold, {} from cache ({} hit / {} miss)",
        fmt_secs(cold),
        fmt_secs(warm),
        stats.plan_hits,
        stats.plan_misses
    );
    if let Some(path) = args.get("out") {
        let text = plan.to_json().to_string();
        std::fs::write(path, &text)
            .map_err(|e| OptError::Io(format!("writing {path}: {e}")))?;
        println!("wrote plan ({} bytes of JSON) to {path}", text.len());
    }
    Ok(0)
}

/// Statically verify an exported plan artifact: re-derive its tiles,
/// transfers, sync groups, memory peaks, and cost from the (network,
/// cluster) context and demand exact agreement (DESIGN.md §10). The
/// network defaults to the plan's recorded net name and the cluster to
/// the P100 preset at the plan's recorded device count;
/// `--network`/`--network-file` and `--devices`/`--cluster` override. A
/// violated invariant exits 2 with `invalid plan [check-name]: ...`.
fn cmd_verify(args: &Args) -> Result<i32> {
    use optcnn::cost::CostModel;
    use optcnn::plan::ExecutionPlan;
    use optcnn::util::json::Json;
    use optcnn::verify::verify_plan;

    let Some(path) = args.positional.first() else {
        return Err(OptError::InvalidArgument(
            "verify requires a plan file: `optcnn verify plan.json`".into(),
        ));
    };
    if args.get("batch").is_some() {
        return Err(OptError::InvalidArgument(
            "verify reads the batch off the plan's own input tiling; --batch does not apply"
                .into(),
        ));
    }
    let text = std::fs::read_to_string(path)
        .map_err(|e| OptError::Io(format!("reading {path}: {e}")))?;
    let doc = Json::parse(&text)
        .map_err(|e| OptError::InvalidArgument(format!("{path}: malformed JSON: {e}")))?;
    let plan = ExecutionPlan::from_json(&doc)
        .map_err(|e| OptError::InvalidArgument(format!("{path}: {e}")))?;

    let network = match network_from_args(args)? {
        Some(spec) => spec,
        None => NetworkSpec::Preset(plan.net.parse().map_err(|_| {
            OptError::InvalidArgument(format!(
                "plan records net `{}`, which is not a builtin preset; pass --network \
                 or --network-file to name the graph to verify against",
                plan.net
            ))
        })?),
    };
    let cluster = match args.get("cluster") {
        Some(file) => {
            if args.get("devices").is_some() {
                return Err(OptError::InvalidArgument(
                    "--devices and --cluster are mutually exclusive".into(),
                ));
            }
            ClusterSpec::load(file)?
        }
        None => ClusterSpec::p100(args.usize_or("devices", plan.ndev)?)?,
    };
    let devices = cluster.device_graph()?;
    // presets are rebuilt at the plan's own global batch (read off its
    // input tiling); a custom spec carries its batch in the document
    let global = match network.fixed_batch() {
        Some(batch) => batch,
        None => plan.global_batch().ok_or_else(|| {
            OptError::InvalidArgument(format!(
                "{path}: plan has no layer tiles to read a batch size from"
            ))
        })?,
    };
    let graph = network.build_graph(global)?;
    let cm = CostModel::new(&graph, &devices);
    let report = verify_plan(&cm, &plan)?;
    print!("{report}");
    println!(
        "{path}: plan verifies clean against {} x{} (batch {})",
        graph.name,
        devices.num_devices(),
        global
    );
    Ok(0)
}

/// Export, validate, and render `GraphSpec` documents: the round-trip
/// tooling for custom networks. `--network <preset> --batch <global>`
/// builds a builtin at an explicit global batch; `--network-file` loads
/// (and thereby fully validates) an arbitrary spec. `--out` writes the
/// spec JSON, `--dot` a Graphviz rendering, `--validate` just reports.
fn cmd_graph(args: &Args) -> Result<i32> {
    let network = match network_from_args(args)? {
        Some(spec @ NetworkSpec::Preset(_)) => {
            // a spec records a concrete global batch; default to the
            // paper's 32 x 4 devices
            spec.build_graph(args.usize_or("batch", 128)?)?
        }
        Some(NetworkSpec::Custom(g)) => g,
        None => {
            return Err(OptError::InvalidArgument(
                "graph requires --network <preset> or --network-file <spec.json>".into(),
            ));
        }
    };
    println!(
        "{}: {} layers, {} edges, {} params, {:.1} GFLOP/step, batch {}, digest {}",
        network.name,
        network.num_layers(),
        network.num_edges(),
        network.total_params(),
        network.total_train_flops() / 1e9,
        network.batch(),
        network.digest()
    );
    if args.flag("validate") {
        // loading already ran the full validation; say so explicitly
        println!("valid: structural and shape invariants hold");
    }
    if let Some(path) = args.get("out") {
        let text = network.to_spec().to_string();
        std::fs::write(path, &text)
            .map_err(|e| OptError::Io(format!("writing {path}: {e}")))?;
        println!("wrote spec ({} bytes of JSON) to {path}", text.len());
    }
    if let Some(path) = args.get("dot") {
        let dot = network.to_dot();
        std::fs::write(path, &dot)
            .map_err(|e| OptError::Io(format!("writing {path}: {e}")))?;
        println!("wrote DOT graph to {path} (render with `dot -Tsvg`)");
    }
    Ok(0)
}

/// The Figure 7/8 grid, fanned across a thread pool. Every worker pulls
/// grid cells from an atomic cursor and answers them through one shared
/// `PlanService`, so the four strategies of a (network, ndev) cell reuse
/// a single cost-table build and warm plans are cache hits regardless of
/// which worker primed them.
fn cmd_sweep(args: &Args) -> Result<i32> {
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::OnceLock;

    use optcnn::planner::{PlanRequest, PlanService};

    // the preset default only applies when no network was named at all:
    // `sweep --network-file x.json` sweeps just that graph, not three
    // unrequested presets on top
    let mut networks: Vec<NetworkSpec> =
        match (args.get("networks"), args.get("network-file")) {
            (None, Some(_)) => Vec::new(),
            _ => args
                .list_or::<Network>("networks", "alexnet,vgg16,inception_v3")?
                .into_iter()
                .map(NetworkSpec::Preset)
                .collect(),
        };
    if let Some(path) = args.get("network-file") {
        // a custom network sweeps like any preset; its fixed global
        // batch is simply replanned across each device count
        networks.push(NetworkSpec::from_spec_file(path)?);
    }
    let devices: Vec<usize> = args.list_or("devices", "1,2,4,8,16")?;
    let default_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.usize_or("threads", default_threads)?.max(1);
    // sweeps run on the P100 preset, so `device` means the P100's 16 GB
    let mem_limit: Option<u64> = match args.get("mem-limit") {
        None => None,
        Some("device") => Some(optcnn::device::ComputeModel::p100().hbm_bytes as u64),
        Some(v) => Some(parse_mem_bytes(v)?),
    };

    let mut grid: Vec<(NetworkSpec, usize, StrategyKind)> = Vec::new();
    for net in &networks {
        for &ndev in &devices {
            for kind in StrategyKind::ALL {
                grid.push((net.clone(), ndev, kind));
            }
        }
    }
    let service = PlanService::builder()
        .prune_dominated(args.flag("prune-dominated"))
        .build()?;
    let cells: Vec<OnceLock<Result<f64>>> = grid.iter().map(|_| OnceLock::new()).collect();
    let cursor = AtomicUsize::new(0);
    // fail fast: once any cell errors (e.g. a device count the preset
    // cannot shape), workers stop picking up new cells instead of
    // grinding through the rest of the grid first
    let failed = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(grid.len()) {
            scope.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                let Some((net, ndev, kind)) = grid.get(i) else { break };
                let (ndev, kind) = (*ndev, *kind);
                let r = PlanRequest::new(net.clone(), ndev)
                    .map(|req| match mem_limit {
                        Some(b) => req.strategy(kind).mem_limit(b),
                        None => req.strategy(kind),
                    })
                    .and_then(|req| service.evaluate(&req))
                    .map(|eval| eval.throughput);
                if r.is_err() {
                    failed.store(true, Ordering::Relaxed);
                }
                let _ = cells[i].set(r);
            });
        }
    });
    if failed.load(Ordering::Relaxed) {
        // surface the first error in grid order
        for cell in &cells {
            if let Some(Err(e)) = cell.get() {
                return Err(e.clone());
            }
        }
    }

    let mut i = 0;
    for net in &networks {
        let budget = match mem_limit {
            Some(b) => format!(", {} budget", fmt_bytes(b as f64)),
            None => String::new(),
        };
        let mut table = Table::new(
            &format!("{net}: simulated throughput (images/s){budget}"),
            &["GPUs", "data", "model", "owt", "layerwise"],
        );
        for &ndev in &devices {
            let mut row = vec![ndev.to_string()];
            for _ in StrategyKind::ALL {
                let cell = cells[i].get().cloned().unwrap_or_else(|| {
                    Err(OptError::InvalidArgument(
                        "sweep worker exited before filling its cell".into(),
                    ))
                })?;
                row.push(format!("{cell:.0}"));
                i += 1;
            }
            table.row(row);
        }
        if args.flag("csv") {
            print!("{}", table.to_csv());
        } else {
            table.print();
        }
    }
    Ok(0)
}

/// Serve plan/evaluate requests over TCP: one JSON request per line, one
/// JSON reply per line (see `optcnn::planner::serve` for the protocol).
fn cmd_serve(args: &Args) -> Result<i32> {
    use optcnn::planner::{serve, PlanService};
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let shards = args.usize_or("shards", 8)?;
    let cap = args.usize_or("cache-cap", 8)?;
    let build_threads = args.usize_or("build-threads", 0)?;
    let verify_loaded = !args.flag("no-verify");
    let defaults = serve::ServeOptions::default();
    let opts = serve::ServeOptions {
        workers: args.usize_or("workers", defaults.workers)?,
        queue_cap: args.usize_or("queue-cap", defaults.queue_cap)?,
        max_conns: args.usize_or("max-conns", defaults.max_conns)?,
        request_timeout: match args.get("request-timeout") {
            None => defaults.request_timeout,
            Some(ms) => std::time::Duration::from_millis(ms.parse().map_err(|_| {
                OptError::InvalidArgument(format!(
                    "--request-timeout: expected milliseconds, got `{ms}`"
                ))
            })?),
        },
    };
    let mut builder = PlanService::builder()
        .shards(shards)
        .shard_capacity(cap)
        .build_threads(build_threads)
        .verify_loaded(verify_loaded)
        .prune_dominated(args.flag("prune-dominated"));
    if let Some(dir) = args.get("plan-store") {
        builder = builder.plan_store(dir);
    }
    let service = Arc::new(builder.build()?);
    let handle = serve::spawn_opts(addr, service, opts)?;
    println!(
        "optcnn serve: listening on {} ({shards} shards x {cap} plans)",
        handle.local_addr()
    );
    if let Some(dir) = args.get("plan-store") {
        println!("plan store: {dir} (content-addressed, verified on load)");
    }
    println!("protocol: one JSON request per line, e.g.");
    println!(r#"  {{"net":"alexnet","devices":4,"strategy":"layerwise","want":"evaluate"}}"#);
    println!(r#"  optional "mem_limit": <bytes/device> bounds the layer-wise search"#);
    println!(r#"  {{"want":"analyze",...}} reports the pre-planning static analysis"#);
    println!(r#"  {{"want":"audit",...}} audits the cost tables + cross-checks backends"#);
    println!(r#"  {{"want":"stats"}} / {{"want":"metrics"}} report counters + latency"#);
    if verify_loaded {
        println!(r#"  {{"want":"verify","plan":{{...}}}} checks a plan before caching it"#);
    } else {
        println!("  --no-verify: posted plans are admitted unchecked");
    }
    handle.join();
    Ok(0)
}

fn cmd_train(args: &Args) -> Result<i32> {
    let steps = args.usize_or("steps", 100)?;
    let ndev = args.usize_or("devices", 4)?;
    let strat: StrategyKind = args.get_or("strategy", "layerwise").parse()?;
    let lr = args.f64_or("lr", 0.01)? as f32;
    let dir = args.get_or("artifacts", "artifacts");
    let store = match ArtifactStore::load(dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e:#}");
            return Ok(1);
        }
    };
    let batch = store.batch;
    if ndev == 0 || batch % ndev != 0 {
        return Err(OptError::InvalidArgument(format!(
            "--devices {ndev} must divide the artifact batch {batch}"
        )));
    }
    let mut p = Planner::builder(Network::MiniCnn)
        .devices(ndev)
        .per_gpu_batch(batch / ndev)
        .build()?;
    let strategy = p.strategy(strat)?;
    println!("training minicnn: batch={batch} devices={ndev} strategy={strat} lr={lr}");
    let g = Network::MiniCnn.graph(batch)?;
    let mut trainer = match Trainer::new(&store, g, strategy, ndev, lr, 42) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e:#}");
            return Ok(1);
        }
    };
    let ds = SyntheticDataset::new(10, 3, 32, 32, 0.3, 7);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (x, y) = ds.batch(step % 16, batch);
        match trainer.step(&x, &y) {
            Ok(loss) => {
                if step % 10 == 0 || step + 1 == steps {
                    println!("step {step:>4}  loss {loss:.4}");
                }
            }
            Err(e) => {
                eprintln!("step {step}: {e:#}");
                return Ok(1);
            }
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} steps in {:.1}s ({:.1} img/s CPU-interpret), comm {} ({} sync)",
        steps,
        dt,
        (steps * batch) as f64 / dt,
        fmt_bytes(trainer.comm.total() as f64),
        fmt_bytes(trainer.comm.sync_bytes as f64)
    );
    println!(
        "planned p2p volume: {}/step ({} tensor + {} sync; matches `optcnn simulate`)",
        fmt_bytes(trainer.plan_comm.total() as f64),
        fmt_bytes(trainer.plan_comm.xfer_bytes as f64),
        fmt_bytes(trainer.plan_comm.sync_bytes as f64)
    );
    Ok(0)
}

fn cmd_info(args: &Args) -> Result<i32> {
    println!("networks:");
    for n in Network::ALL {
        let g = n.graph(32)?;
        println!(
            "  {:<14} {:>4} layers  {:>12} params  {:>8.1} GFLOP/step(b=32)",
            n.name(),
            g.num_layers(),
            g.total_params(),
            g.total_train_flops() / 1e9
        );
    }
    let dir = args.get_or("artifacts", "artifacts");
    match ArtifactStore::load(dir) {
        Ok(s) => println!(
            "artifacts: {} entries at {} (batch={}, devices={})",
            s.len(),
            dir,
            s.batch,
            s.devices
        ),
        Err(_) => println!("artifacts: none at `{dir}` (run `make artifacts`)"),
    }
    Ok(0)
}

/// The paper's measured-`t_C` mode: profile every (layer, configuration)
/// of MiniCNN by executing its artifacts, then run the search on the
/// measured tables and compare against the analytic optimum.
fn cmd_profile(args: &Args) -> Result<i32> {
    use optcnn::cost::{profile, CostModel, CostTables};
    let ndev = args.usize_or("devices", 4)?;
    let reps = args.usize_or("reps", 3)?;
    let dir = args.get_or("artifacts", "artifacts");
    let store = match ArtifactStore::load(dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e:#}");
            return Ok(1);
        }
    };
    let g = Network::MiniCnn.graph(store.batch)?;
    let d = ClusterSpec::p100(ndev)?.device_graph()?;
    let cm = CostModel::new(&g, &d);
    println!("profiling minicnn artifacts ({reps} reps per config)...");
    let measured = match profile::profile_graph(&store, &g, &cm, ndev, reps) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return Ok(1);
        }
    };
    let analytic = optcnn::optimizer::optimize(&CostTables::build(&cm, ndev)?);
    let mut cm_measured = CostModel::new(&g, &d);
    cm_measured.measured_tc = Some(measured);
    let profiled = optcnn::optimizer::optimize(&CostTables::build(&cm_measured, ndev)?);
    let mut table = Table::new(
        &format!("minicnn on {ndev} devices: analytic vs measured-t_C optimum"),
        &["layer", "analytic", "measured"],
    );
    for l in &g.layers {
        table.row(vec![
            l.name.clone(),
            analytic.strategy.config(l.id).label(),
            profiled.strategy.config(l.id).label(),
        ]);
    }
    table.print();
    println!(
        "estimated step: analytic {}, measured-calibrated {}",
        fmt_secs(analytic.cost),
        fmt_secs(profiled.cost)
    );
    Ok(0)
}

fn cmd_run(args: &Args) -> Result<i32> {
    let Some(path) = args.get("config") else {
        return Err(OptError::InvalidArgument("run requires --config <file.toml>".into()));
    };
    let cfg = ExperimentConfig::load(path)?;
    let mut p = cfg.planner()?;
    let eval = p.evaluate(cfg.strategy)?;
    println!(
        "{} x{} ({}): step {} throughput {:.0} img/s comm {}",
        cfg.network,
        cfg.num_devices(),
        cfg.strategy,
        fmt_secs(eval.sim.step_time),
        eval.throughput,
        fmt_bytes(eval.comm.total())
    );
    Ok(0)
}
