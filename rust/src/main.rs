//! `optcnn` — the L3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `optimize`  — run Algorithm 1 and print the per-layer strategy
//! * `simulate`  — evaluate a strategy on the simulated cluster
//! * `plan`      — materialize a strategy's ExecutionPlan (print/export)
//! * `sweep`     — the full Figure 7/8 grid (networks x devices x strategies)
//! * `train`     — real partitioned training of MiniCNN through PJRT
//! * `info`      — networks, artifact status, cluster presets
//!
//! Run `optcnn <cmd> --help-less` with no args for usage.

use optcnn::config::ExperimentConfig;
use optcnn::data::SyntheticDataset;
use optcnn::exec::Trainer;
use optcnn::graph::nets;
use optcnn::pipeline::{Experiment, STRATEGY_NAMES};
use optcnn::runtime::ArtifactStore;
use optcnn::util::cli::Args;
use optcnn::util::table::Table;
use optcnn::util::{fmt_bytes, fmt_secs};

const USAGE: &str = "\
optcnn — layer-wise parallelism for CNN training (ICML'18 reproduction)

USAGE:
  optcnn optimize --network <net> --devices <n>
  optcnn simulate --network <net> --devices <n> --strategy <s>
  optcnn plan     --network <net> --devices <n> [--strategy <s>]
                  [--out plan.json]
  optcnn sweep    [--networks a,b] [--devices 1,2,4,8,16]
  optcnn train    [--steps 100] [--devices 4] [--strategy layerwise]
                  [--lr 0.01] [--artifacts artifacts]
  optcnn profile  [--devices 4] [--reps 3]   (measured-t_C search, minicnn)
  optcnn info
  optcnn run      --config <file.toml>

NETWORKS:   lenet5 alexnet vgg16 inception_v3 resnet18 minicnn
STRATEGIES: data model owt layerwise
";

fn main() {
    let args = Args::parse(std::env::args().skip(1), &["verbose", "csv"]);
    let code = match args.subcommand.as_deref() {
        Some("optimize") => cmd_optimize(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("plan") => cmd_plan(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(&args),
        Some("profile") => cmd_profile(&args),
        Some("run") => cmd_run(&args),
        _ => {
            print!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_optimize(args: &Args) -> i32 {
    let net = args.get_or("network", "vgg16");
    let ndev = args.get_usize("devices", 4);
    let e = Experiment::new(net, ndev);
    let g = e.graph();
    let d = e.devices();
    let t0 = std::time::Instant::now();
    let (strategy, stats) = e.strategy("layerwise", &g, &d);
    let dt = t0.elapsed().as_secs_f64();
    let mut table = Table::new(
        &format!("optimal strategy: {net} on {ndev} GPU(s)"),
        &["layer", "op", "configuration"],
    );
    for l in &g.layers {
        table.row(vec![
            l.name.clone(),
            l.op.mnemonic().to_string(),
            strategy.config(l.id).label(),
        ]);
    }
    table.print();
    let eval = e.evaluate(&g, &d, &strategy);
    let s = stats.unwrap();
    println!(
        "search: {} node elims, {} edge elims, K={}, {:.3}s",
        s.node_eliminations, s.edge_eliminations, s.final_nodes, dt
    );
    println!(
        "estimated step {}  simulated step {}  throughput {:.0} img/s  comm {}/step",
        fmt_secs(eval.estimate),
        fmt_secs(eval.sim.step_time),
        eval.throughput,
        fmt_bytes(eval.comm.total())
    );
    0
}

fn cmd_simulate(args: &Args) -> i32 {
    let net = args.get_or("network", "vgg16");
    let ndev = args.get_usize("devices", 4);
    let strat = args.get_or("strategy", "layerwise");
    let e = Experiment::new(net, ndev);
    if let Some(path) = args.get("trace") {
        // export the simulated schedule as a Chrome trace
        use optcnn::cost::CostModel;
        use optcnn::sim::trace;
        let g = e.graph();
        let d = e.devices();
        let (s, _) = e.strategy(strat, &g, &d);
        let cm = CostModel::new(&g, &d);
        let events = trace::trace_events(&g, &d, &s, &cm);
        if let Err(err) = std::fs::write(path, trace::to_chrome_trace(&events)) {
            eprintln!("writing {path}: {err}");
            return 1;
        }
        println!("wrote {} trace events to {path} (open in chrome://tracing)", events.len());
    }
    let eval = e.run(strat);
    println!("{net} on {ndev} GPU(s), strategy={strat}");
    println!("  estimate (Eq.1): {}", fmt_secs(eval.estimate));
    println!("  simulated step:  {}", fmt_secs(eval.sim.step_time));
    println!("  throughput:      {:.0} images/s", eval.throughput);
    println!("  utilization:     {:.1}%", eval.sim.utilization() * 100.0);
    println!(
        "  comm: {} ({} tensor moves + {} param sync)",
        fmt_bytes(eval.comm.total()),
        fmt_bytes(eval.comm.xfer_bytes),
        fmt_bytes(eval.comm.sync_bytes)
    );
    0
}

/// Materialize a strategy into an `ExecutionPlan`, print its per-layer
/// partitioning and transfer schedule summary, and optionally export the
/// plan as JSON (`--out plan.json`) — the servable-artifact workflow.
fn cmd_plan(args: &Args) -> i32 {
    use optcnn::cost::CostModel;
    use optcnn::plan::PlanCache;
    use optcnn::util::benchkit::time_once;
    let net = args.get_or("network", "vgg16");
    let ndev = args.get_usize("devices", 4);
    let strat = args.get_or("strategy", "layerwise");
    let e = Experiment::new(net, ndev);
    let g = e.graph();
    let d = e.devices();
    let (strategy, _) = e.strategy(strat, &g, &d);
    let cm = CostModel::new(&g, &d);
    let mut cache = PlanCache::default();
    let (plan, cold) = time_once(|| cache.get_or_build(&cm, &strategy));
    let (_, warm) = time_once(|| cache.get_or_build(&cm, &strategy));

    let mut table = Table::new(
        &format!("execution plan: {net} x{ndev}, strategy={strat}"),
        &["layer", "op", "config", "tiles", "in-transfers", "sync"],
    );
    for l in &g.layers {
        let lp = plan.layer(l.id);
        let inbound: usize = plan
            .edges
            .iter()
            .filter(|ep| ep.dst == l.id)
            .map(|ep| ep.transfers.iter().filter(|t| t.is_remote()).count())
            .sum();
        let sync = match &lp.sync {
            Some(s) => fmt_bytes(s.bytes()),
            None => "-".to_string(),
        };
        table.row(vec![
            l.name.clone(),
            l.op.mnemonic().to_string(),
            lp.cfg.label(),
            lp.tiles.len().to_string(),
            inbound.to_string(),
            sync,
        ]);
    }
    table.print();
    println!(
        "totals: {} remote transfers, {} tensor movement + {} parameter sync per step",
        plan.num_transfers(),
        fmt_bytes(plan.xfer_bytes()),
        fmt_bytes(plan.sync_bytes())
    );
    println!(
        "plan build {} cold, {} from cache ({} hit / {} miss)",
        fmt_secs(cold),
        fmt_secs(warm),
        cache.hits,
        cache.misses
    );
    if let Some(path) = args.get("out") {
        let text = plan.to_json().to_string();
        if let Err(err) = std::fs::write(path, &text) {
            eprintln!("writing {path}: {err}");
            return 1;
        }
        println!("wrote plan ({} bytes of JSON) to {path}", text.len());
    }
    0
}

fn cmd_sweep(args: &Args) -> i32 {
    let networks: Vec<String> = args
        .get_or("networks", "alexnet,vgg16,inception_v3")
        .split(',')
        .map(str::to_string)
        .collect();
    let devices: Vec<usize> = args
        .get_or("devices", "1,2,4,8,16")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    for net in &networks {
        let mut table = Table::new(
            &format!("{net}: simulated throughput (images/s)"),
            &[&["GPUs".to_string()], STRATEGY_NAMES.map(String::from).as_slice()]
                .concat()
                .iter()
                .map(String::as_str)
                .collect::<Vec<_>>(),
        );
        for &ndev in &devices {
            let e = Experiment::new(net, ndev);
            let mut row = vec![ndev.to_string()];
            for s in STRATEGY_NAMES {
                row.push(format!("{:.0}", e.run(s).throughput));
            }
            table.row(row);
        }
        if args.flag("csv") {
            print!("{}", table.to_csv());
        } else {
            table.print();
        }
    }
    0
}

fn cmd_train(args: &Args) -> i32 {
    let steps = args.get_usize("steps", 100);
    let ndev = args.get_usize("devices", 4);
    let strat_name = args.get_or("strategy", "layerwise");
    let lr = args.get_f64("lr", 0.01) as f32;
    let dir = args.get_or("artifacts", "artifacts");
    let store = match ArtifactStore::load(dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let batch = store.batch;
    let e = Experiment::new("minicnn", ndev);
    let g = nets::minicnn(batch);
    let d = e.devices();
    let (strategy, _) = Experiment { per_gpu_batch: batch / ndev, ..e.clone() }
        .strategy(strat_name, &g, &d);
    println!("training minicnn: batch={batch} devices={ndev} strategy={strat_name} lr={lr}");
    let mut trainer = match Trainer::new(&store, g, strategy, ndev, lr, 42) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let ds = SyntheticDataset::new(10, 3, 32, 32, 0.3, 7);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (x, y) = ds.batch(step % 16, batch);
        match trainer.step(&x, &y) {
            Ok(loss) => {
                if step % 10 == 0 || step + 1 == steps {
                    println!("step {step:>4}  loss {loss:.4}");
                }
            }
            Err(e) => {
                eprintln!("step {step}: {e:#}");
                return 1;
            }
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} steps in {:.1}s ({:.1} img/s CPU-interpret), comm {} ({} sync)",
        steps,
        dt,
        (steps * batch) as f64 / dt,
        fmt_bytes(trainer.comm.total() as f64),
        fmt_bytes(trainer.comm.sync_bytes as f64)
    );
    println!(
        "planned p2p volume: {}/step ({} tensor + {} sync; matches `optcnn simulate`)",
        fmt_bytes(trainer.plan_comm.total() as f64),
        fmt_bytes(trainer.plan_comm.xfer_bytes as f64),
        fmt_bytes(trainer.plan_comm.sync_bytes as f64)
    );
    0
}

fn cmd_info(args: &Args) -> i32 {
    println!("networks:");
    for n in ["lenet5", "alexnet", "vgg16", "inception_v3", "resnet18", "minicnn"] {
        let g = nets::by_name(n, 32).unwrap();
        println!(
            "  {n:<14} {:>4} layers  {:>12} params  {:>8.1} GFLOP/step(b=32)",
            g.num_layers(),
            g.total_params(),
            g.total_train_flops() / 1e9
        );
    }
    let dir = args.get_or("artifacts", "artifacts");
    match ArtifactStore::load(dir) {
        Ok(s) => println!(
            "artifacts: {} entries at {} (batch={}, devices={})",
            s.len(),
            dir,
            s.batch,
            s.devices
        ),
        Err(_) => println!("artifacts: none at `{dir}` (run `make artifacts`)"),
    }
    0
}

/// The paper's measured-`t_C` mode: profile every (layer, configuration)
/// of MiniCNN by executing its artifacts, then run the search on the
/// measured tables and compare against the analytic optimum.
fn cmd_profile(args: &Args) -> i32 {
    use optcnn::cost::{profile, CostModel, CostTables};
    let ndev = args.get_usize("devices", 4);
    let reps = args.get_usize("reps", 3);
    let dir = args.get_or("artifacts", "artifacts");
    let store = match ArtifactStore::load(dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let g = nets::minicnn(store.batch);
    let d = Experiment::new("minicnn", ndev).devices();
    let cm = CostModel::new(&g, &d);
    println!("profiling minicnn artifacts ({reps} reps per config)...");
    let measured = match profile::profile_graph(&store, &g, &cm, ndev, reps) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let analytic = optcnn::optimizer::optimize(&CostTables::build(&cm, ndev));
    let mut cm_measured = CostModel::new(&g, &d);
    cm_measured.measured_tc = Some(measured);
    let profiled = optcnn::optimizer::optimize(&CostTables::build(&cm_measured, ndev));
    let mut table = Table::new(
        &format!("minicnn on {ndev} devices: analytic vs measured-t_C optimum"),
        &["layer", "analytic", "measured"],
    );
    for l in &g.layers {
        table.row(vec![
            l.name.clone(),
            analytic.strategy.config(l.id).label(),
            profiled.strategy.config(l.id).label(),
        ]);
    }
    table.print();
    println!(
        "estimated step: analytic {}, measured-calibrated {}",
        fmt_secs(analytic.cost),
        fmt_secs(profiled.cost)
    );
    0
}

fn cmd_run(args: &Args) -> i32 {
    let Some(path) = args.get("config") else {
        eprintln!("run requires --config <file.toml>");
        return 2;
    };
    let cfg = match ExperimentConfig::load(path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let e = Experiment {
        network: cfg.network.clone(),
        ndev: cfg.num_devices(),
        per_gpu_batch: cfg.per_gpu_batch,
    };
    let g = e.graph();
    let d = cfg.device_graph();
    let (strategy, _) = e.strategy(&cfg.strategy, &g, &d);
    let eval = e.evaluate(&g, &d, &strategy);
    println!(
        "{} x{} ({}): step {} throughput {:.0} img/s comm {}",
        cfg.network,
        cfg.num_devices(),
        cfg.strategy,
        fmt_secs(eval.sim.step_time),
        eval.throughput,
        fmt_bytes(eval.comm.total())
    );
    0
}
