//! Mini property-based testing framework.
//!
//! The offline registry carries no `proptest`; this module provides the
//! subset the test-suite needs: seeded generators, a `forall` runner with
//! iteration counts, and failure reporting that prints the seed so a
//! failing case replays deterministically.
//!
//! ```
//! use optcnn::prop::{forall, Gen};
//! forall("addition commutes", 100, |g| {
//!     let (a, b) = (g.usize_in(0, 1000), g.usize_in(0, 1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// A generation context handed to each property iteration.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    /// A divisor of `n`, uniform over its divisors.
    pub fn divisor_of(&mut self, n: usize) -> usize {
        let divs: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
        *self.rng.choose(&divs)
    }

    /// A vector of `len` values built by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Access the underlying RNG for anything else.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `body` for `cases` generated inputs. Panics (with the replay seed)
/// on the first failing case. The base seed is fixed for reproducibility;
/// set `OPTCNN_PROP_SEED` to explore a different stream, or to a failing
/// case's printed seed to replay just that case.
pub fn forall(name: &str, cases: usize, body: impl Fn(&mut Gen)) {
    let base: u64 = std::env::var("OPTCNN_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x0C0FFEE);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case} (replay: OPTCNN_PROP_SEED={seed} \
                 with cases=1)"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("reverse twice is identity", 50, |g| {
            let v = g.vec(g.case % 10, |g| g.usize_in(0, 100));
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic]
    fn forall_reports_failures() {
        forall("all numbers are even (false)", 50, |g| {
            let n = g.usize_in(0, 100);
            assert_eq!(n % 2, 0);
        });
    }

    #[test]
    fn divisor_of_divides() {
        forall("divisor_of returns divisors", 200, |g| {
            let n = g.usize_in(1, 300);
            let d = g.divisor_of(n);
            assert_eq!(n % d, 0);
        });
    }
}
