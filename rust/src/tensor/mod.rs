//! Dense f32 tensor substrate.
//!
//! The partitioned-training executor (`exec/`) moves shards of activations,
//! gradients, and parameters between simulated devices; this module gives it
//! slicing (region extract/insert), concatenation, padding, and reduction
//! over row-major dense tensors. Deliberately minimal — the heavy numerics
//! run inside AOT-compiled HLO; Rust only repartitions.

mod region;

pub use region::Region;

/// A dense row-major f32 tensor of arbitrary rank.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor from an explicit buffer. Panics if sizes disagree.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match buffer of {} elements",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Build element-wise from the multi-index.
    pub fn from_fn(shape: &[usize], mut f: impl FnMut(&[usize]) -> f32) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let mut idx = vec![0usize; shape.len()];
        for flat in 0..t.data.len() {
            t.data[flat] = f(&idx);
            // advance multi-index (row-major, last dim fastest)
            for d in (0..shape.len()).rev() {
                idx[d] += 1;
                if idx[d] < shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape without copying. Panics if element counts disagree.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            self.data.len(),
            shape.iter().product::<usize>(),
            "reshape {:?} -> {:?} changes element count",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for d in (0..self.shape.len().saturating_sub(1)).rev() {
            s[d] = s[d + 1] * self.shape[d + 1];
        }
        s
    }

    /// Extract the sub-tensor covered by `region` (must lie inside shape).
    pub fn slice(&self, region: &Region) -> Tensor {
        assert_eq!(region.rank(), self.rank(), "region rank mismatch");
        for d in 0..self.rank() {
            assert!(
                region.end(d) <= self.shape[d] && region.start(d) <= region.end(d),
                "region {:?} out of bounds for shape {:?}",
                region,
                self.shape
            );
        }
        let out_shape = region.extents();
        let mut out = Tensor::zeros(&out_shape);
        copy_region(
            &self.data,
            &self.shape,
            region,
            &mut out.data,
            &out_shape,
            &Region::full(&out_shape),
        );
        out
    }

    /// Write `src` into the positions covered by `region`. `src`'s shape
    /// must equal the region extents.
    pub fn insert(&mut self, region: &Region, src: &Tensor) {
        assert_eq!(region.extents(), src.shape, "insert extents mismatch");
        let shape = self.shape.clone();
        copy_region(
            &src.data,
            &src.shape,
            &Region::full(&src.shape),
            &mut self.data,
            &shape,
            region,
        );
    }

    /// Accumulate `src` into the positions covered by `region`
    /// (element-wise add). Used for halo-gradient scatter where adjacent
    /// tiles' input regions overlap.
    pub fn insert_add(&mut self, region: &Region, src: &Tensor) {
        assert_eq!(region.extents(), src.shape, "insert_add extents mismatch");
        // walk the region rows like copy_region but accumulate
        let rank = self.rank();
        if rank == 0 {
            self.data[0] += src.data[0];
            return;
        }
        let extents = region.extents();
        let dst_strides = self.strides();
        let row = extents[rank - 1];
        let outer: usize = extents[..rank - 1].iter().product();
        let mut idx = vec![0usize; rank - 1];
        let mut s_off = 0usize;
        for _ in 0..outer.max(1) {
            let mut d_off = region.start(rank - 1);
            for d in 0..rank - 1 {
                d_off += (region.start(d) + idx[d]) * dst_strides[d];
            }
            // slice-window add: bounds-checked once, vectorizes
            let dst_row = &mut self.data[d_off..d_off + row];
            let src_row = &src.data[s_off..s_off + row];
            for (a, b) in dst_row.iter_mut().zip(src_row) {
                *a += b;
            }
            s_off += row;
            for d in (0..rank - 1).rev() {
                idx[d] += 1;
                if idx[d] < extents[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }

    /// Concatenate tensors along `axis`. All shapes must agree on the other
    /// dimensions.
    pub fn concat(parts: &[&Tensor], axis: usize) -> Tensor {
        assert!(!parts.is_empty());
        let rank = parts[0].rank();
        let mut out_shape = parts[0].shape.clone();
        out_shape[axis] = parts.iter().map(|p| p.shape[axis]).sum();
        for p in parts {
            assert_eq!(p.rank(), rank);
            for d in 0..rank {
                if d != axis {
                    assert_eq!(p.shape[d], parts[0].shape[d], "concat dim {d} mismatch");
                }
            }
        }
        let mut out = Tensor::zeros(&out_shape);
        let mut offset = 0usize;
        for p in parts {
            let mut region = Region::full(&out_shape);
            region.set(axis, offset, offset + p.shape[axis]);
            out.insert(&region, p);
            offset += p.shape[axis];
        }
        out
    }

    /// Element-wise in-place add. Shapes must match.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// Scale every element in place.
    pub fn scale(&mut self, k: f32) {
        for a in self.data.iter_mut() {
            *a *= k;
        }
    }

    /// Sum of all elements (f64 accumulator).
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    /// Maximum absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// True when all elements are within `tol` of `other`.
    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= tol
    }
}

/// Copy `src_region` of `src` into `dst_region` of `dst`. The two regions
/// must have identical extents. Inner-most contiguous runs are copied with
/// `copy_from_slice`.
fn copy_region(
    src: &[f32],
    src_shape: &[usize],
    src_region: &Region,
    dst: &mut [f32],
    dst_shape: &[usize],
    dst_region: &Region,
) {
    assert_eq!(src_region.extents(), dst_region.extents());
    let rank = src_shape.len();
    if rank == 0 {
        dst[0] = src[0];
        return;
    }
    let extents = src_region.extents();
    let src_strides = strides_of(src_shape);
    let dst_strides = strides_of(dst_shape);
    // Iterate over all but the last dimension; copy rows of the last dim.
    let row = extents[rank - 1];
    let outer: usize = extents[..rank - 1].iter().product();
    let mut idx = vec![0usize; rank - 1];
    for _ in 0..outer.max(1) {
        let mut s_off = src_region.start(rank - 1);
        let mut d_off = dst_region.start(rank - 1);
        for d in 0..rank - 1 {
            s_off += (src_region.start(d) + idx[d]) * src_strides[d];
            d_off += (dst_region.start(d) + idx[d]) * dst_strides[d];
        }
        dst[d_off..d_off + row].copy_from_slice(&src[s_off..s_off + row]);
        for d in (0..rank - 1).rev() {
            idx[d] += 1;
            if idx[d] < extents[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * shape[d + 1];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iota(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|i| i as f32).collect())
    }

    #[test]
    fn slice_extracts_expected_block() {
        // 2x4 matrix, take columns 1..3
        let t = iota(&[2, 4]);
        let r = Region::new(&[(0, 2), (1, 3)]);
        let s = t.slice(&r);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[1.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn insert_then_slice_roundtrips() {
        let mut t = Tensor::zeros(&[3, 3, 3]);
        let r = Region::new(&[(1, 3), (0, 2), (2, 3)]);
        let block = iota(&[2, 2, 1]);
        t.insert(&r, &block);
        assert_eq!(t.slice(&r), block);
        // untouched corner stays zero
        assert_eq!(t.data()[0], 0.0);
    }

    #[test]
    fn insert_add_accumulates_overlaps() {
        let mut t = Tensor::zeros(&[4]);
        let block = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        t.insert_add(&Region::new(&[(0, 2)]), &block);
        t.insert_add(&Region::new(&[(1, 3)]), &block);
        assert_eq!(t.data(), &[1.0, 3.0, 2.0, 0.0]);
    }

    #[test]
    fn insert_add_rank2() {
        let mut t = Tensor::zeros(&[2, 3]);
        let block = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        t.insert_add(&Region::new(&[(0, 2), (1, 3)]), &block);
        t.insert_add(&Region::new(&[(0, 2), (0, 2)]), &block);
        assert_eq!(t.data(), &[1.0, 2.0, 1.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn concat_axis0_and_axis1() {
        let a = iota(&[1, 2]);
        let b = Tensor::from_vec(&[1, 2], vec![10.0, 11.0]);
        let c0 = Tensor::concat(&[&a, &b], 0);
        assert_eq!(c0.shape(), &[2, 2]);
        assert_eq!(c0.data(), &[0.0, 1.0, 10.0, 11.0]);
        let c1 = Tensor::concat(&[&a, &b], 1);
        assert_eq!(c1.shape(), &[1, 4]);
        assert_eq!(c1.data(), &[0.0, 1.0, 10.0, 11.0]);
    }

    #[test]
    fn split_into_equal_tiles_reassembles() {
        // emulate a 2-way sample split + reassemble
        let t = iota(&[4, 3]);
        let top = t.slice(&Region::new(&[(0, 2), (0, 3)]));
        let bot = t.slice(&Region::new(&[(2, 4), (0, 3)]));
        assert_eq!(Tensor::concat(&[&top, &bot], 0), t);
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = iota(&[2, 2]);
        let b = iota(&[2, 2]);
        a.add_assign(&b);
        a.scale(0.5);
        assert_eq!(a.data(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn from_fn_multi_index() {
        let t = Tensor::from_fn(&[2, 3], |ix| (ix[0] * 10 + ix[1]) as f32);
        assert_eq!(t.data(), &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
    }

    #[test]
    fn allclose_tolerance() {
        let a = iota(&[2, 2]);
        let mut b = iota(&[2, 2]);
        b.data_mut()[3] += 1e-4;
        assert!(a.allclose(&b, 1e-3));
        assert!(!a.allclose(&b, 1e-5));
    }

    #[test]
    #[should_panic]
    fn slice_out_of_bounds_panics() {
        let t = iota(&[2, 2]);
        t.slice(&Region::new(&[(0, 3), (0, 2)]));
    }

    #[test]
    fn rank4_nchw_slice() {
        // NCHW tensor: slice channel 1 of sample 0
        let t = iota(&[2, 2, 2, 2]);
        let s = t.slice(&Region::new(&[(0, 1), (1, 2), (0, 2), (0, 2)]));
        assert_eq!(s.shape(), &[1, 1, 2, 2]);
        assert_eq!(s.data(), &[4.0, 5.0, 6.0, 7.0]);
    }
}
