//! Hyper-rectangular regions over tensor index space.
//!
//! Regions are the common currency between the partitioner (`parallel/`),
//! the cost model (bytes moved = overlap volume), and the executor
//! (slice/insert). Half-open ranges `[start, end)` per dimension.

/// A half-open hyper-rectangle `[start_d, end_d)` for each dimension `d`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Region {
    ranges: Vec<(usize, usize)>,
}

impl Region {
    pub fn new(ranges: &[(usize, usize)]) -> Region {
        for &(s, e) in ranges {
            assert!(s <= e, "range start {s} > end {e}");
        }
        Region { ranges: ranges.to_vec() }
    }

    /// The full region of a tensor shape.
    pub fn full(shape: &[usize]) -> Region {
        Region { ranges: shape.iter().map(|&n| (0, n)).collect() }
    }

    pub fn rank(&self) -> usize {
        self.ranges.len()
    }

    pub fn start(&self, d: usize) -> usize {
        self.ranges[d].0
    }

    pub fn end(&self, d: usize) -> usize {
        self.ranges[d].1
    }

    pub fn set(&mut self, d: usize, start: usize, end: usize) {
        assert!(start <= end);
        self.ranges[d] = (start, end);
    }

    /// Per-dimension sizes.
    pub fn extents(&self) -> Vec<usize> {
        self.ranges.iter().map(|&(s, e)| e - s).collect()
    }

    /// Number of index points covered.
    pub fn volume(&self) -> usize {
        self.ranges.iter().map(|&(s, e)| e - s).product()
    }

    pub fn is_degenerate(&self) -> bool {
        self.ranges.iter().any(|&(s, e)| s == e)
    }

    /// Intersection with another region of the same rank; `None` when empty.
    pub fn intersect(&self, other: &Region) -> Option<Region> {
        assert_eq!(self.rank(), other.rank());
        let mut ranges = Vec::with_capacity(self.rank());
        for d in 0..self.rank() {
            let s = self.start(d).max(other.start(d));
            let e = self.end(d).min(other.end(d));
            if s >= e {
                return None;
            }
            ranges.push((s, e));
        }
        Some(Region { ranges })
    }

    /// Volume of the intersection (0 when disjoint). Cheaper than
    /// `intersect().map(volume)` on the cost-model hot path: no allocation.
    pub fn overlap_volume(&self, other: &Region) -> usize {
        debug_assert_eq!(self.rank(), other.rank());
        let mut v: usize = 1;
        for d in 0..self.rank() {
            let s = self.start(d).max(other.start(d));
            let e = self.end(d).min(other.end(d));
            if s >= e {
                return 0;
            }
            v *= e - s;
        }
        v
    }

    /// True when `other` is fully inside `self`.
    pub fn contains(&self, other: &Region) -> bool {
        assert_eq!(self.rank(), other.rank());
        (0..self.rank()).all(|d| self.start(d) <= other.start(d) && other.end(d) <= self.end(d))
    }

    /// Translate `other`'s coordinates into this region's local frame
    /// (subtract `self.start`). Panics unless contained.
    pub fn localize(&self, other: &Region) -> Region {
        assert!(self.contains(other), "{other:?} not contained in {self:?}");
        Region {
            ranges: (0..self.rank())
                .map(|d| (other.start(d) - self.start(d), other.end(d) - self.start(d)))
                .collect(),
        }
    }
}

impl std::fmt::Display for Region {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, (s, e)) in self.ranges.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{s}:{e}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_and_extents() {
        let r = Region::new(&[(0, 2), (1, 4)]);
        assert_eq!(r.volume(), 6);
        assert_eq!(r.extents(), vec![2, 3]);
    }

    #[test]
    fn intersect_overlapping() {
        let a = Region::new(&[(0, 4), (0, 4)]);
        let b = Region::new(&[(2, 6), (1, 3)]);
        let i = a.intersect(&b).unwrap();
        assert_eq!(i, Region::new(&[(2, 4), (1, 3)]));
        assert_eq!(a.overlap_volume(&b), 4);
    }

    #[test]
    fn intersect_disjoint_is_none() {
        let a = Region::new(&[(0, 2), (0, 2)]);
        let b = Region::new(&[(2, 4), (0, 2)]);
        assert!(a.intersect(&b).is_none());
        assert_eq!(a.overlap_volume(&b), 0);
    }

    #[test]
    fn contains_and_localize() {
        let outer = Region::new(&[(2, 8), (4, 10)]);
        let inner = Region::new(&[(3, 5), (4, 6)]);
        assert!(outer.contains(&inner));
        assert_eq!(outer.localize(&inner), Region::new(&[(1, 3), (0, 2)]));
    }

    #[test]
    fn full_covers_shape() {
        let r = Region::full(&[3, 5, 7]);
        assert_eq!(r.volume(), 105);
        assert!(!r.is_degenerate());
    }

    #[test]
    fn display_is_compact() {
        assert_eq!(Region::new(&[(0, 2), (3, 9)]).to_string(), "[0:2, 3:9]");
    }
}
