//! Static verification of execution plans.
//!
//! Plans are exchange artifacts: they ride the wire (`optcnn serve`),
//! land on disk (`optcnn plan --out`), and get hand-edited or version-
//! skewed along the way. Nothing downstream — simulator, executor, cost
//! accounting — defends against a plan whose *numbers* are wrong but
//! whose *structure* parses: the JSON layer only proves indexes are in
//! range. [`verify_plan`] closes that gap with a static analysis pass
//! over ([`ExecutionPlan`], [`CompGraph`](crate::graph::CompGraph),
//! [`DeviceGraph`](crate::device::DeviceGraph)) that, without executing
//! anything, proves the typed invariant list in
//! [`PlanCheck`](crate::error::PlanCheck) — or reports exactly which
//! invariant broke, via [`OptError::InvalidPlan`]:
//!
//! 1. **tile coverage** — each layer's tiles exactly partition its
//!    output tensor (disjoint, gap-free, in-bounds), and every tile sits
//!    on the device the shared placement function assigns it;
//! 2. **transfer completeness** — every consumer tile's `input_region`
//!    is covered by the edge's transfer schedule plus device-local data,
//!    and no transfer references a device outside `placement_shape()`;
//! 3. **sync-group soundness** — parameter shard groups partition each
//!    layer's parameters with no overlapping or orphaned shards;
//! 4. **memory consistency** — the recorded `peak_mem_per_dev` matches
//!    re-derivation through [`memory::peak_per_device`]
//!    (bit-for-bit — both sides sum the same `tile_bytes` terms in the
//!    same order);
//! 5. **cost coherence** — the recorded `cost_s` equals the cost
//!    model's `t_o` re-derivation, bit-for-bit (f64 round-trips exactly
//!    through the JSON layer).
//!
//! The proof strategy is re-derivation: `ExecutionPlan::build` is a
//! deterministic function of (graph, devices, per-layer configs), and
//! the configs are recorded in the plan itself — so each check recomputes
//! its slice of the plan from first principles and demands exact
//! agreement. A plan that passes all five checks is byte-identical to
//! what `build` would produce, which is the strongest statement the IR
//! admits. The checks run in order and stop at the first violation; by
//! the time checks 4–5 re-derive through `output_tiles`, check 1 has
//! already proven every config's degrees divide the layer extents, so
//! no helper can panic on corrupted input.
//!
//! Wired at every trust boundary: the `optcnn verify` subcommand, the
//! opt-out verify-on-load in `PlanService` plan ingestion, and the
//! `{"want":"verify"}` wire probe (DESIGN.md §10).

#![warn(missing_docs)]

use crate::cost::{shard_of_tile, CostModel};
use crate::error::{OptError, PlanCheck, Result};
use crate::memory;
use crate::parallel::{input_region, output_tiles, param_sharding};
use crate::plan::{overlap, ExecutionPlan, Route, SyncGroup, Transfer};

/// The outcome of one passed check — the invariant plus a short summary
/// of what was proven (counts, totals), for CLI/report output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckReport {
    /// The invariant that held.
    pub check: PlanCheck,
    /// Human-readable statement of what was proven.
    pub summary: String,
}

/// Evidence that a plan passed every static check, in check order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyReport {
    /// One entry per [`PlanCheck`], in the order they ran.
    pub checks: Vec<CheckReport>,
}

impl std::fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for c in &self.checks {
            writeln!(f, "ok {:<22} {}", c.check.name(), c.summary)?;
        }
        Ok(())
    }
}

fn fail(check: PlanCheck, detail: String) -> OptError {
    OptError::InvalidPlan { check, detail }
}

/// Statically prove `plan` is exactly what `ExecutionPlan::build` would
/// materialize for its recorded per-layer configs on `cm`'s (graph,
/// devices) pair — or return [`OptError::InvalidPlan`] naming the first
/// violated [`PlanCheck`]. Executes nothing and allocates only the
/// re-derived expectations.
pub fn verify_plan(cm: &CostModel<'_>, plan: &ExecutionPlan) -> Result<VerifyReport> {
    let mut checks = Vec::with_capacity(PlanCheck::ALL.len());
    checks.push(CheckReport {
        check: PlanCheck::TileCoverage,
        summary: check_tile_coverage(cm, plan)?,
    });
    checks.push(CheckReport {
        check: PlanCheck::TransferCompleteness,
        summary: check_transfer_completeness(cm, plan)?,
    });
    checks.push(CheckReport {
        check: PlanCheck::SyncGroups,
        summary: check_sync_groups(cm, plan)?,
    });
    checks.push(CheckReport {
        check: PlanCheck::MemoryConsistency,
        summary: check_memory_consistency(cm, plan)?,
    });
    checks.push(CheckReport {
        check: PlanCheck::CostCoherence,
        summary: check_cost_coherence(cm, plan)?,
    });
    Ok(VerifyReport { checks })
}

/// Check 1: every layer's tiles exactly partition its output tensor and
/// sit on the devices the shared placement function assigns. Also proves
/// the structural frame (layer count, device count, config divisibility)
/// that later checks re-derive through.
fn check_tile_coverage(cm: &CostModel<'_>, plan: &ExecutionPlan) -> Result<String> {
    const CHECK: PlanCheck = PlanCheck::TileCoverage;
    let g = cm.graph;
    if plan.layers.len() != g.num_layers() {
        return Err(fail(
            CHECK,
            format!("plan has {} layers, graph has {}", plan.layers.len(), g.num_layers()),
        ));
    }
    if plan.ndev != cm.devices.num_devices() {
        return Err(fail(
            CHECK,
            format!(
                "plan laid out for {} devices, cluster has {}",
                plan.ndev,
                cm.devices.num_devices()
            ),
        ));
    }
    let mut ntiles = 0usize;
    for (i, (lp, gl)) in plan.layers.iter().zip(g.layers.iter()).enumerate() {
        if lp.layer != i {
            return Err(fail(CHECK, format!("layer {i} carries id {}", lp.layer)));
        }
        // Degrees must divide the output extents (and stay 1 in missing
        // dims) before output_tiles may re-derive the canonical tiling.
        let rank = gl.out_shape.len();
        for d in 0..4 {
            if d >= rank {
                if lp.cfg.deg[d] != 1 {
                    return Err(fail(
                        CHECK,
                        format!(
                            "layer {i} (`{}`): degree {} in missing dimension {d}",
                            gl.name, lp.cfg.deg[d]
                        ),
                    ));
                }
            } else if lp.cfg.deg[d] == 0 || gl.out_shape[d] % lp.cfg.deg[d] != 0 {
                return Err(fail(
                    CHECK,
                    format!(
                        "layer {i} (`{}`): degree {} does not equally partition extent {} \
                         in dimension {d}",
                        gl.name, lp.cfg.deg[d], gl.out_shape[d]
                    ),
                ));
            }
        }
        if lp.tiles.len() != lp.tile_dev.len() {
            return Err(fail(
                CHECK,
                format!("layer {i}: {} tiles but {} placements", lp.tiles.len(), lp.tile_dev.len()),
            ));
        }
        let expect = output_tiles(&gl.out_shape, &lp.cfg);
        if lp.tiles.len() != expect.len() {
            return Err(fail(
                CHECK,
                format!(
                    "layer {i}: {} tiles recorded, config {} implies {}",
                    lp.tiles.len(),
                    lp.cfg.label(),
                    expect.len()
                ),
            ));
        }
        // Coverage diagnostics first (they name the *kind* of damage),
        // then exact agreement with the canonical row-major partition —
        // which is what actually proves disjoint + gap-free + in-bounds.
        let total: usize = gl.out_shape.iter().product();
        let vol: usize = lp.tiles.iter().map(|t| t.volume()).sum();
        for (a, ta) in lp.tiles.iter().enumerate() {
            for (b, tb) in lp.tiles.iter().enumerate().skip(a + 1) {
                if ta.rank() == tb.rank() && ta.intersect(tb).is_some() {
                    return Err(fail(CHECK, format!("layer {i}: tile {a} overlaps tile {b}")));
                }
            }
            if ta.rank() != rank || (0..rank).any(|d| ta.end(d) > gl.out_shape[d]) {
                return Err(fail(
                    CHECK,
                    format!("layer {i}: tile {a} exceeds the output shape {:?}", gl.out_shape),
                ));
            }
        }
        if vol != total {
            return Err(fail(
                CHECK,
                format!("layer {i}: tiles cover {vol} of {total} output elements"),
            ));
        }
        for (t, (got, want)) in lp.tiles.iter().zip(expect.iter()).enumerate() {
            if got != want {
                return Err(fail(
                    CHECK,
                    format!("layer {i}: tile {t} is {got:?}, canonical partition expects {want:?}"),
                ));
            }
        }
        for (t, &dev) in lp.tile_dev.iter().enumerate() {
            if dev >= plan.ndev {
                return Err(fail(
                    CHECK,
                    format!("layer {i}: tile {t} placed on device {dev} >= ndev {}", plan.ndev),
                ));
            }
            if dev != cm.dev_of(t) {
                return Err(fail(
                    CHECK,
                    format!(
                        "layer {i}: tile {t} placed on device {dev}, placement assigns {}",
                        cm.dev_of(t)
                    ),
                ));
            }
        }
        ntiles += lp.tiles.len();
    }
    Ok(format!("{} layers, {ntiles} tiles partition their outputs", plan.layers.len()))
}

/// Check 2: the plan's edge list mirrors the graph's, and each edge's
/// transfer schedule is exactly the canonical (dst-major, src-minor)
/// expansion of the consumer tiles' input-region overlaps — so every
/// needed element arrives (from a transfer or device-local data) and no
/// transfer references a device outside the placement shape.
fn check_transfer_completeness(cm: &CostModel<'_>, plan: &ExecutionPlan) -> Result<String> {
    const CHECK: PlanCheck = PlanCheck::TransferCompleteness;
    let g = cm.graph;
    if plan.edges.len() != g.num_edges() {
        return Err(fail(
            CHECK,
            format!("plan has {} edges, graph has {}", plan.edges.len(), g.num_edges()),
        ));
    }
    let mut ntransfers = 0usize;
    for (j, (ep, &(s, d))) in plan.edges.iter().zip(g.edges.iter()).enumerate() {
        if (ep.src, ep.dst) != (s, d) {
            return Err(fail(
                CHECK,
                format!(
                    "edge {j} is ({}, {}), graph edge order expects ({s}, {d})",
                    ep.src, ep.dst
                ),
            ));
        }
        let Some(in_idx) = g.predecessors(d).iter().position(|&p| p == s) else {
            return Err(fail(CHECK, format!("edge ({s}, {d}) not present in the graph")));
        };
        if ep.in_idx != in_idx {
            return Err(fail(
                CHECK,
                format!("edge ({s}, {d}): in_idx {} recorded, graph says {in_idx}", ep.in_idx),
            ));
        }
        // Out-of-range devices get their own diagnostic before the
        // schedule comparison (the named sub-invariant of this check).
        for (k, t) in ep.transfers.iter().enumerate() {
            if t.src_dev >= plan.ndev || t.dst_dev >= plan.ndev {
                return Err(fail(
                    CHECK,
                    format!(
                        "edge ({s}, {d}): transfer {k} references device {} outside the \
                         {}-device placement shape",
                        t.src_dev.max(t.dst_dev),
                        plan.ndev
                    ),
                ));
            }
        }
        // Re-derive needs + transfers exactly as ExecutionPlan::build.
        let ld = g.layer(d);
        let (sp, dp) = (&plan.layers[s], &plan.layers[d]);
        let src_flat: Vec<overlap::FlatRegion> = sp.tiles.iter().map(overlap::flatten).collect();
        let mut expect = Vec::new();
        for (m, dtile) in dp.tiles.iter().enumerate() {
            let need = input_region(ld, in_idx, dtile);
            let got_need = ep.needs.get(m).cloned().flatten();
            if got_need != need {
                return Err(fail(
                    CHECK,
                    format!(
                        "edge ({s}, {d}): tile {m} records input region {got_need:?}, \
                         operator semantics require {need:?}"
                    ),
                ));
            }
            if let Some(need) = &need {
                let need_flat = overlap::flatten(need);
                let dst_dev = dp.tile_dev[m];
                for (k, stile) in src_flat.iter().enumerate() {
                    let elems = overlap::overlap_elems(&need_flat, stile);
                    if elems == 0 {
                        continue;
                    }
                    let src_dev = sp.tile_dev[k];
                    let route = if src_dev == dst_dev {
                        Route::Local
                    } else if cm.devices.same_node(src_dev, dst_dev) {
                        Route::IntraNode
                    } else {
                        Route::InterNode
                    };
                    expect.push(Transfer {
                        src_tile: k,
                        dst_tile: m,
                        src_dev,
                        dst_dev,
                        elems,
                        route,
                    });
                }
            }
        }
        if ep.needs.len() != dp.tiles.len() {
            return Err(fail(
                CHECK,
                format!(
                    "edge ({s}, {d}): {} need entries for {} consumer tiles",
                    ep.needs.len(),
                    dp.tiles.len()
                ),
            ));
        }
        if ep.transfers != expect {
            // Name the damage: a missing transfer starves a consumer
            // tile, a spurious/mismatched one moves bytes nobody needs.
            for (k, want) in expect.iter().enumerate() {
                match ep.transfers.get(k) {
                    None => {
                        return Err(fail(
                            CHECK,
                            format!(
                                "edge ({s}, {d}): missing transfer src_tile {} -> dst_tile {} \
                                 ({} elems); consumer tile {}'s input region is not covered",
                                want.src_tile, want.dst_tile, want.elems, want.dst_tile
                            ),
                        ));
                    }
                    Some(got) if got != want => {
                        return Err(fail(
                            CHECK,
                            format!(
                                "edge ({s}, {d}): transfer {k} is {got:?}, schedule \
                                 requires {want:?}"
                            ),
                        ));
                    }
                    Some(_) => {}
                }
            }
            let extra = &ep.transfers[expect.len()];
            return Err(fail(
                CHECK,
                format!(
                    "edge ({s}, {d}): spurious transfer src_tile {} -> dst_tile {} not implied \
                     by any input region",
                    extra.src_tile, extra.dst_tile
                ),
            ));
        }
        ntransfers += ep.transfers.len();
    }
    Ok(format!(
        "{} edges, {ntransfers} scheduled transfers cover every input region",
        plan.edges.len()
    ))
}

/// Check 3: each parameterized layer's sync groups are exactly the
/// sharded-PS replica groups its config implies — the groups partition
/// the tile set (no tile synced twice, none orphaned), carry the right
/// devices and exchange bytes, and layers without replicated parameters
/// carry no sync plan at all.
fn check_sync_groups(cm: &CostModel<'_>, plan: &ExecutionPlan) -> Result<String> {
    const CHECK: PlanCheck = PlanCheck::SyncGroups;
    let g = cm.graph;
    let mut ngroups = 0usize;
    for (i, (lp, gl)) in plan.layers.iter().zip(g.layers.iter()).enumerate() {
        let expect = if gl.has_params() {
            let sh = param_sharding(gl, &lp.cfg);
            if sh.replicas > 1 {
                let groups: Vec<SyncGroup> = (0..sh.shards)
                    .map(|shard| {
                        let shard_tiles: Vec<usize> = (0..lp.cfg.total())
                            .filter(|&t| shard_of_tile(&lp.cfg, t) == shard)
                            .collect();
                        let devs: Vec<usize> =
                            shard_tiles.iter().map(|&t| lp.tile_dev[t]).collect();
                        let r = devs.len() as f64;
                        let node = cm.devices.devices[devs[0]].node;
                        let spans_nodes =
                            devs.iter().any(|&dv| cm.devices.devices[dv].node != node);
                        SyncGroup {
                            shard,
                            tiles: shard_tiles,
                            devices: devs,
                            bytes_per_replica: 2.0 * sh.shard_bytes * (r - 1.0) / r,
                            spans_nodes,
                        }
                    })
                    .collect();
                Some((sh.shard_bytes, groups))
            } else {
                None
            }
        } else {
            None
        };
        match (&lp.sync, &expect) {
            (None, None) => {}
            (Some(_), None) => {
                return Err(fail(
                    CHECK,
                    format!(
                        "layer {i} (`{}`) has no replicated parameters but carries a sync plan",
                        gl.name
                    ),
                ));
            }
            (None, Some(_)) => {
                return Err(fail(
                    CHECK,
                    format!(
                        "layer {i} (`{}`) replicates parameters but carries no sync plan",
                        gl.name
                    ),
                ));
            }
            (Some(got), Some((shard_bytes, groups))) => {
                if got.shard_bytes != *shard_bytes {
                    return Err(fail(
                        CHECK,
                        format!(
                            "layer {i}: shard_bytes {} recorded, sharding implies {shard_bytes}",
                            got.shard_bytes
                        ),
                    ));
                }
                if got.groups.len() != groups.len() {
                    return Err(fail(
                        CHECK,
                        format!(
                            "layer {i}: {} sync groups for {} parameter shards",
                            got.groups.len(),
                            groups.len()
                        ),
                    ));
                }
                // Partition diagnostics before exact comparison: the
                // union of group tiles must be 0..total with no repeats.
                let mut seen: Vec<usize> =
                    got.groups.iter().flat_map(|grp| grp.tiles.iter().copied()).collect();
                seen.sort_unstable();
                let all: Vec<usize> = (0..lp.cfg.total()).collect();
                if seen != all {
                    let detail = match seen.windows(2).find(|w| w[0] == w[1]) {
                        Some(w) => format!("tile {} appears in two shard groups", w[0]),
                        None => "sync groups orphan or invent tiles".to_string(),
                    };
                    return Err(fail(CHECK, format!("layer {i}: {detail}")));
                }
                for (gi, (gg, gw)) in got.groups.iter().zip(groups.iter()).enumerate() {
                    if gg != gw {
                        return Err(fail(
                            CHECK,
                            format!(
                                "layer {i}: sync group {gi} is {gg:?}, sharding \
                                 implies {gw:?}"
                            ),
                        ));
                    }
                }
                ngroups += groups.len();
            }
        }
    }
    Ok(format!("{ngroups} shard groups partition their layers' parameters"))
}

/// Check 4: the recorded per-device high-water memory matches an
/// independent re-derivation through [`memory::peak_per_device`] —
/// bit-for-bit, both sides summing the same `tile_bytes` terms in the
/// same order.
fn check_memory_consistency(cm: &CostModel<'_>, plan: &ExecutionPlan) -> Result<String> {
    const CHECK: PlanCheck = PlanCheck::MemoryConsistency;
    let expect = memory::peak_per_device(cm, &plan.strategy());
    if plan.peak_mem_per_dev.len() != expect.len() {
        return Err(fail(
            CHECK,
            format!(
                "peak_mem_per_dev has {} entries for {} devices",
                plan.peak_mem_per_dev.len(),
                expect.len()
            ),
        ));
    }
    for (dv, (&got, &want)) in plan.peak_mem_per_dev.iter().zip(expect.iter()).enumerate() {
        if got != want {
            return Err(fail(
                CHECK,
                format!("device {dv}: recorded peak {got} bytes, memory model derives {want}"),
            ));
        }
    }
    Ok(format!(
        "per-device peaks match the memory model (max {})",
        crate::util::fmt_bytes(plan.peak_mem())
    ))
}

/// Check 5: the recorded step-time estimate equals the cost model's
/// `t_o` over the plan's strategy, bit-for-bit.
fn check_cost_coherence(cm: &CostModel<'_>, plan: &ExecutionPlan) -> Result<String> {
    const CHECK: PlanCheck = PlanCheck::CostCoherence;
    let want = cm.t_o(&plan.strategy());
    if plan.cost_s != want {
        return Err(fail(
            CHECK,
            format!("recorded cost {} s, cost model derives {} s", plan.cost_s, want),
        ));
    }
    Ok(format!("recorded step time {} matches t_o", crate::util::fmt_secs(plan.cost_s)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::graph::nets;
    use crate::optimizer::strategies;

    fn setup(
        net: &str,
        ndev: usize,
        strat: &str,
    ) -> (crate::graph::CompGraph, DeviceGraph, ExecutionPlan) {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let s = strategies::by_name(strat, &g, ndev).unwrap();
        let plan = ExecutionPlan::build(&CostModel::new(&g, &d), &s);
        (g, d, plan)
    }

    #[test]
    fn freshly_built_plans_verify_clean() {
        for (net, ndev, strat) in
            [("lenet5", 2, "data"), ("alexnet", 4, "owt"), ("inception_v3", 2, "model")]
        {
            let (g, d, plan) = setup(net, ndev, strat);
            let cm = CostModel::new(&g, &d);
            let report = verify_plan(&cm, &plan)
                .unwrap_or_else(|e| panic!("{net}@{ndev}/{strat}: {e}"));
            assert_eq!(report.checks.len(), PlanCheck::ALL.len());
            for (c, want) in report.checks.iter().zip(PlanCheck::ALL) {
                assert_eq!(c.check, want);
            }
            let text = report.to_string();
            assert!(text.contains("tile-coverage") && text.contains("cost-coherence"));
        }
    }

    #[test]
    fn verify_round_trips_through_json() {
        use crate::util::json::Json;
        let (g, d, plan) = setup("alexnet", 4, "model");
        let cm = CostModel::new(&g, &d);
        let back =
            ExecutionPlan::from_json(&Json::parse(&plan.to_json().to_string()).unwrap()).unwrap();
        verify_plan(&cm, &back).expect("round-tripped plan must verify bit-for-bit");
    }

    #[test]
    fn wrong_graph_is_rejected_not_panicked() {
        // A structurally different graph can never match the plan; the
        // verifier must return a typed error, not index out of bounds.
        let (_, d, plan) = setup("lenet5", 2, "data");
        let other = nets::alexnet(64).unwrap();
        let cm = CostModel::new(&other, &d);
        let err = verify_plan(&cm, &plan).unwrap_err();
        assert!(matches!(err, OptError::InvalidPlan { .. }), "{err}");
    }
}
