//! Device graphs (paper §4): the hardware model.
//!
//! A device graph holds the accelerators, their grouping into compute
//! nodes, pairwise link bandwidths, and the per-device compute model used
//! by the analytic cost functions. Presets mirror the paper's testbed:
//! 4 nodes x 4 NVIDIA P100, NVLink intra-node, 100 Gb/s EDR InfiniBand
//! inter-node (see DESIGN.md §2 for the substitution rationale).

/// Per-device compute capability (the `t_C` substrate).
#[derive(Debug, Clone, Copy)]
pub struct ComputeModel {
    /// Peak f32 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s (roofline for memory-bound layers).
    pub mem_bw: f64,
    /// Fixed per-layer-invocation overhead, seconds (kernel launch etc).
    pub overhead: f64,
    /// Sustained fraction of peak for dense conv kernels.
    pub conv_eff: f64,
    /// Sustained fraction of peak for large GEMMs (fully-connected).
    pub gemm_eff: f64,
}

impl ComputeModel {
    /// NVIDIA Tesla P100 (SXM2): 10.6 TFLOP/s fp32, 732 GB/s HBM2.
    /// Efficiency factors are the commonly reported cuDNN/cuBLAS sustained
    /// fractions for ImageNet-scale layers.
    pub fn p100() -> ComputeModel {
        ComputeModel {
            peak_flops: 10.6e12,
            mem_bw: 732e9,
            overhead: 10e-6,
            conv_eff: 0.55,
            gemm_eff: 0.70,
        }
    }
}

/// One accelerator.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    /// Compute-node index (devices on one node share NVLink + the NIC).
    pub node: usize,
    pub name: String,
}

/// The device graph: devices + link bandwidths + compute model.
#[derive(Debug, Clone)]
pub struct DeviceGraph {
    pub name: String,
    pub devices: Vec<Device>,
    /// Effective point-to-point bandwidth between device pairs, bytes/s.
    bw: Vec<f64>, // row-major ndev x ndev, diagonal = +inf sentinel (0 cost)
    /// Bandwidth between a device and its node's host/parameter-server
    /// endpoint (PCIe), bytes/s.
    pub host_bw: f64,
    /// Effective bandwidth between the host endpoints of two different
    /// nodes (the NIC), bytes/s.
    pub node_bw: f64,
    pub compute: ComputeModel,
}

impl DeviceGraph {
    /// Generic builder: `nodes x gpus_per_node` devices with uniform
    /// intra-node (`intra_bw`) and effective inter-node (`inter_bw`)
    /// point-to-point bandwidths.
    pub fn cluster(
        name: &str,
        nodes: usize,
        gpus_per_node: usize,
        intra_bw: f64,
        inter_bw: f64,
        host_bw: f64,
        compute: ComputeModel,
    ) -> DeviceGraph {
        assert!(nodes >= 1 && gpus_per_node >= 1);
        let n = nodes * gpus_per_node;
        let devices: Vec<Device> = (0..n)
            .map(|id| Device { id, node: id / gpus_per_node, name: format!("gpu{id}") })
            .collect();
        let mut bw = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                bw[i * n + j] = if i == j {
                    f64::INFINITY
                } else if devices[i].node == devices[j].node {
                    intra_bw
                } else {
                    inter_bw
                };
            }
        }
        DeviceGraph {
            name: name.to_string(),
            devices,
            bw,
            host_bw,
            node_bw: inter_bw * gpus_per_node as f64, // the NIC itself
            compute,
        }
    }

    /// The paper's testbed scaled to `ngpus` in {1, 2, 4, 8, 16}: up to 4
    /// GPUs per node (NVLink ~15 GB/s effective p2p), nodes connected by
    /// 100 Gb/s EDR IB (12.5 GB/s per NIC, shared by the node's 4 GPUs →
    /// ~3.1 GB/s effective p2p when fanned out), PCIe 3.0 x16 host link.
    pub fn p100_cluster(ngpus: usize) -> DeviceGraph {
        let gpus_per_node = ngpus.min(4);
        let nodes = ngpus.div_ceil(gpus_per_node);
        assert_eq!(nodes * gpus_per_node, ngpus, "ngpus must be 1,2,4 or a multiple of 4");
        let nic = 12.5e9;
        DeviceGraph::cluster(
            &format!("p100x{ngpus}"),
            nodes,
            gpus_per_node,
            15e9,
            nic / gpus_per_node as f64,
            12e9,
            ComputeModel::p100(),
        )
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.devices.last().map(|d| d.node + 1).unwrap_or(0)
    }

    /// Point-to-point bandwidth (bytes/s); infinite for i == j.
    pub fn bandwidth(&self, i: usize, j: usize) -> f64 {
        self.bw[i * self.num_devices() + j]
    }

    /// Seconds to move `bytes` from device i to device j (assumption 2).
    pub fn transfer_time(&self, i: usize, j: usize, bytes: f64) -> f64 {
        if i == j || bytes == 0.0 {
            0.0
        } else {
            bytes / self.bandwidth(i, j)
        }
    }

    pub fn same_node(&self, i: usize, j: usize) -> bool {
        self.devices[i].node == self.devices[j].node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p100_presets_have_expected_topology() {
        for (n, nodes) in [(1usize, 1usize), (2, 1), (4, 1), (8, 2), (16, 4)] {
            let d = DeviceGraph::p100_cluster(n);
            assert_eq!(d.num_devices(), n);
            assert_eq!(d.num_nodes(), nodes);
        }
    }

    #[test]
    fn intra_beats_inter_bandwidth() {
        let d = DeviceGraph::p100_cluster(8);
        assert!(d.bandwidth(0, 1) > d.bandwidth(0, 4));
        assert!(d.same_node(0, 3));
        assert!(!d.same_node(3, 4));
    }

    #[test]
    fn transfer_time_linear_in_bytes() {
        let d = DeviceGraph::p100_cluster(2);
        let t1 = d.transfer_time(0, 1, 1e9);
        let t2 = d.transfer_time(0, 1, 2e9);
        assert!((t2 / t1 - 2.0).abs() < 1e-12);
        assert_eq!(d.transfer_time(0, 0, 1e9), 0.0);
        assert_eq!(d.transfer_time(0, 1, 0.0), 0.0);
    }

    #[test]
    #[should_panic]
    fn irregular_gpu_count_rejected() {
        DeviceGraph::p100_cluster(6);
    }
}
