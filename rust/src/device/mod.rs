//! Device graphs (paper §4): the hardware model.
//!
//! A device graph holds the accelerators, their grouping into compute
//! nodes, pairwise link bandwidths, and the per-device compute model used
//! by the analytic cost functions. Presets mirror the paper's testbed:
//! 4 nodes x 4 NVIDIA P100, NVLink intra-node, 100 Gb/s EDR InfiniBand
//! inter-node (see DESIGN.md §2 for the substitution rationale).
//!
//! Constructors validate their arguments and return [`OptError`] — a
//! zero-device or zero-bandwidth cluster would otherwise surface as NaN
//! transfer times deep inside the cost model.

use crate::error::{OptError, Result};

/// The paper testbed's link constants and shape rule, shared by
/// [`DeviceGraph::p100_cluster`] and the planner's `ClusterSpec::p100`
/// so the preset cannot drift between the two entry points.
pub mod p100 {
    use crate::error::{OptError, Result};

    /// NVLink effective point-to-point bandwidth, bytes/s.
    pub const INTRA_BW: f64 = 15e9;
    /// Per-node 100 Gb/s EDR InfiniBand NIC bandwidth, bytes/s (fanned
    /// out across the node's GPUs for the effective p2p rate).
    pub const NIC_BW: f64 = 12.5e9;
    /// PCIe 3.0 x16 host link bandwidth, bytes/s.
    pub const HOST_BW: f64 = 12e9;

    /// Shape `ngpus` devices into `(nodes, gpus_per_node)` the way the
    /// paper's testbed does (up to 4 GPUs per node); errors unless
    /// `ngpus` is 1, 2, 4 or a multiple of 4.
    pub fn shape(ngpus: usize) -> Result<(usize, usize)> {
        if !(ngpus == 1 || ngpus == 2 || (ngpus >= 4 && ngpus % 4 == 0)) {
            return Err(OptError::InvalidCluster(format!(
                "the p100 preset needs 1, 2, 4 or a multiple of 4 devices, got {ngpus}"
            )));
        }
        let gpus_per_node = ngpus.min(4);
        Ok((ngpus / gpus_per_node, gpus_per_node))
    }
}

/// Per-device compute capability (the `t_C` substrate) plus the HBM
/// capacity the memory model budgets against.
#[derive(Debug, Clone, Copy)]
pub struct ComputeModel {
    /// Peak f32 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s (roofline for memory-bound layers).
    pub mem_bw: f64,
    /// HBM capacity, bytes (the default per-device budget for
    /// memory-aware planning; see `memory::MemBudget`).
    pub hbm_bytes: f64,
    /// Fixed per-layer-invocation overhead, seconds (kernel launch etc).
    pub overhead: f64,
    /// Sustained fraction of peak for dense conv kernels.
    pub conv_eff: f64,
    /// Sustained fraction of peak for large GEMMs (fully-connected).
    pub gemm_eff: f64,
}

impl ComputeModel {
    /// NVIDIA Tesla P100 (SXM2): 10.6 TFLOP/s fp32, 732 GB/s HBM2, 16 GB.
    /// Efficiency factors are the commonly reported cuDNN/cuBLAS sustained
    /// fractions for ImageNet-scale layers.
    pub fn p100() -> ComputeModel {
        ComputeModel {
            peak_flops: 10.6e12,
            mem_bw: 732e9,
            hbm_bytes: 16e9,
            overhead: 10e-6,
            conv_eff: 0.55,
            gemm_eff: 0.70,
        }
    }

    /// NVIDIA Tesla V100 (SXM2, 32 GB): 15.7 TFLOP/s fp32, 900 GB/s HBM2.
    pub fn v100() -> ComputeModel {
        ComputeModel {
            peak_flops: 15.7e12,
            mem_bw: 900e9,
            hbm_bytes: 32e9,
            overhead: 10e-6,
            conv_eff: 0.55,
            gemm_eff: 0.70,
        }
    }

    /// NVIDIA A100 (SXM4, 40 GB): 19.5 TFLOP/s fp32, 1555 GB/s HBM2e.
    pub fn a100() -> ComputeModel {
        ComputeModel {
            peak_flops: 19.5e12,
            mem_bw: 1555e9,
            hbm_bytes: 40e9,
            overhead: 8e-6,
            conv_eff: 0.55,
            gemm_eff: 0.70,
        }
    }

    /// Look a preset up by name (`p100`, `v100`, `a100`) — the config-file
    /// entry point for non-P100 clusters.
    pub fn named(name: &str) -> Result<ComputeModel> {
        match name {
            "p100" => Ok(ComputeModel::p100()),
            "v100" => Ok(ComputeModel::v100()),
            "a100" => Ok(ComputeModel::a100()),
            other => Err(OptError::InvalidCluster(format!(
                "unknown compute model `{other}` (known: p100, v100, a100)"
            ))),
        }
    }

    /// Validate the model: every rate must be positive and finite.
    pub fn validate(&self) -> Result<()> {
        for (what, v) in [
            ("peak_flops", self.peak_flops),
            ("mem_bw", self.mem_bw),
            ("hbm_bytes", self.hbm_bytes),
            ("conv_eff", self.conv_eff),
            ("gemm_eff", self.gemm_eff),
        ] {
            if !(v.is_finite() && v > 0.0) {
                return Err(OptError::InvalidCluster(format!(
                    "compute model {what} must be positive and finite, got {v}"
                )));
            }
        }
        if !(self.overhead.is_finite() && self.overhead >= 0.0) {
            return Err(OptError::InvalidCluster(format!(
                "compute model overhead must be nonnegative, got {}",
                self.overhead
            )));
        }
        Ok(())
    }
}

/// One accelerator.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    /// Compute-node index (devices on one node share NVLink + the NIC).
    pub node: usize,
    pub name: String,
}

/// The device graph: devices + link bandwidths + compute model.
#[derive(Debug, Clone)]
pub struct DeviceGraph {
    pub name: String,
    pub devices: Vec<Device>,
    /// Effective point-to-point bandwidth between device pairs, bytes/s.
    bw: Vec<f64>, // row-major ndev x ndev, diagonal = +inf sentinel (0 cost)
    /// Bandwidth between a device and its node's host/parameter-server
    /// endpoint (PCIe), bytes/s.
    pub host_bw: f64,
    /// Effective bandwidth between the host endpoints of two different
    /// nodes (the NIC), bytes/s.
    pub node_bw: f64,
    pub compute: ComputeModel,
}

impl DeviceGraph {
    /// Generic builder: `nodes x gpus_per_node` devices with uniform
    /// intra-node (`intra_bw`) and effective inter-node (`inter_bw`)
    /// point-to-point bandwidths. Errors on degenerate shapes (zero
    /// nodes/devices) and nonpositive or non-finite bandwidths, which
    /// would otherwise propagate NaN/infinite transfer times.
    pub fn cluster(
        name: &str,
        nodes: usize,
        gpus_per_node: usize,
        intra_bw: f64,
        inter_bw: f64,
        host_bw: f64,
        compute: ComputeModel,
    ) -> Result<DeviceGraph> {
        if nodes == 0 || gpus_per_node == 0 {
            return Err(OptError::InvalidCluster(format!(
                "need at least one node and one device per node, got {nodes} x {gpus_per_node}"
            )));
        }
        for (what, bw) in
            [("intra-node", intra_bw), ("inter-node", inter_bw), ("host", host_bw)]
        {
            if !(bw.is_finite() && bw > 0.0) {
                return Err(OptError::InvalidCluster(format!(
                    "{what} bandwidth must be positive and finite, got {bw} B/s"
                )));
            }
        }
        compute.validate()?;
        let n = nodes * gpus_per_node;
        let devices: Vec<Device> = (0..n)
            .map(|id| Device { id, node: id / gpus_per_node, name: format!("gpu{id}") })
            .collect();
        let mut bw = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                bw[i * n + j] = if i == j {
                    f64::INFINITY
                } else if devices[i].node == devices[j].node {
                    intra_bw
                } else {
                    inter_bw
                };
            }
        }
        Ok(DeviceGraph {
            name: name.to_string(),
            devices,
            bw,
            host_bw,
            node_bw: inter_bw * gpus_per_node as f64, // the NIC itself
            compute,
        })
    }

    /// The paper's testbed scaled to `ngpus` in {1, 2, 4, 8, 16}: up to 4
    /// GPUs per node (NVLink ~15 GB/s effective p2p), nodes connected by
    /// 100 Gb/s EDR IB (12.5 GB/s per NIC, shared by the node's 4 GPUs →
    /// ~3.1 GB/s effective p2p when fanned out), PCIe 3.0 x16 host link.
    /// Errors unless `ngpus` is 1, 2, 4 or a multiple of 4 (see [`p100`]).
    pub fn p100_cluster(ngpus: usize) -> Result<DeviceGraph> {
        let (nodes, gpus_per_node) = p100::shape(ngpus)?;
        DeviceGraph::cluster(
            &format!("p100x{ngpus}"),
            nodes,
            gpus_per_node,
            p100::INTRA_BW,
            p100::NIC_BW / gpus_per_node as f64,
            p100::HOST_BW,
            ComputeModel::p100(),
        )
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.devices.last().map(|d| d.node + 1).unwrap_or(0)
    }

    /// The `(nodes, gpus_per_node)` placement geometry that
    /// `Placement::device_of` consumes — the single source of truth
    /// shared by `CostModel::dev_of` and `ExecutionPlan` tile placement.
    /// Every constructor builds node-uniform clusters; the check turns a
    /// future non-uniform layout into a loud error instead of silently
    /// misplacing tiles through truncating division. `dev_of` sits on
    /// the table-build hot path, so only the O(1) count check runs in
    /// release; the per-device layout scan is a debug assertion.
    pub fn placement_shape(&self) -> (usize, usize) {
        let nodes = self.num_nodes().max(1);
        let n = self.num_devices();
        let gpn = n / nodes;
        assert!(
            gpn * nodes == n,
            "cluster `{}` is not node-uniform: {n} devices across {nodes} nodes",
            self.name
        );
        debug_assert!(
            self.devices.iter().all(|d| d.node == d.id / gpn.max(1)),
            "cluster `{}` numbers its nodes non-contiguously",
            self.name
        );
        (nodes, gpn)
    }

    /// Point-to-point bandwidth (bytes/s); infinite for i == j.
    pub fn bandwidth(&self, i: usize, j: usize) -> f64 {
        self.bw[i * self.num_devices() + j]
    }

    /// Seconds to move `bytes` from device i to device j (assumption 2).
    pub fn transfer_time(&self, i: usize, j: usize, bytes: f64) -> f64 {
        if i == j || bytes == 0.0 {
            0.0
        } else {
            bytes / self.bandwidth(i, j)
        }
    }

    pub fn same_node(&self, i: usize, j: usize) -> bool {
        self.devices[i].node == self.devices[j].node
    }

    /// The cluster's structural identity: everything the cost model reads
    /// — per-device node assignment, the full bandwidth matrix, host/NIC
    /// bandwidths, and the compute model — captured by value (f64 bit
    /// patterns, not a lossy hash) so two [`DeviceGraph`]s compare equal
    /// exactly when every cost they produce is identical. The cosmetic
    /// `name` and the HBM capacity are deliberately excluded: neither
    /// enters a cost function (memory budgets key caches separately).
    /// Keys the planner service's single-flight state memo and the
    /// per-layer cost-table memo (`cost::memo`).
    pub fn fingerprint(&self) -> ClusterFingerprint {
        ClusterFingerprint {
            node_of: self.devices.iter().map(|d| d.node).collect(),
            bw_bits: self.bw.iter().map(|b| b.to_bits()).collect(),
            host_bw: self.host_bw.to_bits(),
            node_bw: self.node_bw.to_bits(),
            compute: [
                self.compute.peak_flops.to_bits(),
                self.compute.mem_bw.to_bits(),
                self.compute.overhead.to_bits(),
                self.compute.conv_eff.to_bits(),
                self.compute.gemm_eff.to_bits(),
            ],
        }
    }
}

/// Value identity of a [`DeviceGraph`] (see [`DeviceGraph::fingerprint`]):
/// hashable and comparable, so it can key memo maps without holding the
/// graph itself. Opaque by design — consumers only compare and hash it.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ClusterFingerprint {
    node_of: Vec<usize>,
    bw_bits: Vec<u64>,
    host_bw: u64,
    node_bw: u64,
    compute: [u64; 5],
}

impl ClusterFingerprint {
    /// The fingerprint's canonical textual form: every field the cost
    /// model reads, serialized deterministically (f64s as hex bit
    /// patterns, so `-0.0` vs `0.0` and NaN payloads survive). Two
    /// fingerprints are equal exactly when their canonical strings are —
    /// this is the cluster half of the on-disk plan store's content
    /// address ([`crate::store`]), so it must stay stable across
    /// processes, architectures, and compiler versions (unlike
    /// `DefaultHasher` output, which is only stable within one process).
    pub fn canonical(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::with_capacity(32 + 17 * self.bw_bits.len());
        s.push_str("nodes=");
        for (i, n) in self.node_of.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{n}");
        }
        s.push_str(";bw=");
        for (i, b) in self.bw_bits.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{b:016x}");
        }
        let _ = write!(s, ";host={:016x};node={:016x};compute=", self.host_bw, self.node_bw);
        for (i, c) in self.compute.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{c:016x}");
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p100_presets_have_expected_topology() {
        for (n, nodes) in [(1usize, 1usize), (2, 1), (4, 1), (8, 2), (16, 4)] {
            let d = DeviceGraph::p100_cluster(n).unwrap();
            assert_eq!(d.num_devices(), n);
            assert_eq!(d.num_nodes(), nodes);
        }
    }

    #[test]
    fn intra_beats_inter_bandwidth() {
        let d = DeviceGraph::p100_cluster(8).unwrap();
        assert!(d.bandwidth(0, 1) > d.bandwidth(0, 4));
        assert!(d.same_node(0, 3));
        assert!(!d.same_node(3, 4));
    }

    #[test]
    fn transfer_time_linear_in_bytes() {
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let t1 = d.transfer_time(0, 1, 1e9);
        let t2 = d.transfer_time(0, 1, 2e9);
        assert!((t2 / t1 - 2.0).abs() < 1e-12);
        assert_eq!(d.transfer_time(0, 0, 1e9), 0.0);
        assert_eq!(d.transfer_time(0, 1, 0.0), 0.0);
    }

    #[test]
    fn irregular_gpu_count_rejected() {
        let err = DeviceGraph::p100_cluster(6).unwrap_err();
        assert!(err.to_string().contains("6"), "{err}");
        assert!(DeviceGraph::p100_cluster(0).is_err());
        assert!(DeviceGraph::p100_cluster(3).is_err(), "3 is not a testbed shape");
        assert!(DeviceGraph::p100_cluster(12).is_ok(), "multiples of 4 are");
    }

    #[test]
    fn degenerate_clusters_rejected() {
        let cm = ComputeModel::p100;
        assert!(DeviceGraph::cluster("z", 0, 4, 1e9, 1e9, 1e9, cm()).is_err());
        assert!(DeviceGraph::cluster("z", 1, 0, 1e9, 1e9, 1e9, cm()).is_err());
        assert!(DeviceGraph::cluster("z", 1, 4, 0.0, 1e9, 1e9, cm()).is_err());
        assert!(DeviceGraph::cluster("z", 1, 4, 1e9, -2e9, 1e9, cm()).is_err());
        assert!(DeviceGraph::cluster("z", 1, 4, 1e9, 1e9, f64::NAN, cm()).is_err());
        let mut broken = ComputeModel::p100();
        broken.peak_flops = 0.0;
        assert!(DeviceGraph::cluster("z", 1, 4, 1e9, 1e9, 1e9, broken).is_err());
    }

    #[test]
    fn named_compute_models_resolve() {
        assert!(ComputeModel::named("p100").is_ok());
        assert!(ComputeModel::named("v100").is_ok());
        assert!(ComputeModel::named("a100").is_ok());
        assert!(ComputeModel::named("tpu9000").is_err());
        assert!(ComputeModel::v100().peak_flops > ComputeModel::p100().peak_flops);
    }

    #[test]
    fn presets_carry_their_hbm_capacity() {
        assert_eq!(ComputeModel::p100().hbm_bytes, 16e9);
        assert_eq!(ComputeModel::v100().hbm_bytes, 32e9);
        assert_eq!(ComputeModel::a100().hbm_bytes, 40e9);
        let mut broken = ComputeModel::p100();
        broken.hbm_bytes = 0.0;
        assert!(broken.validate().is_err(), "zero-capacity devices are invalid");
    }

    #[test]
    fn fingerprint_is_structural_not_nominal() {
        let cm = ComputeModel::p100();
        let a = DeviceGraph::cluster("alpha", 2, 2, 15e9, 3e9, 12e9, cm).unwrap();
        let b = DeviceGraph::cluster("beta", 2, 2, 15e9, 3e9, 12e9, cm).unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint(), "names are cosmetic");
        let c = DeviceGraph::cluster("alpha", 2, 2, 15e9, 4e9, 12e9, cm).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint(), "bandwidths are structural");
        let d = DeviceGraph::cluster("alpha", 1, 4, 15e9, 3e9, 12e9, cm).unwrap();
        assert_ne!(a.fingerprint(), d.fingerprint(), "node grouping is structural");
        let mut hbm = cm;
        hbm.hbm_bytes = 99e9;
        let e = DeviceGraph::cluster("alpha", 2, 2, 15e9, 3e9, 12e9, hbm).unwrap();
        assert_eq!(a.fingerprint(), e.fingerprint(), "HBM capacity is not a cost input");
    }

    #[test]
    fn placement_shape_matches_construction() {
        for (nodes, gpn) in [(1usize, 1usize), (1, 4), (2, 3), (4, 4)] {
            let d = DeviceGraph::cluster("s", nodes, gpn, 1e9, 1e9, 1e9, ComputeModel::p100())
                .unwrap();
            assert_eq!(d.placement_shape(), (nodes, gpn));
        }
    }
}
