//! Precomputed cost tables.
//!
//! Algorithm 1 consumes `t_C`, `t_S`, `t_X` as *precomputed* functions
//! (paper line 1). This module evaluates the cost model once for every
//! (layer, configuration) and (edge, configuration-pair) and hands the
//! optimizer flat arrays; the search itself then never touches tensors or
//! regions — only table lookups.
//!
//! [`CostTables::build_budgeted`] additionally masks memory-infeasible
//! configurations out of the enumeration before anything is priced, so a
//! [`MemBudget`]-constrained search is exact over the reduced space
//! (DESIGN.md §3).
//!
//! Construction is an embarrassingly parallel two-stage pipeline
//! (DESIGN.md §7): per-layer tables and per-unique-edge tables are
//! independent work items fanned out over a scoped thread pool, with
//! results merged back in canonical (layer-id / edge-list) order so the
//! output is byte-identical to a serial build regardless of thread count
//! or scheduling. [`CostTables::build_opts`] exposes the thread knob and
//! an optional content-addressed [`TableMemo`] that reuses per-layer and
//! per-edge results *across* builds.

// Tables are built inside long-lived services from user-controlled
// graphs; every failure must surface as a typed `OptError`, never a
// panic (same contract as `verify/` — see DESIGN.md §10, §12).
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use super::memo::{KeyContext, LayerTables, TableMemo};
use super::{CostModel, LINK_LATENCY};
use crate::error::{OptError, Result};
use crate::graph::{spec::layer_canon, Layer, LayerId, OpKind};
use crate::memory::{self, MemBudget};
use crate::parallel::{enumerate_configs, input_region, output_tiles, PConfig, Strategy};
use crate::plan::overlap::{flatten, overlap_elems, FlatRegion};

/// Structural identity of an edge's cost table: edges whose producer
/// operator/shapes, consumer operator/shapes, and input slot coincide
/// have identical `t_X` matrices. The producer's *operator* matters, not
/// just its output shape, because `enumerate_configs` restricts the
/// config space per op (`allowed_dims`): an `Input` and a
/// shape-preserving `Conv2d` with equal outputs have different config
/// lists, so their edge tables have different dimensions and contents.
/// The producer's *input shapes* matter too: under a memory budget the
/// feasibility mask depends on the producer's parameter bytes (derived
/// from its input channels), so two same-op same-output producers with
/// different inputs can keep different config lists. Borrowed fields —
/// hashing allocates nothing (replaces the former `format!`-string
/// signature on the table-build hot path).
#[derive(Hash, PartialEq, Eq)]
struct EdgeSig<'a> {
    src_op: &'a OpKind,
    src_in: &'a [Vec<usize>],
    src_out: &'a [usize],
    dst_op: &'a OpKind,
    dst_out: &'a [usize],
    dst_in: &'a [Vec<usize>],
    in_idx: usize,
}

/// Cost matrix for one graph edge: `cost[ci * num_dst_cfgs + cj]`.
#[derive(Debug, Clone)]
pub struct EdgeTable {
    pub src: LayerId,
    pub dst: LayerId,
    pub cost: Vec<f64>,
}

impl EdgeTable {
    #[inline]
    pub fn at(&self, ci: usize, cj: usize, num_dst: usize) -> f64 {
        self.cost[ci * num_dst + cj]
    }
}

/// Knobs for [`CostTables::build_opts`]. The default (`threads: 0`,
/// `memo: None`) reproduces [`CostTables::build_budgeted`]: all cores,
/// no cross-build reuse.
#[derive(Debug, Clone, Copy, Default)]
pub struct BuildOptions<'a> {
    /// Worker threads for the per-layer and per-edge build stages.
    /// `0` (the default) uses one thread per available core; `1` builds
    /// inline on the calling thread with no pool at all. Any value
    /// produces bit-identical tables — the merge order is canonical,
    /// never arrival order.
    pub threads: usize,
    /// Content-addressed per-layer/per-edge result cache shared across
    /// builds (see [`TableMemo`]); `None` disables reuse. Ignored — the
    /// build is never memoized — when the cost model carries measured
    /// `t_C` timings, which are positional, not content-addressable.
    pub memo: Option<&'a TableMemo>,
}

/// Single-flight cell for one layer's build result in the fan-out stage.
type LayerCell = OnceLock<Result<Arc<LayerTables>>>;

/// All tables for one (graph, device graph, device budget) triple.
#[derive(Debug, Clone)]
pub struct CostTables {
    /// Per-layer candidate configurations (enumeration order is the
    /// canonical config index used everywhere downstream).
    pub configs: Vec<Vec<PConfig>>,
    /// `t_C + t_S` per layer per config index.
    pub node_cost: Vec<Vec<f64>>,
    /// One table per graph edge, in graph edge order.
    pub edges: Vec<EdgeTable>,
    /// Device count the enumeration was built for. Recorded so the
    /// auditor (`audit::audit_tables`) can re-derive the canonical
    /// config lists and budget mask without out-of-band context.
    pub ndev: usize,
    /// The per-device memory budget the build masked against, if any.
    pub budget: Option<MemBudget>,
}

/// Worker-thread count a [`BuildOptions::threads`] setting resolves to:
/// `0` asks the OS for the available parallelism and falls back to `1`
/// (serial, always correct) when that query fails — never a guessed
/// constant. Recorded in `SessionStats`/`ServiceStats` so the `stats`
/// probe exposes what a build actually used.
pub fn resolved_build_workers(threads: usize) -> usize {
    match threads {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

impl CostTables {
    /// Evaluate the cost model exhaustively over the configuration space
    /// for `ndev` available devices (no memory constraint). Fallible
    /// only through internal staging errors ([`OptError::Internal`]):
    /// an unbudgeted build has no infeasibility path.
    pub fn build(cm: &CostModel, ndev: usize) -> Result<CostTables> {
        CostTables::build_budgeted(cm, ndev, None)
    }

    /// [`CostTables::build`] with an optional per-device memory budget:
    /// configurations whose [`memory::layer_peak_bytes`] exceed the
    /// budget are **dropped from the enumeration** before any cost is
    /// evaluated — not merely priced at infinity — so the table
    /// dimensions shrink, both search backends stay exact over the
    /// reduced space, and Algorithm 1's elimination-to-K=2 reduction is
    /// untouched. A layer with *no* feasible configuration surfaces as
    /// [`OptError::Infeasible`], naming the layer and its smallest
    /// overshoot. `budget = None` (or an infinite budget) reproduces the
    /// unconstrained tables exactly (pinned by `tests/memory.rs`).
    pub fn build_budgeted(
        cm: &CostModel,
        ndev: usize,
        budget: Option<MemBudget>,
    ) -> Result<CostTables> {
        CostTables::build_opts(cm, ndev, budget, &BuildOptions::default())
    }

    /// The single construction core behind [`CostTables::build`] and
    /// [`CostTables::build_budgeted`] (the budgeted path is the same
    /// pipeline with the feasibility mask applied inside the per-layer
    /// stage), with explicit [`BuildOptions`].
    ///
    /// The pipeline has two fan-out stages. **Per layer**: enumerate the
    /// configs, apply the budget mask, price `t_C + t_S`, and tile the
    /// output — each layer is independent, so layers are claimed off an
    /// atomic cursor by a scoped thread pool. **Per edge**: structurally
    /// identical edges are deduplicated first ([`EdgeSig`]), then each
    /// unique edge's `t_X` matrix is built the same way. Both stages
    /// write into pre-indexed slots and are merged in canonical order
    /// (ascending layer id, graph edge order), so the resulting tables —
    /// and, when a budget makes some layer infeasible, *which* layer the
    /// error names (the lowest-id one, as in a serial scan) — are
    /// byte-identical for every thread count.
    ///
    /// With a [`TableMemo`], each layer/unique-edge build is first looked
    /// up by its content-addressed key; hits skip the evaluation
    /// entirely. Memoized results are keyed by everything the build
    /// reads (layer canonical form, cluster fingerprint, budget bits,
    /// sync/placement policies), so a hit returns the exact bytes a
    /// fresh build would produce.
    pub fn build_opts(
        cm: &CostModel,
        ndev: usize,
        budget: Option<MemBudget>,
        opts: &BuildOptions<'_>,
    ) -> Result<CostTables> {
        let g = cm.graph;
        // Measured t_C timings are recorded against layer *positions* in
        // one session's graph — not content-addressable. Never memoize.
        let memo = if cm.measured_tc.is_some() { None } else { opts.memo };
        let nthreads = resolved_build_workers(opts.threads);
        let ctx = memo.map(|_| KeyContext::new(cm, ndev, budget));
        let canons: Vec<Arc<str>> = match memo {
            Some(_) => g.layers.iter().map(|l| Arc::from(layer_canon(l).as_str())).collect(),
            None => Vec::new(),
        };

        // ---- stage 1: per-layer tables ----
        // Per layer: the kept configurations plus each one's index in the
        // *unmasked* enumeration — `measured_tc` is recorded against that
        // order, so masked tables must translate before the lookup. Tiles
        // per (layer, config) are computed here once: `t_X` evaluation is
        // the table-build hot path (O(E * C^2 * T^2) overlap tests);
        // hoisting tile and input-region construction out of the
        // config-pair loop removes all allocation from the inner loops
        // (§Perf log #1).
        let build_layer = |l: &Layer| -> Result<LayerTables> {
            let all = enumerate_configs(l, ndev);
            let (configs, orig_idx) = match budget {
                None => {
                    let idx = (0..all.len()).collect();
                    (all, idx)
                }
                Some(b) => {
                    let mut kept = Vec::with_capacity(all.len());
                    let mut idx = Vec::with_capacity(all.len());
                    for (i, c) in all.iter().enumerate() {
                        if b.admits(memory::layer_peak_bytes(l, c)) {
                            kept.push(*c);
                            idx.push(i);
                        }
                    }
                    if kept.is_empty() {
                        let overshoot = all
                            .iter()
                            .map(|c| memory::layer_peak_bytes(l, c) - b.bytes_per_dev)
                            .fold(f64::INFINITY, f64::min);
                        return Err(OptError::Infeasible {
                            layer: l.name.clone(),
                            overshoot: overshoot.ceil().max(1.0) as u64,
                        });
                    }
                    (kept, idx)
                }
            };
            let cost = configs
                .iter()
                .zip(orig_idx.iter())
                .map(|(c, &oi)| {
                    let tc = match &cm.measured_tc {
                        Some(m) => m[l.id][oi],
                        None => cm.t_c(l, c),
                    };
                    tc + cm.t_s(l, c)
                })
                .collect();
            let tiles = configs.iter().map(|c| output_tiles(&l.out_shape, c)).collect();
            Ok(LayerTables { configs, orig_idx, cost, tiles })
        };
        let layer_tables = |l: &Layer| -> Result<Arc<LayerTables>> {
            match (memo, &ctx) {
                (Some(m), Some(ctx)) => m
                    .node_tables(&ctx.layer_key(&canons[l.id]), || build_layer(l))
                    .map_err(|e| match e {
                        // a memoized failure may have been built for a
                        // structurally identical layer under another
                        // cosmetic name — report *this* graph's name
                        OptError::Infeasible { overshoot, .. } => {
                            OptError::Infeasible { layer: l.name.clone(), overshoot }
                        }
                        other => other,
                    }),
                _ => build_layer(l).map(Arc::new),
            }
        };

        let nlayers = g.layers.len();
        let cells: Vec<LayerCell> = (0..nlayers).map(|_| OnceLock::new()).collect();
        let layer_workers = nthreads.min(nlayers).max(1);
        if layer_workers <= 1 {
            for l in &g.layers {
                let _ = cells[l.id].set(layer_tables(l));
            }
        } else {
            let cursor = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..layer_workers {
                    scope.spawn(|| loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= nlayers {
                            break;
                        }
                        let _ = cells[i].set(layer_tables(&g.layers[i]));
                    });
                }
            });
        }
        // Merge in ascending layer id: the lowest-id infeasible layer
        // surfaces first regardless of thread interleaving, exactly as a
        // serial scan would report it.
        let mut per_layer: Vec<Arc<LayerTables>> = Vec::with_capacity(nlayers);
        for cell in cells {
            let filled = cell
                .into_inner()
                .ok_or_else(|| OptError::Internal("layer stage left a cell unset".into()))?;
            per_layer.push(filled?);
        }
        let configs: Vec<Vec<PConfig>> = per_layer.iter().map(|t| t.configs.clone()).collect();
        let node_cost: Vec<Vec<f64>> = per_layer.iter().map(|t| t.cost.clone()).collect();

        // Config totals never exceed `ndev`, so tile indices are always
        // `< ndev` — one flat device-assignment array serves every edge
        // (and keeps edge builds independent of any graph-global maximum,
        // which cross-build memoization requires).
        let dev_of: Vec<usize> = (0..ndev).map(|t| cm.dev_of(t)).collect();

        // ---- stage 2: per-edge transfer tables ----
        let build_edge_cost = |s: LayerId, d: LayerId, in_idx: usize| -> Vec<f64> {
            let ld = g.layer(d);
            let (ts, td) = (&per_layer[s], &per_layer[d]);
            let (cs_len, cd_len) = (ts.configs.len(), td.configs.len());
            let mut cost = vec![0.0f64; cs_len * cd_len];
            // flatten regions to fixed-size arrays: the (m, k) overlap
            // loop is the hottest code in the library (§Perf log #3);
            // the kernel is shared with plan construction
            // (`plan::overlap`), so cost tables and materialized plans
            // charge bytes for exactly the same overlaps.
            let src_flat: Vec<Vec<FlatRegion>> =
                ts.tiles.iter().map(|tiles| tiles.iter().map(flatten).collect()).collect();
            // input regions per destination tile, shared across ci; one
            // scratch buffer reused across cj (§Perf log #4)
            let mut needs: Vec<Option<FlatRegion>> = Vec::with_capacity(ndev);
            for (cj_idx, dst_tiles) in td.tiles.iter().enumerate() {
                needs.clear();
                needs.extend(
                    dst_tiles.iter().map(|t| input_region(ld, in_idx, t).map(|r| flatten(&r))),
                );
                for (ci_idx, src_tiles) in src_flat.iter().enumerate() {
                    let mut worst = 0.0f64;
                    for (m, need) in needs.iter().enumerate() {
                        let Some(need) = need else { continue };
                        let dst_dev = dev_of[m];
                        let mut inbound = 0.0;
                        for (k, stile) in src_tiles.iter().enumerate() {
                            if dev_of[k] == dst_dev {
                                continue;
                            }
                            let overlap = overlap_elems(need, stile);
                            if overlap > 0 {
                                inbound += cm.devices.transfer_time(
                                    dev_of[k],
                                    dst_dev,
                                    overlap as f64 * 4.0,
                                ) + LINK_LATENCY;
                            }
                        }
                        if inbound > worst {
                            worst = inbound;
                        }
                    }
                    cost[ci_idx * cd_len + cj_idx] = worst;
                }
            }
            cost
        };
        let edge_cost = |&(s, d): &(LayerId, LayerId)| -> Arc<Vec<f64>> {
            let in_idx = cm.edge_in_idx(s, d);
            match (memo, &ctx) {
                (Some(m), Some(ctx)) => m.edge_cost(
                    &ctx.edge_key(&canons[s], &canons[d], in_idx),
                    || build_edge_cost(s, d, in_idx),
                ),
                _ => Arc::new(build_edge_cost(s, d, in_idx)),
            }
        };

        // Deduplicate: edges whose (producer op/shape, consumer
        // op/shapes, input slot) coincide have identical cost tables —
        // CNNs repeat layer pairs heavily (VGG stages, Inception
        // modules), so this cuts the expensive evaluations several-fold
        // (§Perf log #2). The within-graph signature carries the same
        // structural information as the memo's canonical forms, so the
        // cross-build memo is consulted once per *unique* edge.
        let edge_list: Vec<(LayerId, LayerId)> = g.edges.clone();
        let mut sig_to_unique: std::collections::HashMap<EdgeSig<'_>, usize> =
            std::collections::HashMap::new();
        let mut unique_edges: Vec<(LayerId, LayerId)> = Vec::new();
        let edge_unique: Vec<usize> = edge_list
            .iter()
            .map(|&(s, d)| {
                let (ls, ld) = (g.layer(s), g.layer(d));
                let sig = EdgeSig {
                    src_op: &ls.op,
                    src_in: &ls.in_shapes,
                    src_out: &ls.out_shape,
                    dst_op: &ld.op,
                    dst_out: &ld.out_shape,
                    dst_in: &ld.in_shapes,
                    in_idx: cm.edge_in_idx(s, d),
                };
                *sig_to_unique.entry(sig).or_insert_with(|| {
                    unique_edges.push((s, d));
                    unique_edges.len() - 1
                })
            })
            .collect();

        let nuniq = unique_edges.len();
        let ecells: Vec<OnceLock<Arc<Vec<f64>>>> = (0..nuniq).map(|_| OnceLock::new()).collect();
        let edge_workers = nthreads.min(nuniq).max(1);
        if edge_workers <= 1 {
            for (i, e) in unique_edges.iter().enumerate() {
                let _ = ecells[i].set(edge_cost(e));
            }
        } else {
            let cursor = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..edge_workers {
                    scope.spawn(|| loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= nuniq {
                            break;
                        }
                        let _ = ecells[i].set(edge_cost(&unique_edges[i]));
                    });
                }
            });
        }
        let unique_costs: Vec<Arc<Vec<f64>>> = ecells
            .into_iter()
            .map(|c| {
                c.into_inner()
                    .ok_or_else(|| OptError::Internal("edge stage left a cell unset".into()))
            })
            .collect::<Result<_>>()?;
        let edges: Vec<EdgeTable> = edge_list
            .iter()
            .zip(edge_unique.iter())
            .map(|(&(s, d), &u)| EdgeTable { src: s, dst: d, cost: unique_costs[u].to_vec() })
            .collect();
        Ok(CostTables { configs, node_cost, edges, ndev, budget })
    }

    pub fn num_configs(&self, layer: LayerId) -> usize {
        self.configs[layer].len()
    }

    /// Largest per-layer configuration count `C` (Table 2's parameter).
    pub fn max_configs(&self) -> usize {
        self.configs.iter().map(|c| c.len()).max().unwrap_or(0)
    }

    /// Full-strategy cost from config indices (must pick one index per
    /// layer). Equals `CostModel::t_o` of the corresponding strategy.
    pub fn strategy_cost(&self, idx: &[usize]) -> f64 {
        let mut t = 0.0;
        for (l, &i) in idx.iter().enumerate() {
            t += self.node_cost[l][i];
        }
        for e in &self.edges {
            t += e.at(idx[e.src], idx[e.dst], self.num_configs(e.dst));
        }
        t
    }

    /// Convert config indices to a `Strategy`.
    pub fn strategy_from_indices(&self, idx: &[usize]) -> Strategy {
        Strategy {
            configs: idx.iter().enumerate().map(|(l, &i)| self.configs[l][i]).collect(),
        }
    }

    /// Index of a given config in a layer's enumeration, if legal.
    pub fn index_of(&self, layer: LayerId, cfg: &PConfig) -> Option<usize> {
        self.configs[layer].iter().position(|c| c == cfg)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::graph::nets;

    #[test]
    fn tables_match_direct_evaluation() {
        let g = nets::lenet5(32).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let t = CostTables::build(&cm, 2).unwrap();
        // pick the serial config everywhere
        let idx: Vec<usize> = (0..g.num_layers())
            .map(|l| t.index_of(l, &PConfig::serial()).unwrap())
            .collect();
        let s = t.strategy_from_indices(&idx);
        let direct = cm.t_o(&s);
        let tabled = t.strategy_cost(&idx);
        assert!((direct - tabled).abs() < 1e-12, "direct {direct} vs tabled {tabled}");
    }

    #[test]
    fn every_layer_has_serial_config() {
        let g = nets::alexnet(64).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let t = CostTables::build(&CostModel::new(&g, &d), 4).unwrap();
        for l in 0..g.num_layers() {
            assert!(t.index_of(l, &PConfig::serial()).is_some());
            assert!(t.num_configs(l) >= 1);
        }
        assert!(t.max_configs() > 4);
    }

    #[test]
    fn thread_count_and_memo_do_not_change_a_single_bit() {
        // The determinism contract behind `BuildOptions`: serial,
        // parallel, cold-memoized, and warm-memoized builds all produce
        // bit-identical tables. (The cross-network exhaustive version
        // lives in tests/table_identity.rs.)
        let g = nets::lenet5(32).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let serial =
            CostTables::build_opts(&cm, 4, None, &BuildOptions { threads: 1, memo: None })
                .unwrap();
        let memo = TableMemo::new();
        let variants = [
            BuildOptions { threads: 3, memo: None },
            BuildOptions { threads: 3, memo: Some(&memo) }, // cold memo
            BuildOptions { threads: 1, memo: Some(&memo) }, // warm memo
        ];
        for opts in &variants {
            let t = CostTables::build_opts(&cm, 4, None, opts).unwrap();
            assert_eq!(t.configs, serial.configs);
            for (a, b) in t.node_cost.iter().zip(serial.node_cost.iter()) {
                let (a, b): (Vec<u64>, Vec<u64>) = (
                    a.iter().map(|x| x.to_bits()).collect(),
                    b.iter().map(|x| x.to_bits()).collect(),
                );
                assert_eq!(a, b);
            }
            for (e, f) in t.edges.iter().zip(serial.edges.iter()) {
                assert_eq!((e.src, e.dst), (f.src, f.dst));
                let (a, b): (Vec<u64>, Vec<u64>) = (
                    e.cost.iter().map(|x| x.to_bits()).collect(),
                    f.cost.iter().map(|x| x.to_bits()).collect(),
                );
                assert_eq!(a, b);
            }
        }
        let s = memo.stats();
        assert!(s.hits > 0, "warm rebuild never hit the memo: {s:?}");
    }

    #[test]
    fn same_shape_different_op_producers_do_not_alias() {
        // Dedup regression: `input` (sample-partition only) and a
        // shape-preserving conv (full 4-D config space) produce
        // identically shaped outputs that feed identical consumers. A
        // signature without the producer's op folds the two edges into
        // one table with the wrong dimensions/contents.
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new("alias");
        let x = b.input(8, 4, 16, 16).unwrap();
        let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), (1, 1)).unwrap(); // out == input's shape
        let d1 = b.conv2d("d1", x, 8, (3, 3), (1, 1), (1, 1)).unwrap();
        let d2 = b.conv2d("d2", c1, 8, (3, 3), (1, 1), (1, 1)).unwrap(); // same op/shapes as d1
        let g = b.finish().unwrap();
        // the trap is armed: both edges share output shapes but the
        // producers' config spaces differ
        assert_eq!(g.layer(x).out_shape, g.layer(c1).out_shape);
        assert_ne!(
            enumerate_configs(g.layer(x), 2).len(),
            enumerate_configs(g.layer(c1), 2).len()
        );

        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let t = CostTables::build(&cm, 2).unwrap();
        for (e, &(s, dd)) in t.edges.iter().zip(g.edges.iter()) {
            assert_eq!(
                e.cost.len(),
                t.num_configs(s) * t.num_configs(dd),
                "edge {s}->{dd} table dimensions aliased across different producer ops"
            );
        }
        // and the dedup'd tables still price transfers correctly: a
        // strategy that channel-partitions c1 (a config the input layer
        // cannot even express) must match direct evaluation
        let mut idx: Vec<usize> = (0..g.num_layers())
            .map(|l| t.index_of(l, &PConfig::serial()).unwrap())
            .collect();
        idx[c1] = t.index_of(c1, &PConfig::channel(2)).unwrap();
        idx[d2] = t.index_of(d2, &PConfig::data(2)).unwrap();
        idx[d1] = t.index_of(d1, &PConfig::new(1, 1, 2, 1)).unwrap();
        let s = t.strategy_from_indices(&idx);
        let direct = cm.t_o(&s);
        let tabled = t.strategy_cost(&idx);
        assert!((direct - tabled).abs() < 1e-12, "direct {direct} vs tabled {tabled}");
    }

    #[test]
    fn budget_masks_configs_and_both_backends_honor_it() {
        use crate::memory::{layer_peak_bytes, MemBudget};
        use crate::optimizer::{self, dfs};
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let free = CostTables::build(&cm, 2).unwrap();
        // a budget at 1.5x the largest per-layer minimum keeps every layer
        // feasible while masking the fattest configurations of the big ones
        let min_peaks: Vec<f64> = g
            .layers
            .iter()
            .map(|l| {
                free.configs[l.id]
                    .iter()
                    .map(|c| layer_peak_bytes(l, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let budget = 1.5 * min_peaks.iter().fold(0.0f64, |a, &b| a.max(b));
        let t = CostTables::build_budgeted(&cm, 2, Some(MemBudget { bytes_per_dev: budget }))
            .unwrap();
        let mut masked = 0usize;
        for l in &g.layers {
            assert!(t.num_configs(l.id) >= 1);
            assert!(t.num_configs(l.id) <= free.num_configs(l.id));
            masked += free.num_configs(l.id) - t.num_configs(l.id);
            for c in &t.configs[l.id] {
                assert!(layer_peak_bytes(l, c) <= budget, "kept config over budget");
            }
        }
        assert!(masked > 0, "budget {budget} masked nothing");
        // table dims shrank with the configs — infinite node cost is NOT
        // how infeasibility is encoded
        for (e, &(s, dd)) in t.edges.iter().zip(g.edges.iter()) {
            assert_eq!(e.cost.len(), t.num_configs(s) * t.num_configs(dd));
        }
        // both backends search the same reduced space and agree
        let dp = optimizer::optimize(&t);
        let brute = dfs::dfs_optimal(&t, None);
        assert!(brute.complete);
        assert!((dp.cost - brute.cost).abs() <= 1e-9 * brute.cost);
        for (l, cfg) in dp.strategy.configs.iter().enumerate() {
            assert!(t.configs[l].contains(cfg), "optimum uses a masked config");
            assert!(layer_peak_bytes(&g.layers[l], cfg) <= budget);
        }
    }

    #[test]
    fn fully_infeasible_layer_is_a_typed_error() {
        use crate::memory::MemBudget;
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let err = CostTables::build_budgeted(&cm, 2, Some(MemBudget::new(1)))
            .expect_err("a 1-byte budget cannot be satisfiable");
        match err {
            crate::error::OptError::Infeasible { layer, overshoot } => {
                assert!(!layer.is_empty());
                assert!(overshoot > 0);
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
    }

    #[test]
    fn infeasibility_reports_the_lowest_layer_id_at_any_thread_count() {
        // Parallel builds race layers, but the merge scans in id order —
        // the reported layer must match the serial scan's.
        use crate::memory::MemBudget;
        let g = nets::vgg16(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let budget = Some(MemBudget::new(1));
        let serial =
            CostTables::build_opts(&cm, 2, budget, &BuildOptions { threads: 1, memo: None })
                .expect_err("a 1-byte budget cannot be satisfiable");
        for threads in [2, 4, 7] {
            let par =
                CostTables::build_opts(&cm, 2, budget, &BuildOptions { threads, memo: None })
                    .expect_err("a 1-byte budget cannot be satisfiable");
            assert_eq!(par, serial, "threads={threads} changed the reported error");
        }
    }

    #[test]
    fn edge_tables_cover_all_graph_edges() {
        let g = nets::inception_v3(32).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let t = CostTables::build(&CostModel::new(&g, &d), 2).unwrap();
        assert_eq!(t.edges.len(), g.num_edges());
        for (e, &(s, dd)) in t.edges.iter().zip(g.edges.iter()) {
            assert_eq!((e.src, e.dst), (s, dd));
            assert_eq!(e.cost.len(), t.num_configs(s) * t.num_configs(dd));
        }
    }
}
