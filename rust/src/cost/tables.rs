//! Precomputed cost tables.
//!
//! Algorithm 1 consumes `t_C`, `t_S`, `t_X` as *precomputed* functions
//! (paper line 1). This module evaluates the cost model once for every
//! (layer, configuration) and (edge, configuration-pair) and hands the
//! optimizer flat arrays; the search itself then never touches tensors or
//! regions — only table lookups.
//!
//! [`CostTables::build_budgeted`] additionally masks memory-infeasible
//! configurations out of the enumeration before anything is priced, so a
//! [`MemBudget`]-constrained search is exact over the reduced space
//! (DESIGN.md §3).

use super::{CostModel, LINK_LATENCY};
use crate::error::{OptError, Result};
use crate::graph::{LayerId, OpKind};
use crate::memory::{self, MemBudget};
use crate::parallel::{enumerate_configs, input_region, output_tiles, PConfig, Strategy};
use crate::plan::overlap::{flatten, overlap_elems, FlatRegion};
use crate::tensor::Region;

/// Structural identity of an edge's cost table: edges whose producer
/// operator/shapes, consumer operator/shapes, and input slot coincide
/// have identical `t_X` matrices. The producer's *operator* matters, not
/// just its output shape, because `enumerate_configs` restricts the
/// config space per op (`allowed_dims`): an `Input` and a
/// shape-preserving `Conv2d` with equal outputs have different config
/// lists, so their edge tables have different dimensions and contents.
/// The producer's *input shapes* matter too: under a memory budget the
/// feasibility mask depends on the producer's parameter bytes (derived
/// from its input channels), so two same-op same-output producers with
/// different inputs can keep different config lists. Borrowed fields —
/// hashing allocates nothing (replaces the former `format!`-string
/// signature on the table-build hot path).
#[derive(Hash, PartialEq, Eq)]
struct EdgeSig<'a> {
    src_op: &'a OpKind,
    src_in: &'a [Vec<usize>],
    src_out: &'a [usize],
    dst_op: &'a OpKind,
    dst_out: &'a [usize],
    dst_in: &'a [Vec<usize>],
    in_idx: usize,
}

/// Cost matrix for one graph edge: `cost[ci * num_dst_cfgs + cj]`.
#[derive(Debug, Clone)]
pub struct EdgeTable {
    pub src: LayerId,
    pub dst: LayerId,
    pub cost: Vec<f64>,
}

impl EdgeTable {
    #[inline]
    pub fn at(&self, ci: usize, cj: usize, num_dst: usize) -> f64 {
        self.cost[ci * num_dst + cj]
    }
}

/// All tables for one (graph, device graph, device budget) triple.
#[derive(Debug, Clone)]
pub struct CostTables {
    /// Per-layer candidate configurations (enumeration order is the
    /// canonical config index used everywhere downstream).
    pub configs: Vec<Vec<PConfig>>,
    /// `t_C + t_S` per layer per config index.
    pub node_cost: Vec<Vec<f64>>,
    /// One table per graph edge, in graph edge order.
    pub edges: Vec<EdgeTable>,
}

impl CostTables {
    /// Evaluate the cost model exhaustively over the configuration space
    /// for `ndev` available devices (no memory constraint).
    pub fn build(cm: &CostModel, ndev: usize) -> CostTables {
        CostTables::build_budgeted(cm, ndev, None)
            .expect("an unbudgeted table build cannot be infeasible")
    }

    /// [`CostTables::build`] with an optional per-device memory budget:
    /// configurations whose [`memory::layer_peak_bytes`] exceed the
    /// budget are **dropped from the enumeration** before any cost is
    /// evaluated — not merely priced at infinity — so the table
    /// dimensions shrink, both search backends stay exact over the
    /// reduced space, and Algorithm 1's elimination-to-K=2 reduction is
    /// untouched. A layer with *no* feasible configuration surfaces as
    /// [`OptError::Infeasible`], naming the layer and its smallest
    /// overshoot. `budget = None` (or an infinite budget) reproduces the
    /// unconstrained tables exactly (pinned by `tests/memory.rs`).
    pub fn build_budgeted(
        cm: &CostModel,
        ndev: usize,
        budget: Option<MemBudget>,
    ) -> Result<CostTables> {
        let g = cm.graph;
        // Per layer: the kept configurations plus each one's index in the
        // *unmasked* enumeration — `measured_tc` is recorded against that
        // order, so masked tables must translate before the lookup.
        let mut configs: Vec<Vec<PConfig>> = Vec::with_capacity(g.layers.len());
        let mut orig_idx: Vec<Vec<usize>> = Vec::with_capacity(g.layers.len());
        for l in &g.layers {
            let all = enumerate_configs(l, ndev);
            match budget {
                None => {
                    orig_idx.push((0..all.len()).collect());
                    configs.push(all);
                }
                Some(b) => {
                    let mut kept = Vec::with_capacity(all.len());
                    let mut idx = Vec::with_capacity(all.len());
                    for (i, c) in all.iter().enumerate() {
                        if b.admits(memory::layer_peak_bytes(l, c)) {
                            kept.push(*c);
                            idx.push(i);
                        }
                    }
                    if kept.is_empty() {
                        let overshoot = all
                            .iter()
                            .map(|c| memory::layer_peak_bytes(l, c) - b.bytes_per_dev)
                            .fold(f64::INFINITY, f64::min);
                        return Err(OptError::Infeasible {
                            layer: l.name.clone(),
                            overshoot: overshoot.ceil().max(1.0) as u64,
                        });
                    }
                    configs.push(kept);
                    orig_idx.push(idx);
                }
            }
        }
        let node_cost: Vec<Vec<f64>> = g
            .layers
            .iter()
            .map(|l| {
                configs[l.id]
                    .iter()
                    .zip(orig_idx[l.id].iter())
                    .map(|(c, &oi)| {
                        let tc = match &cm.measured_tc {
                            Some(m) => m[l.id][oi],
                            None => cm.t_c(l, c),
                        };
                        tc + cm.t_s(l, c)
                    })
                    .collect()
            })
            .collect();
        // Tiles per (layer, config), computed once. `t_X` evaluation is the
        // table-build hot path (O(E * C^2 * T^2) overlap tests); hoisting
        // tile and input-region construction out of the config-pair loop
        // removes all allocation from the inner loops (§Perf log #1).
        let tiles: Vec<Vec<Vec<Region>>> = g
            .layers
            .iter()
            .map(|l| configs[l.id].iter().map(|c| output_tiles(&l.out_shape, c)).collect())
            .collect();
        let max_tiles = tiles
            .iter()
            .flat_map(|per_cfg| per_cfg.iter().map(|t| t.len()))
            .max()
            .unwrap_or(1);
        let dev_of: Vec<usize> = (0..max_tiles).map(|t| cm.dev_of(t)).collect();

        // Edge tables are independent — build them on all cores
        // (std::thread::scope; no rayon in the offline registry).
        let nthreads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let edge_list: Vec<(LayerId, LayerId)> = g.edges.clone();
        let build_edge = |&(s, d): &(LayerId, LayerId)| -> EdgeTable {
            {
                let in_idx = cm.edge_in_idx(s, d);
                let ld = g.layer(d);
                let (cs, cd) = (&configs[s], &configs[d]);
                let mut cost = vec![0.0f64; cs.len() * cd.len()];
                // flatten regions to fixed-size arrays: the (m, k) overlap
                // loop is the hottest code in the library (§Perf log #3);
                // the kernel is shared with plan construction
                // (`plan::overlap`), so cost tables and materialized plans
                // charge bytes for exactly the same overlaps.
                let src_flat: Vec<Vec<FlatRegion>> = (0..cs.len())
                    .map(|ci| tiles[s][ci].iter().map(flatten).collect())
                    .collect();
                for (cj_idx, _) in cd.iter().enumerate() {
                    let dst_tiles = &tiles[d][cj_idx];
                    // input regions per destination tile, shared across ci
                    let needs: Vec<Option<FlatRegion>> = dst_tiles
                        .iter()
                        .map(|t| input_region(ld, in_idx, t).map(|r| flatten(&r)))
                        .collect();
                    for (ci_idx, _) in cs.iter().enumerate() {
                        let src_tiles = &src_flat[ci_idx];
                        let mut worst = 0.0f64;
                        for (m, need) in needs.iter().enumerate() {
                            let Some(need) = need else { continue };
                            let dst_dev = dev_of[m];
                            let mut inbound = 0.0;
                            for (k, stile) in src_tiles.iter().enumerate() {
                                if dev_of[k] == dst_dev {
                                    continue;
                                }
                                let overlap = overlap_elems(need, stile);
                                if overlap > 0 {
                                    inbound += cm.devices.transfer_time(
                                        dev_of[k],
                                        dst_dev,
                                        overlap as f64 * 4.0,
                                    ) + LINK_LATENCY;
                                }
                            }
                            if inbound > worst {
                                worst = inbound;
                            }
                        }
                        cost[ci_idx * cd.len() + cj_idx] = worst;
                    }
                }
                EdgeTable { src: s, dst: d, cost }
            }
        };
        // Deduplicate: edges whose (producer op/shape, consumer
        // op/shapes, input slot) coincide have identical cost tables —
        // CNNs repeat layer pairs heavily (VGG stages, Inception
        // modules), so this cuts the expensive evaluations several-fold
        // (§Perf log #2).
        let mut sig_to_unique: std::collections::HashMap<EdgeSig<'_>, usize> =
            std::collections::HashMap::new();
        let mut unique_edges: Vec<(LayerId, LayerId)> = Vec::new();
        let edge_unique: Vec<usize> = edge_list
            .iter()
            .map(|&(s, d)| {
                let (ls, ld) = (g.layer(s), g.layer(d));
                let sig = EdgeSig {
                    src_op: &ls.op,
                    src_in: &ls.in_shapes,
                    src_out: &ls.out_shape,
                    dst_op: &ld.op,
                    dst_out: &ld.out_shape,
                    dst_in: &ld.in_shapes,
                    in_idx: cm.edge_in_idx(s, d),
                };
                *sig_to_unique.entry(sig).or_insert_with(|| {
                    unique_edges.push((s, d));
                    unique_edges.len() - 1
                })
            })
            .collect();

        let chunk = unique_edges.len().div_ceil(nthreads).max(1);
        let unique_tables: Vec<EdgeTable> = std::thread::scope(|scope| {
            let handles: Vec<_> = unique_edges
                .chunks(chunk)
                .map(|es| scope.spawn(move || es.iter().map(build_edge).collect::<Vec<_>>()))
                .collect();
            handles.into_iter().flat_map(|h| h.join().expect("edge builder panicked")).collect()
        });
        let edges: Vec<EdgeTable> = edge_list
            .iter()
            .zip(edge_unique.iter())
            .map(|(&(s, d), &u)| EdgeTable { src: s, dst: d, cost: unique_tables[u].cost.clone() })
            .collect();
        Ok(CostTables { configs, node_cost, edges })
    }

    pub fn num_configs(&self, layer: LayerId) -> usize {
        self.configs[layer].len()
    }

    /// Largest per-layer configuration count `C` (Table 2's parameter).
    pub fn max_configs(&self) -> usize {
        self.configs.iter().map(|c| c.len()).max().unwrap_or(0)
    }

    /// Full-strategy cost from config indices (must pick one index per
    /// layer). Equals `CostModel::t_o` of the corresponding strategy.
    pub fn strategy_cost(&self, idx: &[usize]) -> f64 {
        let mut t = 0.0;
        for (l, &i) in idx.iter().enumerate() {
            t += self.node_cost[l][i];
        }
        for e in &self.edges {
            t += e.at(idx[e.src], idx[e.dst], self.num_configs(e.dst));
        }
        t
    }

    /// Convert config indices to a `Strategy`.
    pub fn strategy_from_indices(&self, idx: &[usize]) -> Strategy {
        Strategy {
            configs: idx.iter().enumerate().map(|(l, &i)| self.configs[l][i]).collect(),
        }
    }

    /// Index of a given config in a layer's enumeration, if legal.
    pub fn index_of(&self, layer: LayerId, cfg: &PConfig) -> Option<usize> {
        self.configs[layer].iter().position(|c| c == cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::graph::nets;

    #[test]
    fn tables_match_direct_evaluation() {
        let g = nets::lenet5(32).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let t = CostTables::build(&cm, 2);
        // pick the serial config everywhere
        let idx: Vec<usize> = (0..g.num_layers())
            .map(|l| t.index_of(l, &PConfig::serial()).unwrap())
            .collect();
        let s = t.strategy_from_indices(&idx);
        let direct = cm.t_o(&s);
        let tabled = t.strategy_cost(&idx);
        assert!((direct - tabled).abs() < 1e-12, "direct {direct} vs tabled {tabled}");
    }

    #[test]
    fn every_layer_has_serial_config() {
        let g = nets::alexnet(64).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let t = CostTables::build(&CostModel::new(&g, &d), 4);
        for l in 0..g.num_layers() {
            assert!(t.index_of(l, &PConfig::serial()).is_some());
            assert!(t.num_configs(l) >= 1);
        }
        assert!(t.max_configs() > 4);
    }

    #[test]
    fn same_shape_different_op_producers_do_not_alias() {
        // Dedup regression: `input` (sample-partition only) and a
        // shape-preserving conv (full 4-D config space) produce
        // identically shaped outputs that feed identical consumers. A
        // signature without the producer's op folds the two edges into
        // one table with the wrong dimensions/contents.
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new("alias");
        let x = b.input(8, 4, 16, 16).unwrap();
        let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), (1, 1)).unwrap(); // out == input's shape
        let d1 = b.conv2d("d1", x, 8, (3, 3), (1, 1), (1, 1)).unwrap();
        let d2 = b.conv2d("d2", c1, 8, (3, 3), (1, 1), (1, 1)).unwrap(); // same op/shapes as d1
        let g = b.finish().unwrap();
        // the trap is armed: both edges share output shapes but the
        // producers' config spaces differ
        assert_eq!(g.layer(x).out_shape, g.layer(c1).out_shape);
        assert_ne!(
            enumerate_configs(g.layer(x), 2).len(),
            enumerate_configs(g.layer(c1), 2).len()
        );

        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let t = CostTables::build(&cm, 2);
        for (e, &(s, dd)) in t.edges.iter().zip(g.edges.iter()) {
            assert_eq!(
                e.cost.len(),
                t.num_configs(s) * t.num_configs(dd),
                "edge {s}->{dd} table dimensions aliased across different producer ops"
            );
        }
        // and the dedup'd tables still price transfers correctly: a
        // strategy that channel-partitions c1 (a config the input layer
        // cannot even express) must match direct evaluation
        let mut idx: Vec<usize> = (0..g.num_layers())
            .map(|l| t.index_of(l, &PConfig::serial()).unwrap())
            .collect();
        idx[c1] = t.index_of(c1, &PConfig::channel(2)).unwrap();
        idx[d2] = t.index_of(d2, &PConfig::data(2)).unwrap();
        idx[d1] = t.index_of(d1, &PConfig::new(1, 1, 2, 1)).unwrap();
        let s = t.strategy_from_indices(&idx);
        let direct = cm.t_o(&s);
        let tabled = t.strategy_cost(&idx);
        assert!((direct - tabled).abs() < 1e-12, "direct {direct} vs tabled {tabled}");
    }

    #[test]
    fn budget_masks_configs_and_both_backends_honor_it() {
        use crate::memory::{layer_peak_bytes, MemBudget};
        use crate::optimizer::{self, dfs};
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let free = CostTables::build(&cm, 2);
        // a budget at 1.5x the largest per-layer minimum keeps every layer
        // feasible while masking the fattest configurations of the big ones
        let min_peaks: Vec<f64> = g
            .layers
            .iter()
            .map(|l| {
                free.configs[l.id]
                    .iter()
                    .map(|c| layer_peak_bytes(l, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let budget = 1.5 * min_peaks.iter().fold(0.0f64, |a, &b| a.max(b));
        let t = CostTables::build_budgeted(&cm, 2, Some(MemBudget { bytes_per_dev: budget }))
            .unwrap();
        let mut masked = 0usize;
        for l in &g.layers {
            assert!(t.num_configs(l.id) >= 1);
            assert!(t.num_configs(l.id) <= free.num_configs(l.id));
            masked += free.num_configs(l.id) - t.num_configs(l.id);
            for c in &t.configs[l.id] {
                assert!(layer_peak_bytes(l, c) <= budget, "kept config over budget");
            }
        }
        assert!(masked > 0, "budget {budget} masked nothing");
        // table dims shrank with the configs — infinite node cost is NOT
        // how infeasibility is encoded
        for (e, &(s, dd)) in t.edges.iter().zip(g.edges.iter()) {
            assert_eq!(e.cost.len(), t.num_configs(s) * t.num_configs(dd));
        }
        // both backends search the same reduced space and agree
        let dp = optimizer::optimize(&t);
        let brute = dfs::dfs_optimal(&t, None);
        assert!(brute.complete);
        assert!((dp.cost - brute.cost).abs() <= 1e-9 * brute.cost);
        for (l, cfg) in dp.strategy.configs.iter().enumerate() {
            assert!(t.configs[l].contains(cfg), "optimum uses a masked config");
            assert!(layer_peak_bytes(&g.layers[l], cfg) <= budget);
        }
    }

    #[test]
    fn fully_infeasible_layer_is_a_typed_error() {
        use crate::memory::MemBudget;
        let g = nets::lenet5(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let err = CostTables::build_budgeted(&cm, 2, Some(MemBudget::new(1)))
            .expect_err("a 1-byte budget cannot be satisfiable");
        match err {
            crate::error::OptError::Infeasible { layer, overshoot } => {
                assert!(!layer.is_empty());
                assert!(overshoot > 0);
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
    }

    #[test]
    fn edge_tables_cover_all_graph_edges() {
        let g = nets::inception_v3(32).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let t = CostTables::build(&CostModel::new(&g, &d), 2);
        assert_eq!(t.edges.len(), g.num_edges());
        for (e, &(s, dd)) in t.edges.iter().zip(g.edges.iter()) {
            assert_eq!((e.src, e.dst), (s, dd));
            assert_eq!(e.cost.len(), t.num_configs(s) * t.num_configs(dd));
        }
    }
}
