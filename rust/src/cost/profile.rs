//! Measured-mode `t_C`: profile layer configurations by executing their
//! AOT artifacts on the PJRT runtime (the paper's §5.1 methodology —
//! "estimated by processing the layer under that configuration multiple
//! times on the device and measuring the average execution time").
//!
//! Wall-clock on this substrate is CPU interpret-mode time, so measured
//! values are *rescaled* to the device model: we time each configuration,
//! normalize by the serial configuration's time, and apply that relative
//! factor to the analytic serial estimate. This preserves exactly what
//! measurement adds over the analytic model — the relative efficiency of
//! differently-shaped tiles — without pretending a CPU is a P100.

use std::time::Instant;

use anyhow::Result;

use crate::cost::CostModel;
use crate::exec::keys;
use crate::graph::{CompGraph, OpKind};
use crate::parallel::{enumerate_configs, output_tiles, PConfig, DIM_C, DIM_H, DIM_N, DIM_W};
use crate::runtime::{ArtifactStore, Engine};
use crate::tensor::Tensor;

/// Profile every (layer, configuration) of `graph` whose artifacts exist,
/// producing the `measured_tc` table for [`CostModel`]. Configurations
/// without artifacts fall back to the analytic estimate.
///
/// `reps` executions are averaged per configuration (paper: "multiple
/// times ... average execution time").
pub fn profile_graph(
    store: &ArtifactStore,
    graph: &CompGraph,
    cm: &CostModel,
    ndev: usize,
    reps: usize,
) -> Result<Vec<Vec<f64>>> {
    let mut engine = Engine::new(store.clone())?;
    let mut out = Vec::with_capacity(graph.num_layers());
    for l in &graph.layers {
        let cfgs = enumerate_configs(l, ndev);
        // serial analytic anchor for rescaling
        let serial_analytic = cm.t_c(l, &PConfig::serial());
        let serial_measured = measure_cfg(&mut engine, graph, l.id, &PConfig::serial(), reps);
        let mut row = Vec::with_capacity(cfgs.len());
        for cfg in &cfgs {
            let analytic = cm.t_c(l, cfg);
            let t = match (serial_measured, measure_cfg(&mut engine, graph, l.id, cfg, reps)) {
                (Some(base), Some(m)) if base > 0.0 => serial_analytic * (m / base),
                _ => analytic,
            };
            row.push(t);
        }
        out.push(row);
    }
    Ok(out)
}

/// Measure one configuration's per-tile forward-artifact time, seconds.
/// Returns `None` when no artifact exists for the shard shape.
fn measure_cfg(
    engine: &mut Engine,
    graph: &CompGraph,
    id: usize,
    cfg: &PConfig,
    reps: usize,
) -> Option<f64> {
    let l = graph.layer(id);
    let tiles = output_tiles(&l.out_shape, cfg);
    let t0 = &tiles[0];
    let (nt, ct) = (t0.end(DIM_N) - t0.start(DIM_N), t0.end(DIM_C) - t0.start(DIM_C));
    let (key, inputs): (String, Vec<Tensor>) = match &l.op {
        OpKind::Conv2d { kernel, .. } => {
            let cin = l.in_shapes[0][DIM_C];
            let (ht, wt) = (t0.end(DIM_H) - t0.start(DIM_H), t0.end(DIM_W) - t0.start(DIM_W));
            let (hs, ws) = (ht + kernel.0 - 1, wt + kernel.1 - 1);
            (
                keys::conv2d(true, nt, cin, hs, ws, ct, kernel.0, true),
                vec![
                    Tensor::zeros(&[nt, cin, hs, ws]),
                    Tensor::zeros(&[ct, cin, kernel.0, kernel.1]),
                    Tensor::zeros(&[ct]),
                ],
            )
        }
        OpKind::Pool2d { kernel, .. } => {
            let (ht, wt) = (t0.end(DIM_H) - t0.start(DIM_H), t0.end(DIM_W) - t0.start(DIM_W));
            let (hs, ws) = (ht * kernel.0, wt * kernel.1);
            (
                keys::maxpool(true, nt, ct, hs, ws, kernel.0),
                vec![Tensor::zeros(&[nt, ct, hs, ws])],
            )
        }
        OpKind::FullyConnected { .. } => {
            let cin: usize = l.in_shapes[0][1..].iter().product();
            let relu = true; // profile the relu variant; cost is ~identical
            (
                keys::fc(true, nt, cin, ct, relu),
                vec![
                    Tensor::zeros(&[nt, cin]),
                    Tensor::zeros(&[cin, ct]),
                    Tensor::zeros(&[ct]),
                ],
            )
        }
        _ => return None,
    };
    if !engine.store().has(&key) {
        return None;
    }
    // warmup (compile)
    engine.run(&key, &inputs).ok()?;
    let t0 = Instant::now();
    for _ in 0..reps.max(1) {
        engine.run(&key, &inputs).ok()?;
    }
    Some(t0.elapsed().as_secs_f64() / reps.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::graph::nets;

    fn store() -> Option<ArtifactStore> {
        ArtifactStore::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).ok()
    }

    #[test]
    fn profile_minicnn_produces_full_tables() {
        let Some(store) = store() else {
            eprintln!("skipping (run `make artifacts`)");
            return;
        };
        let g = nets::minicnn(store.batch).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let measured = profile_graph(&store, &g, &cm, 4, 2).unwrap();
        assert_eq!(measured.len(), g.num_layers());
        for (l, row) in measured.iter().enumerate() {
            assert_eq!(row.len(), enumerate_configs(g.layer(l), 4).len());
            assert!(row.iter().all(|&t| t.is_finite() && t >= 0.0));
        }
        // measured mode must flow into tables and still admit a search
        let mut cm2 = CostModel::new(&g, &d);
        cm2.measured_tc = Some(measured);
        let tables = crate::cost::CostTables::build(&cm2, 4).unwrap();
        let opt = crate::optimizer::optimize(&tables);
        assert!(opt.cost.is_finite() && opt.cost > 0.0);
    }
}
