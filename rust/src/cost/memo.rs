//! Content-addressed memoization of per-layer and per-edge cost tables
//! (DESIGN.md §7).
//!
//! Per-layer node tables and per-edge transfer tables are pure functions
//! of local structure: a layer's operator/parameters/shapes plus the
//! cluster, budget, and cost-model policies fully determine its config
//! enumeration, feasibility mask, and `t_C + t_S` row; an edge's table is
//! likewise determined by its two endpoint layers and input slot. This
//! module keys those results by value — [`LayerTableKey`] /
//! [`EdgeTableKey`] built from the position- and name-free layer
//! canonical form (`graph::spec`), the structural
//! [`ClusterFingerprint`](crate::device::ClusterFingerprint), the budget
//! bytes' bit pattern, and the sync/placement policies — so two graphs
//! that differ in one branch rebuild only the changed layers. It is the
//! per-layer analogue of the whole-graph digest dedup the plan service
//! performs, and it composes with it: the service consults its
//! single-flight state memo first, and only whole-graph misses reach this
//! per-layer memo.
//!
//! Entries are built **single-flight**: concurrent requests for one key
//! block on one build (the [`SingleFlightLru`](crate::util::sync::SingleFlightLru) cell idiom
//! shared with `planner::service::StateMemo`, model-checked under loom
//! by the `rust/modelcheck` crate), so a service hammered with
//! overlapping graphs builds each distinct layer exactly once — `misses`
//! counts builds that actually ran. Both maps are LRU-bounded, and
//! failed builds (an infeasible layer under a budget) are evicted
//! immediately rather than cached, so a later identical request retries.
//!
//! Memoization is bypassed entirely for measured-`t_C` cost models: the
//! measured timings are per-session arrays, not content-addressable
//! structure.

// Same panic boundary as `tables.rs`: the memo sits inside long-lived
// services, so failures propagate as typed errors, never panics.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::device::ClusterFingerprint;
use crate::error::Result;
use crate::memory::MemBudget;
use crate::parallel::{PConfig, Placement};
use crate::tensor::Region;
use crate::util::sync::{lock, SingleFlightLru};

use super::{CostModel, SyncModel};

/// Identity of one layer's node-cost table: the layer's position-free
/// canonical form plus everything else its enumeration, feasibility
/// mask, and `t_C + t_S` row read. Opaque — constructed internally by
/// the table builder, compared and hashed by the memo.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct LayerTableKey {
    layer: Arc<str>,
    cluster: Arc<ClusterFingerprint>,
    ndev: usize,
    /// `MemBudget::bytes_per_dev` bit pattern; `None` = unbudgeted.
    budget_bits: Option<u64>,
    sync: SyncModel,
    placement: Placement,
}

/// Identity of one edge's transfer-cost table: both endpoints' canonical
/// forms, the consumer input slot, and the build context that shapes the
/// two config lists and the tile placement. `t_X` never reads the sync
/// model, so it is deliberately absent.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EdgeTableKey {
    src: Arc<str>,
    dst: Arc<str>,
    in_idx: usize,
    cluster: Arc<ClusterFingerprint>,
    ndev: usize,
    budget_bits: Option<u64>,
    placement: Placement,
}

/// The build-wide components of memo keys, captured once per table build
/// and combined with per-layer canonical forms as keys are needed.
#[derive(Debug, Clone)]
pub struct KeyContext {
    cluster: Arc<ClusterFingerprint>,
    ndev: usize,
    budget_bits: Option<u64>,
    sync: SyncModel,
    placement: Placement,
}

impl KeyContext {
    /// Capture everything but the layer identity from one build's inputs.
    pub fn new(cm: &CostModel<'_>, ndev: usize, budget: Option<MemBudget>) -> KeyContext {
        KeyContext {
            cluster: Arc::new(cm.devices.fingerprint()),
            ndev,
            budget_bits: budget.map(|b| b.bytes_per_dev.to_bits()),
            sync: cm.sync,
            placement: cm.placement,
        }
    }

    /// The node-table key for a layer with canonical form `canon`.
    pub(crate) fn layer_key(&self, canon: &Arc<str>) -> LayerTableKey {
        LayerTableKey {
            layer: Arc::clone(canon),
            cluster: Arc::clone(&self.cluster),
            ndev: self.ndev,
            budget_bits: self.budget_bits,
            sync: self.sync,
            placement: self.placement,
        }
    }

    /// The edge-table key for an edge between layers with canonical forms
    /// `src` and `dst`, feeding the consumer's input slot `in_idx`.
    pub(crate) fn edge_key(&self, src: &Arc<str>, dst: &Arc<str>, in_idx: usize) -> EdgeTableKey {
        EdgeTableKey {
            src: Arc::clone(src),
            dst: Arc::clone(dst),
            in_idx,
            cluster: Arc::clone(&self.cluster),
            ndev: self.ndev,
            budget_bits: self.budget_bits,
            placement: self.placement,
        }
    }
}

/// One layer's memoized tables: the (budget-masked) config list, each
/// kept config's index in the unmasked enumeration (the `measured_tc`
/// translation), the `t_C + t_S` cost row, and the output tiling per
/// config (reused by every edge build touching the layer).
#[derive(Debug)]
pub struct LayerTables {
    /// Kept configurations, in canonical enumeration order.
    pub configs: Vec<PConfig>,
    /// Each kept config's index in the unmasked enumeration.
    pub orig_idx: Vec<usize>,
    /// `t_C + t_S` per kept config.
    pub cost: Vec<f64>,
    /// Output tiles per kept config (row-major tile order).
    pub tiles: Vec<Vec<Region>>,
}

// Single-flight LRU maps from `util::sync` (the loom-model-checked
// facade): the cell payloads are the finished build artifacts.
type NodeMap = SingleFlightLru<LayerTableKey, Result<Arc<LayerTables>>>;
type EdgeMap = SingleFlightLru<EdgeTableKey, Arc<Vec<f64>>>;

/// Point-in-time counters of a [`TableMemo`] (monotone except the cached
/// sizes, which track the LRU maps).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoStats {
    /// Lookups answered from a completed (or in-flight) build.
    pub hits: u64,
    /// Lookups that ran a build — exactly the number of builds performed.
    pub misses: u64,
    /// Layer (node-table) entries currently resident.
    pub layers_cached: usize,
    /// Edge-table entries currently resident.
    pub edges_cached: usize,
}

/// The shared, thread-safe per-layer/per-edge cost-table memo. One
/// instance typically lives behind a `PlanService` (every build routed
/// through the service reuses it) or a `Planner` session.
pub struct TableMemo {
    nodes: Mutex<NodeMap>,
    edges: Mutex<EdgeMap>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl TableMemo {
    /// Default capacities: 512 layer entries, 1024 edge entries — several
    /// ImageNet-scale networks' worth of distinct layers, with the edge
    /// cap bounding the dominant `C^2`-sized cost matrices.
    pub fn new() -> TableMemo {
        TableMemo::with_capacity(512, 1024)
    }

    /// A memo with explicit per-map entry bounds (both must be >= 1).
    pub fn with_capacity(layer_entries: usize, edge_entries: usize) -> TableMemo {
        TableMemo {
            nodes: Mutex::new(SingleFlightLru::new(layer_entries.max(1))),
            edges: Mutex::new(SingleFlightLru::new(edge_entries.max(1))),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Current counters (see [`MemoStats`]).
    pub fn stats(&self) -> MemoStats {
        MemoStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            layers_cached: lock(&self.nodes).len(),
            edges_cached: lock(&self.edges).len(),
        }
    }

    fn note(&self, ran: bool) {
        if ran {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The node tables for `key`, building single-flight via `build` on a
    /// miss. A failed build is returned but *not* retained, so an
    /// identical later request retries instead of replaying the failure.
    pub(crate) fn node_tables(
        &self,
        key: &LayerTableKey,
        build: impl FnOnce() -> Result<LayerTables>,
    ) -> Result<Arc<LayerTables>> {
        let cell = lock(&self.nodes).cell(key);
        let (out, ran) = cell.get_or_init(|| build().map(Arc::new));
        self.note(ran);
        match out {
            Ok(tables) => Ok(tables),
            Err(e) => {
                lock(&self.nodes).forget(key, &cell);
                Err(e)
            }
        }
    }

    /// The transfer-cost matrix for `key`, building single-flight via
    /// `build` on a miss (edge builds are infallible).
    pub(crate) fn edge_cost(
        &self,
        key: &EdgeTableKey,
        build: impl FnOnce() -> Vec<f64>,
    ) -> Arc<Vec<f64>> {
        let cell = lock(&self.edges).cell(key);
        let (cost, ran) = cell.get_or_init(|| Arc::new(build()));
        self.note(ran);
        cost
    }
}

impl Default for TableMemo {
    fn default() -> TableMemo {
        TableMemo::new()
    }
}

impl std::fmt::Debug for TableMemo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TableMemo").field("stats", &self.stats()).finish()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::error::OptError;
    use crate::graph::nets;

    fn ctx(ndev: usize, budget: Option<MemBudget>) -> (KeyContext, KeyContext) {
        let g = nets::lenet5(32).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        (KeyContext::new(&cm, ndev, budget), KeyContext::new(&cm, ndev, budget))
    }

    #[test]
    fn keys_compare_by_value_across_contexts() {
        let (a, b) = ctx(2, Some(MemBudget::new(1 << 30)));
        let canon: Arc<str> = Arc::from("layer");
        assert_eq!(a.layer_key(&canon), b.layer_key(&canon));
        let other: Arc<str> = Arc::from("other");
        assert_ne!(a.layer_key(&canon), a.layer_key(&other));
        assert_eq!(a.edge_key(&canon, &other, 0), b.edge_key(&canon, &other, 0));
        assert_ne!(a.edge_key(&canon, &other, 0), a.edge_key(&canon, &other, 1));
        // the budget is part of the identity
        let (c, _) = ctx(2, None);
        assert_ne!(a.layer_key(&canon), c.layer_key(&canon));
    }

    #[test]
    fn memo_builds_once_then_hits() {
        let memo = TableMemo::new();
        let (a, _) = ctx(2, None);
        let canon: Arc<str> = Arc::from("layer");
        let key = a.layer_key(&canon);
        let mut builds = 0;
        for _ in 0..3 {
            let t = memo
                .node_tables(&key, || {
                    builds += 1;
                    Ok(LayerTables {
                        configs: vec![PConfig::serial()],
                        orig_idx: vec![0],
                        cost: vec![1.0],
                        tiles: vec![vec![]],
                    })
                })
                .unwrap();
            assert_eq!(t.cost, vec![1.0]);
        }
        assert_eq!(builds, 1, "single-flight: one build for three lookups");
        let s = memo.stats();
        assert_eq!((s.hits, s.misses, s.layers_cached), (2, 1, 1));
    }

    #[test]
    fn failed_builds_are_not_retained() {
        let memo = TableMemo::new();
        let (a, _) = ctx(2, Some(MemBudget::new(1)));
        let canon: Arc<str> = Arc::from("layer");
        let key = a.layer_key(&canon);
        let fail = || Err(OptError::Infeasible { layer: "layer".into(), overshoot: 7 });
        assert!(memo.node_tables(&key, fail).is_err());
        assert_eq!(memo.stats().layers_cached, 0, "failure evicted for retry");
        // the retry runs the builder again
        let mut reran = false;
        let _ = memo.node_tables(&key, || {
            reran = true;
            fail()
        });
        assert!(reran);
        assert_eq!(memo.stats().misses, 2);
    }

    #[test]
    fn lru_bounds_both_maps() {
        let memo = TableMemo::with_capacity(2, 2);
        let (a, _) = ctx(2, None);
        for i in 0..5 {
            let canon: Arc<str> = Arc::from(format!("layer{i}").as_str());
            let key = a.layer_key(&canon);
            let _ = memo.node_tables(&key, || {
                Ok(LayerTables { configs: vec![], orig_idx: vec![], cost: vec![], tiles: vec![] })
            });
            let ekey = a.edge_key(&canon, &canon, 0);
            let _ = memo.edge_cost(&ekey, Vec::new);
        }
        let s = memo.stats();
        assert!(s.layers_cached <= 2 && s.edges_cached <= 2, "{s:?}");
        assert_eq!(s.misses, 10, "every distinct key built exactly once");
    }
}
