//! The cost model (paper §5.1).
//!
//! Three cost functions drive the strategy search:
//!
//! * `t_C(l, c)` — time to process layer `l` under configuration `c`
//!   (forward + backward), from a roofline device model;
//! * `t_X(e, c_i, c_j)` — time to move edge `e`'s tensor between the
//!   producer's and consumer's partitions;
//! * `t_S(l, c)` — parameter-server synchronization time.
//!
//! `t_O(G, D, S) = Σ t_C + Σ t_S + Σ t_X` (Equation 1).
//!
//! The paper *measures* `t_C` per configuration on the target GPU; this
//! reproduction defaults to an analytic roofline calibrated to the same
//! hardware (P100) — see DESIGN.md §2 — and supports a measured mode that
//! overrides `t_C` with timings from PJRT executions.

pub mod memo;
pub mod profile;
pub mod tables;

pub use memo::{MemoStats, TableMemo};
pub use tables::{resolved_build_workers, BuildOptions, CostTables, EdgeTable};

use crate::device::DeviceGraph;
use crate::graph::{CompGraph, Layer, LayerId, OpKind};
use crate::parallel::{
    input_region, output_tiles, param_sharding, PConfig, Placement, Strategy, DIM_C, DIM_N,
};

/// Per-transfer fixed latency, seconds (message setup; paper assumption 2
/// idealizes this away, we keep a small realistic constant that matters
/// only for many-tiny-transfer configurations).
pub(crate) const LINK_LATENCY: f64 = 2e-6;

/// How parameter replicas synchronize (the `t_S` protocol).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SyncModel {
    /// The parameter server for each layer is sharded across the replica
    /// devices themselves (bandwidth-optimal, allreduce-equivalent; what
    /// a tuned runtime achieves). Default.
    #[default]
    Sharded,
    /// A central per-layer parameter server on the first replica's host:
    /// every replica round-trips its full gradient shard through the PS
    /// ingress, which serializes (the paper's §5.1 description, and
    /// representative of 2018 PS deployments).
    Central,
}

/// The cost model over one computation graph and one device graph.
pub struct CostModel<'a> {
    pub graph: &'a CompGraph,
    pub devices: &'a DeviceGraph,
    /// Parameter-synchronization protocol used by `t_S`.
    pub sync: SyncModel,
    /// Tile -> device placement policy.
    pub placement: Placement,
    /// Per-layer override of `t_C` (seconds per configuration), filled by
    /// the measured-profile path; indexed `[layer][config index]` against
    /// the same enumeration order as `parallel::enumerate_configs`.
    pub measured_tc: Option<Vec<Vec<f64>>>,
}

impl<'a> CostModel<'a> {
    pub fn new(graph: &'a CompGraph, devices: &'a DeviceGraph) -> CostModel<'a> {
        CostModel {
            graph,
            devices,
            sync: SyncModel::default(),
            placement: Placement::default(),
            measured_tc: None,
        }
    }

    /// Same model with a different sync protocol (ablation hook).
    pub fn with_sync(mut self, sync: SyncModel) -> CostModel<'a> {
        self.sync = sync;
        self
    }

    /// Same model with a different placement policy (ablation hook).
    pub fn with_placement(mut self, placement: Placement) -> CostModel<'a> {
        self.placement = placement;
        self
    }

    /// Device id running tile `t` under the placement policy. The
    /// `(nodes, gpus_per_node)` geometry comes from the device graph's
    /// validated [`DeviceGraph::placement_shape`] — the same helper every
    /// placement consumer routes through, so `dev_of` can never disagree
    /// with `ExecutionPlan.tile_dev` (which is derived from it).
    pub fn dev_of(&self, t: usize) -> usize {
        let (nodes, gpn) = self.devices.placement_shape();
        self.placement.device_of(t, nodes, gpn)
    }

    /// `t_C`: forward+backward time for one layer under `cfg` (the time of
    /// one tile — tiles run in parallel on distinct devices).
    pub fn t_c(&self, layer: &Layer, cfg: &PConfig) -> f64 {
        if matches!(layer.op, OpKind::Input) {
            return 0.0;
        }
        let total = cfg.total() as f64;
        let cm = &self.devices.compute;
        let flops = layer.train_flops() / total;
        let bytes = layer.mem_bytes() / total;
        let eff = self.efficiency(layer, cfg);
        let t_compute = if eff > 0.0 { flops / (eff * cm.peak_flops) } else { 0.0 };
        let t_mem = bytes / cm.mem_bw;
        t_compute.max(t_mem) + cm.overhead
    }

    /// Sustained fraction of peak for this layer/tile. Dense ops run at
    /// their library efficiency, attenuated when the per-device tile gets
    /// too small to fill the execution units (this is what the paper's
    /// measured `t_C` captures and what makes e.g. a 16-way-split FC layer
    /// slower per-sample than a 4-way split).
    fn efficiency(&self, layer: &Layer, cfg: &PConfig) -> f64 {
        let cm = &self.devices.compute;
        match &layer.op {
            OpKind::Conv2d { .. } => {
                // occupancy ~ output positions per device
                let positions = (layer.out_shape[0] / cfg.deg[0])
                    * (layer.out_shape[2] / cfg.deg[2])
                    * (layer.out_shape[3] / cfg.deg[3]);
                cm.conv_eff * saturate(positions as f64, 256.0)
            }
            OpKind::FullyConnected { .. } => {
                // GEMM M dimension = samples per device, N = output
                // features per device. Skinny GEMMs (small M from deep
                // sample splits, or small N heads like a 1000-way
                // classifier) run far below peak on real hardware — this
                // is what makes moderate degrees optimal for FC layers
                // (paper Figure 3).
                let m = layer.out_shape[DIM_N] / cfg.deg[DIM_N];
                let n = layer.out_shape[DIM_C] / cfg.deg[DIM_C];
                cm.gemm_eff * saturate(m as f64, 8.0) * saturate(n as f64, 1500.0)
            }
            _ => 1.0, // memory-bound ops take the t_mem branch anyway
        }
    }

    /// `t_S`: parameter synchronization time (paper cost function 3).
    ///
    /// Parameters are sharded by the channel degree and replicated across
    /// the sample/height/width degrees; replicas must exchange gradients
    /// and updated parameters each step. The parameter server for each
    /// shard is itself sharded across the replica devices (the standard
    /// bandwidth-optimal layout, and what the paper's Legion data movement
    /// achieves): each of `R` replicas sends `(R-1)/R` of its gradient
    /// shard out and receives `(R-1)/R` of the updated parameters back, so
    ///
    /// `t_S ≈ 2 · shard_bytes · (R-1)/R / bw_eff`,
    ///
    /// where `bw_eff` is the slowest link among the replicas (the NIC when
    /// they span nodes). Distinct channel shards synchronize in parallel.
    pub fn t_s(&self, layer: &Layer, cfg: &PConfig) -> f64 {
        if !layer.has_params() {
            return 0.0;
        }
        let sh = param_sharding(layer, cfg);
        if sh.replicas <= 1 {
            return 0.0; // unique copy, no synchronization needed
        }
        let tiles = cfg.total();
        // Device of tile t is t (contiguous assignment). Group devices by
        // shard = channel tile index.
        let mut worst: f64 = 0.0;
        for shard in 0..sh.shards {
            let replicas: Vec<usize> = (0..tiles)
                .filter(|&t| shard_of_tile(cfg, t) == shard)
                .map(|t| self.dev_of(t))
                .collect();
            let r = replicas.len() as f64;
            // The exchange runs at the slowest link in the replica group
            // (the shared-NIC effective rate once the group spans nodes).
            let bw = replicas
                .iter()
                .skip(1)
                .map(|&d| self.devices.bandwidth(replicas[0], d))
                .fold(self.devices.host_bw, f64::min);
            let t = match self.sync {
                SyncModel::Sharded => {
                    2.0 * sh.shard_bytes * (r - 1.0) / r / bw + LINK_LATENCY * (r - 1.0)
                }
                SyncModel::Central => {
                    // serialized round-trips at the PS ingress
                    2.0 * sh.shard_bytes * r / self.devices.host_bw.min(bw)
                        + LINK_LATENCY * r
                }
            };
            worst = worst.max(t);
        }
        worst
    }

    /// `t_X`: time to deliver the tensor on edge `src -> dst` from the
    /// producer's partitioning `cfg_src` to the consumer's `cfg_dst`.
    /// `in_idx` is which input of `dst` this edge feeds.
    ///
    /// Bytes already resident on the consuming device are free; remote
    /// bytes are charged at the link bandwidth (assumption 2). Transfers
    /// towards distinct destination devices proceed in parallel, so the
    /// cost is the worst destination's inbound time.
    pub fn t_x(
        &self,
        src: &Layer,
        dst: &Layer,
        in_idx: usize,
        cfg_src: &PConfig,
        cfg_dst: &PConfig,
    ) -> f64 {
        let src_tiles = output_tiles(&src.out_shape, cfg_src);
        let dst_tiles = output_tiles(&dst.out_shape, cfg_dst);
        let mut worst: f64 = 0.0;
        for (m, dtile) in dst_tiles.iter().enumerate() {
            let Some(need) = input_region(dst, in_idx, dtile) else {
                continue;
            };
            let dst_dev = self.dev_of(m);
            let mut inbound = 0.0;
            for (k, stile) in src_tiles.iter().enumerate() {
                let src_dev = self.dev_of(k);
                if src_dev == dst_dev {
                    continue; // already local
                }
                let overlap = need.overlap_volume(stile);
                if overlap > 0 {
                    inbound += self.devices.transfer_time(src_dev, dst_dev, overlap as f64 * 4.0)
                        + LINK_LATENCY;
                }
            }
            worst = worst.max(inbound);
        }
        worst
    }

    /// Bytes moved over links for one edge (communication-cost accounting,
    /// Figure 8). Counts every remote byte once.
    pub fn x_bytes(
        &self,
        src: &Layer,
        dst: &Layer,
        in_idx: usize,
        cfg_src: &PConfig,
        cfg_dst: &PConfig,
    ) -> f64 {
        let src_tiles = output_tiles(&src.out_shape, cfg_src);
        let dst_tiles = output_tiles(&dst.out_shape, cfg_dst);
        let mut bytes = 0.0;
        for (m, dtile) in dst_tiles.iter().enumerate() {
            let Some(need) = input_region(dst, in_idx, dtile) else {
                continue;
            };
            for (k, stile) in src_tiles.iter().enumerate() {
                if self.dev_of(k) == self.dev_of(m) {
                    continue;
                }
                bytes += need.overlap_volume(stile) as f64 * 4.0;
            }
        }
        bytes
    }

    /// Bytes moved for parameter synchronization of one layer per step:
    /// with the sharded PS each replica exchanges `2·(R-1)/R` of its shard,
    /// so the layer total is `2 · shard_bytes · (R-1) · shards`.
    pub fn s_bytes(&self, layer: &Layer, cfg: &PConfig) -> f64 {
        if !layer.has_params() {
            return 0.0;
        }
        let sh = param_sharding(layer, cfg);
        if sh.replicas <= 1 {
            return 0.0;
        }
        2.0 * sh.shard_bytes * (sh.replicas - 1) as f64 * sh.shards as f64
    }

    /// The input index of edge `(src, dst)` (its position among `dst`'s
    /// predecessors, in edge order).
    pub fn edge_in_idx(&self, src: LayerId, dst: LayerId) -> usize {
        self.graph
            .predecessors(dst)
            .iter()
            .position(|&p| p == src)
            .expect("edge not present in graph")
    }

    /// Equation 1: estimated per-step time of a full strategy.
    pub fn t_o(&self, strategy: &Strategy) -> f64 {
        let mut t = 0.0;
        for l in &self.graph.layers {
            let cfg = strategy.config(l.id);
            t += self.t_c(l, cfg) + self.t_s(l, cfg);
        }
        for &(s, d) in &self.graph.edges {
            let in_idx = self.edge_in_idx(s, d);
            t += self.t_x(
                self.graph.layer(s),
                self.graph.layer(d),
                in_idx,
                strategy.config(s),
                strategy.config(d),
            );
        }
        t
    }
}

/// Which parameter shard (channel-tile index) tile `t` computes, given the
/// row-major `[n, c, h, w]` tile order.
pub fn shard_of_tile(cfg: &PConfig, t: usize) -> usize {
    let chw = cfg.deg[1] * cfg.deg[2] * cfg.deg[3];
    let within_n = t % chw;
    within_n / (cfg.deg[2] * cfg.deg[3])
}

/// Smooth saturation `x / (x + half)` mapped to (0, 1): ~0.5 at `half`,
/// →1 for large tiles.
fn saturate(x: f64, half: f64) -> f64 {
    x / (x + half)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::nets;

    fn setup() -> (CompGraph, DeviceGraph) {
        (nets::vgg16(32 * 4).unwrap(), DeviceGraph::p100_cluster(4).unwrap())
    }

    #[test]
    fn tc_decreases_with_parallelism() {
        let (g, d) = setup();
        let cm = CostModel::new(&g, &d);
        let conv = g.layers.iter().find(|l| l.name == "conv8").unwrap();
        let t1 = cm.t_c(conv, &PConfig::serial());
        let t4 = cm.t_c(conv, &PConfig::data(4));
        assert!(t4 < t1, "t1={t1} t4={t4}");
        assert!(t4 > t1 / 4.5, "sublinear due to overhead/occupancy");
    }

    #[test]
    fn ts_zero_without_replication() {
        let (g, d) = setup();
        let cm = CostModel::new(&g, &d);
        let fc = g.layers.iter().find(|l| l.name == "fc6").unwrap();
        // channel partitioning shards params: no sync
        assert_eq!(cm.t_s(fc, &PConfig::channel(4)), 0.0);
        // data parallelism replicates them: sync cost > 0
        assert!(cm.t_s(fc, &PConfig::data(4)) > 0.0);
        // pool has no params at all
        let pool = g.layers.iter().find(|l| l.name == "pool1").unwrap();
        assert_eq!(cm.t_s(pool, &PConfig::data(4)), 0.0);
    }

    #[test]
    fn fc_sync_dwarfs_fc_compute_under_data_parallelism() {
        // The Figure 2 observation: synchronizing the ~102M fc6 parameters
        // costs far more than computing the layer.
        let (g, d) = setup();
        let cm = CostModel::new(&g, &d);
        let fc = g.layers.iter().find(|l| l.name == "fc6").unwrap();
        let cfg = PConfig::data(4);
        assert!(cm.t_s(fc, &cfg) > 5.0 * cm.t_c(fc, &cfg));
    }

    #[test]
    fn tx_zero_for_matching_configs() {
        let (g, d) = setup();
        let cm = CostModel::new(&g, &d);
        let c8 = g.layers.iter().find(|l| l.name == "conv8").unwrap();
        let c9 = g.layers.iter().find(|l| l.name == "conv9").unwrap();
        let cfg = PConfig::data(4);
        // same sample partitioning: conv9's tile needs exactly its local
        // sample range (halo is only spatial) -> no remote bytes
        assert_eq!(cm.t_x(c8, c9, 0, &cfg, &cfg), 0.0);
        // but switching to channel partitioning forces an all-gather
        assert!(cm.t_x(c8, c9, 0, &cfg, &PConfig::channel(4)) > 0.0);
    }

    #[test]
    fn tx_halo_is_cheap_relative_to_allgather() {
        let (g, d) = setup();
        let cm = CostModel::new(&g, &d);
        let c8 = g.layers.iter().find(|l| l.name == "conv8").unwrap();
        let c9 = g.layers.iter().find(|l| l.name == "conv9").unwrap();
        let spatial = PConfig::new(1, 1, 2, 2);
        let halo = cm.t_x(c8, c9, 0, &spatial, &spatial);
        let gather = cm.t_x(c8, c9, 0, &PConfig::data(4), &PConfig::channel(4));
        assert!(halo > 0.0, "3x3 conv across a spatial split needs a halo");
        assert!(halo < gather / 5.0, "halo {halo} vs gather {gather}");
    }

    #[test]
    fn eq1_sums_components() {
        let g = nets::lenet5(32).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = Strategy::uniform(g.num_layers(), PConfig::data(2));
        let mut expect = 0.0;
        for l in &g.layers {
            expect += cm.t_c(l, &PConfig::data(2)) + cm.t_s(l, &PConfig::data(2));
        }
        for &(a, b) in &g.edges {
            expect += cm.t_x(
                g.layer(a),
                g.layer(b),
                cm.edge_in_idx(a, b),
                &PConfig::data(2),
                &PConfig::data(2),
            );
        }
        assert!((cm.t_o(&s) - expect).abs() < 1e-12);
    }

    #[test]
    fn channel_parallel_fc_moves_less_than_data_parallel_syncs() {
        // Figure 2's 12x claim, at the bytes level: for fc6 the gradient
        // sync volume under sample partitioning exceeds the input
        // all-gather volume under channel partitioning by >10x.
        let (g, d) = setup();
        let cm = CostModel::new(&g, &d);
        let fc = g.layers.iter().find(|l| l.name == "fc6").unwrap();
        let pool5 = g.layers.iter().find(|l| l.name == "pool5").unwrap();
        let sync = cm.s_bytes(fc, &PConfig::data(2));
        let gather = cm.x_bytes(pool5, fc, 0, &PConfig::data(2), &PConfig::channel(2));
        assert!(sync > 10.0 * gather, "sync {sync} gather {gather}");
    }

    #[test]
    fn shard_of_tile_layout() {
        let cfg = PConfig::new(2, 2, 1, 1);
        // tiles in row-major [n,c]: t0=(n0,c0) t1=(n0,c1) t2=(n1,c0) t3=(n1,c1)
        assert_eq!(shard_of_tile(&cfg, 0), 0);
        assert_eq!(shard_of_tile(&cfg, 1), 1);
        assert_eq!(shard_of_tile(&cfg, 2), 0);
        assert_eq!(shard_of_tile(&cfg, 3), 1);
    }

    #[test]
    fn inter_node_sync_costs_more() {
        let g = nets::alexnet(32 * 16).unwrap();
        let d16 = DeviceGraph::p100_cluster(16).unwrap();
        let d4 = DeviceGraph::p100_cluster(4).unwrap();
        let cm16 = CostModel::new(&g, &d16);
        let cm4 = CostModel::new(&g, &d4);
        let fc = g.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert!(cm16.t_s(fc, &PConfig::data(16)) > cm4.t_s(fc, &PConfig::data(4)));
    }
}
