//! Legacy experiment shim.
//!
//! [`Experiment`] was the crate's original stringly-typed entry point;
//! it survives as a thin delegating wrapper around the typed
//! [`crate::planner::Planner`] session API so old call sites keep
//! working. New code should use [`Planner`] directly — see DESIGN.md §4
//! for the migration table.

use crate::error::Result;
use crate::planner::{Network, Planner, StrategyKind};

pub use crate::planner::{Evaluation as Eval, PER_GPU_BATCH};

/// All strategy names accepted by [`Experiment::run`].
pub const STRATEGY_NAMES: [&str; 4] = ["data", "model", "owt", "layerwise"];

/// One experiment point: a network trained on a P100 cluster. A
/// stringly-typed convenience wrapper over [`Planner`]; name resolution
/// is deferred to [`Experiment::planner`] / [`Experiment::run`], which
/// report unknown names as errors instead of panicking.
#[derive(Debug, Clone)]
pub struct Experiment {
    /// Network name (see [`Network`] for the accepted spellings).
    pub network: String,
    /// Device count (the paper's P100 preset shapes).
    pub ndev: usize,
    /// Per-GPU batch size.
    pub per_gpu_batch: usize,
}

impl Experiment {
    /// An experiment at the paper's default per-GPU batch.
    pub fn new(network: &str, ndev: usize) -> Experiment {
        Experiment { network: network.to_string(), ndev, per_gpu_batch: PER_GPU_BATCH }
    }

    /// Global batch size across the cluster.
    pub fn global_batch(&self) -> usize {
        self.per_gpu_batch * self.ndev
    }

    /// Open the typed planning session this experiment describes.
    pub fn planner(&self) -> Result<Planner> {
        let network: Network = self.network.parse()?;
        Planner::builder(network)
            .devices(self.ndev)
            .per_gpu_batch(self.per_gpu_batch)
            .build()
    }

    /// Resolve + evaluate a strategy by name in one call (one-shot; for
    /// repeated queries keep the [`Experiment::planner`] session).
    pub fn run(&self, strategy_name: &str) -> Result<Eval> {
        let kind: StrategyKind = strategy_name.parse()?;
        self.planner()?.evaluate(kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_strategies_on_alexnet() {
        let e = Experiment::new("alexnet", 4);
        let mut tps = std::collections::BTreeMap::new();
        for s in STRATEGY_NAMES {
            let eval = e.run(s).unwrap();
            assert!(eval.throughput > 0.0);
            assert!(eval.sim.step_time > 0.0);
            tps.insert(s, eval.throughput);
        }
        // the optimizer never loses to the baselines it subsumes (its
        // search space contains them, and throughput is 1/cost)
        let lw = tps["layerwise"];
        for s in ["data", "model", "owt"] {
            assert!(lw >= tps[s] * (1.0 - 1e-9), "layerwise {lw} < {s} {}", tps[s]);
        }
    }

    #[test]
    fn single_device_strategies_coincide() {
        let e = Experiment::new("lenet5", 1);
        let a = e.run("data").unwrap();
        let b = e.run("layerwise").unwrap();
        assert_eq!(a.comm.total(), 0.0);
        assert_eq!(b.comm.total(), 0.0);
        // identical serial execution
        assert!((a.sim.step_time - b.sim.step_time).abs() < 1e-9);
    }

    #[test]
    fn unknown_names_error_instead_of_panicking() {
        assert!(Experiment::new("nope", 4).run("data").is_err());
        assert!(Experiment::new("alexnet", 4).run("nope").is_err());
    }

    #[test]
    fn shim_matches_the_session_api() {
        let e = Experiment::new("lenet5", 2);
        let one_shot = e.run("owt").unwrap();
        let mut session = e.planner().unwrap();
        let warm = session.evaluate(crate::planner::StrategyKind::Owt).unwrap();
        assert_eq!(one_shot.estimate, warm.estimate);
        assert_eq!(one_shot.sim.step_time, warm.sim.step_time);
    }
}
