//! High-level experiment pipeline: the API the CLI, the examples, and the
//! bench harnesses share.
//!
//! One [`Experiment`] = (network, device count, per-GPU batch). It owns
//! graph + device-graph construction, strategy resolution (baselines or
//! the layer-wise optimizer), and evaluation (cost model + discrete-event
//! simulation + communication accounting).

use crate::cost::{CostModel, CostTables};
use crate::device::DeviceGraph;
use crate::graph::{nets, CompGraph};
use crate::metrics::CommBreakdown;
use crate::optimizer::{self, strategies, SearchStats};
use crate::parallel::Strategy;
use crate::plan::ExecutionPlan;
use crate::sim::{steady_state_step_plan, SimReport};

/// The paper's default per-GPU batch size.
pub const PER_GPU_BATCH: usize = 32;

/// All strategy names accepted by [`Experiment::strategy`].
pub const STRATEGY_NAMES: [&str; 4] = ["data", "model", "owt", "layerwise"];

/// One experiment point: a network trained on a cluster.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub network: String,
    pub ndev: usize,
    pub per_gpu_batch: usize,
}

/// Evaluation of one strategy on one experiment point.
#[derive(Debug, Clone)]
pub struct Eval {
    /// Equation 1 estimate (seconds/step) — the paper's validated cost
    /// model (their Table 4 shows it within 10% of the real cluster), and
    /// therefore the primary throughput predictor here.
    pub estimate: f64,
    /// Discrete-event steady-state simulation of the same step (the
    /// independent check; it overlaps communication more aggressively
    /// than the serial-sum estimate).
    pub sim: SimReport,
    /// Per-step communication volume.
    pub comm: CommBreakdown,
    /// Cost-model training throughput (images/s) = batch / estimate.
    pub throughput: f64,
    /// Simulated training throughput (images/s) = batch / sim step.
    pub sim_throughput: f64,
}

impl Experiment {
    pub fn new(network: &str, ndev: usize) -> Experiment {
        Experiment { network: network.to_string(), ndev, per_gpu_batch: PER_GPU_BATCH }
    }

    pub fn global_batch(&self) -> usize {
        self.per_gpu_batch * self.ndev
    }

    pub fn graph(&self) -> CompGraph {
        nets::by_name(&self.network, self.global_batch())
            .unwrap_or_else(|| panic!("unknown network `{}`", self.network))
    }

    pub fn devices(&self) -> DeviceGraph {
        DeviceGraph::p100_cluster(self.ndev)
    }

    /// Build the cost tables for this experiment (the expensive step; call
    /// once and reuse when resolving multiple strategies).
    pub fn tables(&self, graph: &CompGraph, devices: &DeviceGraph) -> CostTables {
        let cm = CostModel::new(graph, devices);
        CostTables::build(&cm, self.ndev)
    }

    /// Resolve a strategy by name: a baseline or `layerwise` (Algorithm 1).
    /// Returns the strategy and, for `layerwise`, the search stats.
    pub fn strategy(
        &self,
        name: &str,
        graph: &CompGraph,
        devices: &DeviceGraph,
    ) -> (Strategy, Option<SearchStats>) {
        match name {
            "layerwise" => {
                let tables = self.tables(graph, devices);
                let opt = optimizer::optimize(&tables);
                (opt.strategy, Some(opt.stats))
            }
            _ => (
                strategies::by_name(name, graph, self.ndev)
                    .unwrap_or_else(|| panic!("unknown strategy `{name}`")),
                None,
            ),
        }
    }

    /// Evaluate a strategy: Eq. 1 estimate, steady-state simulation (sync
    /// on the inter-step critical path), comm volume. Materializes the
    /// strategy's [`ExecutionPlan`] once and derives simulation and
    /// communication accounting from it.
    pub fn evaluate(
        &self,
        graph: &CompGraph,
        devices: &DeviceGraph,
        strategy: &Strategy,
    ) -> Eval {
        let cm = CostModel::new(graph, devices);
        let plan = ExecutionPlan::build(&cm, strategy);
        self.evaluate_plan(&cm, strategy, &plan)
    }

    /// [`Experiment::evaluate`] against a prebuilt (typically cached)
    /// plan: repeated evaluation queries skip all tiling/overlap work.
    pub fn evaluate_plan(
        &self,
        cm: &CostModel,
        strategy: &Strategy,
        plan: &ExecutionPlan,
    ) -> Eval {
        let estimate = cm.t_o(strategy);
        let sim = steady_state_step_plan(plan, cm);
        let comm = plan.comm();
        let throughput = self.global_batch() as f64 / estimate;
        let sim_throughput = sim.throughput(self.global_batch());
        Eval { estimate, sim, comm, throughput, sim_throughput }
    }

    /// Convenience: resolve + evaluate in one call.
    pub fn run(&self, strategy_name: &str) -> Eval {
        let g = self.graph();
        let d = self.devices();
        let (s, _) = self.strategy(strategy_name, &g, &d);
        self.evaluate(&g, &d, &s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_strategies_on_alexnet() {
        let e = Experiment::new("alexnet", 4);
        let mut tps = std::collections::BTreeMap::new();
        for s in STRATEGY_NAMES {
            let eval = e.run(s);
            assert!(eval.throughput > 0.0);
            assert!(eval.sim.step_time > 0.0);
            tps.insert(s, eval.throughput);
        }
        // the optimizer never loses to the baselines it subsumes (its
        // search space contains them, and throughput is 1/cost)
        let lw = tps["layerwise"];
        for s in ["data", "model", "owt"] {
            assert!(lw >= tps[s] * (1.0 - 1e-9), "layerwise {lw} < {s} {}", tps[s]);
        }
    }

    #[test]
    fn single_device_strategies_coincide() {
        let e = Experiment::new("lenet5", 1);
        let a = e.run("data");
        let b = e.run("layerwise");
        assert_eq!(a.comm.total(), 0.0);
        assert_eq!(b.comm.total(), 0.0);
        // identical serial execution
        assert!((a.sim.step_time - b.sim.step_time).abs() < 1e-9);
    }
}
