//! Chrome-trace export of simulated schedules.
//!
//! `trace_events` re-runs the list scheduler while recording every task's
//! (resource, start, end) and emits Chrome `chrome://tracing` /
//! Perfetto-compatible JSON — the visual answer to "where does the step
//! time go under this strategy?". Wired to `optcnn simulate --trace out.json`.

use crate::cost::CostModel;
use crate::device::DeviceGraph;
use crate::graph::CompGraph;
use crate::parallel::Strategy;
use crate::util::json::Json;

/// One scheduled interval.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Track name, e.g. `gpu3`, `nic_out0`, `host1`.
    pub track: String,
    pub name: String,
    pub start: f64,
    pub end: f64,
}

/// Simulate one step and return the schedule as trace events.
///
/// Implementation note: rather than duplicating the scheduler, this
/// re-derives intervals from a high-resolution re-simulation — each
/// compute/transfer/sync task contributes one event on its primary
/// resource track.
pub fn trace_events(
    graph: &CompGraph,
    devices: &DeviceGraph,
    strategy: &Strategy,
    cm: &CostModel,
) -> Vec<TraceEvent> {
    super::simulate_traced(graph, devices, strategy, cm)
}

/// Serialize events as a Chrome trace (`[{ph:"X", ...}]` complete events,
/// microsecond timestamps).
pub fn to_chrome_trace(events: &[TraceEvent]) -> String {
    let arr: Vec<Json> = events
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("ph", Json::Str("X".into())),
                ("ts", Json::Num(e.start * 1e6)),
                ("dur", Json::Num((e.end - e.start) * 1e6)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Str(e.track.clone())),
                ("cat", Json::Str("sim".into())),
            ])
        })
        .collect();
    Json::Arr(arr).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::nets;
    use crate::optimizer::strategies;

    #[test]
    fn trace_covers_all_compute() {
        let g = nets::alexnet(64).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::data_parallel(&g, 2);
        let ev = trace_events(&g, &d, &s, &cm);
        // every non-input layer x 2 tiles appears as a compute event
        let compute_events = ev.iter().filter(|e| e.track.starts_with("gpu")).count();
        assert_eq!(compute_events, (g.num_layers() - 1) * 2);
        // intervals are well-formed
        assert!(ev.iter().all(|e| e.end >= e.start && e.start >= 0.0));
        // sync traffic appears on host/nic tracks
        assert!(ev.iter().any(|e| e.track.starts_with("host") || e.track.starts_with("nic")));
    }

    #[test]
    fn chrome_json_parses_back() {
        let g = nets::lenet5(32).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::owt(&g, 2);
        let ev = trace_events(&g, &d, &s, &cm);
        let json = to_chrome_trace(&ev);
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), ev.len());
    }

    #[test]
    fn events_on_same_track_do_not_overlap() {
        let g = nets::alexnet(64).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::owt(&g, 4);
        let mut ev = trace_events(&g, &d, &s, &cm);
        ev.sort_by(|a, b| {
            (a.track.clone(), a.start).partial_cmp(&(b.track.clone(), b.start)).unwrap()
        });
        for w in ev.windows(2) {
            if w[0].track == w[1].track {
                assert!(
                    w[1].start >= w[0].end - 1e-12,
                    "overlap on {}: {}..{} then {}..{}",
                    w[0].track,
                    w[0].start,
                    w[0].end,
                    w[1].start,
                    w[1].end
                );
            }
        }
    }
}
