//! Discrete-event cluster simulator — the "testbed" substitute.
//!
//! The cost model (Eq. 1) deliberately *sums* every cost term; the real
//! cluster overlaps communication with computation and runs independent
//! branches concurrently (the paper's Legion runtime does this
//! automatically). This simulator provides that independent reference
//! execution: it expands a (graph, strategy) pair into a task DAG —
//! per-tile compute tasks, per-tile-pair transfer tasks, parameter-sync
//! round-trips — and list-schedules it over contended resources
//! (device FIFOs, NVLink pairs, per-node NICs, host links).
//!
//! Table 4's analogue compares Eq. 1 estimates against these simulated
//! step times; Figure 7's throughput numbers come from here.
//!
//! The task DAG is expanded from a materialized [`ExecutionPlan`] — the
//! simulator no longer recomputes tiles, input regions, or overlaps
//! itself. The `simulate_plan*` entry points accept a prebuilt (typically
//! cached) plan so repeated simulation queries pay only for scheduling;
//! the legacy `(graph, devices, strategy)` entry points build the plan
//! internally.

pub mod trace;

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::cost::CostModel;
use crate::device::DeviceGraph;
use crate::graph::{CompGraph, OpKind};
use crate::parallel::Strategy;
use crate::plan::ExecutionPlan;

/// Simulation outcome for one training step.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Makespan of the step (seconds).
    pub step_time: f64,
    /// Bytes moved across links for activation/tensor transfers.
    pub xfer_bytes: f64,
    /// Bytes moved for parameter synchronization.
    pub sync_bytes: f64,
    /// Per-device compute busy time (seconds).
    pub busy: Vec<f64>,
    pub num_tasks: usize,
    pub num_transfers: usize,
}

impl SimReport {
    /// Training throughput in images/second for a given global batch.
    pub fn throughput(&self, global_batch: usize) -> f64 {
        global_batch as f64 / self.step_time
    }

    /// Mean device utilization over the step.
    pub fn utilization(&self) -> f64 {
        if self.step_time == 0.0 {
            return 0.0;
        }
        self.busy.iter().sum::<f64>() / (self.busy.len() as f64 * self.step_time)
    }

    pub fn total_bytes(&self) -> f64 {
        self.xfer_bytes + self.sync_bytes
    }
}

/// Resources a task occupies while running.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Resource {
    Compute(usize),
    /// Intra-node point-to-point link, ordered (src, dst).
    Link(usize, usize),
    /// A node's NIC egress / ingress.
    NicOut(usize),
    NicIn(usize),
    /// A node's host (PCIe) link, used by parameter-server traffic.
    Host(usize),
}

/// What a task represents (used only for trace export).
#[derive(Debug, Clone, Copy)]
enum Tag {
    Compute { layer: usize, tile: usize },
    Transfer { src: usize, dst: usize },
    Sync { layer: usize },
}

struct Task {
    duration: f64,
    resources: [Option<Resource>; 2],
    deps: usize,
    dependents: Vec<usize>,
    bytes: f64,
    is_sync: bool,
    tag: Tag,
}

/// Simulate one training step of `strategy` on the device graph.
///
/// `cm` supplies per-tile compute durations (so measured-profile mode
/// flows through to the simulation as well).
pub fn simulate(
    graph: &CompGraph,
    devices: &DeviceGraph,
    strategy: &Strategy,
    cm: &CostModel,
) -> SimReport {
    simulate_steps(graph, devices, strategy, cm, 1)
}

/// Simulate one training step from a prebuilt [`ExecutionPlan`] (the
/// cached-plan fast path: no tile/region/overlap recomputation).
pub fn simulate_plan(plan: &ExecutionPlan, cm: &CostModel) -> SimReport {
    simulate_plan_steps(plan, cm, 1)
}

/// Simulate `steps` chained steps from a prebuilt plan.
pub fn simulate_plan_steps(plan: &ExecutionPlan, cm: &CostModel, steps: usize) -> SimReport {
    simulate_steps_inner(plan, cm.graph, cm.devices, cm, steps, None)
}

/// [`steady_state_step`] from a prebuilt plan: the plan is expanded for
/// the 1-step and 3-step chains without being re-derived.
pub fn steady_state_step_plan(plan: &ExecutionPlan, cm: &CostModel) -> SimReport {
    steady_state_inner(plan, cm.graph, cm.devices, cm)
}

/// Marginal per-step report from 1-step and 3-step chains of one plan.
///
/// *Every* field is marginal — `(three − one) / 2` — not just
/// `step_time`: a report mixing a marginal step time with one-step
/// extensive fields (`busy`, byte counters, task counts) would make
/// derived quantities like [`SimReport::utilization`] incoherent the
/// moment per-step work stops being chain-position-invariant. (Today
/// each chained step expands to an identical task multiset, so the
/// marginal extensive fields equal the one-step ones; the accounting
/// contract is pinned by `steady_state_reports_marginal_fields`.)
fn steady_state_inner(
    plan: &ExecutionPlan,
    graph: &CompGraph,
    devices: &DeviceGraph,
    cm: &CostModel,
) -> SimReport {
    let one = simulate_steps_inner(plan, graph, devices, cm, 1, None);
    let three = simulate_steps_inner(plan, graph, devices, cm, 3, None);
    SimReport {
        step_time: (three.step_time - one.step_time) / 2.0,
        xfer_bytes: (three.xfer_bytes - one.xfer_bytes) / 2.0,
        sync_bytes: (three.sync_bytes - one.sync_bytes) / 2.0,
        busy: one
            .busy
            .iter()
            .zip(three.busy.iter())
            .map(|(o, t)| (t - o) / 2.0)
            .collect(),
        num_tasks: (three.num_tasks - one.num_tasks) / 2,
        num_transfers: (three.num_transfers - one.num_transfers) / 2,
    }
}

/// Steady-state per-step time: simulate one and three chained steps and
/// report the marginal cost of the additional steps. Chaining puts
/// parameter synchronization on the inter-step critical path (a layer's
/// next forward pass cannot start before its parameters are updated),
/// which single-step simulation would otherwise hide entirely.
pub fn steady_state_step(
    graph: &CompGraph,
    devices: &DeviceGraph,
    strategy: &Strategy,
    cm: &CostModel,
) -> SimReport {
    let plan = ExecutionPlan::build(cm, strategy);
    steady_state_inner(&plan, graph, devices, cm)
}

/// Simulate `steps` chained training steps; `step_time` is the makespan
/// of the whole chain.
pub fn simulate_steps(
    graph: &CompGraph,
    devices: &DeviceGraph,
    strategy: &Strategy,
    cm: &CostModel,
    steps: usize,
) -> SimReport {
    let plan = ExecutionPlan::build(cm, strategy);
    simulate_steps_inner(&plan, graph, devices, cm, steps, None)
}

/// Trace-producing variant of [`simulate`]: one step, with every scheduled
/// interval recorded.
pub(crate) fn simulate_traced(
    graph: &CompGraph,
    devices: &DeviceGraph,
    strategy: &Strategy,
    cm: &CostModel,
) -> Vec<trace::TraceEvent> {
    let plan = ExecutionPlan::build(cm, strategy);
    let mut events = Vec::new();
    simulate_steps_inner(&plan, graph, devices, cm, 1, Some(&mut events));
    events
}

/// Expand the plan's tiles/transfers/sync groups into `steps` chained
/// task DAGs and list-schedule them. The plan supplies all geometry and
/// byte counts; `cm` supplies per-tile compute durations only.
fn simulate_steps_inner(
    plan: &ExecutionPlan,
    graph: &CompGraph,
    devices: &DeviceGraph,
    cm: &CostModel,
    steps: usize,
    trace_out: Option<&mut Vec<trace::TraceEvent>>,
) -> SimReport {
    assert!(steps >= 1);
    assert_eq!(plan.layers.len(), graph.num_layers(), "plan built for a different graph");
    // Plans carry device indices and routes; a plan exported from one
    // cluster must not be scheduled on a differently-sized one (routes
    // for an equally-sized but differently-noded cluster are caught by
    // `PlanCache`'s key, not here).
    assert_eq!(
        plan.ndev,
        devices.num_devices(),
        "plan built for a {}-device cluster, simulating on {}",
        plan.ndev,
        devices.num_devices()
    );
    let mut tasks: Vec<Task> = Vec::new();
    let mut num_transfers = 0usize;
    // sync task ids of the previous step, per layer
    let mut prev_sync: Vec<Vec<usize>> = vec![Vec::new(); graph.num_layers()];
    // all compute task ids of the previous step (synchronous-SGD barrier)
    let mut prev_compute: Vec<usize> = Vec::new();

    fn add_dep(tasks: &mut [Task], from: usize, to: usize) {
        tasks[from].dependents.push(to);
        tasks[to].deps += 1;
    }

    for _step in 0..steps {
        // --- compute tasks ---
        let mut compute_id: Vec<Vec<usize>> = Vec::with_capacity(graph.num_layers());
        let mut this_compute: Vec<usize> = Vec::new();
        for l in &graph.layers {
            let lp = plan.layer(l.id);
            let per_tile = cm.t_c(l, &lp.cfg);
            let mut ids = Vec::with_capacity(lp.tiles.len());
            for t in 0..lp.tiles.len() {
                ids.push(tasks.len());
                tasks.push(Task {
                    duration: if matches!(l.op, OpKind::Input) { 0.0 } else { per_tile },
                    resources: [Some(Resource::Compute(lp.tile_dev[t])), None],
                    deps: 0,
                    dependents: Vec::new(),
                    bytes: 0.0,
                    is_sync: false,
                    tag: Tag::Compute { layer: l.id, tile: t },
                });
            }
            // weight dependency: this step's compute waits for the
            // previous step's parameter sync of the same layer
            for &sync_task in &prev_sync[l.id] {
                for &c in &ids {
                    add_dep(&mut tasks, sync_task, c);
                }
            }
            // synchronous-SGD semantics: the new batch is dispatched only
            // after the previous iteration's compute has drained (gradient
            // sync may still straggle into this step, handled above)
            if matches!(l.op, OpKind::Input) {
                for &p in &prev_compute {
                    for &c in &ids {
                        add_dep(&mut tasks, p, c);
                    }
                }
            }
            this_compute.extend(ids.iter().copied());
            compute_id.push(ids);
        }
        prev_compute = this_compute;

        // --- transfer tasks per edge, straight from the plan's schedule ---
        for ep in &plan.edges {
            for tr in &ep.transfers {
                let from = compute_id[ep.src][tr.src_tile];
                let to = compute_id[ep.dst][tr.dst_tile];
                if !tr.is_remote() {
                    // local: direct dependency, no transfer
                    add_dep(&mut tasks, from, to);
                    continue;
                }
                let bytes = tr.bytes();
                let (dur, res) = transfer_resources(devices, tr.src_dev, tr.dst_dev, bytes);
                let id = tasks.len();
                tasks.push(Task {
                    duration: dur,
                    resources: res,
                    deps: 0,
                    dependents: Vec::new(),
                    bytes,
                    is_sync: false,
                    tag: Tag::Transfer { src: tr.src_dev, dst: tr.dst_dev },
                });
                add_dep(&mut tasks, from, id);
                add_dep(&mut tasks, id, to);
                num_transfers += 1;
            }
        }

        // --- parameter-sync tasks from the plan's shard groups ---
        // Sharded-PS / allreduce-style exchange (matches CostModel::t_s):
        // each replica moves 2 * shard_bytes * (R-1)/R over its own
        // uplink; same-node groups ride the host link, cross-node groups
        // contend on their node's NIC.
        for l in &graph.layers {
            prev_sync[l.id].clear();
            let Some(sync) = &plan.layer(l.id).sync else { continue };
            for grp in &sync.groups {
                for (ri, &dev) in grp.devices.iter().enumerate() {
                    let tile = grp.tiles[ri];
                    let bytes = grp.bytes_per_replica;
                    let node = devices.devices[dev].node;
                    let (dur, res) = if !grp.spans_nodes {
                        (bytes / devices.host_bw, [Some(Resource::Host(node)), None])
                    } else {
                        // The sharded-PS exchange is a round trip: each
                        // replica sends its gradient slices out *and*
                        // receives the reduced parameters back, so it
                        // occupies both directions of its node's NIC —
                        // exactly like activation transfers do. Holding
                        // only `NicOut` would let the inbound half ride
                        // for free alongside co-scheduled transfers
                        // (pinned by `sync_contends_with_transfers_on_nic`).
                        (
                            bytes / devices.node_bw.min(devices.host_bw),
                            [Some(Resource::NicOut(node)), Some(Resource::NicIn(node))],
                        )
                    };
                    let id = tasks.len();
                    tasks.push(Task {
                        duration: dur,
                        resources: res,
                        deps: 0,
                        dependents: Vec::new(),
                        bytes,
                        is_sync: true,
                        tag: Tag::Sync { layer: l.id },
                    });
                    add_dep(&mut tasks, compute_id[l.id][tile], id);
                    prev_sync[l.id].push(id);
                }
            }
        }
    }

    schedule(tasks, devices, num_transfers, trace_out.map(|e| (graph, e)))
}

/// Greedy list scheduling over contended resources.
fn schedule(
    tasks: Vec<Task>,
    devices: &DeviceGraph,
    num_transfers: usize,
    mut trace_out: Option<(&CompGraph, &mut Vec<trace::TraceEvent>)>,
) -> SimReport {
    let n = tasks.len();
    let mut free: HashMap<Resource, f64> = HashMap::new();
    let mut ready_time = vec![0.0f64; n];
    let mut heap: BinaryHeap<Reverse<(OrdF64, usize)>> = BinaryHeap::new();
    let mut deps_left: Vec<usize> = tasks.iter().map(|t| t.deps).collect();
    for (i, t) in tasks.iter().enumerate() {
        if t.deps == 0 {
            heap.push(Reverse((OrdF64(0.0), i)));
        }
    }
    let mut makespan = 0.0f64;
    let mut busy = vec![0.0f64; devices.num_devices()];
    let (mut xfer_bytes, mut sync_bytes) = (0.0f64, 0.0f64);
    let mut scheduled = 0usize;
    while let Some(Reverse((OrdF64(rt), i))) = heap.pop() {
        let start = tasks[i]
            .resources
            .iter()
            .flatten()
            .map(|r| *free.get(r).unwrap_or(&0.0))
            .fold(rt, f64::max);
        let end = start + tasks[i].duration;
        for r in tasks[i].resources.iter().flatten() {
            free.insert(*r, end);
            if let Resource::Compute(d) = r {
                busy[*d] += tasks[i].duration;
            }
        }
        if let Some((graph, events)) = trace_out.as_mut() {
            if tasks[i].duration > 0.0 {
                let primary = tasks[i].resources[0].expect("task without resources");
                events.push(trace::TraceEvent {
                    track: track_name(&primary),
                    name: tag_name(graph, &tasks[i].tag),
                    start,
                    end,
                });
            }
        }
        if tasks[i].bytes > 0.0 {
            if tasks[i].is_sync {
                sync_bytes += tasks[i].bytes;
            } else {
                xfer_bytes += tasks[i].bytes;
            }
        }
        makespan = makespan.max(end);
        scheduled += 1;
        let deps: Vec<usize> = tasks[i].dependents.clone();
        for dep in deps {
            ready_time[dep] = ready_time[dep].max(end);
            deps_left[dep] -= 1;
            if deps_left[dep] == 0 {
                heap.push(Reverse((OrdF64(ready_time[dep]), dep)));
            }
        }
    }
    assert_eq!(scheduled, n, "task DAG has a cycle or unreachable task");

    SimReport { step_time: makespan, xfer_bytes, sync_bytes, busy, num_tasks: n, num_transfers }
}

/// Duration and contended resources of a device-to-device transfer.
fn transfer_resources(
    devices: &DeviceGraph,
    src: usize,
    dst: usize,
    bytes: f64,
) -> (f64, [Option<Resource>; 2]) {
    if devices.same_node(src, dst) {
        (bytes / devices.bandwidth(src, dst), [Some(Resource::Link(src, dst)), None])
    } else {
        // Inter-node traffic runs at the NIC rate but serializes on the
        // endpoints' NICs — contention emerges when many GPUs of one node
        // send at once.
        let (sn, dn) = (devices.devices[src].node, devices.devices[dst].node);
        (bytes / devices.node_bw, [Some(Resource::NicOut(sn)), Some(Resource::NicIn(dn))])
    }
}

/// Trace track name for a resource.
fn track_name(r: &Resource) -> String {
    match r {
        Resource::Compute(d) => format!("gpu{d}"),
        Resource::Link(i, j) => format!("link{i}-{j}"),
        Resource::NicOut(n) => format!("nic_out{n}"),
        Resource::NicIn(n) => format!("nic_in{n}"),
        Resource::Host(n) => format!("host{n}"),
    }
}

/// Trace event name for a task tag.
fn tag_name(graph: &CompGraph, tag: &Tag) -> String {
    match tag {
        Tag::Compute { layer, tile } => format!("{}[{tile}]", graph.layer(*layer).name),
        Tag::Transfer { src, dst } => format!("xfer {src}->{dst}"),
        Tag::Sync { layer } => format!("sync {}", graph.layer(*layer).name),
    }
}

/// Total-order f64 wrapper for the ready queue.
#[derive(PartialEq, PartialOrd)]
struct OrdF64(f64);

impl Eq for OrdF64 {}

#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::nets;
    use crate::optimizer::strategies;
    use crate::parallel::PConfig;

    fn run(net: &str, ndev: usize, strat: &str) -> (SimReport, f64) {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::by_name(strat, &g, ndev).unwrap();
        let rep = simulate(&g, &d, &s, &cm);
        let est = cm.t_o(&s);
        (rep, est)
    }

    #[test]
    fn simulated_time_close_to_estimate_for_chains() {
        // A chain network on data parallelism has limited overlap
        // opportunity: sim and Eq.1 should agree within tens of percent
        // (paper Table 4: within 10% on real hardware).
        let (rep, est) = run("alexnet", 4, "data");
        let rel = (est - rep.step_time) / rep.step_time;
        assert!(rel.abs() < 0.6, "rel diff {rel}: est {est} sim {}", rep.step_time);
    }

    #[test]
    fn sim_never_beats_critical_path() {
        let (rep, _) = run("vgg16", 4, "data");
        // lower bound: the busiest device's compute alone
        let max_busy = rep.busy.iter().cloned().fold(0.0, f64::max);
        assert!(rep.step_time >= max_busy);
    }

    #[test]
    fn overlap_makes_sim_at_most_estimate() {
        // Eq. 1 serializes everything, the scheduler overlaps: the sim
        // should not exceed the estimate by more than scheduling noise.
        for strat in ["data", "model", "owt"] {
            let (rep, est) = run("inception_v3", 4, strat);
            assert!(rep.step_time <= est * 1.05, "{strat}: sim {} > est {est}", rep.step_time);
        }
    }

    #[test]
    fn single_device_has_no_traffic() {
        let (rep, _) = run("lenet5", 1, "data");
        assert_eq!(rep.total_bytes(), 0.0);
        assert_eq!(rep.num_transfers, 0);
        assert!(rep.step_time > 0.0);
    }

    #[test]
    fn data_parallel_syncs_whole_model() {
        let g = nets::alexnet(32 * 4).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::data_parallel(&g, 4);
        let rep = simulate(&g, &d, &s, &cm);
        // sharded PS: 2 x (R-1) x param bytes for R=4 replicas
        let expect = 6.0 * g.total_params() as f64 * 4.0;
        assert!((rep.sync_bytes - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn scaling_devices_improves_throughput() {
        let (r4, _) = run("vgg16", 4, "data");
        let (r16, _) = run("vgg16", 16, "data");
        assert!(r16.throughput(32 * 16) > r4.throughput(32 * 4));
    }

    #[test]
    fn utilization_bounded() {
        let (rep, _) = run("inception_v3", 4, "data");
        let u = rep.utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization {u}");
    }

    #[test]
    fn plan_and_strategy_entry_points_agree_exactly() {
        let g = nets::alexnet(32 * 4).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::owt(&g, 4);
        let direct = simulate(&g, &d, &s, &cm);
        let plan = ExecutionPlan::build(&cm, &s);
        let via_plan = simulate_plan(&plan, &cm);
        assert_eq!(direct.num_tasks, via_plan.num_tasks);
        assert_eq!(direct.num_transfers, via_plan.num_transfers);
        assert_eq!(direct.step_time, via_plan.step_time);
        assert_eq!(direct.xfer_bytes, via_plan.xfer_bytes);
        assert_eq!(direct.sync_bytes, via_plan.sync_bytes);
    }

    #[test]
    fn steady_state_reports_marginal_fields() {
        // Regression for the mixed-accounting bug: `steady_state_*` used
        // to return the 1-step chain's extensive fields next to a
        // marginal `step_time`. All fields are marginal now; on a
        // homogeneous chain the marginal extensive fields must equal one
        // full step's, and the derived utilization must be coherent.
        let g = nets::alexnet(32 * 4).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::data_parallel(&g, 4);
        let steady = steady_state_step(&g, &d, &s, &cm);
        let one = simulate(&g, &d, &s, &cm);
        // marginal sync bytes == one full step's sync bytes
        assert!(
            (steady.sync_bytes - one.sync_bytes).abs() <= 1e-6 * one.sync_bytes,
            "marginal sync {} vs one-step {}",
            steady.sync_bytes,
            one.sync_bytes
        );
        assert!((steady.xfer_bytes - one.xfer_bytes).abs() <= 1e-6 * one.xfer_bytes.max(1.0));
        assert_eq!(steady.num_tasks, one.num_tasks);
        assert_eq!(steady.num_transfers, one.num_transfers);
        assert_eq!(steady.busy.len(), one.busy.len());
        for (m, o) in steady.busy.iter().zip(one.busy.iter()) {
            assert!((m - o).abs() <= 1e-9 * o.max(1e-12), "marginal busy {m} vs {o}");
        }
        let u = steady.utilization();
        assert!(u > 0.0 && u <= 1.0, "steady-state utilization {u}");
    }

    #[test]
    fn sync_contends_with_transfers_on_nic() {
        // Regression for the sync-NIC bug: cross-node parameter-sync
        // tasks held only `NicOut`, so their inbound half rode for free
        // next to co-scheduled activation transfers. Scenario built so
        // node 0's NIC *ingress* is the contended resource:
        //
        //   cluster: 2 nodes x 2 GPUs, slow NIC (node_bw = 1e8 B/s);
        //   conv {n=3} on devices 0,1 (node 0) and 2 (node 1), with a
        //   parameter sync spanning both nodes (two replicas on node 0);
        //   fc {c=2} on devices 0,1 all-gathers the conv output, pulling
        //   two cross-node transfers from device 2 *into* node 0.
        //
        // Post-fix, the two inbound transfers and node 0's two sync
        // round-trips all serialize on `NicIn(0)`, so the makespan is at
        // least the sum of their durations. Pre-fix the syncs only held
        // `NicOut(0)` and ran concurrently with the inbound transfers:
        // the makespan stayed ~2 transfer-durations short of this bound
        // (everything else in the DAG is orders of magnitude faster).
        use crate::device::ComputeModel;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new("sync-nic");
        let x = b.input(1200, 4096, 1, 1).unwrap();
        let c = b.conv2d("conv", x, 64, (1, 1), (1, 1), (0, 0)).unwrap();
        let f = b.fully_connected("fc", c, 2).unwrap();
        b.softmax("sm", f).unwrap();
        let g = b.finish().unwrap();
        // inter_bw 5e7 x 2 GPUs/node => node NIC = 1e8 B/s
        let d =
            DeviceGraph::cluster("nic", 2, 2, 15e9, 5e7, 12e9, ComputeModel::p100()).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = Strategy {
            configs: vec![
                PConfig::data(3),
                PConfig::data(3),
                PConfig::channel(2),
                PConfig::serial(),
            ],
        };
        let plan = ExecutionPlan::build(&cm, &s);
        // the trap is armed: inter-node transfers into node 0 plus a
        // node-spanning sync group with two replicas on node 0
        let inter: Vec<&crate::plan::Transfer> = plan
            .edges
            .iter()
            .flat_map(|e| e.transfers.iter())
            .filter(|t| t.route == crate::plan::Route::InterNode)
            .collect();
        assert_eq!(inter.len(), 2, "expected exactly two cross-node transfers");
        assert!(inter.iter().all(|t| d.devices[t.dst_dev].node == 0));
        let sync = plan.layer(c).sync.as_ref().expect("conv must sync");
        assert!(sync.groups[0].spans_nodes);
        let node0_replicas =
            sync.groups[0].devices.iter().filter(|&&dev| d.devices[dev].node == 0).count();
        assert_eq!(node0_replicas, 2);

        let rep = simulate_plan(&plan, &cm);
        let xfer_in: f64 = inter.iter().map(|t| t.bytes() / d.node_bw).sum();
        let sync_in = node0_replicas as f64 * sync.groups[0].bytes_per_replica
            / d.node_bw.min(d.host_bw);
        let serialized = xfer_in + sync_in;
        assert!(
            rep.step_time >= serialized * (1.0 - 1e-9),
            "NicIn(0) holders must serialize: step {} < bound {serialized}",
            rep.step_time
        );
    }

    #[test]
    fn sync_bytes_match_cost_model_accounting() {
        let g = nets::vgg16(32 * 2).unwrap();
        let d = DeviceGraph::p100_cluster(2).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::data_parallel(&g, 2);
        let rep = simulate(&g, &d, &s, &cm);
        let expect: f64 =
            g.layers.iter().map(|l| cm.s_bytes(l, s.config(l.id))).sum();
        assert!((rep.sync_bytes - expect).abs() < 1.0);
    }
}
