//! Static soundness auditing of cost tables + certified dominance
//! pruning + differential backend cross-checks (DESIGN.md §12).
//!
//! After PR 7 (`verify`: plan-level invariants) and PR 8 (`analyze`:
//! pre-table certificates) the cost tables themselves — and the search
//! backends that consume them — were the one unaudited stage of the
//! pipeline. This module closes that gap with three prongs, none of
//! which execute anything:
//!
//! 1. **Table invariants** ([`audit_tables`]) — the typed
//!    [`TableCheck`](crate::error::TableCheck) list: every `t_C`/`t_X`/
//!    `t_S` entry finite and non-negative; per-layer config lists
//!    canonical (sorted, deduplicated, degrees dividing extents,
//!    products ≤ ndev); edge tables dimensioned exactly
//!    producer-configs × consumer-configs in graph edge order; cost
//!    entries above closed-form *physical lower bounds* (an edge entry
//!    can never beat its remote bytes over the fastest link, a node
//!    entry never beats its round-trip shard-sync bytes), derived from
//!    the same `input_region`/`param_sharding` geometry the cost model
//!    prices — so a cost-model regression that silently *underprices*
//!    communication fails loudly here; and budget-mask coherence (a
//!    budgeted table is bitwise the surviving-index subset of the
//!    unbudgeted build, re-derived through `build_opts`). Any failure is
//!    a typed [`OptError::InvalidTables`] naming its check.
//!
//! 2. **Dominance certificates** — for each layer, the exact set of
//!    configurations that can never appear in an optimal strategy,
//!    judged across *all* contexts: config `b` is dominated by `a` when
//!    `a`'s memory peak does not exceed `b`'s and
//!    `Δnode + Σ_incident-edges max_ctx Δedge < 0` (or `≤ 0` with
//!    `a < b`, matching both backends' first-minimum tie-breaking). For
//!    any fixed assignment of the neighbors, swapping `b` for `a`
//!    changes the total by at most that difference bound, so removing
//!    every dominated config preserves the optimal cost *and* the exact
//!    strategy both backends return — [`prune_tables`] applies it as an
//!    opt-in (`--prune-dominated`) table transformation upstream of
//!    either backend. This is the static analogue of PaSE's
//!    configuration-dominance observation.
//!
//! 3. **Differential backend certification** ([`cross_check`]) — run
//!    Algorithm 1 over the full tables and the exhaustive DFS over the
//!    elimination-reduced residual kernel
//!    ([`optimizer::reduce`](crate::optimizer::reduce)), which is small
//!    where the full space is astronomically large, and demand they
//!    agree on cost and on every kernel-node assignment. Disagreement is
//!    a typed [`OptError::BackendMismatch`] naming the first divergent
//!    layer.
//!
//! Wired at every surface: the `optcnn audit` subcommand, the
//! `{"want":"audit"}` wire probe, `Planner::audit()`, and
//! `--prune-dominated` on optimize/plan/sweep/serve.
//!
//! The auditor always runs over **unpruned** tables: a dominance-pruned
//! table legitimately fails the budget-mask subset re-derivation (its
//! config lists are intentionally not the budget-masked enumeration).

#![warn(missing_docs)]
// The auditor runs inside long-lived services over wire-supplied
// graphs: every failure must be a typed `OptError`, never a panic.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::time::Duration;

use crate::cost::{BuildOptions, CostModel, CostTables};
use crate::error::{OptError, Result, TableCheck};
use crate::memory::layer_peak_bytes;
use crate::optimizer::{self, dfs};
use crate::parallel::{enumerate_configs, input_region, output_tiles, param_sharding};
use crate::plan::overlap::{flatten, overlap_elems, FlatRegion};

/// Relative slack for the lower-bound comparisons: the priced cost sums
/// per-chunk rounded divisions where the bound divides summed bytes
/// once, so honest tables can undershoot the real-arithmetic bound by a
/// few ulps. Mutations that matter (a mispriced formula) miss by orders
/// of magnitude, not 1e-9.
const LOWER_BOUND_SLACK: f64 = 1e-9;

/// One passed table check: the invariant plus a short summary of what
/// was proven (counts, totals), mirroring `verify::CheckReport`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TableCheckReport {
    /// The invariant that held.
    pub check: TableCheck,
    /// Human-readable statement of what was proven.
    pub summary: String,
}

/// Per-layer dominance certificate: which config indices can never
/// appear in an optimal strategy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerDominance {
    /// Layer id.
    pub layer: usize,
    /// Layer name (for reports).
    pub name: String,
    /// Config count before pruning.
    pub configs: usize,
    /// Dominated config indices, ascending.
    pub dominated: Vec<usize>,
}

/// Outcome of one differential backend run (see [`cross_check`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CrossCheckReport {
    /// Residual kernel size (nodes the exhaustive side enumerated over).
    pub kernel_nodes: usize,
    /// Agreed optimal step cost, seconds.
    pub cost: f64,
    /// Search-tree nodes the exhaustive side visited.
    pub visited: u64,
    /// Whether the exhaustive side ran to completion. `false` means the
    /// DFS budget fired first: nothing was *certified* (reported as a
    /// warning, escalated by `--deny-warnings`).
    pub complete: bool,
}

/// Everything one audit proved: the passed invariant checks in order,
/// the per-layer dominance certificates, and (when the caller ran it)
/// the backend cross-check.
#[derive(Debug, Clone)]
pub struct AuditReport {
    /// One entry per [`TableCheck`], in the order they ran.
    pub checks: Vec<TableCheckReport>,
    /// Per-layer dominance certificates (every layer, even clean ones).
    pub dominance: Vec<LayerDominance>,
    /// Total dominated configs across all layers.
    pub dominated_total: usize,
    /// Total configs across all layers.
    pub configs_total: usize,
    /// Non-fatal findings (e.g. an incomplete cross-check).
    pub warnings: Vec<String>,
    /// Differential backend certification, when run (see
    /// [`cross_check`]; `audit_tables` itself leaves this `None`).
    pub cross: Option<CrossCheckReport>,
}

impl AuditReport {
    /// Dominated-config fraction, for reports.
    pub fn dominated_fraction(&self) -> f64 {
        if self.configs_total == 0 {
            0.0
        } else {
            self.dominated_total as f64 / self.configs_total as f64
        }
    }

    /// Machine-readable form (the `--json` / wire-probe payload).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let checks = Json::Arr(
            self.checks
                .iter()
                .map(|c| {
                    Json::obj(vec![
                        ("check", Json::Str(c.check.name().to_string())),
                        ("ok", Json::Bool(true)),
                        ("summary", Json::Str(c.summary.clone())),
                    ])
                })
                .collect(),
        );
        let dominance = Json::Arr(
            self.dominance
                .iter()
                .map(|d| {
                    Json::obj(vec![
                        ("layer", Json::Num(d.layer as f64)),
                        ("name", Json::Str(d.name.clone())),
                        ("configs", Json::Num(d.configs as f64)),
                        (
                            "dominated",
                            Json::Arr(d.dominated.iter().map(|&i| Json::Num(i as f64)).collect()),
                        ),
                    ])
                })
                .collect(),
        );
        let cross = match &self.cross {
            None => Json::Null,
            Some(c) => Json::obj(vec![
                ("kernel_nodes", Json::Num(c.kernel_nodes as f64)),
                ("cost_s", Json::Num(c.cost)),
                ("visited", Json::Num(c.visited as f64)),
                ("complete", Json::Bool(c.complete)),
            ]),
        };
        Json::obj(vec![
            ("checks", checks),
            ("dominance", dominance),
            ("dominated_total", Json::Num(self.dominated_total as f64)),
            ("configs_total", Json::Num(self.configs_total as f64)),
            (
                "warnings",
                Json::Arr(self.warnings.iter().map(|w| Json::Str(w.clone())).collect()),
            ),
            ("cross_check", cross),
        ])
    }
}

impl std::fmt::Display for AuditReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for c in &self.checks {
            writeln!(f, "ok {:<18} {}", c.check.name(), c.summary)?;
        }
        let layers_hit = self.dominance.iter().filter(|d| !d.dominated.is_empty()).count();
        writeln!(
            f,
            "dominance          {} of {} configs dominated across {} layers ({:.1}%)",
            self.dominated_total,
            self.configs_total,
            layers_hit,
            100.0 * self.dominated_fraction()
        )?;
        if let Some(c) = &self.cross {
            if c.complete {
                writeln!(
                    f,
                    "cross-check        backends agree over the {}-node kernel \
                     (cost {}, {} nodes visited)",
                    c.kernel_nodes,
                    crate::util::fmt_secs(c.cost),
                    c.visited
                )?;
            }
        }
        for w in &self.warnings {
            writeln!(f, "warning: {w}")?;
        }
        Ok(())
    }
}

fn fail(check: TableCheck, detail: String) -> OptError {
    OptError::InvalidTables { check, detail }
}

/// Statically audit `t` against the model that (supposedly) built it:
/// prove every [`TableCheck`](crate::error::TableCheck) invariant in
/// order, then compute the per-layer dominance certificates — or return
/// [`OptError::InvalidTables`] naming the first violated check.
/// Executes nothing; the most expensive step re-derives transfer byte
/// counts through the same overlap kernel the builder priced with.
///
/// Run this over **unpruned** tables; see the module docs.
pub fn audit_tables(cm: &CostModel<'_>, t: &CostTables) -> Result<AuditReport> {
    let mut checks = Vec::with_capacity(TableCheck::ALL.len());
    checks.push(TableCheckReport {
        check: TableCheck::FiniteCosts,
        summary: check_finite_costs(t)?,
    });
    checks.push(TableCheckReport {
        check: TableCheck::ConfigCanonical,
        summary: check_config_canonical(cm, t)?,
    });
    checks.push(TableCheckReport { check: TableCheck::EdgeDims, summary: check_edge_dims(cm, t)? });
    checks.push(TableCheckReport {
        check: TableCheck::LowerBounds,
        summary: check_lower_bounds(cm, t)?,
    });
    checks.push(TableCheckReport {
        check: TableCheck::BudgetMask,
        summary: check_budget_mask(cm, t)?,
    });

    let dominance = dominance_certificates(cm, t);
    let dominated_total = dominance.iter().map(|d| d.dominated.len()).sum();
    let configs_total = t.configs.iter().map(|c| c.len()).sum();
    Ok(AuditReport {
        checks,
        dominance,
        dominated_total,
        configs_total,
        warnings: Vec::new(),
        cross: None,
    })
}

/// Check 1: every table entry is finite and non-negative — times can be
/// zero (an `Input` layer, a co-located transfer) but never negative,
/// NaN, or infinite.
fn check_finite_costs(t: &CostTables) -> Result<String> {
    const CHECK: TableCheck = TableCheck::FiniteCosts;
    let mut entries = 0usize;
    for (l, row) in t.node_cost.iter().enumerate() {
        for (c, &v) in row.iter().enumerate() {
            if !v.is_finite() || v < 0.0 {
                return Err(fail(
                    CHECK,
                    format!("layer {l} config {c}: node cost {v} is not finite and non-negative"),
                ));
            }
        }
        entries += row.len();
    }
    for (j, e) in t.edges.iter().enumerate() {
        for (k, &v) in e.cost.iter().enumerate() {
            if !v.is_finite() || v < 0.0 {
                return Err(fail(
                    CHECK,
                    format!(
                        "edge {j} ({} -> {}) entry {k}: transfer cost {v} is not finite \
                         and non-negative",
                        e.src, e.dst
                    ),
                ));
            }
        }
        entries += e.cost.len();
    }
    Ok(format!("{entries} cost entries finite and non-negative"))
}

/// Check 2: per-layer config lists are canonical — each config legal
/// for its layer (degrees divide extents, product ≤ ndev) and the list
/// a strictly-increasing subsequence of the canonical enumeration
/// (sorted, deduplicated); for unbudgeted tables, the *whole*
/// enumeration.
fn check_config_canonical(cm: &CostModel<'_>, t: &CostTables) -> Result<String> {
    const CHECK: TableCheck = TableCheck::ConfigCanonical;
    let g = cm.graph;
    if t.configs.len() != g.num_layers() {
        return Err(fail(
            CHECK,
            format!("table covers {} layers, graph has {}", t.configs.len(), g.num_layers()),
        ));
    }
    if t.ndev == 0 || t.ndev != cm.devices.num_devices() {
        return Err(fail(
            CHECK,
            format!("table built for {} devices, cluster has {}", t.ndev, cm.devices.num_devices()),
        ));
    }
    let mut total = 0usize;
    for (l, gl) in g.layers.iter().enumerate() {
        let list = &t.configs[l];
        if list.is_empty() {
            return Err(fail(CHECK, format!("layer {l} (`{}`): empty config list", gl.name)));
        }
        for (i, cfg) in list.iter().enumerate() {
            if cfg.total() > t.ndev {
                return Err(fail(
                    CHECK,
                    format!(
                        "layer {l} (`{}`) config {i}: degree product {} exceeds {} devices",
                        gl.name,
                        cfg.total(),
                        t.ndev
                    ),
                ));
            }
            for d in 0..4 {
                let extent = gl.out_shape.get(d).copied().unwrap_or(1);
                if cfg.deg[d] == 0 || extent % cfg.deg[d] != 0 {
                    return Err(fail(
                        CHECK,
                        format!(
                            "layer {l} (`{}`) config {i}: degree {} does not divide \
                             extent {extent} in dimension {d}",
                            gl.name, cfg.deg[d]
                        ),
                    ));
                }
            }
        }
        // Sorted/deduped == a strictly-increasing walk of the canonical
        // enumeration (which also proves each config is *allowed* for
        // this operator, not merely divisibility-legal).
        let canon = enumerate_configs(gl, t.ndev);
        let mut cursor = 0usize;
        for (i, cfg) in list.iter().enumerate() {
            match canon[cursor..].iter().position(|c| c == cfg) {
                Some(off) => cursor += off + 1,
                None => {
                    let detail = if canon.contains(cfg) {
                        format!(
                            "layer {l} (`{}`) config {i} ({}) is out of canonical order \
                             or duplicated",
                            gl.name,
                            cfg.label()
                        )
                    } else {
                        format!(
                            "layer {l} (`{}`) config {i} ({}) is not in the canonical \
                             enumeration for this operator",
                            gl.name,
                            cfg.label()
                        )
                    };
                    return Err(fail(CHECK, detail));
                }
            }
        }
        if t.budget.is_none() && list.len() != canon.len() {
            return Err(fail(
                CHECK,
                format!(
                    "layer {l} (`{}`): unbudgeted table keeps {} of {} canonical configs",
                    gl.name,
                    list.len(),
                    canon.len()
                ),
            ));
        }
        total += list.len();
    }
    Ok(format!("{total} configs canonical across {} layers", g.num_layers()))
}

/// Check 3: the structural frame — node-cost rows sized to their config
/// lists, one edge table per graph edge in graph edge order, each
/// dimensioned exactly producer-configs × consumer-configs.
fn check_edge_dims(cm: &CostModel<'_>, t: &CostTables) -> Result<String> {
    const CHECK: TableCheck = TableCheck::EdgeDims;
    let g = cm.graph;
    if t.node_cost.len() != t.configs.len() {
        return Err(fail(
            CHECK,
            format!("{} node-cost rows for {} config lists", t.node_cost.len(), t.configs.len()),
        ));
    }
    for (l, row) in t.node_cost.iter().enumerate() {
        if row.len() != t.configs[l].len() {
            return Err(fail(
                CHECK,
                format!(
                    "layer {l}: node-cost row has {} entries for {} configs",
                    row.len(),
                    t.configs[l].len()
                ),
            ));
        }
    }
    if t.edges.len() != g.num_edges() {
        return Err(fail(
            CHECK,
            format!("table has {} edge tables, graph has {} edges", t.edges.len(), g.num_edges()),
        ));
    }
    let n = t.configs.len();
    for (j, (e, &(s, d))) in t.edges.iter().zip(g.edges.iter()).enumerate() {
        if (e.src, e.dst) != (s, d) {
            return Err(fail(
                CHECK,
                format!("edge {j} is ({}, {}), graph edge order expects ({s}, {d})", e.src, e.dst),
            ));
        }
        if e.src >= n || e.dst >= n || e.src >= e.dst {
            return Err(fail(
                CHECK,
                format!("edge {j} ({}, {}) is not topological over {n} layers", e.src, e.dst),
            ));
        }
        let want = t.configs[e.src].len() * t.configs[e.dst].len();
        if e.cost.len() != want {
            return Err(fail(
                CHECK,
                format!(
                    "edge {j} ({} -> {}): {} entries, producer-configs x consumer-configs \
                     requires {} x {} = {want}",
                    e.src,
                    e.dst,
                    e.cost.len(),
                    t.configs[e.src].len(),
                    t.configs[e.dst].len()
                ),
            ));
        }
    }
    Ok(format!("{} edge tables dimensioned producer x consumer", t.edges.len()))
}

/// Fastest point-to-point link bandwidth in the cluster (off-diagonal
/// max); `None` for a single-device cluster, where no transfer can be
/// remote.
fn fastest_link(cm: &CostModel<'_>) -> Option<f64> {
    let n = cm.devices.num_devices();
    let mut best: Option<f64> = None;
    for i in 0..n {
        for j in 0..n {
            if i != j {
                let bw = cm.devices.bandwidth(i, j);
                best = Some(best.map_or(bw, |b: f64| b.max(bw)));
            }
        }
    }
    best
}

/// Check 4: closed-form physical lower bounds. An edge entry can never
/// undercut its worst destination's inbound remote bytes over the
/// *fastest* link in the cluster; a node entry can never undercut the
/// round-trip gradient/parameter exchange its replication implies
/// (`2 · shard_bytes · (R-1)/R` over the fastest path). Both bounds
/// re-derive their geometry (`output_tiles`, `input_region`,
/// `param_sharding`, tile placement) independently of the priced
/// values, so a cost model that silently underprices communication
/// fails here with the offending entry named.
fn check_lower_bounds(cm: &CostModel<'_>, t: &CostTables) -> Result<String> {
    const CHECK: TableCheck = TableCheck::LowerBounds;
    let g = cm.graph;
    let Some(bw_max) = fastest_link(cm) else {
        return Ok("single-device cluster: every transfer is local".to_string());
    };
    // t_S's effective bandwidth is a min-fold seeded with the host
    // bandwidth, so it can never exceed min(host_bw, fastest link).
    let sync_bw_cap = cm.devices.host_bw.min(bw_max);

    let mut nodes_checked = 0usize;
    for (l, gl) in g.layers.iter().enumerate() {
        for (c, cfg) in t.configs[l].iter().enumerate() {
            if !gl.has_params() {
                continue;
            }
            let sh = param_sharding(gl, cfg);
            if sh.replicas <= 1 {
                continue;
            }
            let r = sh.replicas as f64;
            let bound = 2.0 * sh.shard_bytes * (r - 1.0) / r / sync_bw_cap;
            let got = t.node_cost[l][c];
            if got < bound * (1.0 - LOWER_BOUND_SLACK) {
                return Err(fail(
                    CHECK,
                    format!(
                        "layer {l} (`{}`) config {c} ({}): node cost {got} beats the \
                         sync round-trip lower bound {bound} ({} replicas of a \
                         {}-byte shard over the fastest path)",
                        gl.name,
                        cfg.label(),
                        sh.replicas,
                        sh.shard_bytes
                    ),
                ));
            }
            nodes_checked += 1;
        }
    }

    let dev_of: Vec<usize> = (0..t.ndev).map(|i| cm.dev_of(i)).collect();
    let mut entries_checked = 0usize;
    for e in &t.edges {
        let (ls, ld) = (g.layer(e.src), g.layer(e.dst));
        let in_idx = cm.edge_in_idx(e.src, e.dst);
        let cd_len = t.configs[e.dst].len();
        // Same flattened-region overlap kernel the builder priced with,
        // counting bytes instead of seconds.
        let src_flat: Vec<Vec<FlatRegion>> = t.configs[e.src]
            .iter()
            .map(|c| output_tiles(&ls.out_shape, c).iter().map(flatten).collect())
            .collect();
        for (cj, cfg_d) in t.configs[e.dst].iter().enumerate() {
            let needs: Vec<Option<FlatRegion>> = output_tiles(&ld.out_shape, cfg_d)
                .iter()
                .map(|dt| input_region(ld, in_idx, dt).map(|r| flatten(&r)))
                .collect();
            for (ci, src_tiles) in src_flat.iter().enumerate() {
                let mut worst_bytes = 0.0f64;
                for (m, need) in needs.iter().enumerate() {
                    let Some(need) = need else { continue };
                    let dst_dev = dev_of[m];
                    let mut inbound = 0.0;
                    for (k, stile) in src_tiles.iter().enumerate() {
                        if dev_of[k] == dst_dev {
                            continue;
                        }
                        inbound += overlap_elems(need, stile) as f64 * 4.0;
                    }
                    worst_bytes = worst_bytes.max(inbound);
                }
                let bound = worst_bytes / bw_max;
                let got = e.at(ci, cj, cd_len);
                if got < bound * (1.0 - LOWER_BOUND_SLACK) {
                    return Err(fail(
                        CHECK,
                        format!(
                            "edge ({} -> {}) entry ({ci}, {cj}): transfer cost {got} beats \
                             the physical lower bound {bound} ({worst_bytes} remote bytes \
                             over the fastest link)",
                            e.src, e.dst
                        ),
                    ));
                }
                entries_checked += 1;
            }
        }
    }
    Ok(format!(
        "{entries_checked} transfer entries and {nodes_checked} sync entries above their \
         physical lower bounds"
    ))
}

/// Check 5: budget-mask coherence. A budgeted table must be *bitwise*
/// the surviving-index subset of the unbudgeted build: its config list
/// exactly the admitted subset of the canonical enumeration, its cost
/// rows and edge entries the corresponding entries of a fresh
/// unbudgeted `build_opts` build. Unbudgeted tables have no mask —
/// the check passes vacuously.
fn check_budget_mask(cm: &CostModel<'_>, t: &CostTables) -> Result<String> {
    const CHECK: TableCheck = TableCheck::BudgetMask;
    let g = cm.graph;
    let Some(budget) = t.budget else {
        return Ok("unbudgeted table: nothing masked".to_string());
    };
    let full = CostTables::build_opts(cm, t.ndev, None, &BuildOptions::default())?;
    // Surviving indices per layer, re-derived from the budget.
    let mut kept: Vec<Vec<usize>> = Vec::with_capacity(g.num_layers());
    for (l, gl) in g.layers.iter().enumerate() {
        let keep: Vec<usize> = full.configs[l]
            .iter()
            .enumerate()
            .filter(|(_, c)| budget.admits(layer_peak_bytes(gl, c)))
            .map(|(i, _)| i)
            .collect();
        let want: Vec<_> = keep.iter().map(|&i| full.configs[l][i]).collect();
        if t.configs[l] != want {
            return Err(fail(
                CHECK,
                format!(
                    "layer {l} (`{}`): stale budget mask — table keeps {} configs, the \
                     budget admits {}",
                    gl.name,
                    t.configs[l].len(),
                    want.len()
                ),
            ));
        }
        for (i, &oi) in keep.iter().enumerate() {
            if t.node_cost[l][i].to_bits() != full.node_cost[l][oi].to_bits() {
                return Err(fail(
                    CHECK,
                    format!(
                        "layer {l} (`{}`) config {i}: node cost {} is not bitwise the \
                         unbudgeted build's {}",
                        gl.name, t.node_cost[l][i], full.node_cost[l][oi]
                    ),
                ));
            }
        }
        kept.push(keep);
    }
    for (j, (e, fe)) in t.edges.iter().zip(full.edges.iter()).enumerate() {
        let (ks, kd) = (&kept[e.src], &kept[e.dst]);
        let full_cd = full.configs[e.dst].len();
        for (ci, &oi) in ks.iter().enumerate() {
            for (cj, &oj) in kd.iter().enumerate() {
                let got = e.at(ci, cj, kd.len());
                let want = fe.at(oi, oj, full_cd);
                if got.to_bits() != want.to_bits() {
                    return Err(fail(
                        CHECK,
                        format!(
                            "edge {j} ({} -> {}) entry ({ci}, {cj}): transfer cost {got} \
                             is not bitwise the unbudgeted build's {want}",
                            e.src, e.dst
                        ),
                    ));
                }
            }
        }
    }
    Ok(format!(
        "budgeted table is bitwise the surviving-index subset of the unbudgeted build \
         ({} per device)",
        crate::util::fmt_bytes(budget.bytes_per_dev)
    ))
}

/// `a` dominates `b` for layer `l` iff `a` is never worse in any
/// context: its memory peak does not exceed `b`'s, and the worst-case
/// total-cost difference `Δnode + Σ_incident-edges max_ctx Δedge` is
/// negative — or zero with `a < b`, in which case both backends'
/// first-minimum tie-breaking already prefers `a`.
fn dominates(
    a: usize,
    b: usize,
    peaks: &[f64],
    node_row: &[f64],
    out_edges: &[(&crate::cost::EdgeTable, usize)],
    in_edges: &[(&crate::cost::EdgeTable, usize)],
) -> bool {
    if peaks[a] > peaks[b] {
        return false;
    }
    let mut d = node_row[a] - node_row[b];
    for &(e, cd_len) in out_edges {
        let mut worst = f64::NEG_INFINITY;
        for cj in 0..cd_len {
            worst = worst.max(e.at(a, cj, cd_len) - e.at(b, cj, cd_len));
        }
        d += worst;
    }
    for &(e, cd_len) in in_edges {
        let cs_len = e.cost.len() / cd_len;
        let mut worst = f64::NEG_INFINITY;
        for ci in 0..cs_len {
            worst = worst.max(e.at(ci, a, cd_len) - e.at(ci, b, cd_len));
        }
        d += worst;
    }
    d < 0.0 || (d <= 0.0 && a < b)
}

/// The per-layer dominance certificates over audited tables: for each
/// layer, the exact set of config indices some other config dominates
/// across all contexts. Sound to remove all of them at once — the
/// lexicographic tie rule makes the relation acyclic, so every
/// dominated config has a *kept* dominator.
pub fn dominance_certificates(cm: &CostModel<'_>, t: &CostTables) -> Vec<LayerDominance> {
    let g = cm.graph;
    let mut out = Vec::with_capacity(g.num_layers());
    for (l, gl) in g.layers.iter().enumerate() {
        let m = t.configs[l].len();
        let peaks: Vec<f64> = t.configs[l].iter().map(|c| layer_peak_bytes(gl, c)).collect();
        let out_edges: Vec<(&crate::cost::EdgeTable, usize)> = t
            .edges
            .iter()
            .filter(|e| e.src == l)
            .map(|e| (e, t.configs[e.dst].len()))
            .collect();
        let in_edges: Vec<(&crate::cost::EdgeTable, usize)> = t
            .edges
            .iter()
            .filter(|e| e.dst == l)
            .map(|e| (e, m))
            .collect();
        let mut dominated = Vec::new();
        for b in 0..m {
            if (0..m).any(|a| {
                a != b && dominates(a, b, &peaks, &t.node_cost[l], &out_edges, &in_edges)
            }) {
                dominated.push(b);
            }
        }
        out.push(LayerDominance { layer: l, name: gl.name.clone(), configs: m, dominated });
    }
    out
}

/// Remove every dominated config from `t` (see
/// [`dominance_certificates`]), returning the pruned tables and the
/// number of configs removed. Exactness: both backends return the
/// byte-identical optimal strategy over the pruned tables — the
/// dominated configs can never appear in a first-minimum optimum.
///
/// The pruned tables are a *search input*, not an audit subject: their
/// config lists are intentionally not the budget-masked enumeration,
/// so they would fail [`audit_tables`]' canonical/mask re-derivation.
pub fn prune_tables(cm: &CostModel<'_>, t: &CostTables) -> (CostTables, usize) {
    let certs = dominance_certificates(cm, t);
    let mut removed = 0usize;
    let mut keep: Vec<Vec<usize>> = Vec::with_capacity(t.configs.len());
    for cert in &certs {
        let m = t.configs[cert.layer].len();
        let mut is_dom = vec![false; m];
        for &b in &cert.dominated {
            is_dom[b] = true;
        }
        let kept: Vec<usize> = (0..m).filter(|&i| !is_dom[i]).collect();
        // The relation is irreflexive-by-construction and acyclic, so at
        // least one config survives; guard anyway so a future criterion
        // change can never produce an unsearchable table.
        if kept.is_empty() {
            keep.push((0..m).collect());
        } else {
            removed += m - kept.len();
            keep.push(kept);
        }
    }
    let configs = keep
        .iter()
        .enumerate()
        .map(|(l, ks)| ks.iter().map(|&i| t.configs[l][i]).collect())
        .collect();
    let node_cost = keep
        .iter()
        .enumerate()
        .map(|(l, ks)| ks.iter().map(|&i| t.node_cost[l][i]).collect())
        .collect();
    let edges = t
        .edges
        .iter()
        .map(|e| {
            let (ks, kd) = (&keep[e.src], &keep[e.dst]);
            let cd_len = t.configs[e.dst].len();
            let mut cost = Vec::with_capacity(ks.len() * kd.len());
            for &ci in ks {
                for &cj in kd {
                    cost.push(e.at(ci, cj, cd_len));
                }
            }
            crate::cost::EdgeTable { src: e.src, dst: e.dst, cost }
        })
        .collect();
    (CostTables { configs, node_cost, edges, ndev: t.ndev, budget: t.budget }, removed)
}

/// Differential backend certification: run Algorithm 1 over the full
/// tables and the exhaustive DFS over the elimination-reduced residual
/// kernel ([`optimizer::reduce`]), and demand bit-level agreement on
/// the kernel assignments plus cost agreement to relative 1e-9. Both
/// searches break ties by first minimum over the same canonical config
/// order, so on honest tables the assignments match exactly.
///
/// Returns [`OptError::BackendMismatch`] (naming the first divergent
/// layer) on disagreement. A DFS that hits `dfs_budget` before
/// completing certifies nothing: the report comes back with
/// `complete: false` for the caller to surface as a warning.
pub fn cross_check(
    cm: &CostModel<'_>,
    t: &CostTables,
    dfs_budget: Option<Duration>,
) -> Result<CrossCheckReport> {
    let full = optimizer::optimize(t);
    let red = optimizer::reduce(t);
    let r = dfs::dfs_optimal(&red.tables, dfs_budget);
    if !r.complete {
        return Ok(CrossCheckReport {
            kernel_nodes: red.nodes.len(),
            cost: full.cost,
            visited: r.visited,
            complete: false,
        });
    }
    let Some(kernel) = r.strategy else {
        return Err(OptError::Internal(
            "complete kernel DFS returned no strategy".to_string(),
        ));
    };
    let scale = full.cost.abs().max(r.cost.abs()).max(1e-30);
    let costs_agree = (full.cost - r.cost).abs() <= 1e-9 * scale;
    for (p, &node) in red.nodes.iter().enumerate() {
        if kernel.configs[p] != full.strategy.configs[node] {
            return Err(OptError::BackendMismatch {
                layer: cm.graph.layer(node).name.clone(),
                detail: format!(
                    "elimination assigns {}, exhaustive DFS over the residual kernel \
                     assigns {} (costs {} vs {})",
                    full.strategy.configs[node].label(),
                    kernel.configs[p].label(),
                    full.cost,
                    r.cost
                ),
            });
        }
    }
    if !costs_agree {
        let layer = red.nodes.first().map(|&n| cm.graph.layer(n).name.clone());
        return Err(OptError::BackendMismatch {
            layer: layer.unwrap_or_else(|| "(empty kernel)".to_string()),
            detail: format!(
                "identical assignments but diverging costs: elimination {} vs \
                 exhaustive {}",
                full.cost, r.cost
            ),
        });
    }
    Ok(CrossCheckReport {
        kernel_nodes: red.nodes.len(),
        cost: full.cost,
        visited: r.visited,
        complete: true,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::device::DeviceGraph;
    use crate::graph::nets;
    use crate::memory::MemBudget;

    fn setup(net: &str, ndev: usize) -> (crate::graph::CompGraph, DeviceGraph) {
        (nets::by_name(net, 32 * ndev).unwrap(), DeviceGraph::p100_cluster(ndev).unwrap())
    }

    #[test]
    fn honest_tables_audit_clean() {
        let (g, d) = setup("lenet5", 2);
        let cm = CostModel::new(&g, &d);
        let t = CostTables::build(&cm, 2).unwrap();
        let report = audit_tables(&cm, &t).unwrap();
        assert_eq!(report.checks.len(), TableCheck::ALL.len());
        for (c, want) in report.checks.iter().zip(TableCheck::ALL) {
            assert_eq!(c.check, want);
        }
        let text = report.to_string();
        assert!(text.contains("finite-costs") && text.contains("budget-mask"));
    }

    #[test]
    fn budgeted_tables_audit_clean() {
        let (g, d) = setup("alexnet", 4);
        let cm = CostModel::new(&g, &d);
        let budget = Some(MemBudget::new(16 << 30));
        let t = CostTables::build_budgeted(&cm, 4, budget).unwrap();
        audit_tables(&cm, &t).unwrap();
    }

    #[test]
    fn pruned_search_is_byte_identical_on_alexnet() {
        let (g, d) = setup("alexnet", 2);
        let cm = CostModel::new(&g, &d);
        let t = CostTables::build(&cm, 2).unwrap();
        let (pt, removed) = prune_tables(&cm, &t);
        assert!(removed > 0, "alexnet@2 must have dominated configs");
        let a = optimizer::optimize(&t);
        let b = optimizer::optimize(&pt);
        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{} vs {}", a.cost, b.cost);
        assert_eq!(a.strategy.configs, b.strategy.configs);
    }

    #[test]
    fn cross_check_certifies_builtins() {
        for net in ["lenet5", "alexnet"] {
            let (g, d) = setup(net, 2);
            let cm = CostModel::new(&g, &d);
            let t = CostTables::build(&cm, 2).unwrap();
            let c = cross_check(&cm, &t, None).unwrap();
            assert!(c.complete, "{net}");
            assert!(c.kernel_nodes <= 2, "{net}");
        }
    }

    #[test]
    fn mutated_entry_fails_its_named_check() {
        let (g, d) = setup("lenet5", 2);
        let cm = CostModel::new(&g, &d);
        let mut t = CostTables::build(&cm, 2).unwrap();
        t.node_cost[1][0] = f64::NAN;
        match audit_tables(&cm, &t) {
            Err(OptError::InvalidTables { check: TableCheck::FiniteCosts, .. }) => {}
            other => panic!("expected finite-costs failure, got {other:?}"),
        }
    }
}
