//! Design-choice ablations called out in DESIGN.md:
//!
//! 1. **Placement** — contiguous (the paper's implicit policy) vs
//!    round-robin-across-nodes tile placement.
//! 2. **Sync protocol** — sharded-PS (allreduce-equivalent) vs central
//!    per-layer parameter server.
//! 3. **Interconnect** — how the optimal strategy's shape shifts as the
//!    inter-node bandwidth sweeps from 10 GbE to NVLink-class.

use optcnn::cost::{CostModel, CostTables, SyncModel};
use optcnn::device::{ComputeModel, DeviceGraph};
use optcnn::graph::nets;
use optcnn::optimizer::{self, strategies};
use optcnn::parallel::Placement;
use optcnn::util::fmt_secs;
use optcnn::util::table::Table;

fn main() {
    placement_ablation();
    sync_ablation();
    bandwidth_ablation();
}

fn placement_ablation() {
    let mut table = Table::new(
        "ablation 1: tile placement (layer-wise optimum, est. step time)",
        &["network", "devices", "contiguous", "round-robin nodes", "penalty"],
    );
    for (net, ndev) in [("alexnet", 16usize), ("vgg16", 16), ("inception_v3", 16)] {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let mut row = vec![net.to_string(), ndev.to_string()];
        let mut times = Vec::new();
        for p in [Placement::Contiguous, Placement::RoundRobinNodes] {
            let cm = CostModel::new(&g, &d).with_placement(p);
            let t = CostTables::build(&cm, ndev).unwrap();
            let opt = optimizer::optimize(&t);
            times.push(opt.cost);
            row.push(fmt_secs(opt.cost));
        }
        row.push(format!("{:.2}x", times[1] / times[0]));
        table.row(row);
    }
    table.print();
    println!(
        "the optimizer re-plans around either placement (penalties within a few \
         percent); placement matters for FIXED strategies, not for the search\n"
    );
}

fn sync_ablation() {
    let mut table = Table::new(
        "ablation 2: parameter-sync protocol (est. step time, 16 GPUs)",
        &["network", "strategy", "sharded PS", "central PS", "penalty"],
    );
    for net in ["alexnet", "vgg16"] {
        let ndev = 16;
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        for strat in ["data", "layerwise"] {
            let mut row = vec![net.to_string(), strat.to_string()];
            let mut times = Vec::new();
            for sync in [SyncModel::Sharded, SyncModel::Central] {
                let cm = CostModel::new(&g, &d).with_sync(sync);
                let cost = if strat == "layerwise" {
                    optimizer::optimize(&CostTables::build(&cm, ndev).unwrap()).cost
                } else {
                    cm.t_o(&strategies::data_parallel(&g, ndev))
                };
                times.push(cost);
                row.push(fmt_secs(cost));
            }
            row.push(format!("{:.2}x", times[1] / times[0]));
            table.row(row);
        }
    }
    table.print();
    println!("layer-wise search absorbs most of a slow PS by re-planning; \
              data parallelism cannot\n");
}

fn bandwidth_ablation() {
    let ndev = 16;
    let g = nets::vgg16(32 * ndev).unwrap();
    let mut table = Table::new(
        "ablation 3: inter-node bandwidth sweep (VGG-16, 16 GPUs)",
        &["inter-node BW", "layerwise step", "data step", "gain", "fc config"],
    );
    for gbps in [1.25f64, 3.125, 6.25, 12.5, 15.0] {
        let d = DeviceGraph::cluster(
            "sweep",
            4,
            4,
            15e9,
            gbps * 1e9,
            12e9,
            ComputeModel::p100(),
        )
        .unwrap();
        let cm = CostModel::new(&g, &d);
        let t = CostTables::build(&cm, ndev).unwrap();
        let opt = optimizer::optimize(&t);
        let dp = cm.t_o(&strategies::data_parallel(&g, ndev));
        let fc6 = g.layers.iter().find(|l| l.name == "fc6").unwrap();
        table.row(vec![
            format!("{gbps} GB/s"),
            fmt_secs(opt.cost),
            fmt_secs(dp),
            format!("{:.2}x", dp / opt.cost),
            opt.strategy.config(fc6.id).label(),
        ]);
    }
    table.print();
    println!("layer-wise's advantage grows as the interconnect shrinks — \
              the paper's distributed-training motivation\n");
}
