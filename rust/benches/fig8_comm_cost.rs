//! Figure 8: communication cost (data transferred per step) for the four
//! strategies across networks and cluster sizes.
//!
//! Paper: OWT reduces comm by 1.1-23.0x vs data/model parallelism, and
//! layer-wise parallelism reduces it by a further 1.2-2.5x.

use optcnn::planner::{Network, Planner, StrategyKind};
use optcnn::util::fmt_bytes;
use optcnn::util::table::Table;

fn main() {
    let mut owt_gain_range = (f64::INFINITY, 0.0f64);
    let mut lw_gain_range = (f64::INFINITY, 0.0f64);
    for net in [Network::AlexNet, Network::Vgg16, Network::InceptionV3] {
        let mut table = Table::new(
            &format!("Figure 8: {net} communication cost per step"),
            &["GPUs", "data", "model", "owt", "layerwise", "lw vs owt"],
        );
        for ndev in [4usize, 8, 16] {
            let mut p = Planner::builder(net).devices(ndev).build().unwrap();
            let vols: Vec<f64> = StrategyKind::ALL
                .iter()
                .map(|&kind| p.evaluate(kind).unwrap().comm.total())
                .collect();
            let owt_gain = vols[0].max(vols[1]) / vols[2];
            let lw_gain = vols[2] / vols[3];
            owt_gain_range = (owt_gain_range.0.min(owt_gain), owt_gain_range.1.max(owt_gain));
            lw_gain_range = (lw_gain_range.0.min(lw_gain), lw_gain_range.1.max(lw_gain));
            table.row(vec![
                ndev.to_string(),
                fmt_bytes(vols[0]),
                fmt_bytes(vols[1]),
                fmt_bytes(vols[2]),
                fmt_bytes(vols[3]),
                format!("{lw_gain:.2}x"),
            ]);
        }
        table.print();
        println!();
    }
    println!(
        "OWT vs data/model: {:.1}-{:.1}x (paper 1.1-23.0x); layer-wise vs OWT: \
         {:.2}-{:.2}x (paper 1.2-2.5x)\n",
        owt_gain_range.0, owt_gain_range.1, lw_gain_range.0, lw_gain_range.1
    );
}
