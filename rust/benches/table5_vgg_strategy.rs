//! Table 5: the optimal parallelization strategy (under the cost model)
//! for VGG-16 on 4 GPUs of a single node.
//!
//! Paper's strategy: sample parallelism for the early conv/pool stages,
//! height/width (mixed-dimension) parallelism for the last conv stage,
//! channel parallelism with *decreasing degree* for the fully-connected
//! layers, serial softmax. The reproduction should show the same regime
//! transitions (data -> mixed -> model), with the exact dimensions chosen
//! by the calibrated cost model.

use optcnn::graph::OpKind;
use optcnn::planner::{Network, Planner};
use optcnn::util::table::Table;

fn main() {
    let mut p = Planner::builder(Network::Vgg16).devices(4).build().unwrap();
    let opt = p.optimize().unwrap();
    let strategy = &opt.strategy;
    let g = p.graph();

    let mut table = Table::new(
        "Table 5: optimal VGG-16 strategy, 4 GPUs (1 node)",
        &["layers", "parallelization configuration"],
    );
    // group consecutive layers with identical configs, paper-style
    let mut run_start = 0usize;
    for id in 1..=g.num_layers() {
        let split = id == g.num_layers() || strategy.config(id) != strategy.config(run_start);
        if split {
            let label = if id - run_start == 1 {
                g.layer(run_start).name.clone()
            } else {
                format!(
                    "{} .. {} ({} layers)",
                    g.layer(run_start).name,
                    g.layer(id - 1).name,
                    id - run_start
                )
            };
            table.row(vec![label, strategy.config(run_start).label()]);
            run_start = id;
        }
    }
    table.print();

    // regime checks (the paper's qualitative claims)
    let first_conv = g.layers.iter().find(|l| matches!(l.op, OpKind::Conv2d { .. })).unwrap();
    let fc6 = g.layers.iter().find(|l| l.name == "fc6").unwrap();
    let c_first = strategy.config(first_conv.id);
    let c_fc = strategy.config(fc6.id);
    println!("early convs use sample parallelism: {}", c_first.deg[0] > 1);
    println!(
        "fully-connected layers use channel parallelism (no param sync): {}",
        c_fc.deg[1] > 1 && c_fc.deg[0] == 1
    );
    println!("search reduced the graph to K = {} nodes\n", opt.stats.final_nodes);
}
