//! Figure 2: communication for the first fully-connected layer of VGG-16
//! (fc6) on 2 GPUs — sample parallelism (gradient sync) vs channel
//! parallelism (input transfer).
//!
//! Paper claim: "using parallelism in the channel dimension reduces
//! communication costs by 12x" for this layer.

use optcnn::cost::CostModel;
use optcnn::device::DeviceGraph;
use optcnn::graph::nets;
use optcnn::parallel::PConfig;
use optcnn::util::fmt_bytes;
use optcnn::util::table::Table;

fn main() {
    let ndev = 2;
    let g = nets::vgg16(32 * ndev).unwrap();
    let d = DeviceGraph::p100_cluster(ndev).unwrap();
    let cm = CostModel::new(&g, &d);
    let fc6 = g.layers.iter().find(|l| l.name == "fc6").expect("fc6");
    let pool5 = g.layers.iter().find(|l| l.name == "pool5").expect("pool5");

    let mut table = Table::new(
        "Figure 2: VGG-16 fc6 on 2 GPUs — communication per step",
        &["parallelism", "param sync", "input transfer", "total"],
    );
    let mut totals = Vec::new();
    for (label, cfg) in [
        ("sample {n=2}", PConfig::data(2)),
        ("channel {c=2}", PConfig::channel(2)),
    ] {
        // producer (pool5) stays sample-partitioned, as in the figure
        let sync = cm.s_bytes(fc6, &cfg);
        let xfer = cm.x_bytes(pool5, fc6, 0, &PConfig::data(2), &cfg);
        table.row(vec![
            label.to_string(),
            fmt_bytes(sync),
            fmt_bytes(xfer),
            fmt_bytes(sync + xfer),
        ]);
        totals.push(sync + xfer);
    }
    table.print();
    println!(
        "channel parallelism reduces fc6 communication by {:.1}x (paper: 12x)\n",
        totals[0] / totals[1]
    );
}
