//! The throughput/memory Pareto frontier: optimal step time as a
//! function of the per-device memory budget (vgg16, 4 devices, 32/GPU).
//!
//! Sweeps the budget from just above the tightest satisfiable point
//! (the largest per-layer minimum peak — below it some layer has no
//! feasible configuration at all) up to unconstrained, re-running the
//! feasibility-masked search at each point. The interesting region is
//! the low end, where the mask forces higher-degree (more
//! communication-heavy) configurations and the step time climbs — the
//! trade-off a 16 GB P100 forces that a 40 GB A100 does not.

use optcnn::graph::nets;
use optcnn::memory::layer_peak_bytes;
use optcnn::parallel::enumerate_configs;
use optcnn::planner::{Network, Planner, StrategyKind};
use optcnn::util::benchkit::time_once;
use optcnn::util::fmt_bytes;

fn main() {
    let ndev = 4usize;
    let g = nets::vgg16(32 * ndev).unwrap();
    // The feasibility floor: the largest per-layer minimum peak. Any
    // budget below this is Infeasible by construction.
    let floor = g
        .layers
        .iter()
        .map(|l| {
            enumerate_configs(l, ndev)
                .iter()
                .map(|c| layer_peak_bytes(l, c))
                .fold(f64::INFINITY, f64::min)
        })
        .fold(0.0f64, f64::max);
    println!(
        "== mem_frontier: vgg16 x{ndev}, 32/GPU (feasibility floor {}) ==",
        fmt_bytes(floor)
    );

    // below the floor: the typed infeasibility, not a panic
    let mut starved = Planner::builder(Network::Vgg16)
        .devices(ndev)
        .mem_limit((floor * 0.5) as u64)
        .build()
        .unwrap();
    match starved.evaluate(StrategyKind::Layerwise) {
        Err(e) => println!("budget {:>10}  {e}", fmt_bytes(floor * 0.5)),
        Ok(_) => panic!("a budget below the floor must be infeasible"),
    }

    let mut frontier: Vec<(f64, f64, f64)> = Vec::new();
    for mult in [1.0f64, 1.25, 1.5, 2.0, 3.0, 4.0, 8.0, f64::INFINITY] {
        let budget = if mult.is_finite() { Some((floor * mult).ceil() as u64) } else { None };
        let mut b = Planner::builder(Network::Vgg16).devices(ndev);
        if let Some(bytes) = budget {
            b = b.mem_limit(bytes);
        }
        let mut p = b.build().unwrap();
        let (eval, dt) = time_once(|| p.evaluate(StrategyKind::Layerwise).unwrap());
        let peak = eval.peak_mem();
        let label = match budget {
            Some(bytes) => fmt_bytes(bytes as f64),
            None => "unlimited".to_string(),
        };
        println!(
            "budget {label:>10}  est {:>9.3} ms  sim {:>9.3} ms  peak/dev {:>10}  ({:.0} ms)",
            eval.estimate * 1e3,
            eval.sim.step_time * 1e3,
            fmt_bytes(peak),
            dt * 1e3
        );
        frontier.push((mult, eval.estimate, peak));
    }

    // Pareto sanity on the searched objective (the Eq. 1 estimate):
    // relaxing the budget never worsens the optimum, because the masked
    // space at a smaller budget is a subset of the larger one.
    for w in frontier.windows(2) {
        let (tight, loose) = (&w[0], &w[1]);
        assert!(
            loose.1 <= tight.1 * (1.0 + 1e-9),
            "relaxing the budget (x{} -> x{}) worsened the optimum: {} -> {}",
            tight.0,
            loose.0,
            tight.1,
            loose.1
        );
    }
    println!("-> frontier is monotone: looser budgets are never slower\n");
}
