//! Figure 1: execution time for parallelizing one convolutional layer
//! (Conv8 of VGG-16) on 4 GPUs using different dimensions.
//!
//! The paper's bars are per-dimension layer times measured on P100s; here
//! they come from the calibrated cost model (t_C + t_X-from-a-matching-
//! producer + t_S), which is exactly what the search consumes.
//!
//! Expected shape: a spatial or mixed split beats pure sample-dimension
//! parallelism for this layer (large spatial extent, modest batch per
//! GPU, parameter sync under sample replication).

use optcnn::cost::{CostModel, SyncModel};
use optcnn::device::DeviceGraph;
use optcnn::graph::nets;
use optcnn::parallel::PConfig;
use optcnn::util::benchkit::bench;
use optcnn::util::table::Table;

fn main() {
    let ndev = 4;
    let g = nets::vgg16(32 * ndev).unwrap();
    let d = DeviceGraph::p100_cluster(ndev).unwrap();
    let cm = CostModel::new(&g, &d);
    let conv8 = g.layers.iter().find(|l| l.name == "conv8").expect("conv8");
    let conv7 = g.layers.iter().find(|l| l.name == "conv7").expect("conv7");

    let configs = [
        ("{n=4} (sample)", PConfig::new(4, 1, 1, 1)),
        ("{c=4} (channel)", PConfig::new(1, 4, 1, 1)),
        ("{h=4} (height)", PConfig::new(1, 1, 4, 1)),
        ("{w=4} (width)", PConfig::new(1, 1, 1, 4)),
        ("{h=2, w=2}", PConfig::new(1, 1, 2, 2)),
        ("{n=2, c=2}", PConfig::new(2, 2, 1, 1)),
    ];

    // The figure's measured system synchronized parameters through a
    // parameter server (paper §5.1); we show both that protocol and the
    // bandwidth-optimal sharded sync as an ablation.
    let cm_central = CostModel::new(&g, &d).with_sync(SyncModel::Central);
    let mut table = Table::new(
        "Figure 1: VGG-16 Conv8 on 4 GPUs, per-dimension parallelization",
        &[
            "configuration",
            "t_C (ms)",
            "t_X (ms)",
            "t_S central",
            "total (central PS)",
            "total (sharded)",
        ],
    );
    let mut best = ("", f64::INFINITY);
    let mut sample_total = 0.0;
    for (label, cfg) in &configs {
        // producer feeds conv8 under the same configuration (the paper's
        // setup: only the layer's own dimension assignment varies)
        let tc = cm.t_c(conv8, cfg) * 1e3;
        let tx = cm.t_x(conv7, conv8, 0, cfg, cfg) * 1e3;
        let ts_c = cm_central.t_s(conv8, cfg) * 1e3;
        let ts_s = cm.t_s(conv8, cfg) * 1e3;
        let total = tc + tx + ts_c;
        table.row(vec![
            label.to_string(),
            format!("{tc:.2}"),
            format!("{tx:.2}"),
            format!("{ts_c:.2}"),
            format!("{total:.2}"),
            format!("{:.2}", tc + tx + ts_s),
        ]);
        if total < best.1 {
            best = (label, total);
        }
        if label.starts_with("{n=4}") {
            sample_total = total;
        }
    }
    table.print();
    println!(
        "best: {} ({:.2} ms) — {:.2}x faster than sample-dimension parallelism \
         (paper: data parallelism is suboptimal for this layer)\n",
        best.0,
        best.1,
        sample_total / best.1
    );

    // micro-bench: this evaluation sits on the optimizer's hot path.
    bench("cost_model_tc_tx_ts(conv8)", || {
        let cfg = PConfig::new(1, 1, 2, 2);
        cm.t_c(conv8, &cfg) + cm.t_x(conv7, conv8, 0, &cfg, &cfg) + cm.t_s(conv8, &cfg)
    });
}
