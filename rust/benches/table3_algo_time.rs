//! Table 3: execution time for finding the optimal parallelization
//! strategy — elimination DP (Algorithm 1) vs depth-first baseline.
//!
//! Paper (4 GPUs): LeNet-5 5.6s/0.01s, AlexNet 2.1h/0.02s, VGG-16 and
//! Inception-v3 >24h/0.1s and /0.4s; K = 2 everywhere. The DFS baseline
//! here gets a 10-second budget; networks that exceed it are reported as
//! `> 10 s (timeout)` — the paper's `> 24 hours` analogue.

use std::time::Duration;

use optcnn::cost::{CostModel, CostTables};
use optcnn::device::DeviceGraph;
use optcnn::graph::nets;
use optcnn::optimizer::{self, dfs};
use optcnn::util::benchkit::time_once;
use optcnn::util::table::Table;

const DFS_BUDGET: Duration = Duration::from_secs(10);

fn main() {
    let ndev = 4;
    let mut table = Table::new(
        "Table 3: strategy-search time, 4 GPUs",
        &["network", "#layers", "DFS baseline", "Algorithm 1", "K", "same optimum"],
    );
    for net in ["lenet5", "alexnet", "vgg16", "inception_v3"] {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let tables = CostTables::build(&cm, ndev).unwrap();

        let (opt, t_dp) = time_once(|| optimizer::optimize(&tables));
        let (brute, t_dfs) = time_once(|| dfs::dfs_optimal(&tables, Some(DFS_BUDGET)));

        let dfs_cell = if brute.complete {
            format!("{:.2} s", t_dfs)
        } else {
            format!("> {:.0} s (timeout)", t_dfs)
        };
        let same = if brute.complete {
            if (brute.cost - opt.cost).abs() <= 1e-9 * opt.cost { "yes" } else { "NO" }
        } else {
            "n/a"
        };
        table.row(vec![
            net.to_string(),
            g.num_layers().to_string(),
            dfs_cell,
            format!("{:.4} s", t_dp),
            opt.stats.final_nodes.to_string(),
            same.to_string(),
        ]);
    }
    table.print();
    println!("complexity: DFS O(E*C^N) vs Algorithm 1 O(E*C^3 + K*C^K)\n");
}
