//! Planner session amortization: cold-build versus warm-session query
//! latency for the acceptance workload (vgg16, 4 devices, layerwise).
//!
//! Complements PR 1's `plan_reuse` bench: that one measures plan-level
//! caching in isolation; this one measures the full public-API path —
//! a fresh `Planner` per query (cost tables + search + plan + sim) versus
//! one long-lived session absorbing repeated queries, which is the
//! serving scenario the session API exists for.

use optcnn::planner::{Network, Planner, StrategyKind};
use optcnn::util::benchkit::{bench, bench_json, time_once};

fn main() {
    let net = Network::Vgg16;
    let ndev = 4usize;
    println!("== planner session: {net} x{ndev}, layerwise ==");

    // cold path: everything from scratch, once (too slow to loop)
    let (_cold_eval, cold) = time_once(|| {
        let mut p = Planner::builder(net).devices(ndev).build().unwrap();
        p.evaluate(StrategyKind::Layerwise).unwrap()
    });
    println!(
        "cold_build_and_query(vgg16, 4 dev)           {:>12.3} ms  (tables + search + plan + sim)",
        cold * 1e3
    );

    // warm path: one session, repeated queries
    let mut session = Planner::builder(net).devices(ndev).build().unwrap();
    session.evaluate(StrategyKind::Layerwise).unwrap(); // prime the session
    let warm = bench("warm_session_query(vgg16, 4 dev)", || {
        session.evaluate(StrategyKind::Layerwise).unwrap()
    });

    // strategy-only lookup (plan + tables + search all cached)
    let strat = bench("warm_strategy_lookup(vgg16, 4 dev)", || {
        session.strategy(StrategyKind::Layerwise).unwrap()
    });

    let stats = session.session_stats();
    println!(
        "session counters: {} table build(s), {} search(es), {} plan hits / {} misses",
        stats.table_builds, stats.searches, stats.plan_hits, stats.plan_misses
    );
    assert_eq!(stats.table_builds, 1, "a session must build tables exactly once");
    assert_eq!(stats.searches, 1, "a session must search exactly once");
    println!(
        "-> warm query is {:.0}x cheaper than cold build-and-query \
         (strategy lookup alone: {:.0}x)\n",
        cold / warm.median.max(1e-12),
        cold / strat.median.max(1e-12)
    );
    if let Ok(path) = std::env::var("OPTCNN_BENCH_JSON") {
        let doc = bench_json(
            "planner_session",
            &[
                ("cold_build_and_query".to_string(), cold),
                ("warm_session_query".to_string(), warm.median),
                ("warm_strategy_lookup".to_string(), strat.median),
            ],
        )
        .expect("planner_session measured nothing");
        std::fs::write(&path, doc.to_string()).expect("writing bench JSON");
        println!("wrote machine-readable results to {path}");
    }
}
