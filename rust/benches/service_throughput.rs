//! Plan-serving throughput and latency: the in-process `PlanService`
//! hammer (cold/warm, single/multi-threaded), plus a real TCP load test
//! against `optcnn serve`'s bounded worker pool — hundreds of concurrent
//! connections, client-measured p50/p99 request latency, the
//! store-backed warm-restart path (asserted to build zero tables), and
//! a deterministic overload-shedding scenario.
//!
//! Run: `cargo bench --bench service_throughput`
//! `OPTCNN_BENCH_JSON=<path>` additionally writes the measurements as a
//! machine-readable document (the CI `bench-serve` artifact).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use optcnn::planner::{serve, Network, PlanRequest, PlanService, StrategyKind};
use optcnn::util::benchkit::{bench_json, time_once};
use optcnn::util::table::Table;

/// The working set: {lenet5, alexnet} x {2, 4} devices x all 4
/// strategies = 16 grid points, 4 distinct (network, cluster) states.
fn grid() -> Vec<PlanRequest> {
    let mut reqs = Vec::new();
    for net in [Network::LeNet5, Network::AlexNet] {
        for ndev in [2usize, 4] {
            for kind in StrategyKind::ALL {
                reqs.push(PlanRequest::new(net, ndev).expect("preset shape").strategy(kind));
            }
        }
    }
    reqs
}

/// The same grid as newline-delimited wire requests.
fn wire_grid() -> Vec<String> {
    let mut lines = Vec::new();
    for net in [Network::LeNet5, Network::AlexNet] {
        for ndev in [2usize, 4] {
            for kind in StrategyKind::ALL {
                lines.push(format!(
                    r#"{{"net":"{net}","devices":{ndev},"strategy":"{kind}","want":"evaluate"}}"#
                ));
            }
        }
    }
    lines
}

/// Answer `total` requests round-robin over `reqs` from `threads`
/// workers pulling an atomic cursor; returns wall-clock seconds.
fn hammer(service: &PlanService, reqs: &[PlanRequest], total: usize, threads: usize) -> f64 {
    let cursor = AtomicUsize::new(0);
    let (_, dt) = time_once(|| {
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    service.evaluate(&reqs[i % reqs.len()]).expect("bench request failed");
                });
            }
        });
    });
    dt
}

/// Drive `clients` concurrent connections against the server, each
/// sending `per_client` grid requests on one connection and measuring
/// the write-to-reply wall latency of every request. Returns the sorted
/// per-request latencies in seconds.
fn load(addr: SocketAddr, lines: &[String], clients: usize, per_client: usize) -> Vec<f64> {
    let mut latencies: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || {
                    let stream = TcpStream::connect(addr).expect("connect");
                    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
                    let mut writer = stream;
                    let mut out = Vec::with_capacity(per_client);
                    for r in 0..per_client {
                        let line = &lines[(c + r) % lines.len()];
                        let t0 = Instant::now();
                        writer.write_all(line.as_bytes()).expect("write");
                        writer.write_all(b"\n").expect("write");
                        writer.flush().expect("flush");
                        let mut reply = String::new();
                        reader.read_line(&mut reply).expect("read");
                        out.push(t0.elapsed().as_secs_f64());
                        assert!(
                            reply.contains(r#""ok":true"#),
                            "load-test request failed: {reply}"
                        );
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    latencies
}

/// The `q`-quantile (nearest-rank) of an ascending non-empty slice.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Generous pool bounds for the latency scenarios: the point there is
/// queueing behavior under a bounded worker count, not shedding.
fn roomy() -> serve::ServeOptions {
    serve::ServeOptions { queue_cap: 512, max_conns: 4096, ..Default::default() }
}

fn main() {
    let reqs = grid();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut json: Vec<(String, f64)> = Vec::new();
    let mut table = Table::new(
        "plan-service throughput ({lenet5, alexnet} x {2, 4} devices x 4 strategies)",
        &["scenario", "requests", "seconds", "req/s"],
    );
    let mut row = |table: &mut Table, name: String, n: usize, dt: f64| {
        table.row(vec![name, n.to_string(), format!("{dt:.3}"), format!("{:.0}", n as f64 / dt)]);
    };

    // cold, single-threaded: every request is a first touch
    let service = Arc::new(PlanService::new());
    let cold1 = hammer(&service, &reqs, reqs.len(), 1);
    row(&mut table, "cold, 1 thread".into(), reqs.len(), cold1);

    // warm: the same grid over and over, everything served from caches
    let rounds = 50;
    let total = reqs.len() * rounds;
    let warm1 = hammer(&service, &reqs, total, 1);
    row(&mut table, "warm, 1 thread".into(), total, warm1);
    let warm_n = hammer(&service, &reqs, total, threads);
    row(&mut table, format!("warm, {threads} threads"), total, warm_n);

    // cold, multi-threaded: N workers racing on fresh state exercises
    // the single-flight memo (duplicate misses block on one build)
    let fresh = Arc::new(PlanService::new());
    let cold_n = hammer(&fresh, &reqs, reqs.len(), threads);
    row(&mut table, format!("cold, {threads} threads"), reqs.len(), cold_n);

    table.print();
    let s = fresh.stats();
    println!(
        "cold x{threads} shared-state reuse: {} table builds, {} searches, \
         {} build-waits, {} plans cached, {}/{} plan hits/misses",
        s.table_builds, s.searches, s.build_waits, s.plans_cached, s.plan_hits, s.plan_misses
    );
    assert_eq!(s.table_builds, 4, "one build per distinct (network, cluster) state");
    assert_eq!(s.plan_hits + s.plan_misses, reqs.len() as u64);
    json.push(("inprocess/cold_1t_s".into(), cold1));
    json.push(("inprocess/warm_1t_s".into(), warm1));
    json.push((format!("inprocess/warm_{threads}t_s"), warm_n));

    // == TCP load test against the bounded worker pool ==
    let lines = wire_grid();
    let clients = 200;
    let per_client = 4;
    println!("\n== serve: {clients} concurrent connections x {per_client} requests ==");
    let mut serve_table =
        Table::new("optcnn serve latency (client-measured)", &["scenario", "p50", "p99", "max"]);

    // cold server: the first touches pay table builds inside requests
    let svc = Arc::new(PlanService::new());
    let handle = serve::spawn_opts("127.0.0.1:0", Arc::clone(&svc), roomy()).expect("spawn");
    let cold = load(handle.local_addr(), &lines, clients, per_client);
    // warm server: identical traffic, everything answered from shards
    let warm = load(handle.local_addr(), &lines, clients, per_client);
    handle.shutdown();

    // store-backed warm restart: a *fresh* service over a primed plan
    // store serves the whole grid from disk — zero table builds
    let store_dir =
        std::env::temp_dir().join(format!("optcnn-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let primer = PlanService::builder().plan_store(&store_dir).build().expect("primer");
    for req in &reqs {
        primer.plan(req).expect("prime store");
    }
    drop(primer);
    let restarted =
        Arc::new(PlanService::builder().plan_store(&store_dir).build().expect("restart"));
    let handle =
        serve::spawn_opts("127.0.0.1:0", Arc::clone(&restarted), roomy()).expect("spawn");
    let store_warm = load(handle.local_addr(), &lines, clients, per_client);
    handle.shutdown();
    let s = restarted.stats();
    assert_eq!(
        s.table_builds, 0,
        "a store-backed restart must serve the whole grid without building"
    );
    assert_eq!(s.store_hits, reqs.len() as u64, "every grid point loaded from disk once");
    let _ = std::fs::remove_dir_all(&store_dir);

    for (name, lat) in [("cold", &cold), ("warm", &warm), ("store_warm", &store_warm)] {
        let (p50, p99) = (quantile(lat, 0.50), quantile(lat, 0.99));
        let max = *lat.last().expect("nonempty");
        serve_table.row(vec![
            name.to_string(),
            format!("{:.1}ms", p50 * 1e3),
            format!("{:.1}ms", p99 * 1e3),
            format!("{:.1}ms", max * 1e3),
        ]);
        json.push((format!("serve/{name}/p50_s"), p50));
        json.push((format!("serve/{name}/p99_s"), p99));
    }
    serve_table.print();

    // overload: a single parked worker with a rendezvous queue must shed
    // every extra connection with the typed reply, deterministically
    let svc = Arc::new(PlanService::new());
    let tiny = serve::ServeOptions { workers: 1, queue_cap: 0, ..Default::default() };
    let handle = serve::spawn_opts("127.0.0.1:0", Arc::clone(&svc), tiny).expect("spawn");
    let addr = handle.local_addr();
    let holder = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(holder.try_clone().expect("clone"));
    let mut writer = holder;
    writer.write_all(b"{\"want\": \"stats\"}\n").expect("write");
    writer.flush().expect("flush");
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("read");
    let flood = 64;
    let mut shed_seen = 0usize;
    for _ in 0..flood {
        let mut r = BufReader::new(TcpStream::connect(addr).expect("connect"));
        let mut line = String::new();
        r.read_line(&mut line).expect("read");
        if line.contains(r#""error":"overloaded""#) {
            shed_seen += 1;
        }
    }
    let frac = shed_seen as f64 / flood as f64;
    println!("overload: {shed_seen}/{flood} connections shed with the typed reply");
    assert_eq!(shed_seen, flood, "a saturated rendezvous pool sheds every extra connection");
    assert_eq!(handle.metrics().shed.load(Ordering::Relaxed), flood as u64);
    json.push(("serve/overload/shed_fraction".into(), frac));
    drop(writer);
    drop(reader);
    handle.shutdown();

    if let Ok(path) = std::env::var("OPTCNN_BENCH_JSON") {
        let doc = bench_json("serve", &json).expect("serve bench measured nothing");
        std::fs::write(&path, doc.to_string()).expect("writing bench JSON");
        println!("wrote machine-readable results to {path}");
    }
}
