//! Plan-serving throughput: requests/s through one shared `PlanService`,
//! cold (first touch pays tables + search + plan build) versus warm
//! (cache hits), single- versus multi-threaded.
//!
//! Run: `cargo bench --bench service_throughput`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use optcnn::planner::{Network, PlanRequest, PlanService, StrategyKind};
use optcnn::util::benchkit::time_once;
use optcnn::util::table::Table;

/// The working set: {lenet5, alexnet} x {2, 4} devices x all 4
/// strategies = 16 grid points, 4 distinct (network, cluster) states.
fn grid() -> Vec<PlanRequest> {
    let mut reqs = Vec::new();
    for net in [Network::LeNet5, Network::AlexNet] {
        for ndev in [2usize, 4] {
            for kind in StrategyKind::ALL {
                reqs.push(PlanRequest::new(net, ndev).expect("preset shape").strategy(kind));
            }
        }
    }
    reqs
}

/// Answer `total` requests round-robin over `reqs` from `threads`
/// workers pulling an atomic cursor; returns wall-clock seconds.
fn hammer(service: &PlanService, reqs: &[PlanRequest], total: usize, threads: usize) -> f64 {
    let cursor = AtomicUsize::new(0);
    let (_, dt) = time_once(|| {
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    service.evaluate(&reqs[i % reqs.len()]).expect("bench request failed");
                });
            }
        });
    });
    dt
}

fn main() {
    let reqs = grid();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut table = Table::new(
        "plan-service throughput ({lenet5, alexnet} x {2, 4} devices x 4 strategies)",
        &["scenario", "requests", "seconds", "req/s"],
    );
    let mut row = |name: String, n: usize, dt: f64| {
        table.row(vec![name, n.to_string(), format!("{dt:.3}"), format!("{:.0}", n as f64 / dt)]);
    };

    // cold, single-threaded: every request is a first touch
    let service = Arc::new(PlanService::new());
    let cold1 = hammer(&service, &reqs, reqs.len(), 1);
    row("cold, 1 thread".into(), reqs.len(), cold1);

    // warm: the same grid over and over, everything served from caches
    let rounds = 50;
    let total = reqs.len() * rounds;
    let warm1 = hammer(&service, &reqs, total, 1);
    row("warm, 1 thread".into(), total, warm1);
    let warm_n = hammer(&service, &reqs, total, threads);
    row(format!("warm, {threads} threads"), total, warm_n);

    // cold, multi-threaded: N workers racing on fresh state exercises
    // the single-flight memo (duplicate misses block on one build)
    let fresh = Arc::new(PlanService::new());
    let cold_n = hammer(&fresh, &reqs, reqs.len(), threads);
    row(format!("cold, {threads} threads"), reqs.len(), cold_n);

    table.print();
    let s = fresh.stats();
    println!(
        "cold x{threads} shared-state reuse: {} table builds, {} searches, \
         {} build-waits, {} plans cached, {}/{} plan hits/misses",
        s.table_builds, s.searches, s.build_waits, s.plans_cached, s.plan_hits, s.plan_misses
    );
    assert_eq!(s.table_builds, 4, "one build per distinct (network, cluster) state");
    assert_eq!(s.plan_hits + s.plan_misses, reqs.len() as u64);
}
