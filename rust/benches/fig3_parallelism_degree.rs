//! Figure 3: computation and communication time vs degree of parallelism
//! for two Inception-v3 layers — an early convolution (3rd layer) and the
//! final fully-connected layer — on the 16-GPU cluster (global batch 512),
//! varying how many devices the layer actually uses.
//!
//! Expected shape: the conv layer performs best at the full 16 GPUs; the
//! FC layer's synchronization cost makes a small degree (~4) optimal —
//! the paper's motivation for searching the *degree* dimension.

use optcnn::cost::CostModel;
use optcnn::device::DeviceGraph;
use optcnn::graph::nets;
use optcnn::parallel::PConfig;
use optcnn::util::table::Table;

fn main() {
    let g = nets::inception_v3(32 * 16).unwrap();
    let d = DeviceGraph::p100_cluster(16).unwrap();
    let cm = CostModel::new(&g, &d);
    // 3rd layer = stem_conv3; last parameterized layer = fc
    let conv = g.layers.iter().find(|l| l.name == "stem_conv3").unwrap();
    let fc = g.layers.iter().find(|l| l.name == "fc").unwrap();

    let mut conv_best = (0usize, f64::INFINITY);
    let mut fc_best = (0usize, f64::INFINITY);
    let mut table = Table::new(
        "Figure 3: Inception-v3 on 16 GPUs — time vs degree of parallelism (ms)",
        &["degree", "conv comp", "conv comm", "conv total", "fc comp", "fc comm", "fc total"],
    );
    for degree in [1usize, 2, 4, 8, 16] {
        let cfg = PConfig::data(degree);
        let rows: Vec<f64> = [conv, fc]
            .iter()
            .flat_map(|l| {
                let comp = cm.t_c(l, &cfg) * 1e3;
                let comm = cm.t_s(l, &cfg) * 1e3;
                vec![comp, comm, comp + comm]
            })
            .collect();
        if rows[2] < conv_best.1 {
            conv_best = (degree, rows[2]);
        }
        if rows[5] < fc_best.1 {
            fc_best = (degree, rows[5]);
        }
        table.row(
            std::iter::once(degree.to_string())
                .chain(rows.iter().map(|v| format!("{v:.3}")))
                .collect(),
        );
    }
    table.print();
    println!(
        "conv layer optimal at degree {}, fc layer optimal at degree {} \
         (paper: 16 and 4)\n",
        conv_best.0, fc_best.0
    );
}
