//! Figure 7: training throughput (images/s) for AlexNet, VGG-16 and
//! Inception-v3 across 1-16 GPUs under data, model, OWT and layer-wise
//! parallelism, plus the linear-scaling ideal.
//!
//! Paper headline: layer-wise parallelism beats the best baseline by up
//! to 2.2x (AlexNet), 1.5x (VGG-16) and 1.4x (Inception-v3), and scales
//! to 12.2x / 14.8x / 15.5x at 16 GPUs (vs at most 6.1x / 10.2x / 11.2x
//! for the baselines).

use optcnn::planner::{Network, Planner, StrategyKind};
use optcnn::util::table::Table;

fn main() {
    for net in [Network::AlexNet, Network::Vgg16, Network::InceptionV3] {
        let mut table = Table::new(
            &format!("Figure 7: {net} training throughput (images/s)"),
            &["GPUs (nodes)", "data", "model", "owt", "layerwise", "ideal"],
        );
        let base = Planner::builder(net)
            .devices(1)
            .build()
            .unwrap()
            .evaluate(StrategyKind::Data)
            .unwrap()
            .throughput;
        let mut speedup_best_baseline: f64 = 0.0;
        let mut speedup_layerwise: f64 = 0.0;
        let mut max_gain: f64 = 0.0;
        for ndev in [1usize, 2, 4, 8, 16] {
            let mut p = Planner::builder(net).devices(ndev).build().unwrap();
            let mut row = vec![format!("{} ({})", ndev, ndev.div_ceil(4).max(1))];
            let mut tps = Vec::new();
            for kind in StrategyKind::ALL {
                let tp = p.evaluate(kind).unwrap().throughput;
                tps.push(tp);
                row.push(format!("{tp:.0}"));
            }
            row.push(format!("{:.0}", base * ndev as f64));
            table.row(row);
            let best_baseline = tps[..3].iter().cloned().fold(0.0, f64::max);
            max_gain = max_gain.max(tps[3] / best_baseline);
            if ndev == 16 {
                speedup_best_baseline = best_baseline / base;
                speedup_layerwise = tps[3] / base;
            }
        }
        table.print();
        println!(
            "{net}: layer-wise up to {:.2}x over best baseline; 16-GPU speedup \
             {:.1}x vs {:.1}x (best baseline)\n",
            max_gain, speedup_layerwise, speedup_best_baseline
        );
    }
}
