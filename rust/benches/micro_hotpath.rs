//! Micro-benchmarks for the library's hot paths (the §Perf working set):
//! cost-table construction, the elimination DP, the simulator, and the
//! tensor repartitioning primitives used by the executor.

use optcnn::cost::{BuildOptions, CostModel, CostTables, TableMemo};
use optcnn::device::DeviceGraph;
use optcnn::graph::nets;
use optcnn::optimizer;
use optcnn::parallel::{output_tiles, PConfig};
use optcnn::sim::simulate;
use optcnn::tensor::{Region, Tensor};
use optcnn::util::benchkit::{bench, bench_json, time_once};

const BUILTINS: [&str; 7] =
    ["lenet5", "alexnet", "vgg16", "inception_v3", "resnet18", "resnet50", "minicnn"];

fn main() {
    println!("== micro: cost tables ==");
    for (net, ndev) in [("vgg16", 4usize), ("inception_v3", 4), ("inception_v3", 16)] {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let (r, dt) = time_once(|| CostTables::build(&cm, ndev));
        r.unwrap();
        println!("cost_tables_build({net}, {ndev} dev)          {dt:>10.3}s");
    }

    // Cold-plan acceptance bench: serial vs parallel vs warm-memo table
    // construction for every builtin. `OPTCNN_BENCH_JSON=<path>` writes
    // the measurements as a machine-readable document; CI uploads it as
    // the `bench-cold-plan` artifact on every run.
    println!("\n== micro: cold plan build (serial / parallel / warm-memo) ==");
    let mut cold_plan: Vec<(String, f64)> = Vec::new();
    for net in BUILTINS {
        let ndev = 4usize;
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let ser = BuildOptions { threads: 1, memo: None };
        let (r, t_ser) = time_once(|| CostTables::build_opts(&cm, ndev, None, &ser));
        r.unwrap();
        let memo = TableMemo::new();
        let par = BuildOptions { threads: 0, memo: Some(&memo) };
        let (r, t_par) = time_once(|| CostTables::build_opts(&cm, ndev, None, &par));
        r.unwrap();
        let (r, t_warm) = time_once(|| CostTables::build_opts(&cm, ndev, None, &par));
        r.unwrap();
        println!(
            "cold_plan({net:<12} {ndev} dev)  serial {:>9.1}ms  parallel {:>9.1}ms  \
             warm {:>9.1}ms  ({:.1}x / {:.0}x)",
            t_ser * 1e3,
            t_par * 1e3,
            t_warm * 1e3,
            t_ser / t_par.max(1e-12),
            t_ser / t_warm.max(1e-12),
        );
        cold_plan.push((format!("{net}/serial"), t_ser));
        cold_plan.push((format!("{net}/parallel"), t_par));
        cold_plan.push((format!("{net}/warm_memo"), t_warm));
    }
    if let Ok(path) = std::env::var("OPTCNN_BENCH_JSON") {
        let doc = bench_json("cold_plan", &cold_plan).expect("cold_plan measured nothing");
        std::fs::write(&path, doc.to_string()).expect("writing bench JSON");
        println!("wrote machine-readable results to {path}");
    }

    println!("\n== micro: elimination DP ==");
    for (net, ndev) in [("vgg16", 16usize), ("inception_v3", 16)] {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let tables = CostTables::build(&cm, ndev).unwrap();
        bench(&format!("optimize({net}, {ndev} dev)"), || optimizer::optimize(&tables));
    }

    // Dominance-pruned search: the elimination DP over the full tables vs
    // the tables with certified-dominated configurations removed
    // (`--prune-dominated`; DESIGN.md §12). The optimum is byte-identical
    // — asserted here — so the delta is pure search time. With
    // `OPTCNN_BENCH_JSON` set, the measurements are also written as
    // `BENCH_prune.json` next to the cold-plan document, and CI uploads
    // both through the same `BENCH_*.json` artifact glob.
    println!("\n== micro: dominance-pruned search ==");
    let mut pruned_search: Vec<(String, f64)> = Vec::new();
    for (net, ndev) in [("alexnet", 4usize), ("vgg16", 4), ("inception_v3", 4)] {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let tables = CostTables::build(&cm, ndev).unwrap();
        let (pruned, removed) = optcnn::audit::prune_tables(&cm, &tables);
        let full = bench(&format!("optimize_full({net}, {ndev} dev)"), || {
            optimizer::optimize(&tables)
        });
        let slim = bench(&format!("optimize_pruned({net}, {ndev} dev)"), || {
            optimizer::optimize(&pruned)
        });
        assert_eq!(
            optimizer::optimize(&tables).cost.to_bits(),
            optimizer::optimize(&pruned).cost.to_bits(),
            "pruning must not change the optimum"
        );
        println!("  {net}: {removed} dominated configuration(s) removed");
        pruned_search.push((format!("{net}/full"), full.median));
        pruned_search.push((format!("{net}/pruned"), slim.median));
    }
    if let Ok(path) = std::env::var("OPTCNN_BENCH_JSON") {
        let doc =
            bench_json("pruned_search", &pruned_search).expect("pruned_search measured nothing");
        let prune_path = std::path::Path::new(&path).with_file_name("BENCH_prune.json");
        std::fs::write(&prune_path, doc.to_string()).expect("writing bench JSON");
        println!("wrote machine-readable results to {}", prune_path.display());
    }

    println!("\n== micro: simulator ==");
    for net in ["vgg16", "inception_v3"] {
        let ndev = 16;
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = optcnn::optimizer::strategies::data_parallel(&g, ndev);
        let r = simulate(&g, &d, &s, &cm);
        bench(
            &format!("simulate({net}, 16 dev, {} tasks)", r.num_tasks),
            || simulate(&g, &d, &s, &cm),
        );
    }

    println!("\n== micro: tensor repartitioning ==");
    let t = Tensor::zeros(&[32, 64, 56, 56]);
    let tiles = output_tiles(t.shape(), &PConfig::new(2, 1, 2, 1));
    bench("slice_4tiles(32x64x56x56)", || {
        tiles.iter().map(|r| t.slice(r).len()).sum::<usize>()
    });
    let mut acc = Tensor::zeros(&[32, 64, 58, 58]);
    let slab = Tensor::zeros(&[16, 64, 31, 58]);
    let r = Region::new(&[(0, 16), (0, 64), (27, 58), (0, 58)]);
    bench("insert_add_halo_slab", || {
        acc.insert_add(&r, &slab);
        acc.data()[0]
    });

    println!("\n== micro: cost model kernels ==");
    let g = nets::inception_v3(512).unwrap();
    let d = DeviceGraph::p100_cluster(16).unwrap();
    let cm = CostModel::new(&g, &d);
    let concat = g.layers.iter().find(|l| l.name == "mixedB3_concat").unwrap();
    let pred = g.predecessors(concat.id)[0];
    let a = PConfig::data(16);
    let b = PConfig::new(2, 4, 2, 1);
    bench("t_x(concat 17x17x768, 16x16 tiles)", || {
        cm.t_x(g.layer(pred), concat, 0, &a, &b)
    });
}
