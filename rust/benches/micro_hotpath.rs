//! Micro-benchmarks for the library's hot paths (the §Perf working set):
//! cost-table construction, the elimination DP, the simulator, and the
//! tensor repartitioning primitives used by the executor.

use optcnn::cost::{CostModel, CostTables};
use optcnn::device::DeviceGraph;
use optcnn::graph::nets;
use optcnn::optimizer;
use optcnn::parallel::{output_tiles, PConfig};
use optcnn::sim::simulate;
use optcnn::tensor::{Region, Tensor};
use optcnn::util::benchkit::{bench, time_once};

fn main() {
    println!("== micro: cost tables ==");
    for (net, ndev) in [("vgg16", 4usize), ("inception_v3", 4), ("inception_v3", 16)] {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let (_, dt) = time_once(|| CostTables::build(&cm, ndev));
        println!("cost_tables_build({net}, {ndev} dev)          {dt:>10.3}s");
    }

    println!("\n== micro: elimination DP ==");
    for (net, ndev) in [("vgg16", 16usize), ("inception_v3", 16)] {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let tables = CostTables::build(&cm, ndev);
        bench(&format!("optimize({net}, {ndev} dev)"), || optimizer::optimize(&tables));
    }

    println!("\n== micro: simulator ==");
    for net in ["vgg16", "inception_v3"] {
        let ndev = 16;
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = optcnn::optimizer::strategies::data_parallel(&g, ndev);
        let r = simulate(&g, &d, &s, &cm);
        bench(
            &format!("simulate({net}, 16 dev, {} tasks)", r.num_tasks),
            || simulate(&g, &d, &s, &cm),
        );
    }

    println!("\n== micro: tensor repartitioning ==");
    let t = Tensor::zeros(&[32, 64, 56, 56]);
    let tiles = output_tiles(t.shape(), &PConfig::new(2, 1, 2, 1));
    bench("slice_4tiles(32x64x56x56)", || {
        tiles.iter().map(|r| t.slice(r).len()).sum::<usize>()
    });
    let mut acc = Tensor::zeros(&[32, 64, 58, 58]);
    let slab = Tensor::zeros(&[16, 64, 31, 58]);
    let r = Region::new(&[(0, 16), (0, 64), (27, 58), (0, 58)]);
    bench("insert_add_halo_slab", || {
        acc.insert_add(&r, &slab);
        acc.data()[0]
    });

    println!("\n== micro: cost model kernels ==");
    let g = nets::inception_v3(512).unwrap();
    let d = DeviceGraph::p100_cluster(16).unwrap();
    let cm = CostModel::new(&g, &d);
    let concat = g.layers.iter().find(|l| l.name == "mixedB3_concat").unwrap();
    let pred = g.predecessors(concat.id)[0];
    let a = PConfig::data(16);
    let b = PConfig::new(2, 4, 2, 1);
    bench("t_x(concat 17x17x768, 16x16 tiles)", || {
        cm.t_x(g.layer(pred), concat, 0, &a, &b)
    });
}
