//! Plan materialization and reuse (§Perf log #5): repeated simulation of
//! a cached `ExecutionPlan` versus the seed's recompute-per-call path,
//! plus the cost of plan construction and cache lookups themselves.
//!
//! This is the serving scenario the plan IR exists for — a planner
//! answering many simulate/evaluate queries over a small working set of
//! (network, strategy, cluster) triples.

use optcnn::cost::CostModel;
use optcnn::device::DeviceGraph;
use optcnn::graph::nets;
use optcnn::optimizer::strategies;
use optcnn::plan::{ExecutionPlan, PlanCache};
use optcnn::sim::{simulate, simulate_plan};
use optcnn::util::benchkit::bench;

fn main() {
    for (net, ndev) in [("vgg16", 4usize), ("inception_v3", 4), ("inception_v3", 16)] {
        println!("== plan reuse: {net} x{ndev} ==");
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        let s = strategies::data_parallel(&g, ndev);

        let build = bench(&format!("plan_build({net}, {ndev} dev)"), || {
            ExecutionPlan::build(&cm, &s)
        });
        let plan = ExecutionPlan::build(&cm, &s);
        let recompute = bench(&format!("simulate_recompute({net}, {ndev} dev)"), || {
            simulate(&g, &d, &s, &cm)
        });
        let cached = bench(&format!("simulate_cached_plan({net}, {ndev} dev)"), || {
            simulate_plan(&plan, &cm)
        });
        let mut cache = PlanCache::default();
        cache.get_or_build(&cm, &s);
        bench(&format!("plan_cache_hit({net}, {ndev} dev)"), || {
            cache.get_or_build(&cm, &s)
        });
        println!(
            "  -> cached-plan simulate is {:.2}x the recompute path \
             (plan build amortized over {:.1} queries)\n",
            recompute.median / cached.median.max(1e-12),
            build.median / (recompute.median - cached.median).abs().max(1e-12)
        );
    }
}
