//! Table 4: relative difference between the cost-model estimate
//! `t_O(G, D, S)` (Eq. 1) and the "actual" per-step execution time — here
//! the discrete-event cluster simulation — for the layer-wise-optimal
//! strategy on each network/cluster.
//!
//! Paper: within +-10% everywhere (their "actual" is the real cluster).

use optcnn::planner::{Network, Planner, StrategyKind};
use optcnn::util::table::Table;

fn main() {
    let mut table = Table::new(
        "Table 4: (estimate - simulated) / simulated, layer-wise strategy",
        &["devices", "alexnet", "vgg16", "inception_v3"],
    );
    let mut worst: f64 = 0.0;
    for ndev in [1usize, 2, 4, 8, 16] {
        let mut row = vec![format!(
            "{} GPU ({} node{})",
            ndev,
            ndev.div_ceil(4).max(1),
            if ndev > 4 { "s" } else { "" }
        )];
        for net in [Network::AlexNet, Network::Vgg16, Network::InceptionV3] {
            let mut p = Planner::builder(net).devices(ndev).build().unwrap();
            let eval = p.evaluate(StrategyKind::Layerwise).unwrap();
            let rel = (eval.estimate - eval.sim.step_time) / eval.sim.step_time;
            worst = worst.max(rel.abs());
            row.push(format!("{:+.0}%", rel * 100.0));
        }
        table.row(row);
    }
    table.print();
    println!("worst |relative difference|: {:.1}% (paper: <= 10%)\n", worst * 100.0);
}
